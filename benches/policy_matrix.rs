//! Policy-lab matrix bench: every dispatch × scaling policy combination
//! across the sweepable autoscaler cadence, ranked on the latency
//! histogram (`workload::diff::run_policy_matrix`).  Reports wall time
//! for the full matrix plus the per-combo virtual-time metrics the
//! rankings are built from; the committed snapshot pins only the
//! deterministic (virtual-time) numbers, never wall clock.
//!
//! Self-contained: generates its own catalog and synthetic-stub forest,
//! so it runs on a fresh checkout without `make artifacts`.
//!
//! ```bash
//! cargo bench --bench policy_matrix
//! # JIAGU_BENCH_SNAPSHOT=BENCH_policy_matrix.json writes the
//! # machine-normalized snapshot (deterministic metrics only).
//! ```

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::traces::{PoissonParams, Workload};
use jiagu::util::bench::Table;
use jiagu::util::json::{arr, num, obj, s, Json};
use jiagu::workload::diff;
use std::sync::Arc;
use std::time::Instant;

const N_FUNCTIONS: usize = 8;
const N_NODES: usize = 6;
const DURATION_S: usize = 5;
const SEED: u64 = 4242;
/// Deterministic runs: wall time is the only noise, so two repeats with
/// a min-take suffice — and the repeat doubles as a determinism guard.
const REPEATS: usize = 2;

fn main() {
    let cat = Catalog::from_functions(make_catalog(N_FUNCTIONS, 0x90110c));
    let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
        ForestParams::synthetic_stub(jiagu::model::N_FEATURES, 0.05, 0.05),
    ));
    let wl = Workload::poisson(
        &cat,
        &PoissonParams { duration_s: DURATION_S, ..Default::default() },
        SEED,
    );
    let mut cfg = RunConfig::jiagu_45();
    cfg.n_nodes = N_NODES;
    cfg.duration_s = DURATION_S;
    cfg.requests = true;
    cfg.seed = SEED;
    // shorten both release triggers so scaling policies differ inside the
    // bench horizon (the 45/60 s defaults never fire in a 5 s run)
    cfg.autoscaler.release_duration_s = 3.0;
    cfg.autoscaler.keepalive_duration_s = 6.0;

    let mut best_s = f64::INFINITY;
    let mut kept = None;
    for _ in 0..REPEATS {
        let t0 = Instant::now();
        let m = diff::run_policy_matrix(&cat, &cfg, &predictor, &wl, false)
            .expect("policy matrix");
        best_s = best_s.min(t0.elapsed().as_secs_f64());
        if let Some(prev) = &kept {
            // the determinism guard: repeats may only move wall time
            assert_eq!(
                diff::matrix_json(prev).to_string(),
                diff::matrix_json(&m).to_string(),
                "policy matrix must be byte-stable across repeats"
            );
        }
        kept = Some(m);
    }
    let m = kept.expect("at least one repeat");
    assert!(m.violations.is_empty(), "invariant violations: {:?}", m.violations);

    let mut table =
        Table::new(&["combo", "p99 ms", "qos viol", "density", "served"]);
    let mut snapshot_rows = Vec::new();
    for o in &m.outcomes {
        let qos_violations: u64 = o.report.request_qos_violations.iter().sum();
        table.row(&[
            o.scheduler.clone(),
            format!("{:.3}", o.report.request_p99_ms),
            format!("{qos_violations}"),
            format!("{:.3}", o.report.density),
            format!("{}", o.report.requests_served),
        ]);
        snapshot_rows.push(obj(vec![
            ("combo", s(&o.scheduler)),
            ("density", num(o.report.density)),
            ("p99_ms", num(o.report.request_p99_ms)),
            ("qos_violations", num(qos_violations as f64)),
            ("requests_served", num(o.report.requests_served as f64)),
        ]));
    }
    table.print(&format!(
        "policy matrix ({} combos, {DURATION_S}s horizon, wall {:.1} ms)",
        m.outcomes.len(),
        best_s * 1e3
    ));
    for (metric, order) in &m.rankings {
        println!("  best {metric}: {}", order.first().map(String::as_str).unwrap_or("-"));
    }
    println!("(matrix byte-identical across repeats — asserted)");

    if let Ok(out) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !out.is_empty() {
            let payload = obj(vec![
                ("bench", s("policy_matrix")),
                ("bootstrap", Json::Bool(false)),
                ("combos", arr(snapshot_rows)),
                ("duration_s", num(DURATION_S as f64)),
            ]);
            std::fs::write(&out, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {out}");
        }
    }
}

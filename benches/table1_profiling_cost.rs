//! Table 1 — profiling-cost scaling and scheduling speed of each design.
//!
//! The paper's complexity claims: Jiagu/Gsight O(n) solo-run profiling;
//! Owl O(n²k) pairwise; Pythia O(n²) per-function models; Whare-map
//! O(n^k) full-colocation history.  We count *actual* profiling samples
//! our Owl port takes (memoized pair table) next to the analytic counts,
//! and measure each scheduler's per-decision latency ("fast scheduling"
//! = ~1 ms or less; Gsight pays model inference on the critical path).

mod common;

use common::{Bench, Table};
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::scheduler::{OwlScheduler, Scheduler};
use jiagu::traces;

fn analytic_samples(n: u64, k: u64, scheme: &str) -> String {
    let v: f64 = match scheme {
        "solo" => n as f64,                        // Jiagu / Gsight
        "pair" => (n * n * k) as f64,              // Owl
        "per-fn" => (n * n) as f64,                // Pythia
        "combo" => (n as f64).powi(k as i32),      // Whare-map
        _ => unreachable!(),
    };
    if v >= 1e9 {
        format!("{:.1e}", v)
    } else {
        format!("{v:.0}")
    }
}

fn main() {
    let b = Bench::load();
    let k = 10u64;

    let mut t = Table::new(&[
        "n functions",
        "Jiagu O(n)",
        "Gsight O(n)",
        "Owl O(n^2 k)",
        "Pythia O(n^2)",
        "Whare-map O(n^k)",
    ]);
    for n in [6u64, 15, 30, 60] {
        t.row(&[
            n.to_string(),
            analytic_samples(n, k, "solo"),
            analytic_samples(n, k, "solo"),
            analytic_samples(n, k, "pair"),
            analytic_samples(n, k, "per-fn"),
            analytic_samples(n, k, "combo"),
        ]);
    }
    t.print("Table 1 (profiling cost scaling, k = 10 colocated instances): profiling runs needed");

    // measured: Owl's actual memoized profiling queries over a full run
    let dur = common::duration().min(900);
    let trace = traces::paper_traces(&b.cat, dur).swap_remove(0);
    {
        let mut cluster = jiagu::cluster::Cluster::new(4);
        let mut owl = OwlScheduler::new(7);
        for f in 0..b.cat.len() {
            let plan = owl.schedule(&b.cat, &cluster, f, 4, 0.0).unwrap();
            let _ = plan.commit(&b.cat, &mut cluster, 0.0);
        }
        println!(
            "\nmeasured: Owl profiling samples after touching all {} functions: {} (pair table, memoized)",
            b.cat.len(),
            owl.profiling_samples
        );
        println!(
            "measured: Jiagu profiling = {} solo runs (one per function) + runtime colocation samples",
            b.cat.len()
        );
    }

    // "fast scheduling?" column: per-decision latency of each scheduler
    let mut t2 = Table::new(&["system", "mean decision", "p99 decision", "fast (<~1ms)?"]);
    for (name, cfg) in [
        ("Jiagu", RunConfig::jiagu_45()),
        ("Gsight", RunConfig::with_scheduler(SchedulerKind::Gsight)),
        ("Owl", RunConfig::with_scheduler(SchedulerKind::Owl)),
        ("K8s", RunConfig::with_scheduler(SchedulerKind::Kubernetes)),
    ] {
        let r = b.run(cfg, &trace, dur);
        t2.row(&[
            name.to_string(),
            format!("{:.3}ms", r.scheduling_ms_mean),
            format!("{:.3}ms", r.scheduling_ms_p99),
            if r.scheduling_ms_mean < 1.0 { "yes" } else { "no" }.to_string(),
        ]);
    }
    t2.print("Table 1 (scheduling speed): measured per-decision latency");
}

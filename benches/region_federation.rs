//! Region-federation throughput and crash-replay overhead: wall-clock
//! cost of the two-region federation at 1 / 2 / 4 phase-1 worker
//! threads, clean vs one region crashed at mid-horizon and replayed
//! from seed.  Every clean/crashed pair must merge to the same bytes
//! (asserted here, not just in CI), so the overhead column is the only
//! thing the failure plan is allowed to move.
//!
//! Self-contained: generates its own catalog and uses the synthetic-stub
//! forest, so it runs on a fresh checkout without `make artifacts`.
//!
//! ```bash
//! cargo bench --bench region_federation
//! # JIAGU_BENCH_DURATION=60 scales the virtual horizon (default 20 s);
//! # JIAGU_BENCH_JSON=path.json additionally writes the rows as JSON
//! # (uploaded as a CI workflow artifact);
//! # JIAGU_BENCH_SNAPSHOT=BENCH_region_federation.json writes the
//! # machine-normalized snapshot (deterministic event counts only;
//! # no wall-clock fields).
//! ```

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::controlplane::region::{FederatedControlPlane, FederationStats};
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::sim::RunReport;
use jiagu::traces::{PoissonParams, Workload};
use jiagu::util::bench::Table;
use jiagu::util::json::{arr, num, obj, s, Json};
use std::sync::Arc;
use std::time::Instant;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];
const REGIONS: [usize; 2] = [8, 8];
const N_FUNCTIONS: usize = 8;
/// Deterministic runs: wall time is the only noise, so a few repeats
/// with a min-take are enough.
const REPEATS: usize = 3;

fn main() {
    let duration_s: usize = std::env::var("JIAGU_BENCH_DURATION")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);
    let crash_ms = duration_s as f64 * 1000.0 / 2.0;
    let cat = Catalog::from_functions(make_catalog(N_FUNCTIONS, 0xbe7c));
    let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
        ForestParams::synthetic_stub(jiagu::model::N_FEATURES, 0.05, 0.05),
    ));
    let workload = Workload::poisson(
        &cat,
        &PoissonParams { duration_s, bin_ms: 100.0, mean_concurrency: 3.0 },
        0x51ed,
    );

    let run = |shards: usize, crash: bool| -> (RunReport, FederationStats, f64) {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = REGIONS.iter().sum();
        cfg.duration_s = duration_s;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.seed = 4242;
        cfg.shards = shards;
        cfg.regions = REGIONS.to_vec();
        if crash {
            cfg.failures = vec![(1, crash_ms)];
        }
        let fed = FederatedControlPlane::new(cat.clone(), cfg, predictor.clone())
            .expect("valid federation");
        let mut best_s = f64::INFINITY;
        let mut result = None;
        for _ in 0..REPEATS {
            let t0 = Instant::now();
            let out = fed.run_workload(&workload).expect("federated run");
            best_s = best_s.min(t0.elapsed().as_secs_f64());
            result = Some(out);
        }
        let (report, stats) = result.expect("at least one repeat");
        (report, stats, best_s)
    };

    let mut table =
        Table::new(&["shards", "events", "lost", "clean ms", "crashed ms", "overhead"]);
    let mut rows = Vec::new();
    let mut snapshot_rows = Vec::new();
    let mut reference: Option<RunReport> = None;
    for shards in SHARD_COUNTS {
        let (clean, clean_stats, clean_s) = run(shards, false);
        let (crashed, stats, crashed_s) = run(shards, true);
        assert!(clean.events_processed > 0, "the scenario must process events");
        assert_eq!(
            clean, crashed,
            "{shards} shards: crash-replay must reproduce the uncrashed bytes"
        );
        assert_eq!(clean_stats.crashes, 0);
        assert_eq!(stats.crashes, 1, "{shards} shards: the plan must fire");
        assert!(stats.lost_events > 0, "the doomed run must lose real work");
        if let Some(r) = &reference {
            assert_eq!(*r, clean, "{shards}-thread report must be bit-identical to 1-thread");
        }
        let overhead = crashed_s / clean_s;
        table.row(&[
            format!("{shards}"),
            format!("{}", clean.events_processed),
            format!("{}", stats.lost_events),
            format!("{:.1}", clean_s * 1e3),
            format!("{:.1}", crashed_s * 1e3),
            format!("{overhead:.2}x"),
        ]);
        rows.push(obj(vec![
            ("shards", num(shards as f64)),
            ("regions", num(REGIONS.len() as f64)),
            ("events_processed", num(clean.events_processed as f64)),
            ("lost_events", num(stats.lost_events as f64)),
            ("clean_wall_seconds", num(clean_s)),
            ("crashed_wall_seconds", num(crashed_s)),
            ("recovery_overhead", num(overhead)),
        ]));
        snapshot_rows.push(obj(vec![
            ("events_processed", num(clean.events_processed as f64)),
            ("lost_events", num(stats.lost_events as f64)),
            ("regions", num(REGIONS.len() as f64)),
            ("shards", num(shards as f64)),
        ]));
        if reference.is_none() {
            reference = Some(clean);
        }
    }
    table.print(&format!(
        "region federation ({} regions, crash at {crash_ms:.0} ms, {duration_s}s horizon)",
        REGIONS.len()
    ));
    println!("(clean and crash-replay reports byte-identical at every thread count — asserted)");

    if let Ok(path) = std::env::var("JIAGU_BENCH_JSON") {
        if !path.is_empty() {
            let payload = obj(vec![
                ("bench", s("region_federation")),
                ("duration_s", num(duration_s as f64)),
                ("rows", arr(rows)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_JSON");
            println!("wrote {path}");
        }
    }

    if let Ok(path) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !path.is_empty() {
            let payload = obj(vec![
                ("bench", s("region_federation")),
                ("bootstrap", Json::Bool(false)),
                ("duration_s", num(duration_s as f64)),
                ("rows", arr(snapshot_rows)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {path}");
        }
    }
}

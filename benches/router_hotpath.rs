//! Per-request router hot path: sustained requests/sec through
//! `Router::route` + `Router::complete` — the operations the event core
//! performs once per invocation, so their cost bounds how much traffic a
//! simulated control plane can absorb per wall-clock second.
//!
//! Two shapes bound real usage:
//!
//! * **steady state** — every routed request is eventually completed, so
//!   the in-flight population stays near-constant and picks walk the
//!   full weighted serving set;
//! * **queue churn** — arrivals outpace completions for a stretch, so
//!   FIFO queues grow and drain (the tail-latency regime).
//!
//! ```bash
//! cargo bench --bench router_hotpath
//! # JIAGU_BENCH_SNAPSHOT=BENCH_router_hotpath.json additionally writes
//! # the machine-normalized snapshot (deterministic scenario shapes + the
//! # dimensionless churn/steady throughput ratio; no wall-clock fields).
//! ```

use jiagu::router::{RouteOutcome, Router};
use jiagu::util::bench::{bench, Table};
use jiagu::util::json::{arr, num, obj, s as jstr, Json};
use std::collections::VecDeque;
use std::time::Duration;

const FUNCTIONS: usize = 16;
const INSTANCES_PER_FN: usize = 24;
const NODES: usize = 64;

fn populated_router(seed: u64) -> Router {
    let mut r = Router::with_seed(seed);
    let mut id = 0u64;
    for f in 0..FUNCTIONS {
        for i in 0..INSTANCES_PER_FN {
            r.add(f, id, (f * INSTANCES_PER_FN + i) % NODES);
            id += 1;
        }
    }
    r
}

fn main() {
    let mut table = Table::new(&["scenario", "ns/request", "Mreq/s", "p99 ns/request"]);

    // steady state: route one request, complete one in-service request
    let mut r = populated_router(0x5eed);
    let mut started: VecDeque<u64> = VecDeque::new();
    let mut f = 0usize;
    let mut routed = 0u64;
    let s = bench(1000, Duration::from_millis(300), || {
        match r.route(f, routed as f64) {
            RouteOutcome::Started { instance, .. } => started.push_back(instance),
            RouteOutcome::Queued { .. } => {}
            RouteOutcome::ColdWait => unreachable!("every function has serving instances"),
        }
        routed += 1;
        f = (f + 1) % FUNCTIONS;
        // complete the oldest in-service request; its queue head (if
        // any) immediately re-enters service on the same instance
        if started.len() > FUNCTIONS {
            let id = started.pop_front().expect("non-empty");
            if r.complete(id).is_some() {
                started.push_back(id);
            }
        }
    });
    // one route + (amortised) one complete per iteration
    let per_req = s.mean_ns / 2.0;
    let steady_per_req = per_req;
    table.row(&[
        format!("steady state ({} fns x {} inst)", FUNCTIONS, INSTANCES_PER_FN),
        format!("{per_req:.1}"),
        format!("{:.2}", 1e3 / per_req),
        format!("{:.1}", s.p99_ns / 2.0),
    ]);

    // queue churn: bursts of 64 arrivals, then drain 64 completions
    let mut r = populated_router(0xc4u64);
    let mut busy: VecDeque<u64> = VecDeque::new();
    let mut t = 0u64;
    let s = bench(50, Duration::from_millis(300), || {
        for _ in 0..64 {
            let outcome = r.route(t as usize % FUNCTIONS, t as f64);
            if let RouteOutcome::Started { instance, .. } = outcome {
                busy.push_back(instance);
            }
            t += 1;
        }
        for _ in 0..64 {
            let Some(id) = busy.pop_front() else { break };
            if r.complete(id).is_some() {
                busy.push_back(id);
            }
        }
    });
    // 64 routes + up to 64 completes per iteration
    let per_req = s.mean_ns / 128.0;
    table.row(&[
        "queue churn (64-deep bursts)".to_string(),
        format!("{per_req:.1}"),
        format!("{:.2}", 1e3 / per_req),
        format!("{:.1}", s.p99_ns / 128.0),
    ]);

    table.print("router hot path (seeded weighted pick + FIFO queues)");
    assert!(r.total_in_flight() < u32::MAX); // keep the optimizer honest

    if let Ok(path) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !path.is_empty() {
            let rows = vec![
                obj(vec![
                    ("instances_per_fn", num(INSTANCES_PER_FN as f64)),
                    ("functions", num(FUNCTIONS as f64)),
                    ("ops_per_iteration", num(2.0)),
                    ("scenario", jstr("steady_state")),
                ]),
                obj(vec![
                    ("instances_per_fn", num(INSTANCES_PER_FN as f64)),
                    ("functions", num(FUNCTIONS as f64)),
                    ("ops_per_iteration", num(128.0)),
                    ("scenario", jstr("queue_churn")),
                ]),
            ];
            let payload = obj(vec![
                ("bench", jstr("router_hotpath")),
                ("bootstrap", Json::Bool(false)),
                // dimensionless: >1 means bursty churn routes faster per
                // request than steady state (batched queue operations)
                ("churn_over_steady_throughput", num(steady_per_req / per_req)),
                ("scenarios", arr(rows)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {path}");
        }
    }
}

//! Fig. 16 — prediction error across model classes on the same dataset:
//! Jiagu's RFR vs ESP-style ridge, gradient-boosted trees (XGBoost
//! stand-in), linear regression and MLP-2/3/4.
//!
//! Paper: RFR sits in the best tier (with low training cost and natural
//! incremental retraining); linear regression is the clear loser because
//! interference is non-linear.

mod common;

use common::{Bench, Table};
use jiagu::util::json::Json;

fn main() {
    let b = Bench::load();
    let j = Json::parse_file(&b.artifacts.join("model_comparison.json"))
        .expect("model_comparison.json — run `make artifacts`");
    let fig16 = j.get("fig16").unwrap();
    let mut t = Table::new(&["model", "error", "training time", "input dims"]);
    let order = ["jiagu_rfr", "xgboost", "esp", "mlp2", "mlp3", "mlp4", "linear"];
    for name in order {
        let m = fig16.get(name).unwrap();
        t.row(&[
            name.to_string(),
            format!("{:.1}%", 100.0 * m.get("error").unwrap().as_f64().unwrap()),
            format!("{:.1}s", m.get("fit_seconds").unwrap().as_f64().unwrap()),
            format!("{}", m.get("dims").unwrap().as_usize().unwrap()),
        ]);
    }
    t.print("Fig. 16: prediction error per model class (paper: RFR best tier; linear worst)");
    println!("\nNote: all models share the same features + log-slowdown target; only the model class varies.");
    println!("RFR additionally supports incremental retraining (the §6 periodic-retrain loop), unlike the closed-form fits.");
}

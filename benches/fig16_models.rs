//! Fig. 16 — prediction error across model classes on the same dataset:
//! Jiagu's RFR vs ESP-style ridge, gradient-boosted trees (XGBoost
//! stand-in), linear regression and MLP-2/3/4.
//!
//! Paper: RFR sits in the best tier (with low training cost and natural
//! incremental retraining); linear regression is the clear loser because
//! interference is non-linear.
//!
//! The baseline-model rows come from the Python pipeline
//! (`make artifacts-jax`); the native generator only trains the deployed
//! RFR, so missing rows are reported as absent rather than crashing.

mod common;

use common::{Bench, Table};
use jiagu::util::json::Json;

fn main() {
    let b = Bench::load();
    let j = Json::parse_file(&b.artifacts.join("model_comparison.json"))
        .expect("model_comparison.json — run `make artifacts` (or `make artifacts-jax`)");
    let fig16 = j.get("fig16").unwrap();
    let mut t = Table::new(&["model", "error", "training time", "input dims"]);
    let order = ["jiagu_rfr", "xgboost", "esp", "mlp2", "mlp3", "mlp4", "linear"];
    let mut missing = Vec::new();
    for name in order {
        let Some(m) = fig16.opt(name) else {
            missing.push(name);
            continue;
        };
        t.row(&[
            name.to_string(),
            format!("{:.1}%", 100.0 * m.get("error").unwrap().as_f64().unwrap()),
            format!("{:.1}s", m.get("fit_seconds").unwrap().as_f64().unwrap()),
            m.get("dims").unwrap().as_usize().unwrap().to_string(),
        ]);
    }
    t.print("Fig. 16: prediction error per model class (paper: RFR best tier; linear worst)");
    if !missing.is_empty() {
        println!(
            "\n(not in this artifact set: {} — regenerate with `make artifacts-jax` for the full baseline line-up)",
            missing.join(", ")
        );
    }
    println!("\nNote: all models share the same features + log-slowdown target; only the model class varies.");
    println!("RFR additionally supports incremental retraining (the §6 periodic-retrain loop), unlike the closed-form fits.");
}

//! Fig. 11 — scheduling performance under extreme scenarios.
//!
//! Best case: the timer trace (one function scaled at a fixed period) —
//! after the first decision everything hits Jiagu's fast path; paper
//! reports Gsight's scheduling overhead 11.9× larger and 126.3% longer
//! cold starts with cfork.  Worst case: concurrencies flip 0↔1 with gaps
//! past the keep-alive, so every decision is a slow path and Jiagu
//! degrades to Gsight's level.  Panels b/c add cfork vs Docker init.

mod common;

use common::{cold_start_ms, Bench, Table};
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::traces;

fn main() {
    let b = Bench::load();
    let dur = common::duration();
    let cases = [
        ("timer (best case)", traces::timer_trace(&b.cat, dur, 90)),
        ("0<->1 flip (worst case)", traces::worstcase_trace(&b.cat, dur, 90, 20)),
    ];
    let mut t = Table::new(&[
        "scenario",
        "system",
        "sched cost",
        "vs Gsight",
        "inf/sched",
        "fast/slow",
        "coldstart cfork",
        "coldstart docker",
        "calib cfork",
    ]);
    for (name, trace) in &cases {
        let j = b.run(RunConfig::jiagu_45(), trace, dur);
        let g = b.run(RunConfig::with_scheduler(SchedulerKind::Gsight), trace, dur);
        for (sys, r) in [("Jiagu", &j), ("Gsight", &g)] {
            t.row(&[
                name.to_string(),
                sys.to_string(),
                format!("{:.3}ms", r.scheduling_ms_mean),
                format!(
                    "{:.2}x",
                    r.scheduling_ms_mean / g.scheduling_ms_mean.max(1e-12)
                ),
                format!("{:.2}", r.inferences_per_schedule),
                format!("{}/{}", r.fast_decisions, r.slow_decisions),
                format!("{:.2}ms", cold_start_ms(r, 8.4)),
                format!("{:.2}ms", cold_start_ms(r, 85.5)),
                format!("{:.1}ms", 8.4 + r.inferences_per_schedule * 21.78),
            ]);
        }
    }
    t.print("Fig. 11: extreme scenarios (paper: best case Gsight overhead 11.9x Jiagu's, cfork cold start +126.3%; worst case Jiagu ~= Gsight)");
    println!("\nNote: with Docker (85.5 ms init) instance initialisation dominates either way — the paper's point that");
    println!("scheduling-cost reductions matter as init optimisations (cfork etc.) push init below 10 ms.");
}

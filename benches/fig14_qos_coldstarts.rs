//! Fig. 14 — (a) per-function QoS violation on trace A; (b) cold starts
//! avoided by dual-staged scaling + migration.
//!
//! Paper: (a) every function < 10% violations for all schedulers;
//! (b) with 45 s release sensitivity all re-routing is logical; with 30 s
//! a small share (<20%) would need real cold starts, which on-demand
//! migration of cached instances avoids.

mod common;

use common::{Bench, Table};
use jiagu::config::RunConfig;
use jiagu::traces;

fn main() {
    let b = Bench::load();
    let dur = common::duration();
    let traces_all = traces::paper_traces(&b.cat, dur);

    // (a) per-function QoS violations on trace A
    let mut headers = vec!["system".to_string()];
    headers.extend(b.cat.functions.iter().map(|f| f.name.clone()));
    let mut t = Table::new(&headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for (name, cfg) in b.lineup() {
        let r = b.run(cfg, &traces_all[0], dur);
        let mut cells = vec![name.to_string()];
        cells.extend(
            r.per_function_violation
                .iter()
                .map(|v| format!("{:.1}%", 100.0 * v)),
        );
        t.row(&cells);
    }
    t.print("Fig. 14a: per-function QoS violation rate on Trace A (paper: all < 10%)");

    // (b) logical vs would-be-real cold starts, 30/45 s sensitivity,
    // with and without on-demand migration
    let mut t2 = Table::new(&[
        "trace",
        "release",
        "migration",
        "logical CS",
        "real-after-release",
        "logical share",
        "migrations",
    ]);
    for trace in &traces_all {
        for (release, label) in [(45.0, "45s"), (30.0, "30s")] {
            for migration in [true, false] {
                let mut cfg = RunConfig::jiagu_45();
                cfg.autoscaler.release_duration_s = release;
                cfg.autoscaler.migration = migration;
                let r = b.run(cfg, trace, dur);
                t2.row(&[
                    trace.name.clone(),
                    label.to_string(),
                    if migration { "on" } else { "off" }.to_string(),
                    r.logical_cold_starts.to_string(),
                    r.real_after_release.to_string(),
                    format!("{:.1}%", 100.0 * r.logical_fraction()),
                    r.migrations.to_string(),
                ]);
            }
        }
    }
    t2.print("Fig. 14b: re-routing served logically vs needing real cold starts (paper: 45s fully logical; 30s <20% real, avoidable by migration)");
}

//! Fig. 13 — normalized function density across traces A–D for every
//! scheduler, K8s = 100%.
//!
//! Paper: all QoS-aware schedulers beat K8s; Owl trails (2-function
//! colocation limit); Gsight ≈ Jiagu-NoDS; dual-staged scaling lifts
//! Jiagu-45 and Jiagu-30 further, up to +54.8% over K8s, +22% over
//! Gsight, +38.3% over Owl, with QoS violations still < 10%.

mod common;

use common::{Bench, Table};
use jiagu::traces;

fn main() {
    let b = Bench::load();
    let dur = common::duration();
    let lineup = b.lineup();
    let mut t = Table::new(&[
        "trace", "K8s", "Owl", "Gsight", "Jiagu-NoDS", "Jiagu-45", "Jiagu-30",
    ]);
    let mut qos_t = Table::new(&[
        "trace", "K8s", "Owl", "Gsight", "Jiagu-NoDS", "Jiagu-45", "Jiagu-30",
    ]);
    for trace in traces::paper_traces(&b.cat, dur) {
        let mut cells = vec![trace.name.clone()];
        let mut qcells = vec![trace.name.clone()];
        let mut k8s_density = 1.0;
        for (name, cfg) in &lineup {
            let r = b.run(cfg.clone(), &trace, dur);
            if *name == "K8s" {
                k8s_density = r.density;
            }
            cells.push(format!("{:.1}%", 100.0 * r.density / k8s_density));
            qcells.push(format!("{:.1}%", 100.0 * r.qos_violation_rate));
        }
        t.row(&cells);
        qos_t.row(&qcells);
    }
    t.print("Fig. 13: normalized function density, K8s = 100% (paper: Jiagu-30 up to 154.8%)");
    qos_t.print("QoS violation rates for the same runs (paper: all < 10%)");
}

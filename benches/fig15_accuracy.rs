//! Fig. 15 — prediction accuracy of the deployed model.
//!
//! (a) overall/split-half/per-function error, scalability to 30/60
//! functions, and the Gsight-style instance-granularity comparison —
//! from `artifacts/model_comparison.json` (computed at `make artifacts`).
//! (b) error convergence as samples of a behaviour-changed function
//! arrive (incremental retraining).
//!
//! Additionally cross-checks the *deployed* forest against freshly
//! sampled ground truth from the Rust mirror.  Rows that only the Python
//! pipeline computes (30/60-function scale-out, Gsight features, the
//! fig15b convergence series) are skipped when absent, so the bench runs
//! on natively generated artifacts too.

mod common;

use common::{Bench, Table};
use jiagu::interference::{ground_truth_latency, NodeMix};
use jiagu::model::feature_row;
use jiagu::util::json::Json;
use jiagu::util::rng::Rng;

fn main() {
    let b = Bench::load();
    let j = Json::parse_file(&b.artifacts.join("model_comparison.json"))
        .expect("model_comparison.json — run `make artifacts` (or `make artifacts-jax`)");

    // (a) errors recorded at training time
    let a = j.get("fig15a").unwrap();
    let mut t = Table::new(&["config", "mean relative error"]);
    for key in [
        "jiagu",
        "jiagu_split1",
        "jiagu_split2",
        "jiagu_30fn",
        "jiagu_60fn",
        "gsight",
    ] {
        match a.opt(key) {
            Some(v) => t.row(&[
                key.to_string(),
                format!("{:.1}%", 100.0 * v.as_f64().unwrap()),
            ]),
            None => t.row(&[key.to_string(), "n/a (artifacts-jax only)".to_string()]),
        }
    }
    t.print("Fig. 15a: prediction error (paper: ~10-20%, no overfit across splits, stable at 30/60 functions)");

    let mut t_fn = Table::new(&["function", "error"]);
    if let Some(Json::Obj(m)) = a.opt("per_function") {
        for (name, v) in m {
            t_fn.row(&[name.clone(), format!("{:.1}%", 100.0 * v.as_f64().unwrap())]);
        }
    }
    t_fn.print("Fig. 15a: per-function error");

    // deployed-forest spot check against the ground-truth mirror
    let mut rng = Rng::seed_from(77);
    let mut rows = Vec::new();
    let mut truths = Vec::new();
    for _ in 0..200 {
        let kn = rng.range_u64(1, 4) as usize;
        let fids = rng.choose_k(b.cat.len(), kn);
        let entries: Vec<(usize, u32, u32)> = fids
            .iter()
            .map(|f| (*f, rng.range_u64(1, 8) as u32, rng.range_u64(0, 3) as u32))
            .collect();
        let mix = NodeMix::new(entries.clone());
        let target = entries[0].0;
        rows.push(feature_row(&b.cat, &mix, target));
        truths.push(ground_truth_latency(&b.cat, &mix, target));
    }
    let preds = b.predictor.predict(&rows).unwrap();
    let err: f64 = preds
        .iter()
        .zip(&truths)
        .map(|(p, t)| ((*p as f64) - t).abs() / t)
        .sum::<f64>()
        / truths.len() as f64;
    println!(
        "\ndeployed forest vs Rust ground-truth mirror over 200 fresh mixes: {:.1}% mean relative error",
        100.0 * err
    );

    // (b) convergence series
    let Some(bseries) = j.opt("fig15b") else {
        println!("\nFig. 15b: convergence series not in this artifact set (run `make artifacts-jax`)");
        return;
    };
    let pts = bseries.get("sample_points").unwrap().f64_vec().unwrap();
    let mut t2_headers: Vec<String> = vec!["function".into()];
    t2_headers.extend(pts.iter().map(|p| format!("n={p}")));
    let mut t2 = Table::new(&t2_headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    if let Json::Obj(series) = bseries.get("series").unwrap() {
        let mut avg = vec![0.0; pts.len()];
        let mut count = 0;
        for (name, errs) in series {
            let errs = errs.f64_vec().unwrap();
            let mut cells = vec![name.clone()];
            cells.extend(errs.iter().map(|e| format!("{:.0}%", 100.0 * e)));
            t2.row(&cells);
            for (i, e) in errs.iter().enumerate() {
                avg[i] += e;
            }
            count += 1;
        }
        let mut cells = vec!["(average)".to_string()];
        cells.extend(avg.iter().map(|e| format!("{:.0}%", 100.0 * e / count as f64)));
        t2.row(&cells);
    }
    t2.print("Fig. 15b: error vs samples after a function's behaviour changes (paper: rapid drop, convergence within ~5-30 samples)");
}

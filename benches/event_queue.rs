//! Event-queue hot path: sustained events/sec through the deterministic
//! `(due_ms, seq)` binary heap that replaced the 1 s tick loop.
//!
//! Two shapes bound the engine's real usage:
//!
//! * **bulk drain** — a workload injection pushes tens of thousands of
//!   `LoadChange` events up front, then the run pops them all;
//! * **steady churn** — at steady state every pop of a periodic event
//!   pushes its successor, so the heap stays near-constant size.
//!
//! ```bash
//! cargo bench --bench event_queue
//! ```

use jiagu::engine::{Event, EventQueue};
use jiagu::util::bench::{bench, Table};
use jiagu::util::rng::Rng;
use std::time::Duration;

const BULK: usize = 10_000;
const CHURN_HEAP: usize = 1_024;

fn random_event(rng: &mut Rng, i: u64) -> (f64, Event) {
    let due = rng.below(1_800_000) as f64; // anywhere in a 1800 s run (ms)
    let event = match rng.below(4) {
        0 => Event::ColdStartComplete { instance: i },
        1 => Event::DeferredUpdateDue { node: (i % 64) as usize, version: i },
        2 => Event::LoadChange { function: (i % 36) as usize, rps: due % 97.0 },
        _ => Event::MonitorTick,
    };
    (due, event)
}

fn main() {
    let mut table = Table::new(&["scenario", "ns/event", "Mevents/s", "p99 ns/event"]);

    // bulk drain: push BULK randomized events, pop until empty
    let mut rng = Rng::seed_from(0xE7E27);
    let events: Vec<(f64, Event)> =
        (0..BULK as u64).map(|i| random_event(&mut rng, i)).collect();
    let mut sink = 0.0f64;
    let s = bench(3, Duration::from_millis(300), || {
        let mut q = EventQueue::new();
        for (due, e) in &events {
            q.push(*due, e.clone());
        }
        while let Some(popped) = q.pop() {
            sink += popped.due_ms;
        }
    });
    // each iteration moves BULK events through push *and* pop
    let per_event = s.mean_ns / (2 * BULK) as f64;
    table.row(&[
        format!("bulk drain ({BULK} events)"),
        format!("{per_event:.1}"),
        format!("{:.1}", 1e3 / per_event),
        format!("{:.1}", s.p99_ns / (2 * BULK) as f64),
    ]);

    // steady churn: heap holds CHURN_HEAP events; each iteration pops the
    // earliest and pushes a successor (the periodic-event pattern)
    let mut q = EventQueue::new();
    let mut rng = Rng::seed_from(0xC4412);
    for i in 0..CHURN_HEAP as u64 {
        let (due, e) = random_event(&mut rng, i);
        q.push(due, e);
    }
    let mut i = CHURN_HEAP as u64;
    let s = bench(1000, Duration::from_millis(300), || {
        let popped = q.pop().expect("heap never drains");
        sink += popped.due_ms;
        let (_, e) = random_event(&mut rng, i);
        q.push(popped.due_ms + 1000.0, e);
        i += 1;
    });
    // one pop + one push per iteration
    let per_event = s.mean_ns / 2.0;
    table.row(&[
        format!("steady churn (heap {CHURN_HEAP})"),
        format!("{per_event:.1}"),
        format!("{:.1}", 1e3 / per_event),
        format!("{:.1}", s.p99_ns / 2.0),
    ]);

    table.print("event queue throughput (deterministic (due, seq) binary heap)");
    assert!(sink.is_finite()); // keep the optimizer honest
}

//! Event-queue hot path: sustained events/sec through both [`Timeline`]
//! implementations — the deterministic `(due_ms, seq)` binary heap and
//! the hierarchical timing wheel — over the shapes that bound the
//! engine's real usage:
//!
//! * **bulk drain** — a workload injection pushes tens of thousands of
//!   `LoadChange` events up front, then the run pops them all;
//! * **steady churn** — at steady state every pop of a periodic event
//!   pushes its successor, so the queue stays near-constant size;
//! * **million churn** — the same churn with 1,000,000 scheduled events
//!   resident: the regime the wheel exists for (`O(1)` push/pop vs the
//!   heap's `O(log n)`).  The wheel must sustain at least the heap's
//!   events/sec here — asserted, not just printed.
//!
//! ```bash
//! cargo bench --bench event_queue
//! # JIAGU_BENCH_SNAPSHOT=BENCH_event_queue.json additionally writes the
//! # machine-normalized snapshot (deterministic scenario sizes + the
//! # dimensionless wheel/heap throughput ratios; no wall-clock fields).
//! ```

use jiagu::engine::{AnyTimeline, Event, QueueKind, Timeline};
use jiagu::util::bench::{bench, Summary, Table};
use jiagu::util::json::{arr, num, obj, s, Json};
use jiagu::util::rng::Rng;
use std::time::Duration;

const BULK: usize = 10_000;
const CHURN_SMALL: usize = 1_024;
const CHURN_MILLION: usize = 1_000_000;

fn random_event(rng: &mut Rng, i: u64) -> (f64, Event) {
    let due = rng.below(1_800_000) as f64; // anywhere in a 1800 s run (ms)
    let event = match rng.below(4) {
        0 => Event::ColdStartComplete { instance: i },
        1 => Event::DeferredUpdateDue { node: (i % 64) as usize, version: i },
        2 => Event::LoadChange { function: (i % 36) as usize, rps: due % 97.0 },
        _ => Event::MonitorTick,
    };
    (due, event)
}

/// Push `BULK` randomized events, pop until empty; fresh queue per
/// iteration.  Returns ns per event (one push + one pop each).
fn bulk_drain(kind: QueueKind) -> Summary {
    let mut rng = Rng::seed_from(0xE7E27);
    let events: Vec<(f64, Event)> = (0..BULK as u64).map(|i| random_event(&mut rng, i)).collect();
    let mut sink = 0.0f64;
    let summary = bench(3, Duration::from_millis(300), || {
        let mut q = AnyTimeline::new(kind);
        for (due, e) in &events {
            q.push(*due, e.clone());
        }
        while let Some(popped) = q.pop() {
            sink += popped.due_ms;
        }
    });
    assert!(sink.is_finite()); // keep the optimizer honest
    summary
}

/// The queue holds `size` events; each iteration pops the earliest and
/// pushes a successor 1 s later (the periodic-event pattern), so the
/// population never moves.
fn steady_churn(kind: QueueKind, size: usize) -> Summary {
    let mut q = AnyTimeline::new(kind);
    let mut rng = Rng::seed_from(0xC4412);
    for i in 0..size as u64 {
        let (due, e) = random_event(&mut rng, i);
        q.push(due, e);
    }
    let mut i = size as u64;
    let mut sink = 0.0f64;
    let summary = bench(1000, Duration::from_millis(300), || {
        let popped = q.pop().expect("queue never drains");
        sink += popped.due_ms;
        let (_, e) = random_event(&mut rng, i);
        q.push(popped.due_ms + 1000.0, e);
        i += 1;
    });
    assert!(sink.is_finite());
    summary
}

fn main() {
    let mut table = Table::new(&["scenario", "queue", "ns/event", "Mevents/s", "p99 ns/event"]);
    // (snapshot key, display name, events resident, ops per iteration)
    let scenarios: [(&str, String, usize); 3] = [
        ("bulk_drain", format!("bulk drain ({BULK} events)"), BULK),
        ("steady_churn", format!("steady churn (queue {CHURN_SMALL})"), CHURN_SMALL),
        ("million_churn", format!("million churn (queue {CHURN_MILLION})"), CHURN_MILLION),
    ];

    let mut ratios: Vec<(&str, Json)> = Vec::new();
    let mut million_per_event = [0.0f64; 2]; // [heap, wheel]
    for (key, display, size) in &scenarios {
        let mut per_event = [0.0f64; 2];
        for (slot, kind) in [QueueKind::Heap, QueueKind::Wheel].into_iter().enumerate() {
            let (summary, ops) = if *key == "bulk_drain" {
                (bulk_drain(kind), (2 * BULK) as f64)
            } else {
                (steady_churn(kind, *size), 2.0)
            };
            per_event[slot] = summary.mean_ns / ops;
            table.row(&[
                display.clone(),
                kind.name().to_string(),
                format!("{:.1}", per_event[slot]),
                format!("{:.1}", 1e3 / per_event[slot]),
                format!("{:.1}", summary.p99_ns / ops),
            ]);
        }
        // dimensionless and machine-normalized: >1 means the wheel is faster
        ratios.push((*key, num(per_event[0] / per_event[1])));
        if *key == "million_churn" {
            million_per_event = per_event;
        }
    }
    table.print("event queue throughput (Timeline: binary heap vs hierarchical timing wheel)");

    assert!(
        million_per_event[1] <= million_per_event[0],
        "wheel must sustain at least the heap's events/sec at 1M resident events \
         (heap {:.1} ns/event, wheel {:.1} ns/event)",
        million_per_event[0],
        million_per_event[1],
    );
    println!("(wheel >= heap events/sec at 1M resident events — asserted)");

    if let Ok(path) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !path.is_empty() {
            let rows = scenarios
                .iter()
                .map(|(key, _, size)| {
                    obj(vec![("events", num(*size as f64)), ("scenario", s(key))])
                })
                .collect::<Vec<_>>();
            let payload = obj(vec![
                ("bench", s("event_queue")),
                ("bootstrap", Json::Bool(false)),
                ("scenarios", arr(rows)),
                ("wheel_over_heap_throughput", obj(ratios)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {path}");
        }
    }
}

//! Prediction hot path: the flattened batched [`FlatForest`] engine vs
//! the scalar reference [`NativeForest::predict_one`] walk, over the
//! batch shapes the schedulers actually submit:
//!
//! * **batch 1** — the accuracy-monitor probe (one row per function);
//! * **batch 32** — a typical capacity sweep (`candidates × qos targets`);
//! * **batch 1024** — a Gsight-style fanout validation / refresh burst.
//!
//! Two properties are asserted, not just printed:
//!
//! 1. the flat engine's outputs are **bit-identical** to the reference
//!    walk on every row (the contract that keeps the determinism matrix
//!    byte-identical with the flat engine serving all predictions);
//! 2. the flat engine sustains at least the reference's rows/sec at every
//!    batch size — the whole point of the SoA layout and tree-major
//!    blocking is that it must never be slower.
//!
//! ```bash
//! cargo bench --bench forest_inference
//! # JIAGU_BENCH_SNAPSHOT=BENCH_forest_inference.json additionally writes
//! # the machine-normalized snapshot (deterministic forest/batch sizes +
//! # the dimensionless flat/reference throughput ratios; no wall-clock
//! # fields).
//! ```

use jiagu::runtime::{FlatForest, FlatScratch, ForestParams, NativeForest};
use jiagu::util::bench::{bench, Table};
use jiagu::util::json::{arr, num, obj, s, Json};
use jiagu::util::rng::Rng;
use std::time::Duration;

// realistic artifact shape: the trained forest is 40 trees x depth 7
// over the 44-dim feature contract
const N_TREES: usize = 40;
const DEPTH: usize = 7;
const N_FEATURES: usize = 44;
// (batch size, snapshot ratio key)
const BATCHES: [(usize, &str); 3] = [(1, "batch_1"), (32, "batch_32"), (1024, "batch_1024")];

fn random_forest(rng: &mut Rng) -> ForestParams {
    let n_internal = (1usize << DEPTH) - 1;
    let n_leaves = 1usize << DEPTH;
    let params = ForestParams {
        n_trees: N_TREES,
        depth: DEPTH,
        n_features: N_FEATURES,
        feature: (0..N_TREES)
            .map(|_| (0..n_internal).map(|_| rng.below(N_FEATURES as u64) as i32).collect())
            .collect(),
        threshold: (0..N_TREES)
            .map(|_| (0..n_internal).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
            .collect(),
        leaf: (0..N_TREES)
            .map(|_| (0..n_leaves).map(|_| rng.range_f64(-0.3, 0.3) as f32).collect())
            .collect(),
        mean: (0..N_FEATURES).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
        std: (0..N_FEATURES).map(|_| rng.range_f64(0.5, 2.0) as f32).collect(),
        test_error: 0.0,
        fit_seconds: 0.0,
    };
    params.validate().expect("generated forest must be well-formed");
    params
}

fn main() {
    let mut rng = Rng::seed_from(0xF0_4E57);
    let params = random_forest(&mut rng);
    let reference = NativeForest::new(params.clone());
    let flat = FlatForest::from_params(&params);
    let mut scratch = FlatScratch::default();

    let mut table = Table::new(&["batch", "engine", "ns/row", "Mrows/s", "p99 ns/row"]);
    let mut rows_json: Vec<Json> = Vec::new();
    let mut ratios: Vec<(&str, Json)> = Vec::new();
    let mut slower_than_reference: Vec<String> = Vec::new();

    for (batch, ratio_key) in BATCHES {
        let data: Vec<f32> =
            (0..batch * N_FEATURES).map(|_| rng.range_f64(-10.0, 10.0) as f32).collect();

        // the contract first: every row bit-identical to the reference walk
        let got = flat.predict(&data, &mut scratch);
        for r in 0..batch {
            let want = reference.predict_one(&data[r * N_FEATURES..(r + 1) * N_FEATURES]);
            assert_eq!(
                got[r].to_bits(),
                want.to_bits(),
                "flat engine diverged from the reference walk at batch {batch}, row {r}"
            );
        }

        let mut out = Vec::with_capacity(batch);
        let flat_summary = bench(10, Duration::from_millis(300), || {
            flat.predict_into(&data, &mut scratch, &mut out);
        });
        let mut sink = 0.0f64;
        let ref_summary = bench(10, Duration::from_millis(300), || {
            for r in 0..batch {
                sink += reference.predict_one(&data[r * N_FEATURES..(r + 1) * N_FEATURES])
                    as f64;
            }
        });
        assert!(sink.is_finite()); // keep the optimizer honest

        let flat_ns = flat_summary.mean_ns / batch as f64;
        let ref_ns = ref_summary.mean_ns / batch as f64;
        for (engine, summary, ns) in
            [("flat", &flat_summary, flat_ns), ("reference", &ref_summary, ref_ns)]
        {
            table.row(&[
                batch.to_string(),
                engine.to_string(),
                format!("{ns:.1}"),
                format!("{:.2}", 1e3 / ns),
                format!("{:.1}", summary.p99_ns / batch as f64),
            ]);
        }
        // dimensionless and machine-normalized: >1 means flat is faster
        ratios.push((ratio_key, num(ref_ns / flat_ns)));
        if flat_ns > ref_ns {
            slower_than_reference.push(format!(
                "batch {batch}: flat {flat_ns:.1} ns/row vs reference {ref_ns:.1} ns/row"
            ));
        }
        rows_json.push(obj(vec![
            ("batch", num(batch as f64)),
            ("n_trees", num(N_TREES as f64)),
            ("depth", num(DEPTH as f64)),
            ("n_features", num(N_FEATURES as f64)),
        ]));
    }
    table.print("forest inference (flat SoA batched engine vs scalar reference walk)");

    assert!(
        slower_than_reference.is_empty(),
        "flat engine must sustain at least the reference's rows/sec: {}",
        slower_than_reference.join("; ")
    );
    println!("(flat >= reference rows/sec at batch 1/32/1024 — asserted)");
    println!("(flat output bit-identical to the reference walk — asserted)");

    if let Ok(path) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !path.is_empty() {
            let payload = obj(vec![
                ("bench", s("forest_inference")),
                ("bootstrap", Json::Bool(false)),
                ("scenarios", arr(rows_json)),
                ("flat_over_reference_throughput", obj(ratios)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {path}");
        }
    }
}

//! Trace-replay throughput: wall-clock events/sec (and trace
//! records/sec) streaming a generated million-invocation Azure-style
//! log through the control plane — unsharded and across the sharded
//! layout.  The replay path's claim is *bounded memory at full
//! fidelity*: the reader never materializes the trace, yet the replay
//! stays byte-deterministic (asserted here across repeats).
//!
//! Self-contained: generates its own catalog, trace file (in the temp
//! dir) and synthetic-stub forest, so it runs on a fresh checkout
//! without `make artifacts`.
//!
//! ```bash
//! cargo bench --bench trace_replay
//! # JIAGU_TRACE_INVOCATIONS=200000 shrinks the trace (default 1M);
//! # JIAGU_BENCH_JSON=path.json additionally writes the rows as JSON;
//! # JIAGU_BENCH_SNAPSHOT=BENCH_trace_replay.json writes the
//! # machine-normalized snapshot (deterministic counts only).
//! ```

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::util::bench::Table;
use jiagu::util::json::{arr, num, obj, s, Json};
use jiagu::workload::replay::{
    generate_trace_file, replay_path, ReplayOptions, TraceFormat, TraceGenSpec,
};
use std::sync::Arc;
use std::time::Instant;

const N_FUNCTIONS: usize = 8;
const N_NODES: usize = 16;
/// Virtual trace horizon (s): ~16.7k rps aggregate at the default 1M.
const TRACE_SECONDS: usize = 60;
/// Deterministic runs: wall time is the only noise, so two repeats with
/// a min-take suffice — and the repeat doubles as a determinism guard.
const REPEATS: usize = 2;

fn main() {
    let invocations: u64 = std::env::var("JIAGU_TRACE_INVOCATIONS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1_000_000);
    let cat = Catalog::from_functions(make_catalog(N_FUNCTIONS, 0x7ace));
    let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
        ForestParams::synthetic_stub(jiagu::model::N_FEATURES, 0.05, 0.05),
    ));
    let path = std::env::temp_dir().join(format!("jiagu_bench_trace_{invocations}.csv"));
    let spec = TraceGenSpec {
        invocations,
        duration_s: TRACE_SECONDS,
        seed: 0x7ace,
        format: TraceFormat::Csv,
    };
    let t0 = Instant::now();
    let written = generate_trace_file(&path, &cat, &spec).expect("trace generation");
    let gen_secs = t0.elapsed().as_secs_f64();
    println!(
        "generated {written} invocations over {TRACE_SECONDS}s in {:.1} ms ({:.0}/sec)",
        gen_secs * 1e3,
        written as f64 / gen_secs
    );

    let opts = ReplayOptions::default();
    let mut table =
        Table::new(&["scenario", "events", "wall ms", "events/sec", "records/sec"]);
    let mut rows = Vec::new();
    let mut snapshot_rows = Vec::new();
    for (scenario, shards, partitions) in [("unsharded", 0usize, 1usize), ("sharded-2x2", 2, 2)]
    {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = N_NODES;
        cfg.duration_s = TRACE_SECONDS;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.seed = 4242;
        cfg.shards = shards;
        cfg.partitions = partitions;
        let mut best_s = f64::INFINITY;
        let mut kept = None;
        for _ in 0..REPEATS {
            let t0 = Instant::now();
            let (report, stats) =
                replay_path(&cat, &cfg, predictor.clone(), &path, &opts).expect("replay");
            best_s = best_s.min(t0.elapsed().as_secs_f64());
            if let Some((prev_report, prev_stats)) = &kept {
                // the determinism guard: repeats may only move wall time
                assert_eq!(*prev_report, report, "{scenario}: replay must be byte-stable");
                assert_eq!(*prev_stats, stats);
            }
            kept = Some((report, stats));
        }
        let (report, stats) = kept.expect("at least one repeat");
        assert_eq!(stats.invocations, written, "{scenario}: every record must be read");
        assert_eq!(stats.clipped, 0, "{scenario}: the trace fits the horizon");
        assert!(report.requests_served > 0, "{scenario}: traffic must be served");
        let events_per_sec = report.events_processed as f64 / best_s;
        let records_per_sec = stats.invocations as f64 / best_s;
        table.row(&[
            scenario.to_string(),
            format!("{}", report.events_processed),
            format!("{:.1}", best_s * 1e3),
            format!("{events_per_sec:.0}"),
            format!("{records_per_sec:.0}"),
        ]);
        rows.push(obj(vec![
            ("scenario", s(scenario)),
            ("shards", num(shards as f64)),
            ("partitions", num(partitions as f64)),
            ("invocations", num(stats.invocations as f64)),
            ("emitted", num(stats.emitted as f64)),
            ("events_processed", num(report.events_processed as f64)),
            ("wall_seconds", num(best_s)),
            ("events_per_sec", num(events_per_sec)),
            ("records_per_sec", num(records_per_sec)),
        ]));
        snapshot_rows.push(obj(vec![
            ("emitted", num(stats.emitted as f64)),
            ("events_processed", num(report.events_processed as f64)),
            ("invocations", num(stats.invocations as f64)),
            ("partitions", num(partitions as f64)),
            ("requests_served", num(report.requests_served as f64)),
            ("scenario", s(scenario)),
            ("shards", num(shards as f64)),
        ]));
    }
    table.print(&format!("trace replay ({written} invocations, {TRACE_SECONDS}s horizon)"));
    println!("(reports byte-identical across repeats — asserted)");
    std::fs::remove_file(&path).ok();

    if let Ok(out) = std::env::var("JIAGU_BENCH_JSON") {
        if !out.is_empty() {
            let payload = obj(vec![
                ("bench", s("trace_replay")),
                ("duration_s", num(TRACE_SECONDS as f64)),
                ("invocations", num(written as f64)),
                ("rows", arr(rows)),
            ]);
            std::fs::write(&out, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_JSON");
            println!("wrote {out}");
        }
    }

    if let Ok(out) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !out.is_empty() {
            let payload = obj(vec![
                ("bench", s("trace_replay")),
                ("bootstrap", Json::Bool(false)),
                ("duration_s", num(TRACE_SECONDS as f64)),
                ("scenarios", arr(snapshot_rows)),
            ]);
            std::fs::write(&out, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {out}");
        }
    }
}

//! Fig. 17 — model performance.
//!
//! (a) training time + input dimensionality: Jiagu's function-granularity
//! features (44 dims) vs Gsight-style instance-granularity (404 dims) —
//! from `artifacts/model_comparison.json` (the Gsight row needs
//! `make artifacts-jax`; natively generated artifacts carry the Jiagu row).
//! (b) inference cost vs number of batched inputs, *measured live*
//! through the loaded predictor (paper: only ~+2 ms going to 100 inputs —
//! batched capacity sweeps are nearly free).

mod common;

use common::{bench, Bench, Table};
use jiagu::util::json::Json;
use jiagu::util::rng::Rng;
use std::time::Duration;

fn main() {
    let b = Bench::load();
    let j = Json::parse_file(&b.artifacts.join("model_comparison.json"))
        .expect("model_comparison.json — run `make artifacts` (or `make artifacts-jax`)");

    // (a)
    let a = j.get("fig17a").unwrap();
    let mut t = Table::new(&["model", "input dims", "training time"]);
    for name in ["jiagu", "gsight"] {
        let Some(m) = a.opt(name) else {
            t.row(&[
                format!("{name} granularity"),
                "n/a".to_string(),
                "n/a (artifacts-jax only)".to_string(),
            ]);
            continue;
        };
        t.row(&[
            format!("{name} granularity"),
            m.get("dims").unwrap().as_usize().unwrap().to_string(),
            format!("{:.1}s", m.get("fit_seconds").unwrap().as_f64().unwrap()),
        ]);
    }
    t.print("Fig. 17a: training time and dimensionality (paper: function-granularity is ~10x smaller and faster)");

    // (b) measured inference latency vs batch size
    let mut rng = Rng::seed_from(5);
    let n_feat = b.predictor.n_features();
    let mut t2 = Table::new(&["batch rows", "mean", "p99", "per-row"]);
    let mut base_mean = 0.0;
    for rows_n in [1usize, 2, 4, 8, 16, 32, 64, 100, 128, 256] {
        let rows: Vec<Vec<f32>> = (0..rows_n)
            .map(|_| (0..n_feat).map(|_| rng.range_f64(0.0, 100.0) as f32).collect())
            .collect();
        let s = bench(3, Duration::from_millis(400), || {
            b.predictor.predict(&rows).unwrap();
        });
        if rows_n == 1 {
            base_mean = s.mean_ms();
        }
        t2.row(&[
            rows_n.to_string(),
            format!("{:.3}ms", s.mean_ms()),
            format!("{:.3}ms", s.p99_ms()),
            format!("{:.1}us", 1000.0 * s.mean_ms() / rows_n as f64),
        ]);
        if rows_n == 100 {
            println!(
                "  -> +{:.2} ms going from 1 to 100 batched inputs (paper: ~+2 ms)",
                s.mean_ms() - base_mean
            );
        }
    }
    t2.print("Fig. 17b: predictor inference latency vs batched inputs (measured live)");
}

//! Shared bench harness: load artifacts, run simulations, print
//! paper-style tables.  Every bench binary regenerates the rows/series of
//! one table or figure of the paper (see DESIGN.md per-experiment index).

use jiagu::catalog::Catalog;
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::sim::{load_predictor, RunReport, Simulation};
use jiagu::traces::TraceSet;
use std::sync::Arc;

#[allow(unused_imports)]
pub use jiagu::util::bench::{bench, summarize, Table};

/// Default simulated horizon for the sim-driven benches.  Override with
/// JIAGU_BENCH_DURATION (CI wants shorter; paper-style runs want longer).
#[allow(dead_code)]
pub fn duration() -> usize {
    std::env::var("JIAGU_BENCH_DURATION")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1200)
}

#[allow(dead_code)]
pub struct Bench {
    pub cat: Catalog,
    pub artifacts: std::path::PathBuf,
    pub predictor: Arc<dyn jiagu::runtime::Predictor>,
}

#[allow(dead_code)]
impl Bench {
    /// Load artifacts + the predictor: PJRT when the `pjrt` feature is
    /// compiled in, otherwise the pure-Rust forest (set JIAGU_NATIVE=1 to
    /// force the native forest, e.g. for scheduler-only profiling).
    pub fn load() -> Self {
        let artifacts = jiagu::artifacts_dir();
        let cat = Catalog::load(&artifacts.join("functions.json"))
            .expect("run `make artifacts` before `cargo bench`");
        let native = std::env::var("JIAGU_NATIVE").is_ok();
        let predictor = load_predictor(&artifacts, native).expect("predictor");
        Self { cat, artifacts, predictor }
    }

    /// One simulated run of `cfg` over `trace`.
    pub fn run(&self, mut cfg: RunConfig, trace: &TraceSet, duration_s: usize) -> RunReport {
        cfg.duration_s = duration_s;
        self.predictor.stats().reset();
        Simulation::new(self.cat.clone(), cfg, self.predictor.clone())
            .run(trace)
            .expect("simulation")
    }

    /// The paper's scheduler line-up for Figs. 13/14.
    pub fn lineup(&self) -> Vec<(&'static str, RunConfig)> {
        vec![
            ("K8s", RunConfig::with_scheduler(SchedulerKind::Kubernetes)),
            ("Owl", RunConfig::with_scheduler(SchedulerKind::Owl)),
            ("Gsight", RunConfig::with_scheduler(SchedulerKind::Gsight)),
            ("Jiagu-NoDS", RunConfig::jiagu_nods()),
            ("Jiagu-45", RunConfig::jiagu_45()),
            ("Jiagu-30", RunConfig::jiagu_30()),
        ]
    }
}

/// Cold-start latency mean for a run under a given init model: measured
/// per-call decision cost + constant init latency (see DESIGN.md
/// "Scheduling-cost measurement model").
#[allow(dead_code)]
pub fn cold_start_ms(report: &RunReport, init_ms: f64) -> f64 {
    report.scheduling_ms_mean + init_ms
}

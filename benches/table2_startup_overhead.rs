//! Table 2 — scheduling overhead relative to container startup across
//! state-of-the-art init-optimised systems.
//!
//! The paper uses its ported Gsight's 21.78 ms average scheduling cost
//! against each system's published startup latency.  We substitute the
//! *measured* scheduling cost of our Gsight port (and show Jiagu's for
//! contrast); published startup latencies come from the papers cited in
//! Table 2.

mod common;

use common::{Bench, Table};
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::traces;

/// (system, container startup ms) as published (paper Table 2).
const SYSTEMS: &[(&str, f64)] = &[
    ("AWS Snapstart", 100.0),
    ("Replayable", 54.0),
    ("Fireworks", 50.0),
    ("SOCK", 20.0),
    ("Molecule (cfork)", 8.4),
    ("SEUSS", 7.5),
    ("Catalyzer", 0.97),
    ("Faasm", 0.5),
];

fn main() {
    let b = Bench::load();
    let dur = common::duration().min(900);
    let trace = traces::paper_traces(&b.cat, dur).swap_remove(0);
    let g = b.run(RunConfig::with_scheduler(SchedulerKind::Gsight), &trace, dur);
    let j = b.run(RunConfig::jiagu_45(), &trace, dur);
    println!(
        "measured model-based scheduling cost: Gsight {:.3} ms (paper's port: 21.78 ms), Jiagu {:.3} ms",
        g.scheduling_ms_mean, j.scheduling_ms_mean
    );

    let mut t = Table::new(&[
        "system",
        "container startup",
        "Gsight sched overhead",
        "Jiagu sched overhead",
    ]);
    for (name, startup) in SYSTEMS {
        t.row(&[
            name.to_string(),
            format!("{startup}ms"),
            format!("{:.1}%", 100.0 * g.scheduling_ms_mean / startup),
            format!("{:.1}%", 100.0 * j.scheduling_ms_mean / startup),
        ]);
    }
    t.print("Table 2: scheduling cost as % of container startup (paper: Gsight >20% on Snapstart, 2.6x on Molecule, 43.6x on Faasm)");
    println!("\nShape check: the faster the init path, the more model-on-critical-path scheduling dominates;");
    println!("pre-decision scheduling keeps the overhead negligible even for sub-ms init systems.");
}

//! Fig. 12 — scheduling performance on real-world traces A–D.
//!
//! Three panels per trace: scheduling cost, model inferences per
//! schedule, cold-start latency with cfork (8.4 ms init), each for Jiagu
//! (pre-decision) vs Gsight (inference on the critical path), Gsight
//! normalised to 1.  Paper: 81.0–93.7% lower scheduling cost, 83.8–92.1%
//! fewer inferences, 57.4–69.3% lower cold start.

mod common;

use common::{cold_start_ms, Bench, Table};
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::traces;

fn main() {
    let b = Bench::load();
    let dur = common::duration();
    let mut t = Table::new(&[
        "trace",
        "sched Jiagu",
        "sched Gsight",
        "reduction",
        "inf/sched J",
        "inf/sched G",
        "reduction",
        "coldstart J (cfork)",
        "coldstart G (cfork)",
        "reduction",
        "calib J",
        "calib G",
        "calib reduction",
    ]);
    for trace in traces::paper_traces(&b.cat, dur) {
        let j = b.run(RunConfig::jiagu_45(), &trace, dur);
        let g = b.run(
            RunConfig::with_scheduler(SchedulerKind::Gsight),
            &trace,
            dur,
        );
        let red = |a: f64, bb: f64| format!("{:.1}%", 100.0 * (1.0 - a / bb.max(1e-12)));
        let cs_j = cold_start_ms(&j, 8.4);
        let cs_g = cold_start_ms(&g, 8.4);
        // paper-calibrated cold start: our XLA forest inference is ~70x
        // faster than the paper's 21.78 ms sklearn model, so we also
        // report init + (inferences/schedule x 21.78 ms) to isolate the
        // *policy* effect (how often inference blocks a cold start)
        let cal_j = 8.4 + j.inferences_per_schedule * 21.78;
        let cal_g = 8.4 + g.inferences_per_schedule * 21.78;
        t.row(&[
            trace.name.clone(),
            format!("{:.3}ms", j.scheduling_ms_mean),
            format!("{:.3}ms", g.scheduling_ms_mean),
            red(j.scheduling_ms_mean, g.scheduling_ms_mean),
            format!("{:.2}", j.inferences_per_schedule),
            format!("{:.2}", g.inferences_per_schedule),
            red(j.inferences_per_schedule, g.inferences_per_schedule),
            format!("{cs_j:.2}ms"),
            format!("{cs_g:.2}ms"),
            red(cs_j, cs_g),
            format!("{cal_j:.1}ms"),
            format!("{cal_g:.1}ms"),
            red(cal_j, cal_g),
        ]);
    }
    t.print("Fig. 12: scheduling cost / inferences / cold start on real-world traces (paper: 81.0-93.7% / 83.8-92.1% / 57.4-69.3% reductions)");
    println!("\n'calib' columns price each critical-path inference at the paper's measured 21.78 ms model cost;");
    println!("they isolate the scheduling-policy effect from our much faster XLA forest (see EXPERIMENTS.md).");
}

//! Hot-path microbenchmarks (perf tracking, EXPERIMENTS.md §Perf).
//!
//! Times the building blocks the paper's latency claims rest on:
//! feature-row construction, the fast-path table lookup + placement, the
//! slow-path capacity sweep, a full asynchronous update, and the
//! native-vs-PJRT predictor at the sweep's batch size.

mod common;

use common::{bench, Bench, Table};
use jiagu::capacity::{self, CapacityConfig};
use jiagu::cluster::Cluster;
use jiagu::interference::NodeMix;
use jiagu::model::features::FeatureBuilder;
use jiagu::runtime::{ForestParams, NativeForest};
use jiagu::scheduler::{JiaguScheduler, Scheduler};
use jiagu::util::rng::Rng;
use std::time::Duration;

fn main() {
    let b = Bench::load();
    let cfg = CapacityConfig::default();
    let mut t = Table::new(&["operation", "mean", "p50", "p99"]);
    let budget = Duration::from_millis(500);

    // representative 3-function mix
    let mix = NodeMix::new(vec![(0, 4, 1), (2, 3, 0), (5, 2, 1)]);

    // 1. feature row build (hoisted builder)
    {
        let builder = FeatureBuilder::new(&b.cat, &mix);
        let mut row = Vec::with_capacity(jiagu::model::N_FEATURES);
        let s = bench(100, budget, || {
            builder.row_into(0, &mut row);
            std::hint::black_box(&row);
        });
        t.row(&[
            "feature row (row_into)".into(),
            format!("{:.0}ns", s.mean_ns),
            format!("{:.0}ns", s.p50_ns),
            format!("{:.0}ns", s.p99_ns),
        ]);
    }

    // 2. native forest single prediction
    let native = NativeForest::new(ForestParams::load(&b.artifacts.join("forest.json")).unwrap());
    {
        let row = FeatureBuilder::new(&b.cat, &mix).row(0);
        let s = bench(100, budget, || {
            std::hint::black_box(native.predict_one(&row));
        });
        t.row(&[
            "native forest x1".into(),
            format!("{:.0}ns", s.mean_ns),
            format!("{:.0}ns", s.p50_ns),
            format!("{:.0}ns", s.p99_ns),
        ]);
    }

    // 3. PJRT predictor at sweep batch (capacity sweep row count)
    {
        let builder = FeatureBuilder::new(&b.cat, &mix);
        let rows: Vec<Vec<f32>> = (0..84).map(|i| builder.row(i % b.cat.len())).collect();
        let s = bench(5, budget, || {
            b.predictor.predict(&rows).unwrap();
        });
        t.row(&[
            "predictor x84 (sweep batch)".into(),
            format!("{:.3}ms", s.mean_ms()),
            format!("{:.3}ms", s.p50_ms()),
            format!("{:.3}ms", s.p99_ms()),
        ]);
        let rows1 = rows[..1].to_vec();
        let s = bench(5, budget, || {
            b.predictor.predict(&rows1).unwrap();
        });
        t.row(&[
            "predictor x1".into(),
            format!("{:.3}ms", s.mean_ms()),
            format!("{:.3}ms", s.p50_ms()),
            format!("{:.3}ms", s.p99_ms()),
        ]);
    }

    // 4. capacity sweep (slow path body)
    {
        let s = bench(5, budget, || {
            capacity::compute_capacity(&b.cat, &mix, 0, b.predictor.as_ref(), &cfg).unwrap();
        });
        t.row(&[
            "capacity sweep (slow path)".into(),
            format!("{:.3}ms", s.mean_ms()),
            format!("{:.3}ms", s.p50_ms()),
            format!("{:.3}ms", s.p99_ms()),
        ]);
    }

    // 5. fast-path schedule decision (table hit): plan + commit, with the
    // asynchronous refresh computed + landed separately (off-path billing)
    {
        let mut cluster = Cluster::new(8);
        let mut sched = JiaguScheduler::new(b.predictor.clone(), cfg.clone(), 8);
        // warm the table
        let warm = sched.schedule(&b.cat, &cluster, 0, 1, 0.0).unwrap();
        let warm = warm.commit(&b.cat, &mut cluster, 0.0);
        for node in warm.touched_nodes() {
            if let Some(u) = sched.on_node_changed(&b.cat, &cluster, node, 0.0).unwrap() {
                sched.complete_deferred(u);
            }
        }
        let mut rng = Rng::seed_from(3);
        let mut decision_ns = Vec::new();
        let mut async_ns = Vec::new();
        for i in 0..400 {
            let f = rng.below(b.cat.len() as u64) as usize;
            let plan = sched.schedule(&b.cat, &cluster, f, 1, i as f64).unwrap();
            decision_ns.push(plan.decision_nanos as f64);
            let committed = plan.commit(&b.cat, &mut cluster, i as f64);
            // refresh cost is off the critical path; land it immediately
            // so the next iteration's tables stay warm
            let mut refresh_ns = 0u64;
            for node in committed.touched_nodes() {
                if let Some(u) =
                    sched.on_node_changed(&b.cat, &cluster, node, i as f64).unwrap()
                {
                    refresh_ns += u.nanos;
                    sched.complete_deferred(u);
                }
            }
            async_ns.push(refresh_ns as f64);
            // keep the cluster from saturating: evict what we placed
            for p in &committed.placements {
                cluster.evict(&b.cat, p.instance);
            }
        }
        let d = common::summarize(&decision_ns);
        let a = common::summarize(&async_ns);
        t.row(&[
            "schedule decision (mixed fast/slow)".into(),
            format!("{:.3}ms", d.mean_ns / 1e6),
            format!("{:.3}ms", d.p50_ns / 1e6),
            format!("{:.3}ms", d.p99_ns / 1e6),
        ]);
        t.row(&[
            "async update (off critical path)".into(),
            format!("{:.3}ms", a.mean_ns / 1e6),
            format!("{:.3}ms", a.p50_ns / 1e6),
            format!("{:.3}ms", a.p99_ns / 1e6),
        ]);
    }

    t.print("Hot-path microbenchmarks (see EXPERIMENTS.md §Perf)");
}

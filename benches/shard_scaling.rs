//! Shard-scaling throughput: wall-clock events/sec of the sharded
//! control plane at 1 / 2 / 4 worker threads over the *same* partition
//! layout — the tentpole claim that parallel shards buy throughput
//! without buying nondeterminism.  Every run must merge to the same
//! bytes (asserted here, not just in CI), so the speedup column is the
//! only thing allowed to move between rows.
//!
//! Self-contained: generates its own catalog and uses the synthetic-stub
//! forest, so it runs on a fresh checkout without `make artifacts`.
//!
//! ```bash
//! cargo bench --bench shard_scaling
//! # JIAGU_BENCH_DURATION=60 scales the virtual horizon (default 20 s);
//! # JIAGU_BENCH_JSON=path.json additionally writes the rows as JSON
//! # (uploaded as a CI workflow artifact);
//! # JIAGU_BENCH_SNAPSHOT=BENCH_shard_scaling.json writes the
//! # machine-normalized snapshot (deterministic event counts + the
//! # dimensionless speedups; no wall-clock fields).
//! ```

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::controlplane::shard::ShardedControlPlane;
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::sim::RunReport;
use jiagu::traces::{PoissonParams, Workload};
use jiagu::util::bench::Table;
use jiagu::util::json::{arr, num, obj, s, Json};
use std::sync::Arc;
use std::time::Instant;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];
const PARTITIONS: usize = 4;
const N_FUNCTIONS: usize = 8;
const N_NODES: usize = 16;
/// Deterministic runs: wall time is the only noise, so a few repeats
/// with a min-take are enough.
const REPEATS: usize = 3;

fn main() {
    let duration_s: usize = std::env::var("JIAGU_BENCH_DURATION")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);
    let cat = Catalog::from_functions(make_catalog(N_FUNCTIONS, 0xbe7c));
    let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
        ForestParams::synthetic_stub(jiagu::model::N_FEATURES, 0.05, 0.05),
    ));
    let workload = Workload::poisson(
        &cat,
        &PoissonParams { duration_s, bin_ms: 100.0, mean_concurrency: 3.0 },
        0x51ed,
    );

    let run = |shards: usize| -> (RunReport, f64) {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = N_NODES;
        cfg.duration_s = duration_s;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.seed = 4242;
        cfg.partitions = PARTITIONS;
        cfg.shards = shards;
        let plane =
            ShardedControlPlane::new(cat.clone(), cfg, predictor.clone()).expect("valid layout");
        let mut best_s = f64::INFINITY;
        let mut report = None;
        for _ in 0..REPEATS {
            let t0 = Instant::now();
            let r = plane.run_workload(&workload).expect("sharded run");
            best_s = best_s.min(t0.elapsed().as_secs_f64());
            report = Some(r);
        }
        (report.expect("at least one repeat"), best_s)
    };

    let mut table = Table::new(&["shards", "events", "wall ms", "events/sec", "speedup"]);
    let mut rows = Vec::new();
    let mut snapshot_rows = Vec::new();
    let mut reference: Option<(RunReport, f64)> = None;
    for shards in SHARD_COUNTS {
        let (report, secs) = run(shards);
        assert!(report.events_processed > 0, "the scenario must process events");
        let events_per_sec = report.events_processed as f64 / secs;
        let speedup = match &reference {
            None => 1.0,
            Some((reference_report, reference_secs)) => {
                // the determinism guard: parallelism may only move time
                assert_eq!(
                    *reference_report,
                    report,
                    "{shards}-shard report must be bit-identical to 1-shard"
                );
                reference_secs / secs
            }
        };
        table.row(&[
            format!("{shards}"),
            format!("{}", report.events_processed),
            format!("{:.1}", secs * 1e3),
            format!("{events_per_sec:.0}"),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("shards", num(shards as f64)),
            ("partitions", num(PARTITIONS as f64)),
            ("events_processed", num(report.events_processed as f64)),
            ("wall_seconds", num(secs)),
            ("events_per_sec", num(events_per_sec)),
            ("speedup", num(speedup)),
        ]));
        snapshot_rows.push(obj(vec![
            ("events_processed", num(report.events_processed as f64)),
            ("partitions", num(PARTITIONS as f64)),
            ("shards", num(shards as f64)),
            ("speedup", num(speedup)),
        ]));
        if reference.is_none() {
            reference = Some((report, secs));
        }
    }
    table.print(&format!("shard scaling ({PARTITIONS} partitions, {duration_s}s horizon)"));
    println!("(reports byte-identical across all shard counts — asserted)");

    if let Ok(path) = std::env::var("JIAGU_BENCH_JSON") {
        if !path.is_empty() {
            let payload = obj(vec![
                ("bench", s("shard_scaling")),
                ("duration_s", num(duration_s as f64)),
                ("rows", arr(rows)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_JSON");
            println!("wrote {path}");
        }
    }

    if let Ok(path) = std::env::var("JIAGU_BENCH_SNAPSHOT") {
        if !path.is_empty() {
            let payload = obj(vec![
                ("bench", s("shard_scaling")),
                ("bootstrap", Json::Bool(false)),
                ("duration_s", num(duration_s as f64)),
                ("rows", arr(snapshot_rows)),
            ]);
            std::fs::write(&path, format!("{}\n", payload.to_string()))
                .expect("writing JIAGU_BENCH_SNAPSHOT");
            println!("wrote {path}");
        }
    }
}

//! Workload-lab properties (registered as a `[[test]]` target;
//! `autotests = false`):
//!
//! * **Fuzzer determinism, end to end** — for every tested scenario
//!   family, the same seed yields a byte-identical `Workload` event
//!   stream, and replaying it yields a byte-identical `RunReport`
//!   across shard counts 1/2/4 × queue heap/wheel: the adversarial
//!   scenarios inherit the engine's full replay contract.
//! * **Dropped-arrival partition exactness** — the per-function safety
//!   cap's dropped counts partition exactly under `Workload::restrict`:
//!   for any cell layout, the per-cell `arrivals_dropped` sum equals
//!   the unsharded count, because every cell synthesizes with the same
//!   arrival seed (`RunConfig::arrival_seed` pinned by
//!   `ShardedControlPlane::cell_config`) and synthesis is
//!   per-function-seeded.

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::controlplane::shard::ShardedControlPlane;
use jiagu::engine::QueueKind;
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::sim::effective_arrival_seed;
use jiagu::traces::{LoadEvent, Workload, MAX_ARRIVALS_PER_FUNCTION};
use jiagu::workload::fuzz::{ScenarioFamily, ScenarioFuzzer};
use std::sync::Arc;

fn stub_predictor() -> Arc<dyn Predictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        0.05,
        0.05,
    )))
}

fn catalog() -> Catalog {
    Catalog::from_functions(make_catalog(6, 5))
}

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::jiagu_45();
    cfg.n_nodes = 6;
    cfg.duration_s = 6;
    cfg.requests = true;
    cfg.eval_interval_ms = 250.0;
    cfg.partitions = 2;
    cfg
}

/// Satellite contract: same fuzzer seed ⇒ byte-identical event stream
/// and byte-identical reports at shards 1/2/4 × queue heap/wheel, for
/// three distinct scenario families.
#[test]
fn fuzzer_scenarios_replay_identically_across_shards_and_queues() {
    let cat = catalog();
    let families = [
        ScenarioFamily::CorrelatedBurst,
        ScenarioFamily::ColdStampede,
        ScenarioFamily::SquareWave,
    ];
    for family in families {
        let fuzzer = ScenarioFuzzer::new(17, base_cfg().duration_s);
        let wl = fuzzer.workload(&cat, family);
        assert_eq!(
            wl.events,
            fuzzer.workload(&cat, family).events,
            "{}: same seed must regenerate the same stream",
            family.name()
        );
        let mut reference = None;
        for shards in [1usize, 2, 4] {
            for queue in [QueueKind::Heap, QueueKind::Wheel] {
                let mut cfg = base_cfg();
                cfg.shards = shards;
                cfg.queue = queue;
                let report =
                    ShardedControlPlane::new(cat.clone(), cfg, stub_predictor())
                        .unwrap()
                        .run_workload(&wl)
                        .unwrap();
                match &reference {
                    None => {
                        assert!(
                            report.requests_served > 0,
                            "{}: the scenario must route traffic",
                            family.name()
                        );
                        reference = Some(report);
                    }
                    Some(r) => assert_eq!(
                        *r,
                        report,
                        "{}: {shards} shards / {queue:?} must be byte-identical",
                        family.name()
                    ),
                }
            }
        }
    }
}

/// A workload hot enough that the per-function synthesis cap engages on
/// every function.
fn flood(n_functions: usize) -> Workload {
    // 450k rps × 10 s ≈ 4.5M draws per function against the ~4.2M cap
    let events = (0..n_functions)
        .map(|f| LoadEvent { at_ms: 0.0, function: f, rps: 450_000.0 })
        .collect();
    Workload { name: "flood".into(), n_functions, events, duration_ms: 10_000.0 }
}

/// Satellite contract: per-cell dropped counts sum exactly to the
/// unsharded count under any partition layout, as long as every cell
/// uses the same arrival seed — synthesis is per-function-seeded, so
/// `restrict` keeps each function's stream (and its dropped tail)
/// bit-identical.
#[test]
fn restricted_synthesis_partitions_dropped_counts_exactly() {
    let wl = flood(2);
    let seed = 3;
    let (all, dropped_all) = wl.synthesize_arrivals_counted(seed);
    assert_eq!(all.len(), 2 * MAX_ARRIVALS_PER_FUNCTION, "cap must engage on both");
    assert!(dropped_all > 0);
    for cells in [1usize, 2] {
        let mut kept = 0usize;
        let mut dropped = 0u64;
        for c in 0..cells {
            let (a, d) = wl
                .restrict(|f| f % cells == c)
                .synthesize_arrivals_counted(seed);
            kept += a.len();
            dropped += d;
        }
        assert_eq!(kept, all.len(), "{cells} cells: kept arrivals partition");
        assert_eq!(dropped, dropped_all, "{cells} cells: dropped counts partition");
    }
}

/// The piece that makes the partition exact in the sharded control
/// plane: every cell's config pins the *same* effective arrival seed,
/// whether derived from the run seed or set explicitly.
#[test]
fn cell_configs_pin_one_arrival_seed_for_every_cell() {
    let cat = catalog();
    for explicit in [None, Some(99u64)] {
        let mut cfg = base_cfg();
        cfg.shards = 2;
        cfg.arrival_seed = explicit;
        let expected = effective_arrival_seed(&cfg);
        let scp = ShardedControlPlane::new(cat.clone(), cfg, stub_predictor()).unwrap();
        for c in 0..scp.layout().partitions() {
            let cell = scp.cell_config(c);
            assert_eq!(
                cell.arrival_seed,
                Some(expected),
                "cell {c} (explicit {explicit:?}) must thin the shared stream"
            );
            assert_eq!(effective_arrival_seed(&cell), expected);
        }
    }
}

//! Property tests over the per-request router (hand-rolled generators
//! over the crate's seeded RNG — no proptest offline; every failure
//! reports its seed):
//!
//! * the router never dispatches a request to a node without a serving
//!   instance of the function (and never to a non-saturated instance),
//! * in-flight accounting never goes negative or drifts: per-node gauges
//!   always equal the per-instance sums and the test's own outstanding
//!   count, under adversarial completions included,
//! * two replica `ControlPlane`s fed the same event stream make
//!   byte-identical routing decisions.
//!
//! Registered in `Cargo.toml` as a `[[test]]` target — `autotests =
//! false`, so an unregistered file would silently never run (and `make
//! test` now fails on exactly that).

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::cluster::{Cluster, InstanceId, InstanceState};
use jiagu::config::RunConfig;
use jiagu::controlplane::ControlPlane;
use jiagu::router::{Dispatch, RouteOutcome, Router};
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::traces::{PoissonParams, Workload};
use jiagu::util::rng::Rng;
use std::sync::Arc;

fn catalog(seed: u64) -> Catalog {
    Catalog::from_functions(make_catalog(6, seed))
}

fn stub_predictor() -> Arc<dyn Predictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        0.05,
        0.05,
    )))
}

/// Random place/release/reactivate/route/complete sequences against a
/// live cluster: every dispatch must land on a saturated instance of the
/// requested function, and the router's in-flight accounting must match
/// a shadow count exactly (never negative, never drifting).
#[test]
fn random_sequences_route_only_to_serving_instances() {
    for seed in 0..8u64 {
        let cat = catalog(seed);
        let mut rng = Rng::seed_from(seed ^ 0x70e7);
        let mut cluster = Cluster::new(4);
        let mut router = Router::with_seed(seed);
        // instances whose head-of-line request is in service right now
        let mut in_service: Vec<InstanceId> = Vec::new();
        let mut outstanding: i64 = 0;
        for step in 0..600usize {
            let now = step as f64 * 10.0;
            let f = rng.below(cat.len() as u64) as usize;
            match rng.below(10) {
                // grow: place + ready + join routing set
                0 | 1 => {
                    let node = rng.below(cluster.n_nodes() as u64) as usize;
                    let id = cluster.place(&cat, f, node, now);
                    cluster.mark_ready(id, now);
                    router.add(f, id, node);
                }
                // shrink: release one serving instance, re-dispatch its
                // orphaned queue
                2 => {
                    let serving = router.serving(f).to_vec();
                    if let Some(id) = serving.first().copied() {
                        let orphaned = router.remove(f, id);
                        cluster.release(id, now);
                        outstanding -= orphaned.len() as i64;
                        for arrival in orphaned {
                            match router.route(f, arrival) {
                                RouteOutcome::ColdWait => {}
                                RouteOutcome::Started { instance, .. } => {
                                    outstanding += 1;
                                    in_service.push(instance);
                                }
                                RouteOutcome::Queued { .. } => outstanding += 1,
                            }
                        }
                    }
                }
                // logical cold start: cached instance rejoins
                3 => {
                    if let Some(id) = cluster.cached_of(f).first().copied() {
                        let node = cluster.instance(id).unwrap().node;
                        cluster.reactivate(id, now);
                        router.add(f, id, node);
                    }
                }
                // complete the in-service request on some busy instance
                4 | 5 => {
                    if !in_service.is_empty() {
                        let idx = rng.below(in_service.len() as u64) as usize;
                        let id = in_service.swap_remove(idx);
                        outstanding -= 1;
                        if router.complete(id).is_some() {
                            in_service.push(id); // queue head enters service
                            outstanding += 1;
                        }
                    }
                }
                // route one request
                _ => match router.route(f, now) {
                    RouteOutcome::Started { instance, node } => {
                        outstanding += 1;
                        in_service.push(instance);
                        let inst = cluster.instance(instance).unwrap_or_else(|| {
                            panic!("seed {seed} step {step}: routed to unknown instance")
                        });
                        assert_eq!(inst.function, f, "seed {seed} step {step}");
                        assert_eq!(inst.state, InstanceState::Saturated, "seed {seed}");
                        assert_eq!(inst.node, node, "seed {seed} step {step}");
                        assert!(
                            !cluster.find_instances(node, f, InstanceState::Saturated).is_empty(),
                            "seed {seed} step {step}: node {node} serves nothing of fn {f}"
                        );
                    }
                    RouteOutcome::Queued { instance, node } => {
                        outstanding += 1;
                        let inst = cluster.instance(instance).unwrap();
                        assert_eq!(inst.function, f, "seed {seed} step {step}");
                        assert_eq!(inst.state, InstanceState::Saturated, "seed {seed}");
                        assert!(
                            !cluster.find_instances(node, f, InstanceState::Saturated).is_empty(),
                            "seed {seed} step {step}: node {node} serves nothing of fn {f}"
                        );
                    }
                    RouteOutcome::ColdWait => {
                        assert_eq!(
                            router.serving_count(f),
                            0,
                            "seed {seed} step {step}: cold-wait despite serving instances"
                        );
                    }
                },
            }
            assert!(outstanding >= 0, "seed {seed} step {step}: negative outstanding");
            assert_eq!(
                router.total_in_flight() as i64, outstanding,
                "seed {seed} step {step}: in-flight gauges drifted"
            );
            router.check_consistent(&cluster).unwrap_or_else(|e| {
                panic!("seed {seed} step {step}: {e}");
            });
            cluster.check_invariants().unwrap();
        }
        // healthy storms never need the saturating-repair path: nonzero
        // repairs would mean `remove`/`dec_node` under-accounted somewhere
        assert_eq!(router.gauge_skew_repairs(), 0, "seed {seed}: gauges skewed");
    }
}

/// Adversarial completion storms (unknown ids, double completes, idle
/// instances) must never underflow any gauge.
#[test]
fn in_flight_gauges_survive_adversarial_completions() {
    let mut router = Router::with_seed(3);
    router.add(0, 1, 0);
    assert!(router.complete(1).is_none(), "idle instance: nothing to complete");
    assert!(router.complete(999).is_none(), "unknown instance is a no-op");
    assert_eq!(router.node_in_flight(0), 0);
    let RouteOutcome::Started { instance, .. } = router.route(0, 1.0) else {
        panic!("single idle instance must start service");
    };
    assert_eq!(instance, 1);
    assert!(router.complete(1).is_none());
    for _ in 0..5 {
        assert!(router.complete(1).is_none(), "double completes stay no-ops");
    }
    assert_eq!(router.total_in_flight(), 0);
    assert_eq!(router.node_in_flight(0), 0);
    assert_eq!(router.peak_node_in_flight(), 1, "peak is a high-water mark");
    // none of the no-op completes above is allowed to reach the
    // saturating-repair fallback — that path is for skewed gauges only
    assert_eq!(router.gauge_skew_repairs(), 0, "no-op completes never repair");
}

/// The typed [`Dispatch`] verdict from `pick` must classify the picked
/// instance's load exactly: `Routed` iff its service slot is free,
/// `Saturated` iff a request is in flight on it, `ColdQueued` iff the
/// function has no serving instance at all — and `pick` itself must
/// never move a gauge (it is the read-only half of `route`).
#[test]
fn pick_verdicts_classify_instance_load_exactly() {
    let mut saw = [false; 3]; // Routed, Saturated, ColdQueued
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from(seed ^ 0xd15b);
        let mut router = Router::with_seed(seed);
        let n_fns = 4u64;
        let mut next_id: InstanceId = 0;
        let mut in_service: Vec<InstanceId> = Vec::new();
        for step in 0..600usize {
            let f = rng.below(n_fns) as usize;
            match rng.below(8) {
                // grow the routing set
                0 | 1 => {
                    next_id += 1;
                    router.add(f, next_id, rng.below(3) as usize);
                }
                // finish one in-service request
                2 => {
                    if !in_service.is_empty() {
                        let idx = rng.below(in_service.len() as u64) as usize;
                        let id = in_service.swap_remove(idx);
                        if router.complete(id).is_some() {
                            in_service.push(id); // queue head enters service
                        }
                    }
                }
                // drive load through the full route path
                3 | 4 | 5 => {
                    if let RouteOutcome::Started { instance, .. } = router.route(f, step as f64) {
                        in_service.push(instance);
                    }
                }
                // oracle step: pick and classify
                _ => {
                    let serving = router.serving(f).to_vec();
                    let gauges: Vec<u32> =
                        serving.iter().map(|&i| router.in_flight_of(i)).collect();
                    let verdict = router.pick(f);
                    match verdict {
                        Dispatch::ColdQueued => {
                            assert!(
                                serving.is_empty(),
                                "seed {seed} step {step}: ColdQueued despite serving instances"
                            );
                            assert_eq!(verdict.instance(), None);
                            saw[2] = true;
                        }
                        Dispatch::Routed(id) => {
                            assert!(serving.contains(&id), "seed {seed} step {step}");
                            assert_eq!(
                                router.in_flight_of(id),
                                0,
                                "seed {seed} step {step}: Routed onto a busy instance"
                            );
                            assert_eq!(verdict.instance(), Some(id));
                            saw[0] = true;
                        }
                        Dispatch::Saturated(id) => {
                            assert!(serving.contains(&id), "seed {seed} step {step}");
                            assert!(
                                router.in_flight_of(id) > 0,
                                "seed {seed} step {step}: Saturated verdict on an idle instance"
                            );
                            assert_eq!(verdict.instance(), Some(id));
                            saw[1] = true;
                        }
                    }
                    // pick never touches queueing state
                    assert_eq!(router.serving(f), &serving[..], "seed {seed} step {step}");
                    let after: Vec<u32> =
                        serving.iter().map(|&i| router.in_flight_of(i)).collect();
                    assert_eq!(gauges, after, "seed {seed} step {step}: pick moved a gauge");
                }
            }
        }
    }
    assert!(saw.iter().all(|&s| s), "a Dispatch variant was never exercised");
}

/// Two replica control planes fed the same workload + arrival stream pop
/// the same events and make byte-identical routing decisions — the
/// precondition for sharded/replicated control planes (ROADMAP).
#[test]
fn control_plane_replicas_make_byte_identical_routing_decisions() {
    for seed in [7u64, 19] {
        let cat = catalog(1);
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 4;
        cfg.seed = seed;
        cfg.duration_s = 8;
        cfg.eval_interval_ms = 500.0;
        let params = PoissonParams { duration_s: 8, ..Default::default() };
        let workload = Workload::poisson(&cat, &params, seed);
        let arrivals = workload.synthesize_arrivals(seed ^ 0xa441);
        assert!(!arrivals.is_empty());

        let mut planes: Vec<ControlPlane> = (0..2)
            .map(|_| {
                let mut cp = ControlPlane::new(cat.clone(), cfg.clone(), stub_predictor());
                cp.inject_workload(&workload);
                cp.inject_arrivals(&arrivals);
                cp
            })
            .collect();

        let mut total_requests = 0usize;
        for chunk in 1..=4u32 {
            let until = chunk as f64 * 2000.0;
            let a = planes[0].run_until(until).unwrap();
            let b = planes[1].run_until(until).unwrap();
            assert_eq!(a.requests, b.requests, "seed {seed}: routing decisions diverged");
            assert_eq!(a.cold_waits, b.cold_waits, "seed {seed}");
            assert_eq!(a.in_flight, b.in_flight, "seed {seed}");
            assert_eq!(a.peak_node_in_flight, b.peak_node_in_flight, "seed {seed}");
            assert_eq!(a.events_processed, b.events_processed, "seed {seed}");
            total_requests += a.requests.len();
            for f in 0..cat.len() {
                assert_eq!(
                    planes[0].router().serving(f),
                    planes[1].router().serving(f),
                    "seed {seed}: serving sets diverged for fn {f}"
                );
            }
            for cp in &planes {
                cp.router().check_consistent(cp.cluster()).unwrap();
            }
        }
        assert!(total_requests > 0, "seed {seed}: the scenario must route requests");
    }
}

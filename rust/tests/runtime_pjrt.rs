//! Runtime integration: the PJRT path (AOT HLO through the CPU client)
//! must reproduce the Python reference predictions and agree with the
//! pure-Rust forest traversal.
//!
//! Compile-gated on the `pjrt` feature: the default offline build has no
//! `xla` crate, so this whole target reduces to an empty test binary
//! unless `cargo test --features pjrt` is requested (which additionally
//! needs the `artifacts-jax` HLO outputs — the runtime checks below still
//! skip loudly when those are missing).

#![cfg(feature = "pjrt")]

use jiagu::runtime::{ForestParams, NativeForest, PjrtPredictor, Predictor};
use jiagu::util::json::Json;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = jiagu::artifacts_dir();
    if dir.join("meta.json").exists() && dir.join("model_b1.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn check_rows(dir: &std::path::Path) -> (Vec<Vec<f32>>, Vec<f32>) {
    let j = Json::parse_file(&dir.join("predict_check.json")).unwrap();
    let x: Vec<Vec<f32>> = j
        .get("x")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.f32_vec().unwrap())
        .collect();
    let want = j.get("expected_ms").unwrap().f32_vec().unwrap();
    (x, want)
}

#[test]
fn pjrt_matches_python_reference() {
    let Some(dir) = artifacts() else { return };
    let (x, want) = check_rows(&dir);
    let pred = PjrtPredictor::load(&dir).unwrap();
    let got = pred.predict(&x).unwrap();
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        let rel = (g - w).abs() / w.abs().max(1e-6);
        assert!(rel < 1e-4, "PJRT {g} vs python {w}");
    }
}

#[test]
fn native_forest_matches_pjrt() {
    let Some(dir) = artifacts() else { return };
    let (x, _) = check_rows(&dir);
    let pjrt = PjrtPredictor::load(&dir).unwrap();
    let native = NativeForest::new(ForestParams::load(&dir.join("forest.json")).unwrap());
    let a = pjrt.predict(&x).unwrap();
    let b = native.predict(&x);
    for (g, w) in a.iter().zip(&b) {
        let rel = (g - w).abs() / w.abs().max(1e-6);
        assert!(rel < 1e-4, "pjrt {g} vs native {w}");
    }
}

#[test]
fn batching_pads_and_chunks_correctly() {
    let Some(dir) = artifacts() else { return };
    let (x, _) = check_rows(&dir);
    let pred = PjrtPredictor::load(&dir).unwrap();
    // single-row calls == batched call, row by row
    let batched = pred.predict(&x).unwrap();
    for (i, row) in x.iter().take(5).enumerate() {
        let single = pred.predict(std::slice::from_ref(row)).unwrap();
        let rel = (single[0] - batched[i]).abs() / batched[i].abs().max(1e-6);
        assert!(rel < 1e-5, "row {i}: {} vs {}", single[0], batched[i]);
    }
    // oversized batch (> largest variant) must chunk transparently
    let mut big = Vec::new();
    while big.len() < 300 {
        big.extend(x.iter().cloned());
    }
    big.truncate(300);
    let out = pred.predict(&big).unwrap();
    assert_eq!(out.len(), 300);
    for i in 0..x.len().min(300) {
        let rel = (out[i] - batched[i]).abs() / batched[i].abs().max(1e-6);
        assert!(rel < 1e-5);
    }
}

#[test]
fn inference_stats_accumulate() {
    let Some(dir) = artifacts() else { return };
    let (x, _) = check_rows(&dir);
    let pred = PjrtPredictor::load(&dir).unwrap();
    pred.predict(&x[..3]).unwrap();
    pred.predict(&x[..1]).unwrap();
    let (calls, rows, nanos) = pred.stats().snapshot();
    assert_eq!(calls, 2);
    assert_eq!(rows, 4);
    assert!(nanos > 0);
}

#[test]
fn forest_swap_changes_predictions() {
    let Some(dir) = artifacts() else { return };
    let (x, _) = check_rows(&dir);
    let mut pred = PjrtPredictor::load(&dir).unwrap();
    let before = pred.predict(&x[..2]).unwrap();
    // retrained stand-in: same shapes, all leaves shifted by +ln(2)
    let mut params = ForestParams::load(&dir.join("forest.json")).unwrap();
    for row in &mut params.leaf {
        for v in row {
            *v += std::f32::consts::LN_2;
        }
    }
    pred.swap_forest(params).unwrap();
    let after = pred.predict(&x[..2]).unwrap();
    for (b, a) in before.iter().zip(&after) {
        let ratio = a / b;
        assert!((ratio - 2.0).abs() < 1e-3, "leaf shift must double output: {ratio}");
    }
}

//! Property tests over the shard merge algebra (hand-rolled generators
//! over the crate's seeded RNG — no proptest offline; every failure
//! reports its seed):
//!
//! * `LatencyHistogram::merge` is exactly associative *and* commutative,
//! * `RunReport::merge` is exactly associative over reports with
//!   disjoint function ownership (the shape real partitions have),
//! * merging in a permuted order leaves every aggregate unchanged —
//!   "order-insensitive up to the pinned merge order": only the raw
//!   sample vectors remember the order, and everything derived from
//!   them sorts or sums order-independently,
//! * end-to-end: the shard count never changes any aggregate — the
//!   merged report of a partitioned Poisson run is bit-identical for
//!   every worker-thread count, and the 1-partition layout reproduces
//!   the plain unsharded simulation exactly.
//!
//! Registered in `Cargo.toml` as a `[[test]]` target (`autotests =
//! false`; `make check-test-targets` fails on unregistered files).

use jiagu::artifacts::make_catalog;
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::controlplane::shard::ShardedControlPlane;
use jiagu::metrics::{LatencyHistogram, Samples};
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::sim::{RunReport, Simulation};
use jiagu::traces::{PoissonParams, Workload};
use jiagu::util::rng::Rng;
use std::sync::Arc;

fn stub_predictor() -> Arc<dyn Predictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        0.05,
        0.05,
    )))
}

fn random_hist(rng: &mut Rng) -> LatencyHistogram {
    let mut h = LatencyHistogram::new(8.0, 16);
    for _ in 0..rng.range_u64(0, 64) {
        // spread across bins, overflow and the degenerate path
        let v = match rng.below(8) {
            0 => -1.0,
            1 => 10_000.0,
            _ => rng.range_f64(0.0, 160.0),
        };
        h.record(v);
    }
    h
}

fn merged(a: &LatencyHistogram, b: &LatencyHistogram) -> LatencyHistogram {
    let mut m = a.clone();
    m.merge(b).unwrap();
    m
}

#[test]
fn histogram_merge_is_associative_and_commutative() {
    for seed in 0..32u64 {
        let mut rng = Rng::seed_from(seed ^ 0x4157);
        let (a, b, c) = (random_hist(&mut rng), random_hist(&mut rng), random_hist(&mut rng));
        let left = merged(&merged(&a, &b), &c);
        let right = merged(&a, &merged(&b, &c));
        assert_eq!(left, right, "associativity, seed {seed}");
        assert_eq!(merged(&a, &b), merged(&b, &a), "commutativity, seed {seed}");
        assert_eq!(left.count(), a.count() + b.count() + c.count());
        assert_eq!(
            left.to_json().to_string(),
            right.to_json().to_string(),
            "serialised bytes must agree too, seed {seed}"
        );
    }
}

const N_FUNCTIONS: usize = 6;

/// A synthetic partition report: `cell` (of `cells`) owns functions
/// `f % cells == cell`, so per-function tables are disjoint across the
/// operands — the shape `ShardedControlPlane` merges.  Samples use
/// dyadic values (k/64) so sums are exact under any regrouping, exactly
/// like the integral instance/node-second sums of real runs.  Derived
/// fields are left zeroed: `merge` recomputes them from the sufficient
/// statistics, which is itself part of what these tests pin.
fn synthetic_report(rng: &mut Rng, cell: usize, cells: usize) -> RunReport {
    let dyadic = |rng: &mut Rng| rng.range_u64(0, 1 << 12) as f64 / 64.0;
    let mut scheduling_samples = Samples::default();
    let mut cold_start_samples = Samples::default();
    for _ in 0..rng.range_u64(1, 12) {
        scheduling_samples.push(dyadic(rng));
    }
    for _ in 0..rng.range_u64(0, 8) {
        cold_start_samples.push(dyadic(rng));
    }
    let mut latency_hist = LatencyHistogram::default();
    let mut request_counts = vec![0u64; N_FUNCTIONS];
    let mut request_qos_violations = vec![0u64; N_FUNCTIONS];
    let mut qos_violating = vec![0.0; N_FUNCTIONS];
    let mut qos_totals = vec![0.0; N_FUNCTIONS];
    for f in 0..N_FUNCTIONS {
        if f % cells != cell {
            continue; // foreign function: this partition never saw it
        }
        let served = rng.range_u64(0, 40);
        for _ in 0..served {
            latency_hist.record(rng.range_f64(0.0, 900.0));
        }
        request_counts[f] = served;
        request_qos_violations[f] = rng.range_u64(0, served);
        qos_totals[f] = rng.range_u64(0, 500) as f64;
        qos_violating[f] = (qos_totals[f] * rng.f64()).floor();
    }
    let isolated_functions =
        (cell..N_FUNCTIONS).step_by(cells).filter(|_| rng.below(3) == 0).collect();
    RunReport {
        scheduler: "jiagu".into(),
        trace: "synthetic".into(),
        duration_s: 60,
        cells: 1,
        owned_functions: (cell..N_FUNCTIONS).step_by(cells).collect(),
        events_processed: rng.range_u64(0, 10_000),
        density: 0.0,
        qos_violation_rate: 0.0,
        per_function_violation: Vec::new(),
        scheduling_ms_mean: 0.0,
        scheduling_ms_p99: 0.0,
        cold_start_ms_mean: 0.0,
        cold_start_ms_p99: 0.0,
        inferences_per_schedule: 0.0,
        critical_inferences: rng.range_u64(0, 100),
        async_inferences: rng.range_u64(0, 100),
        memo_hits: rng.range_u64(0, 100),
        memo_misses: rng.range_u64(0, 100),
        schedule_calls: rng.range_u64(1, 50),
        instances_started: rng.range_u64(0, 50),
        fast_decisions: rng.range_u64(0, 40),
        slow_decisions: rng.range_u64(0, 10),
        logical_cold_starts: rng.range_u64(0, 20),
        real_after_release: rng.range_u64(0, 20),
        migrations: rng.range_u64(0, 5),
        released: rng.range_u64(0, 20),
        evicted: rng.range_u64(0, 5),
        peak_nodes: rng.range_u64(1, 8) as usize,
        async_nanos: rng.range_u64(0, 1 << 30),
        isolated_functions,
        requests_served: latency_hist.count(),
        request_p50_ms: 0.0,
        request_p95_ms: 0.0,
        request_p99_ms: 0.0,
        request_counts,
        request_qos_violations,
        cold_wait_requests: rng.range_u64(0, 30),
        stranded_requests: rng.range_u64(0, 10),
        arrivals_dropped: rng.range_u64(0, 4),
        peak_node_in_flight: rng.range_u64(0, 64) as u32,
        peak_in_flight: rng.range_u64(0, 128) as u32,
        latency_hist,
        qos_violating,
        qos_totals,
        instance_seconds: rng.range_u64(0, 5_000) as f64,
        node_seconds: rng.range_u64(1, 500) as f64,
        scheduling_samples,
        cold_start_samples,
    }
}

fn fold(reports: &[&RunReport]) -> RunReport {
    let mut out = reports[0].clone();
    for r in &reports[1..] {
        out.merge(r).unwrap();
    }
    out
}

#[test]
fn report_merge_is_associative_over_disjoint_partitions() {
    for seed in 0..24u64 {
        let mut rng = Rng::seed_from(seed ^ 0x5a5d);
        let a = synthetic_report(&mut rng, 0, 3);
        let b = synthetic_report(&mut rng, 1, 3);
        let c = synthetic_report(&mut rng, 2, 3);
        let left = fold(&[&fold(&[&a, &b]), &c]);
        let right = fold(&[&a, &fold(&[&b, &c])]);
        assert_eq!(left, right, "associativity (full PartialEq surface), seed {seed}");
        // merged sufficient statistics really accumulated
        assert_eq!(
            left.requests_served,
            a.requests_served + b.requests_served + c.requests_served
        );
        assert_eq!(
            left.scheduling_samples.len(),
            a.scheduling_samples.len() + b.scheduling_samples.len() + c.scheduling_samples.len()
        );
    }
}

#[test]
fn report_merge_aggregates_are_order_insensitive() {
    for seed in 0..24u64 {
        let mut rng = Rng::seed_from(seed ^ 0x0bd2);
        let a = synthetic_report(&mut rng, 0, 3);
        let b = synthetic_report(&mut rng, 1, 3);
        let c = synthetic_report(&mut rng, 2, 3);
        let pinned = fold(&[&a, &b, &c]);
        for permuted in [fold(&[&c, &a, &b]), fold(&[&b, &c, &a]), fold(&[&c, &b, &a])] {
            // only the raw sample vectors remember the merge order; every
            // aggregate — counters, tables, histogram, ratios, means and
            // percentiles — must be bit-equal under permutation
            assert_eq!(pinned.events_processed, permuted.events_processed);
            assert_eq!(pinned.density, permuted.density, "seed {seed}");
            assert_eq!(pinned.qos_violation_rate, permuted.qos_violation_rate);
            assert_eq!(pinned.per_function_violation, permuted.per_function_violation);
            assert_eq!(pinned.scheduling_ms_mean, permuted.scheduling_ms_mean);
            assert_eq!(pinned.scheduling_ms_p99, permuted.scheduling_ms_p99);
            assert_eq!(pinned.cold_start_ms_mean, permuted.cold_start_ms_mean);
            assert_eq!(pinned.cold_start_ms_p99, permuted.cold_start_ms_p99);
            assert_eq!(pinned.inferences_per_schedule, permuted.inferences_per_schedule);
            assert_eq!(pinned.latency_hist, permuted.latency_hist);
            assert_eq!(pinned.request_counts, permuted.request_counts);
            assert_eq!(pinned.request_qos_violations, permuted.request_qos_violations);
            assert_eq!(pinned.request_p50_ms, permuted.request_p50_ms);
            assert_eq!(pinned.request_p95_ms, permuted.request_p95_ms);
            assert_eq!(pinned.request_p99_ms, permuted.request_p99_ms);
            assert_eq!(pinned.isolated_functions, permuted.isolated_functions);
            assert_eq!(pinned.peak_nodes, permuted.peak_nodes);
            assert_eq!(pinned.peak_node_in_flight, permuted.peak_node_in_flight);
            assert_eq!(pinned.peak_in_flight, permuted.peak_in_flight);
            assert_eq!(pinned.requests_served, permuted.requests_served);
            assert_eq!(pinned.stranded_requests, permuted.stranded_requests);
            assert_eq!(pinned.cold_wait_requests, permuted.cold_wait_requests);
            assert_eq!(pinned.arrivals_dropped, permuted.arrivals_dropped);
        }
    }
}

#[test]
fn incompatible_reports_are_rejected() {
    let mut rng = Rng::seed_from(7);
    let base = synthetic_report(&mut rng, 0, 2);
    let other = synthetic_report(&mut rng, 1, 2);

    let mut wrong_trace = base.clone();
    let mut o = other.clone();
    o.trace = "different".into();
    assert!(wrong_trace.merge(&o).is_err(), "trace mismatch must fail");

    let mut wrong_sched = base.clone();
    let mut o = other.clone();
    o.scheduler = "k8s".into();
    assert!(wrong_sched.merge(&o).is_err(), "scheduler mismatch must fail");

    let mut wrong_horizon = base.clone();
    let mut o = other.clone();
    o.duration_s = 61;
    assert!(wrong_horizon.merge(&o).is_err(), "horizon mismatch must fail");

    let mut wrong_catalog = base.clone();
    let mut o = other.clone();
    o.qos_totals.pop();
    assert!(wrong_catalog.merge(&o).is_err(), "catalog-size mismatch must fail");

    let mut wrong_bins = base.clone();
    let mut o = other.clone();
    o.latency_hist = LatencyHistogram::new(1.0, 4);
    assert!(wrong_bins.merge(&o).is_err(), "histogram-binning mismatch must fail");

    // global-id remapping bug: both operands claim ownership of the same
    // function — the merge must refuse before touching any aggregate
    let mut overlapping = base.clone();
    let o = base.clone();
    let snapshot = overlapping.clone();
    assert!(overlapping.merge(&o).is_err(), "overlapping ownership must fail");
    assert_eq!(overlapping, snapshot, "a rejected merge must leave self unchanged");
}

/// The end-to-end invariant the CI matrix pins through the CLI: for a
/// fixed partition layout, the worker-thread count never moves a single
/// bit of the merged report.
#[test]
fn shard_count_never_changes_any_aggregate_end_to_end() {
    let cat = Catalog::from_functions(make_catalog(8, 0x5ca1e));
    let wl = Workload::poisson(&cat, &PoissonParams { duration_s: 10, ..Default::default() }, 61);
    let run = |shards: usize, partitions: usize| {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 8;
        cfg.duration_s = 10;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.seed = 99;
        cfg.shards = shards;
        cfg.partitions = partitions;
        ShardedControlPlane::new(cat.clone(), cfg, stub_predictor())
            .unwrap()
            .run_workload(&wl)
            .unwrap()
    };
    let reference = run(1, 4);
    assert!(reference.requests_served > 0, "the scenario must route traffic");
    assert!(reference.events_processed > 0);
    for shards in [2, 4, 8] {
        // shards beyond the partition count clamp to it — still identical
        assert_eq!(reference, run(shards, 4), "shards = {shards}");
    }
    // a different *layout* is a different system: partitions move bits
    assert_ne!(reference, run(1, 2), "partition count is part of the semantics");
}

#[test]
fn single_partition_layout_reproduces_the_unsharded_plane() {
    let cat = Catalog::from_functions(make_catalog(6, 0xfeed));
    let wl = Workload::poisson(&cat, &PoissonParams { duration_s: 8, ..Default::default() }, 17);
    let mut cfg = RunConfig::jiagu_45();
    cfg.n_nodes = 6;
    cfg.duration_s = 8;
    cfg.requests = true;
    cfg.seed = 5;
    cfg.partitions = 1;
    cfg.shards = 1;
    let sharded = ShardedControlPlane::new(cat.clone(), cfg.clone(), stub_predictor())
        .unwrap()
        .run_workload(&wl)
        .unwrap();
    let plain = Simulation::new(cat, cfg, stub_predictor()).run_workload(&wl).unwrap();
    assert_eq!(sharded, plain, "P = 1 must be the identity embedding");
}

//! End-to-end simulation smoke tests over the full stack with the native
//! predictor (artifact-gated; PJRT covered in runtime_pjrt.rs and the
//! serve_trace example).

use jiagu::catalog::Catalog;
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::sim::{load_predictor, Simulation};
use jiagu::traces;

fn setup() -> Option<(Catalog, std::path::PathBuf)> {
    let dir = jiagu::artifacts_dir();
    if !dir.join("functions.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some((Catalog::load(&dir.join("functions.json")).unwrap(), dir))
}

#[test]
fn jiagu_run_holds_qos_and_beats_k8s_density() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 420).swap_remove(0);

    let mut k8s_cfg = RunConfig::with_scheduler(SchedulerKind::Kubernetes);
    k8s_cfg.duration_s = 420;
    let k8s = Simulation::new(cat.clone(), k8s_cfg, predictor.clone())
        .run(&trace)
        .unwrap();

    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 420;
    let jiagu = Simulation::new(cat.clone(), cfg, predictor).run(&trace).unwrap();

    assert!(jiagu.qos_violation_rate < 0.10, "QoS {:.3}", jiagu.qos_violation_rate);
    assert!(
        jiagu.density > k8s.density,
        "jiagu density {:.2} must beat k8s {:.2}",
        jiagu.density,
        k8s.density
    );
    assert!(jiagu.fast_decisions > 0, "fast path must be exercised");
    assert!(jiagu.instances_started > 0);
}

#[test]
fn fast_path_dominates_on_realworld_trace() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    // the >80% fast-path claim is about steady state — the horizon must
    // amortise the one-time (function, node) table warm-up
    let trace = traces::paper_traces(&cat, 1000).swap_remove(1);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 1000;
    let r = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    let fast_rate =
        r.fast_decisions as f64 / (r.fast_decisions + r.slow_decisions).max(1) as f64;
    // paper: >80% of scheduling goes through the fast path
    assert!(fast_rate > 0.8, "fast-path rate {fast_rate:.2}");
    // fast path means far fewer critical inferences than schedule calls
    assert!(r.inferences_per_schedule < 1.0, "{}", r.inferences_per_schedule);
}

#[test]
fn gsight_pays_inference_every_schedule() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 300).swap_remove(0);
    let mut cfg = RunConfig::with_scheduler(SchedulerKind::Gsight);
    cfg.duration_s = 300;
    let r = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    assert!(r.inferences_per_schedule >= 1.0, "{}", r.inferences_per_schedule);
    assert_eq!(r.fast_decisions, 0);
}

#[test]
fn worstcase_trace_forces_slow_path() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::worstcase_trace(&cat, 420, 90, 15);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 420;
    let r = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    let slow_rate =
        r.slow_decisions as f64 / (r.fast_decisions + r.slow_decisions).max(1) as f64;
    assert!(
        slow_rate > 0.5,
        "worst case should mostly hit the slow path: {slow_rate:.2} ({} fast / {} slow)",
        r.fast_decisions,
        r.slow_decisions
    );
}

#[test]
fn dual_staged_produces_logical_cold_starts_on_fluctuating_load() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 600).swap_remove(2);
    let mut cfg = RunConfig::jiagu_30(); // most sensitive variant
    cfg.duration_s = 600;
    let r = Simulation::new(cat.clone(), cfg, predictor.clone()).run(&trace).unwrap();
    assert!(r.released > 0, "release stage must fire");
    assert!(r.logical_cold_starts > 0, "logical cold starts must fire");

    // NoDS on the same trace: no releases, no logical cold starts
    let mut nods = RunConfig::jiagu_nods();
    nods.duration_s = 600;
    let r2 = Simulation::new(cat, nods, predictor).run(&trace).unwrap();
    assert_eq!(r2.released, 0);
    assert_eq!(r2.logical_cold_starts, 0);
}

#[test]
fn runs_are_deterministic_given_seed_modulo_timing() {
    // Plan/commit + the virtual-time deferred queue make determinism
    // provable: decision *timing* is wall-clock and varies, but every
    // counter in the report must replay bit-identically (deferred
    // refreshes land one whole tick after submission regardless of the
    // measured nanos, see controlplane::MAX_ASYNC_COMPLETION_MS).
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 240).swap_remove(3);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 240;
    let a = Simulation::new(cat.clone(), cfg.clone(), predictor.clone())
        .run(&trace)
        .unwrap();
    let b = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    assert_eq!(a.instances_started, b.instances_started);
    assert_eq!(a.schedule_calls, b.schedule_calls);
    assert_eq!(a.fast_decisions, b.fast_decisions);
    assert_eq!(a.slow_decisions, b.slow_decisions);
    assert_eq!(a.critical_inferences, b.critical_inferences);
    assert_eq!(a.async_inferences, b.async_inferences);
    assert_eq!(a.logical_cold_starts, b.logical_cold_starts);
    assert_eq!(a.real_after_release, b.real_after_release);
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.released, b.released);
    assert_eq!(a.evicted, b.evicted);
    assert_eq!(a.peak_nodes, b.peak_nodes);
    assert_eq!(a.isolated_functions, b.isolated_functions);
    assert!((a.density - b.density).abs() < 1e-12);
    assert!((a.qos_violation_rate - b.qos_violation_rate).abs() < 1e-12);
    for (x, y) in a.per_function_violation.iter().zip(&b.per_function_violation) {
        assert!((x - y).abs() < 1e-12);
    }
}

#[test]
fn unpredictability_fallback_isolates_function() {
    // Force the fallback through the typed feedback API and verify the
    // scheduler keeps the flagged function on dedicated nodes at the
    // request-packing limit.
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let mut cluster = jiagu::cluster::Cluster::new(4);
    let mut sched = jiagu::scheduler::JiaguScheduler::new(
        predictor,
        jiagu::capacity::CapacityConfig::default(),
        4,
    );
    use jiagu::scheduler::{Scheduler, SchedulerFeedback};
    // colocate some normal functions first
    let _ = sched.schedule(&cat, &cluster, 1, 3, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
    let _ = sched.schedule(&cat, &cluster, 2, 3, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
    // flag function 0 as unpredictable via control-plane feedback
    sched.apply_feedback(SchedulerFeedback::Unpredictability { function: 0, isolated: true });
    assert!(sched.is_isolated(0));
    let plan = sched.schedule(&cat, &cluster, 0, 20, 1.0).unwrap();
    assert_eq!(plan.critical_inferences, 0, "fallback must not use the model");
    let committed = plan.commit(&cat, &mut cluster, 1.0);
    assert_eq!(committed.placements.len(), 20);
    let limit = cat.request_packing_limit(0);
    for n in 0..cluster.n_nodes() {
        let (sat, cached) = cluster.counts(n, 0);
        if sat + cached == 0 {
            continue;
        }
        // dedicated: nothing else on the node
        for inst in cluster.node_instances(n) {
            assert_eq!(inst.function, 0, "node {n} must be dedicated");
        }
        assert!(sat + cached <= limit, "node {n} over request limit");
    }
    // unflag: scheduling goes back through capacity tables
    sched.apply_feedback(SchedulerFeedback::Unpredictability { function: 0, isolated: false });
    assert!(!sched.is_isolated(0));
}

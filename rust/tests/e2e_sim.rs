//! End-to-end simulation smoke tests over the full stack with the native
//! predictor (artifact-gated; PJRT covered in runtime_pjrt.rs and the
//! serve_trace example).

use jiagu::catalog::Catalog;
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::sim::{load_predictor, Simulation};
use jiagu::traces;

fn setup() -> Option<(Catalog, std::path::PathBuf)> {
    let dir = jiagu::artifacts_dir();
    if !dir.join("functions.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some((Catalog::load(&dir.join("functions.json")).unwrap(), dir))
}

#[test]
fn jiagu_run_holds_qos_and_beats_k8s_density() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 420).swap_remove(0);

    let mut k8s_cfg = RunConfig::with_scheduler(SchedulerKind::Kubernetes);
    k8s_cfg.duration_s = 420;
    let k8s = Simulation::new(cat.clone(), k8s_cfg, predictor.clone())
        .run(&trace)
        .unwrap();

    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 420;
    let jiagu = Simulation::new(cat.clone(), cfg, predictor).run(&trace).unwrap();

    assert!(jiagu.qos_violation_rate < 0.10, "QoS {:.3}", jiagu.qos_violation_rate);
    assert!(
        jiagu.density > k8s.density,
        "jiagu density {:.2} must beat k8s {:.2}",
        jiagu.density,
        k8s.density
    );
    assert!(jiagu.fast_decisions > 0, "fast path must be exercised");
    assert!(jiagu.instances_started > 0);
}

#[test]
fn fast_path_dominates_on_realworld_trace() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    // the >80% fast-path claim is about steady state — the horizon must
    // amortise the one-time (function, node) table warm-up
    let trace = traces::paper_traces(&cat, 1000).swap_remove(1);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 1000;
    let r = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    let fast_rate =
        r.fast_decisions as f64 / (r.fast_decisions + r.slow_decisions).max(1) as f64;
    // paper: >80% of scheduling goes through the fast path
    assert!(fast_rate > 0.8, "fast-path rate {fast_rate:.2}");
    // fast path means far fewer critical inferences than schedule calls
    assert!(r.inferences_per_schedule < 1.0, "{}", r.inferences_per_schedule);
}

#[test]
fn gsight_pays_inference_every_schedule() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 300).swap_remove(0);
    let mut cfg = RunConfig::with_scheduler(SchedulerKind::Gsight);
    cfg.duration_s = 300;
    let r = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    assert!(r.inferences_per_schedule >= 1.0, "{}", r.inferences_per_schedule);
    assert_eq!(r.fast_decisions, 0);
}

#[test]
fn worstcase_trace_forces_slow_path() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::worstcase_trace(&cat, 420, 90, 15);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 420;
    let r = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    let slow_rate =
        r.slow_decisions as f64 / (r.fast_decisions + r.slow_decisions).max(1) as f64;
    assert!(
        slow_rate > 0.5,
        "worst case should mostly hit the slow path: {slow_rate:.2} ({} fast / {} slow)",
        r.fast_decisions,
        r.slow_decisions
    );
}

#[test]
fn dual_staged_produces_logical_cold_starts_on_fluctuating_load() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 600).swap_remove(2);
    let mut cfg = RunConfig::jiagu_30(); // most sensitive variant
    cfg.duration_s = 600;
    let r = Simulation::new(cat.clone(), cfg, predictor.clone()).run(&trace).unwrap();
    assert!(r.released > 0, "release stage must fire");
    assert!(r.logical_cold_starts > 0, "logical cold starts must fire");

    // NoDS on the same trace: no releases, no logical cold starts
    let mut nods = RunConfig::jiagu_nods();
    nods.duration_s = 600;
    let r2 = Simulation::new(cat, nods, predictor).run(&trace).unwrap();
    assert_eq!(r2.released, 0);
    assert_eq!(r2.logical_cold_starts, 0);
}

#[test]
fn replays_are_bit_identical_full_report() {
    // The event core makes determinism total: every due time comes from
    // virtual time + the modelled CostModel (never the wall clock), the
    // queue pops in (due_ms, seq) order, so the *entire* RunReport —
    // latency percentiles included — must compare equal across replays.
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let trace = traces::paper_traces(&cat, 240).swap_remove(3);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 240;
    let a = Simulation::new(cat.clone(), cfg.clone(), predictor.clone())
        .run(&trace)
        .unwrap();
    let b = Simulation::new(cat, cfg, predictor).run(&trace).unwrap();
    assert_eq!(a, b, "full RunReport must replay bit-identically");
}

#[test]
fn subsecond_poisson_workload_replays_bit_identical_and_serves() {
    // The same total-determinism contract must hold for workloads the
    // tick loop could not express: 100 ms Poisson bins — now with the
    // per-request model on, so the histogram, per-function violation
    // counts and in-flight gauges are part of the replayed surface.
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let params = traces::PoissonParams { duration_s: 45, ..Default::default() };
    let wl = traces::Workload::poisson(&cat, &params, 77);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 45;
    cfg.requests = true;
    let a = Simulation::new(cat.clone(), cfg.clone(), predictor.clone())
        .run_workload(&wl)
        .unwrap();
    let b = Simulation::new(cat.clone(), cfg, predictor).run_workload(&wl).unwrap();
    assert_eq!(a, b, "sub-second workload must replay bit-identically");
    // the new per-request fields, asserted field by field so a future
    // PartialEq regression cannot silently shrink the replayed surface
    assert_eq!(a.latency_hist, b.latency_hist, "histogram bins must replay");
    assert_eq!(a.request_qos_violations, b.request_qos_violations);
    assert_eq!(a.peak_node_in_flight, b.peak_node_in_flight);
    assert_eq!(a.cold_wait_requests, b.cold_wait_requests);
    assert!(a.instances_started > 0, "poisson load must drive scale-ups");
    // cold starts complete at sched_cost + init (cfork 8.4 ms), far
    // below the tick boundary the old loop rounded up to
    assert!(
        a.cold_start_ms_mean > 8.4 && a.cold_start_ms_mean < 100.0,
        "event-resolution cold start latency, got {}",
        a.cold_start_ms_mean
    );
    // the per-request surface is genuinely populated and coherent
    assert!(a.requests_served > 0, "arrivals must be synthesized and routed");
    assert_eq!(
        a.latency_hist.bins().iter().sum::<u64>() + a.latency_hist.overflow(),
        a.requests_served,
        "every attributed request lands in exactly one bin"
    );
    assert!(a.request_p50_ms > 0.0);
    assert!(a.request_p95_ms >= a.request_p50_ms);
    assert!(a.request_p99_ms >= a.request_p95_ms);
    assert_eq!(a.request_qos_violations.len(), cat.len());
    assert_eq!(
        a.request_counts.iter().sum::<u64>(),
        a.requests_served,
        "per-function counts must partition the attributed requests"
    );
    for (served, violated) in a.request_counts.iter().zip(&a.request_qos_violations) {
        assert!(violated <= served, "violations bounded by requests per function");
    }
    assert!(a.cold_wait_requests > 0, "pre-cold-start arrivals must wait");
    assert!(a.peak_node_in_flight > 0);
}

#[test]
fn request_model_leaves_aggregate_metrics_untouched() {
    // The per-request path draws from its own seeded streams: switching
    // it on must not move any aggregate metric (density, QoS windows,
    // fast-path counters, cold starts) for the same seed.
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let params = traces::PoissonParams { duration_s: 30, ..Default::default() };
    let wl = traces::Workload::poisson(&cat, &params, 31);
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = 30;
    let off = Simulation::new(cat.clone(), cfg.clone(), predictor.clone())
        .run_workload(&wl)
        .unwrap();
    cfg.requests = true;
    let on = Simulation::new(cat, cfg, predictor).run_workload(&wl).unwrap();
    assert_eq!(off.requests_served, 0, "off = no per-request attribution");
    assert!(on.requests_served > 0);
    assert_eq!(off.density, on.density);
    assert_eq!(off.qos_violation_rate, on.qos_violation_rate);
    assert_eq!(off.instances_started, on.instances_started);
    assert_eq!(off.fast_decisions, on.fast_decisions);
    assert_eq!(off.slow_decisions, on.slow_decisions);
    assert_eq!(off.cold_start_ms_mean, on.cold_start_ms_mean);
    assert_eq!(off.released, on.released);
    assert_eq!(off.logical_cold_starts, on.logical_cold_starts);
}

#[test]
fn unpredictability_fallback_isolates_function() {
    // Force the fallback through the typed feedback API and verify the
    // scheduler keeps the flagged function on dedicated nodes at the
    // request-packing limit.
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let mut cluster = jiagu::cluster::Cluster::new(4);
    let mut sched = jiagu::scheduler::JiaguScheduler::new(
        predictor,
        jiagu::capacity::CapacityConfig::default(),
        4,
    );
    use jiagu::scheduler::{Scheduler, SchedulerFeedback};
    // colocate some normal functions first
    let _ = sched.schedule(&cat, &cluster, 1, 3, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
    let _ = sched.schedule(&cat, &cluster, 2, 3, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
    // flag function 0 as unpredictable via control-plane feedback
    sched.apply_feedback(SchedulerFeedback::Unpredictability { function: 0, isolated: true });
    assert!(sched.is_isolated(0));
    let plan = sched.schedule(&cat, &cluster, 0, 20, 1.0).unwrap();
    assert_eq!(plan.critical_inferences, 0, "fallback must not use the model");
    let committed = plan.commit(&cat, &mut cluster, 1.0);
    assert_eq!(committed.placements.len(), 20);
    let limit = cat.request_packing_limit(0);
    for n in 0..cluster.n_nodes() {
        let (sat, cached) = cluster.counts(n, 0);
        if sat + cached == 0 {
            continue;
        }
        // dedicated: nothing else on the node
        for inst in cluster.node_instances(n) {
            assert_eq!(inst.function, 0, "node {n} must be dedicated");
        }
        assert!(sat + cached <= limit, "node {n} over request limit");
    }
    // unflag: scheduling goes back through capacity tables
    sched.apply_feedback(SchedulerFeedback::Unpredictability { function: 0, isolated: false });
    assert!(!sched.is_isolated(0));
}

//! Policy-lab property tests (`jiagu::policy`):
//!
//! * the default `weighted` dispatch policy reproduces the pre-refactor
//!   router algorithm byte-for-byte (a shadow implementation driven by a
//!   twin RNG stays in lockstep through route/complete storms);
//! * every dispatch × scaling policy replays byte-identically across
//!   shard counts 1/2/4 and both `Timeline` implementations;
//! * power-of-two-choices never picks an instance outside the serving
//!   set;
//! * the `harvesting` scaling policy never increases any function's QoS
//!   violations on the golden scenario;
//! * SITA rejects non-finite/zero duration estimates with a typed error
//!   instead of silently routing everything to interval 0.

use jiagu::artifacts::{latency_golden_scenario, make_catalog};
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::controlplane::shard::ShardedControlPlane;
use jiagu::engine::QueueKind;
use jiagu::policy::{
    make_dispatch_policy, CandidateView, DispatchPolicy, DispatchPolicyKind,
    PowerOfTwoPolicy, ScalingPolicyKind, SitaDispatch,
};
use jiagu::router::{RouteOutcome, Router};
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::sim::{RunReport, Simulation};
use jiagu::traces::{PoissonParams, Workload};
use jiagu::util::rng::Rng;
use std::sync::Arc;

fn stub_predictor() -> Arc<dyn Predictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        0.05,
        0.05,
    )))
}

/// The pre-refactor `Router::pick` algorithm, verbatim: one `f64` draw,
/// weights `1 / (1 + in_flight)`, threshold walk defaulting to the last
/// serving instance.  The byte-identity contract of the default policy
/// is exactly "indistinguishable from this".
fn shadow_pick(serving: &[u64], in_flight: &[u32], rng: &mut Rng) -> u64 {
    let u = rng.f64();
    let mut total = 0.0;
    let mut weights = Vec::with_capacity(serving.len());
    for &id in serving {
        let n = in_flight.get(id as usize).copied().unwrap_or(0);
        let w = 1.0 / (1.0 + n as f64);
        total += w;
        weights.push(w);
    }
    let mut r = u * total;
    let mut picked = *serving.last().expect("non-empty serving set");
    for (&id, w) in serving.iter().zip(&weights) {
        r -= w;
        if r <= 0.0 {
            picked = id;
            break;
        }
    }
    picked
}

#[test]
fn default_policy_matches_the_prerefactor_router_in_lockstep() {
    const SEED: u64 = 0xd15b;
    let mut router = Router::with_seed(SEED);
    let mut twin = Rng::seed_from(SEED);
    // shadow state: serving sets in insertion order + in-flight gauges
    let mut serving: Vec<Vec<u64>> = vec![Vec::new(); 2];
    let mut in_flight = vec![0u32; 16];
    for (f, id, node) in
        [(0usize, 0u64, 0usize), (0, 1, 1), (0, 2, 2), (0, 3, 0), (1, 4, 1), (1, 5, 2)]
    {
        router.add(f, id, node);
        serving[f].push(id);
    }
    // a function nobody serves: ColdWait must not advance either stream
    assert_eq!(router.route(7, 0.0), RouteOutcome::ColdWait);
    let mut step = Rng::seed_from(99);
    for i in 0..600 {
        let t = i as f64;
        let f = (step.below(2)) as usize;
        let expect = shadow_pick(&serving[f], &in_flight, &mut twin);
        let got = match router.route(f, t) {
            RouteOutcome::Started { instance, .. } => instance,
            RouteOutcome::Queued { instance, .. } => instance,
            RouteOutcome::ColdWait => panic!("both functions are served"),
        };
        assert_eq!(got, expect, "step {i}: policy diverged from the shadow");
        in_flight[got as usize] += 1;
        // drain a pseudo-random busy instance now and then, mirrored
        if step.below(3) == 0 {
            let id = step.below(6);
            if in_flight[id as usize] > 0 {
                router.complete(id);
                in_flight[id as usize] -= 1;
            }
        }
    }
}

#[test]
fn every_policy_replays_byte_identically_across_shards_and_queues() {
    let cat = Catalog::from_functions(make_catalog(8, 0x5ca1e));
    let predictor = stub_predictor();
    let wl = Workload::poisson(
        &cat,
        &PoissonParams { duration_s: 3, ..Default::default() },
        61,
    );
    let combos = [
        (DispatchPolicyKind::Weighted, ScalingPolicyKind::Baseline),
        (DispatchPolicyKind::PowerOfTwo, ScalingPolicyKind::Baseline),
        (DispatchPolicyKind::Locality, ScalingPolicyKind::Baseline),
        (DispatchPolicyKind::Sita, ScalingPolicyKind::Baseline),
        (DispatchPolicyKind::Weighted, ScalingPolicyKind::Harvesting),
    ];
    for (dispatch, scaling) in combos {
        let mut reports: Vec<RunReport> = Vec::new();
        for shards in [1usize, 2, 4] {
            for queue in [QueueKind::Heap, QueueKind::Wheel] {
                let mut cfg = RunConfig::jiagu_45();
                cfg.n_nodes = 6;
                cfg.duration_s = 3;
                cfg.requests = true;
                cfg.eval_interval_ms = 250.0;
                cfg.seed = 77;
                cfg.shards = shards;
                cfg.partitions = 4;
                cfg.queue = queue;
                cfg.dispatch_policy = dispatch;
                cfg.scaling_policy = scaling;
                let report =
                    ShardedControlPlane::new(cat.clone(), cfg, predictor.clone())
                        .unwrap()
                        .run_workload(&wl)
                        .unwrap();
                reports.push(report);
            }
        }
        assert!(
            reports.iter().all(|r| *r == reports[0]),
            "{}+{}: report must not depend on shard count or queue kind",
            dispatch.name(),
            scaling.name()
        );
        assert!(
            reports[0].requests_served > 0,
            "{}+{}: traffic must be served",
            dispatch.name(),
            scaling.name()
        );
    }
}

#[test]
fn power_of_two_never_picks_outside_the_serving_set() {
    let serving = [3u64, 9, 12];
    let mut in_flight = vec![0u32; 16];
    in_flight[3] = 20; // heavy
    in_flight[9] = 1;
    in_flight[12] = 0;
    in_flight[5] = 0; // idle but NOT serving — must never be picked
    let node_of = vec![0usize; 16];
    let node_in_flight = vec![0u32; 4];
    let view = CandidateView {
        function: 0,
        serving: &serving,
        in_flight: &in_flight,
        node_of: &node_of,
        node_in_flight: &node_in_flight,
    };
    let mut policy = PowerOfTwoPolicy::default();
    let mut rng = Rng::seed_from(0x9c);
    let mut picked_heavy = 0u32;
    for _ in 0..500 {
        let picked = policy.pick(&view, &mut rng);
        assert!(serving.contains(&picked), "picked non-serving instance {picked}");
        if picked == 3 {
            picked_heavy += 1;
        }
    }
    // d=2 choices: the heavy instance only wins when drawn twice (~1/9)
    assert!(picked_heavy < 150, "heavy instance over-picked: {picked_heavy}/500");
}

#[test]
fn harvesting_never_raises_golden_qos_violations() {
    let cat = Catalog::from_functions(make_catalog(8, 0xa7));
    let predictor = stub_predictor();
    let (cfg, wl) = latency_golden_scenario(&cat);
    let baseline = Simulation::new(cat.clone(), cfg.clone(), predictor.clone())
        .run_workload(&wl)
        .unwrap();
    let mut harvest_cfg = cfg;
    harvest_cfg.scaling_policy = ScalingPolicyKind::Harvesting;
    let harvested = Simulation::new(cat, harvest_cfg, predictor)
        .run_workload(&wl)
        .unwrap();
    for (f, (h, b)) in harvested
        .request_qos_violations
        .iter()
        .zip(&baseline.request_qos_violations)
        .enumerate()
    {
        assert!(h <= b, "fn {f}: harvesting raised QoS violations {h} > {b}");
    }
    // stronger on the golden scenario: both release-trigger candidates
    // (45 s release, 60 s keep-alive) sit beyond the 10 s horizon, so
    // harvesting is provably inert there — byte-identical, not just <=
    assert_eq!(harvested, baseline, "harvesting must be inert on the golden horizon");
}

#[test]
fn sita_rejects_degenerate_duration_estimates_with_a_typed_error() {
    for bad in [f64::NAN, 0.0, -3.0, f64::INFINITY] {
        let mut funcs = make_catalog(4, 0x517a);
        funcs[1].solo_latency_ms = bad;
        let cat = Catalog::from_functions(funcs);
        let err = SitaDispatch::from_catalog(&cat)
            .expect_err("degenerate estimate must be rejected");
        assert_eq!(err.function, 1);
        if bad.is_nan() {
            assert!(err.estimate_ms.is_nan());
        } else {
            assert_eq!(err.estimate_ms, bad);
        }
        // the factory propagates the same typed error through anyhow
        let any = make_dispatch_policy(DispatchPolicyKind::Sita, &cat)
            .expect_err("factory must propagate the rejection");
        assert!(any.to_string().contains("function 1"), "unexpected: {any}");
    }
    // a healthy generated catalog constructs fine
    let cat = Catalog::from_functions(make_catalog(4, 0x517a));
    assert!(SitaDispatch::from_catalog(&cat).is_ok());
}

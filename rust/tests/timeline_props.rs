//! Property tests pinning the [`Timeline`] determinism contract across
//! implementations (hand-rolled generators over the crate's seeded RNG —
//! no proptest offline; every failure reports its seed):
//!
//! * on randomized interleavings of pushes and pops — same-ms bursts,
//!   sub-ms jitter, behind-cursor pushes, far-future dues past the
//!   wheel's top-level rotation — [`TimingWheel`] emits the exact
//!   `(due_ms, seq, event)` stream the reference [`EventQueue`] heap
//!   does, `to_bits`-identical on every due time;
//! * `pop_due` windows (strict and inclusive) agree at every step;
//! * events racked at level 1 survive the level-0 window carry (the
//!   `refill` re-admission pass's regression case);
//! * end to end: the fixed-seed latency-golden scenario produces
//!   **equal `RunReport`s** under `queue = heap` and `queue = wheel`,
//!   at shard counts 1, 2 and 4 — the invariant the CI determinism
//!   matrix re-checks through the CLI byte-for-byte.
//!
//! Registered in `Cargo.toml` as a `[[test]]` target (`autotests =
//! false`; `make check-test-targets` fails on unregistered files).

use jiagu::artifacts::{latency_golden_scenario, make_catalog};
use jiagu::catalog::Catalog;
use jiagu::controlplane::shard::ShardedControlPlane;
use jiagu::engine::{Event, EventQueue, QueueKind, Timeline, TimingWheel};
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::util::rng::Rng;
use std::sync::Arc;

/// A randomized due time exercising every bucketing regime of the wheel:
/// the current slot, same-ms bursts, sub-ms jitter, each level span, and
/// dues beyond one whole top-level rotation (64^4 ms) that land in the
/// overflow list.
fn random_due(rng: &mut Rng, now_ms: f64) -> f64 {
    match rng.below(8) {
        // same-ms burst: a whole-millisecond tick shared by many events
        0 => now_ms.floor() + rng.below(4) as f64,
        // sub-ms jitter inside the current few ticks
        1 => now_ms + rng.f64() * 4.0,
        // level-0 span (ms)
        2 => now_ms + rng.f64() * 60.0,
        // level-1 span (tens of ms to seconds)
        3 => now_ms + rng.f64() * 4_000.0,
        // level-2 span (seconds to minutes)
        4 => now_ms + rng.f64() * 260_000.0,
        // level-3 span (minutes to hours)
        5 => now_ms + rng.f64() * 16_000_000.0,
        // beyond one top-level rotation: the overflow list
        6 => now_ms + 17_000_000.0 + rng.f64() * 40_000_000.0,
        // behind the current drain point (late scheduling)
        _ => (now_ms - rng.f64() * 50.0).max(0.0),
    }
}

fn random_event(rng: &mut Rng) -> Event {
    match rng.below(4) {
        0 => Event::MonitorTick,
        1 => Event::AutoscalerEval,
        2 => Event::LoadChange { function: rng.below(8) as usize, rps: rng.f64() * 50.0 },
        _ => Event::ColdStartComplete { instance: rng.below(1 << 20) },
    }
}

/// Randomized interleavings of push / pop / pop_due / peek: the wheel and
/// the heap must agree on every observation, `to_bits`-exact.
#[test]
fn wheel_pop_stream_matches_heap_on_randomized_interleavings() {
    for seed in 0..48u64 {
        let mut rng = Rng::seed_from(seed ^ 0x7157_11e1);
        let mut heap = EventQueue::new();
        let mut wheel = TimingWheel::new();
        let mut now_ms = 0.0f64;
        for step in 0..2_000u32 {
            match rng.below(10) {
                // pushes dominate so the queues stay populated
                0..=5 => {
                    let due = random_due(&mut rng, now_ms);
                    let ev = random_event(&mut rng);
                    let sa = heap.push(due, ev.clone());
                    let sb = wheel.push(due, ev);
                    assert_eq!(sa, sb, "seed {seed} step {step}: seq counters diverged");
                }
                6 | 7 => {
                    let a = heap.pop();
                    let b = wheel.pop();
                    match (&a, &b) {
                        (Some(x), Some(y)) => {
                            assert_eq!(
                                x.due_ms.to_bits(),
                                y.due_ms.to_bits(),
                                "seed {seed} step {step}: due {} vs {}",
                                x.due_ms,
                                y.due_ms
                            );
                            assert_eq!(x.seq, y.seq, "seed {seed} step {step}");
                            assert_eq!(x.event, y.event, "seed {seed} step {step}");
                            now_ms = now_ms.max(x.due_ms);
                        }
                        (None, None) => {}
                        _ => panic!("seed {seed} step {step}: one queue drained early"),
                    }
                }
                8 => {
                    let limit = now_ms + rng.f64() * 5_000.0;
                    let inclusive = rng.below(2) == 0;
                    let a = heap.pop_due(limit, inclusive);
                    let b = wheel.pop_due(limit, inclusive);
                    assert_eq!(
                        a.as_ref().map(|s| (s.due_ms.to_bits(), s.seq)),
                        b.as_ref().map(|s| (s.due_ms.to_bits(), s.seq)),
                        "seed {seed} step {step}: pop_due({limit}, {inclusive})"
                    );
                    if let Some(s) = a {
                        now_ms = now_ms.max(s.due_ms);
                    }
                }
                _ => {
                    assert_eq!(
                        heap.peek_due().map(f64::to_bits),
                        wheel.peek_due().map(f64::to_bits),
                        "seed {seed} step {step}: peek_due"
                    );
                    assert_eq!(heap.len(), wheel.len(), "seed {seed} step {step}");
                }
            }
        }
        // drain both completely: the tails must agree too
        loop {
            let a = heap.pop();
            let b = wheel.pop();
            match (a, b) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.due_ms.to_bits(), y.due_ms.to_bits(), "seed {seed} drain");
                    assert_eq!(x.seq, y.seq, "seed {seed} drain");
                }
                (None, None) => break,
                _ => panic!("seed {seed}: drain lengths diverged"),
            }
        }
    }
}

/// Dense same-millisecond bursts — hundreds of events sharing one slot,
/// differing only in fractional due and push order — must pop in the
/// exact `(due_ms, seq)` order on both implementations.
#[test]
fn same_ms_bursts_preserve_push_order_ties() {
    let mut rng = Rng::seed_from(0xb0a57);
    let mut heap = EventQueue::new();
    let mut wheel = TimingWheel::new();
    for _ in 0..600 {
        // three whole-ms ticks, many exact collisions on each
        let tick = 100.0 + rng.below(3) as f64;
        let due = if rng.below(2) == 0 { tick } else { tick + rng.below(10) as f64 / 10.0 };
        let ev = random_event(&mut rng);
        heap.push(due, ev.clone());
        wheel.push(due, ev);
    }
    let mut popped = 0;
    while let Some(a) = heap.pop() {
        let b = wheel.pop().expect("wheel holds the same multiset");
        assert_eq!(a.due_ms.to_bits(), b.due_ms.to_bits());
        assert_eq!(a.seq, b.seq, "tie at due {} broke differently", a.due_ms);
        popped += 1;
    }
    assert_eq!(popped, 600);
    assert!(wheel.is_empty());
}

/// Regression: an event racked at level 1 must survive the cursor
/// carrying across its slot boundary through the level-0 drain
/// (`slot 63 + 1` never runs a cascade).  Without the re-admission pass
/// in `refill`, a fresh level-0 push into the newly entered window
/// drains ahead of the level-1 slot's contents and strands them.
#[test]
fn events_racked_above_survive_the_level0_window_carry() {
    for seed in 0..200u64 {
        let mut rng = Rng::seed_from(seed ^ 0x57a4d);
        let mut heap = EventQueue::new();
        let mut wheel = TimingWheel::new();
        let base = (rng.range_u64(1, 1 << 22) * 64) as f64;
        // park both cursors near the top of one level-0 window
        for _ in 0..8 {
            let due = base + 55.0 + rng.f64() * 8.0;
            let ev = random_event(&mut rng);
            heap.push(due, ev.clone());
            wheel.push(due, ev);
        }
        for _ in 0..6 {
            let a = heap.pop().unwrap();
            let b = wheel.pop().unwrap();
            assert_eq!((a.due_ms.to_bits(), a.seq), (b.due_ms.to_bits(), b.seq));
        }
        // one level-1 slot ahead: racked at level 1, not level 0
        let d1 = base + 64.0 + rng.f64() * 2.0;
        heap.push(d1, Event::MonitorTick);
        wheel.push(d1, Event::MonitorTick);
        // drain the rest of the old window — the carry crosses the
        // level-1 slot boundary without a cascade
        while matches!(heap.peek_due(), Some(d) if d < base + 64.0) {
            let a = heap.pop().unwrap();
            let b = wheel.pop().unwrap();
            assert_eq!((a.due_ms.to_bits(), a.seq), (b.due_ms.to_bits(), b.seq));
        }
        // a fresh push into the new window's level 0, due after d1
        let d2 = d1 + 1.0 + rng.f64();
        heap.push(d2, Event::AutoscalerEval);
        wheel.push(d2, Event::AutoscalerEval);
        loop {
            match (heap.pop(), wheel.pop()) {
                (Some(a), Some(b)) => assert_eq!(
                    (a.due_ms.to_bits(), a.seq),
                    (b.due_ms.to_bits(), b.seq),
                    "seed {seed}: level-1 event stranded behind the carry"
                ),
                (None, None) => break,
                _ => panic!("seed {seed}: queues diverged in length"),
            }
        }
    }
}

fn stub_predictor() -> Arc<dyn Predictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        0.05,
        0.05,
    )))
}

/// The tentpole's end-to-end guarantee: swapping the Timeline
/// implementation never moves a single bit of the golden scenario's
/// report, at any shard count.  (The CI determinism matrix re-checks the
/// same invariant through `jiagu run --json` byte comparison.)
#[test]
fn golden_scenario_reports_identical_under_heap_and_wheel_at_all_shard_counts() {
    let cat = Catalog::from_functions(make_catalog(8, 0x5ca1e));
    for shards in [1usize, 2, 4] {
        let run = |queue: QueueKind| {
            let (mut cfg, wl) = latency_golden_scenario(&cat);
            cfg.shards = shards;
            cfg.queue = queue;
            ShardedControlPlane::new(cat.clone(), cfg, stub_predictor())
                .unwrap()
                .run_workload(&wl)
                .unwrap()
        };
        let heap = run(QueueKind::Heap);
        let wheel = run(QueueKind::Wheel);
        assert!(heap.requests_served > 0, "scenario must route traffic");
        assert_eq!(heap, wheel, "queue impl moved bits at shards = {shards}");
    }
}

//! Property tests over the coordinator: random event sequences must
//! preserve the cluster/router/scheduler invariants regardless of
//! scheduler choice.  (Hand-rolled generators over the crate's seeded RNG
//! — no proptest offline; every failure reports its seed.)

use jiagu::autoscaler::{Autoscaler, AutoscalerConfig};
use jiagu::capacity::CapacityConfig;
use jiagu::catalog::{Catalog, FunctionSpec};
use jiagu::cluster::{Cluster, InstanceState};
use jiagu::interference;
use jiagu::router::Router;
use jiagu::runtime::{ForestParams, NativeForestPredictor};
use jiagu::scheduler::{
    GsightScheduler, JiaguScheduler, KubernetesScheduler, OwlScheduler, Scheduler,
};
use jiagu::util::rng::Rng;
use std::sync::Arc;

fn test_catalog(n: usize, seed: u64) -> Catalog {
    let mut rng = Rng::seed_from(seed);
    let mut specs = Vec::new();
    for i in 0..n {
        let base = rng.range_f64(20.0, 120.0);
        let pressure: Vec<f64> = (0..6).map(|_| rng.range_f64(0.5, 3.0)).collect();
        let sensitivity: Vec<f64> = (0..6).map(|_| rng.range_f64(0.05, 0.4)).collect();
        let solo = interference::slowdown(
            &interference::utilisation_single(&pressure),
            &sensitivity,
        ) * base;
        specs.push(FunctionSpec {
            name: format!("fn{i}"),
            profile: (0..13).map(|_| rng.range_f64(0.5, 5.0)).collect(),
            solo_latency_ms: solo,
            saturated_rps: 2500.0 / base,
            qos_latency_ms: 1.2 * solo,
            milli_cpu: 4000,
            mem_mb: 10 * 1024,
            pressure,
            sensitivity,
            base_latency_ms: base,
        });
    }
    Catalog::from_functions(specs)
}

fn stub_predictor(log_latency: f32) -> Arc<NativeForestPredictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        log_latency,
        log_latency,
    )))
}

fn schedulers(seed: u64) -> Vec<Box<dyn Scheduler>> {
    vec![
        Box::new(JiaguScheduler::new(stub_predictor(0.05), CapacityConfig::default(), 4)),
        Box::new(KubernetesScheduler::new()),
        Box::new(GsightScheduler::new(stub_predictor(0.05))),
        Box::new(OwlScheduler::new(seed)),
    ]
}

/// Random schedule/evict sequences keep cluster invariants for every
/// scheduler implementation.
#[test]
fn random_schedule_evict_sequences_keep_invariants() {
    for seed in 0..8u64 {
        let cat = test_catalog(5, seed);
        for mut sched in schedulers(seed) {
            let mut rng = Rng::seed_from(seed * 31 + 7);
            let mut cluster = Cluster::new(4);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..120 {
                let now = step as f64 * 250.0;
                if rng.f64() < 0.6 || live.is_empty() {
                    let f = rng.below(cat.len() as u64) as usize;
                    let count = rng.range_u64(1, 4) as u32;
                    let instances_before = cluster.instances_len();
                    let nodes_before = cluster.n_nodes();
                    let plan = sched.schedule(&cat, &cluster, f, count, now).unwrap();
                    // planning must be pure: nothing moves until commit
                    assert_eq!(cluster.instances_len(), instances_before, "{}", sched.name());
                    assert_eq!(cluster.n_nodes(), nodes_before, "{}", sched.name());
                    let committed = plan.commit(&cat, &mut cluster, now);
                    assert_eq!(
                        committed.placements.len(),
                        count as usize,
                        "{}: all requested instances placed",
                        sched.name()
                    );
                    for node in committed.touched_nodes() {
                        if let Some(u) =
                            sched.on_node_changed(&cat, &cluster, node, now).unwrap()
                        {
                            sched.complete_deferred(u);
                        }
                    }
                    for p in &committed.placements {
                        cluster.mark_ready(p.instance, now);
                        live.push(p.instance);
                    }
                } else {
                    let idx = rng.below(live.len() as u64) as usize;
                    let id = live.swap_remove(idx);
                    let node = cluster.instance(id).unwrap().node;
                    cluster.evict(&cat, id).unwrap();
                    if let Some(u) = sched.on_node_changed(&cat, &cluster, node, now).unwrap() {
                        sched.complete_deferred(u);
                    }
                }
                cluster.check_invariants().unwrap_or_else(|e| {
                    panic!("{} seed {seed} step {step}: {e}", sched.name())
                });
            }
        }
    }
}

/// The dual-staged autoscaler keeps router/cluster consistent under a
/// random load signal, and only ever routes to saturated instances.
#[test]
fn autoscaler_random_loads_keep_router_consistent() {
    for seed in 0..6u64 {
        let cat = test_catalog(4, seed + 100);
        let mut cluster = Cluster::new(4);
        let mut router = Router::new();
        let mut sched =
            JiaguScheduler::new(stub_predictor(0.05), CapacityConfig::default(), 4);
        let mut autoscaler = Autoscaler::new(
            AutoscalerConfig {
                release_duration_s: 5.0,
                keepalive_duration_s: 12.0,
                dual_staged: true,
                migration: true,
            },
            cat.len(),
        );
        let mut rng = Rng::seed_from(seed ^ 0xbeef);
        let mut loads = vec![0.0; cat.len()];
        for t in 0..180usize {
            let now = t as f64 * 1000.0;
            // random walk loads, occasionally dropping to zero
            for (f, load) in loads.iter_mut().enumerate() {
                let sat = cat.get(f).saturated_rps;
                if rng.f64() < 0.05 {
                    *load = 0.0;
                } else {
                    *load = (*load + rng.normal_ms(0.0, 1.5) * sat).clamp(0.0, 10.0 * sat);
                }
            }
            let out = autoscaler
                .tick(&cat, &mut cluster, &mut router, &mut sched, &loads, now)
                .unwrap();
            // land the submitted refreshes immediately (the engine's
            // virtual-time queue is exercised by the controlplane tests)
            for u in out.deferred {
                sched.complete_deferred(u);
            }
            // new instances become ready next tick
            for id in out.cold_started {
                cluster.mark_ready(id, now);
                let inst = cluster.instance(id).unwrap();
                let (f, node) = (inst.function, inst.node);
                router.add(f, id, node);
            }
            cluster.check_invariants().unwrap();
            router.check_consistent(&cluster).unwrap();
        }
    }
}

/// NoDS (traditional keep-alive) never produces cached instances; DS
/// produces them and converts some back logically.
#[test]
fn dual_staged_vs_nods_state_machines() {
    let cat = test_catalog(3, 55);
    for (ds, expect_cached) in [(true, true), (false, false)] {
        let mut cluster = Cluster::new(3);
        let mut router = Router::new();
        let mut sched =
            JiaguScheduler::new(stub_predictor(0.05), CapacityConfig::default(), 3);
        let mut autoscaler = Autoscaler::new(
            AutoscalerConfig {
                release_duration_s: 3.0,
                // keep cached instances alive across the low half-wave
                // (20 s) so the next high phase finds them
                keepalive_duration_s: 30.0,
                dual_staged: ds,
                migration: ds,
            },
            cat.len(),
        );
        let mut saw_cached = false;
        let mut saw_logical = false;
        for t in 0..120usize {
            let now = t as f64 * 1000.0;
            // square-wave load: high for 20s, low for 20s
            let high = (t / 20) % 2 == 0;
            let loads: Vec<f64> = (0..cat.len())
                .map(|f| {
                    let sat = cat.get(f).saturated_rps;
                    if high {
                        6.0 * sat
                    } else {
                        1.5 * sat
                    }
                })
                .collect();
            let out = autoscaler
                .tick(&cat, &mut cluster, &mut router, &mut sched, &loads, now)
                .unwrap();
            for u in out.deferred {
                sched.complete_deferred(u);
            }
            saw_logical |= out.logical_cold_starts > 0;
            for id in out.cold_started {
                cluster.mark_ready(id, now);
                let inst = cluster.instance(id).unwrap();
                let (f, node) = (inst.function, inst.node);
                router.add(f, id, node);
            }
            for n in 0..cluster.n_nodes() {
                for f in 0..cat.len() {
                    if !cluster.find_instances(n, f, InstanceState::Cached).is_empty() {
                        saw_cached = true;
                    }
                }
            }
            router.check_consistent(&cluster).unwrap();
        }
        assert_eq!(saw_cached, expect_cached, "dual_staged={ds}");
        if ds {
            assert!(saw_logical, "square wave must trigger logical cold starts");
        }
    }
}

/// Two event queues fed the same randomized schedule pop bit-identical
/// sequences, and the pop order equals a *stable* sort of the pushes by
/// due time — i.e. exact-due collisions resolve by the monotone push
/// sequence number, never by heap internals.
#[test]
fn event_queue_pop_order_is_deterministic_with_seq_tiebreak() {
    use jiagu::engine::{Event, EventQueue};
    for seed in 0..8u64 {
        let mut rng = Rng::seed_from(seed ^ 0x5eed);
        let mut pushed: Vec<(f64, Event)> = Vec::new();
        for i in 0..500u64 {
            // coarse due grid → many exact ties exercise the tie-break
            let due = rng.below(40) as f64 * 250.0;
            let event = match rng.below(4) {
                0 => Event::ColdStartComplete { instance: i },
                1 => Event::DeferredUpdateDue { node: i as usize % 7, version: i },
                2 => Event::LoadChange { function: i as usize % 5, rps: i as f64 },
                _ => Event::AutoscalerEval,
            };
            pushed.push((due, event));
        }
        let mut a = EventQueue::new();
        let mut b = EventQueue::new();
        for (due, e) in &pushed {
            a.push(*due, e.clone());
            b.push(*due, e.clone());
        }
        // the reference order: a stable sort by due keeps push order on ties
        let mut expected = pushed.clone();
        expected.sort_by(|x, y| x.0.total_cmp(&y.0));
        let mut popped = Vec::new();
        while let (Some(x), Some(y)) = (a.pop(), b.pop()) {
            assert_eq!(x.due_ms, y.due_ms, "seed {seed}: replicas diverged");
            assert_eq!(x.seq, y.seq, "seed {seed}: replicas diverged");
            assert_eq!(x.event, y.event, "seed {seed}: replicas diverged");
            popped.push((x.due_ms, x.event));
        }
        assert!(a.is_empty() && b.is_empty());
        assert_eq!(popped, expected, "seed {seed}: pop order != stable due-order");
    }
}

/// Owl never exceeds two distinct functions per node over random workloads.
#[test]
fn owl_two_function_invariant_under_random_load() {
    for seed in 0..4u64 {
        let cat = test_catalog(6, seed + 41);
        let mut cluster = Cluster::new(3);
        let mut sched = OwlScheduler::new(seed);
        let mut rng = Rng::seed_from(seed);
        for step in 0..80 {
            let f = rng.below(cat.len() as u64) as usize;
            let plan = sched
                .schedule(&cat, &cluster, f, rng.range_u64(1, 3) as u32, step as f64)
                .unwrap();
            let _ = plan.commit(&cat, &mut cluster, step as f64);
            for n in 0..cluster.n_nodes() {
                assert!(cluster.mix(n).entries.len() <= 2);
            }
        }
    }
}

//! Cross-language golden-vector tests: the Rust mirrors of the ground
//! truth interference model and the feature builder must match the Python
//! originals bit-for-bit (f64) / to f32 rounding (features).
//!
//! Vectors come from `artifacts/interference_check.json`, emitted by
//! `make artifacts`.  Tests skip (with a loud message) when artifacts are
//! absent so `cargo test` still runs on a fresh checkout.

use jiagu::catalog::Catalog;
use jiagu::interference::{ground_truth_latency, node_utilisation, NodeMix};
use jiagu::model::feature_row;
use jiagu::util::json::Json;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = jiagu::artifacts_dir();
    if dir.join("interference_check.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load(dir: &std::path::Path) -> (Catalog, Vec<Json>) {
    let cat = Catalog::load(&dir.join("functions.json")).unwrap();
    let cases = Json::parse_file(&dir.join("interference_check.json"))
        .unwrap()
        .as_arr()
        .unwrap()
        .to_vec();
    (cat, cases)
}

fn mix_of(cat: &Catalog, case: &Json) -> (NodeMix, usize) {
    let names = case.get("functions").unwrap().str_vec().unwrap();
    let sat = case.get("sat").unwrap().f64_vec().unwrap();
    let cached = case.get("cached").unwrap().f64_vec().unwrap();
    let target_pos = case.get("target").unwrap().as_usize().unwrap();
    let mut entries = Vec::new();
    let mut target_fid = 0;
    for (i, name) in names.iter().enumerate() {
        let fid = cat.id_of(name).expect("golden function in catalog");
        entries.push((fid, sat[i] as u32, cached[i] as u32));
        if i == target_pos {
            target_fid = fid;
        }
    }
    (NodeMix::new(entries), target_fid)
}

#[test]
fn ground_truth_latency_matches_python_exactly() {
    let Some(dir) = artifacts() else { return };
    let (cat, cases) = load(&dir);
    assert!(cases.len() >= 32);
    for case in &cases {
        let (mix, target) = mix_of(&cat, case);
        let want = case.get("latency_ms").unwrap().as_f64().unwrap();
        let got = ground_truth_latency(&cat, &mix, target);
        let rel = (got - want).abs() / want.max(1e-12);
        assert!(rel < 1e-12, "latency mismatch: got {got}, want {want}");
    }
}

#[test]
fn node_utilisation_matches_python_exactly() {
    let Some(dir) = artifacts() else { return };
    let (cat, cases) = load(&dir);
    for case in &cases {
        let (mix, _) = mix_of(&cat, case);
        let want = case.get("utilisation").unwrap().f64_vec().unwrap();
        let got = node_utilisation(&cat, &mix);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12, "utilisation mismatch: {got:?} vs {want:?}");
        }
    }
}

#[test]
fn feature_rows_match_python_to_f32() {
    let Some(dir) = artifacts() else { return };
    let (cat, cases) = load(&dir);
    for case in &cases {
        let (mix, target) = mix_of(&cat, case);
        let want = case.get("features").unwrap().f32_vec().unwrap();
        let got = feature_row(&cat, &mix, target);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let denom = w.abs().max(1.0);
            assert!(
                (g - w).abs() / denom < 1e-6,
                "feature {i}: got {g}, want {w}"
            );
        }
    }
}

/// Per-request tail-latency golden: replaying the fixed 100 ms-bin
/// Poisson scenario (`artifacts::latency_golden_scenario`) over the
/// checked-in forest must reproduce `latency_golden.json` — histogram
/// included — **byte for byte**.  Any nondeterminism anywhere on the
/// request path (arrival synthesis, pick RNG, queue ordering, service
/// times, histogram fold) breaks this test.
#[test]
fn per_request_latency_histogram_matches_golden_byte_identical() {
    let Some(dir) = artifacts() else { return };
    let path = dir.join("latency_golden.json");
    if !path.exists() {
        eprintln!("SKIP: latency_golden.json absent (re-run `make artifacts`)");
        return;
    }
    let cat = Catalog::load(&dir.join("functions.json")).unwrap();
    let forest = jiagu::runtime::ForestParams::load(&dir.join("forest.json")).unwrap();
    let got = jiagu::artifacts::latency_golden(&cat, forest).unwrap();
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        format!("{}\n", got.to_string()),
        want,
        "latency golden must replay byte-identically"
    );
    // sanity on the vectors themselves (golden JSON is well-formed)
    let parsed = Json::parse(&want).unwrap();
    let p50 = parsed.get("p50_ms").unwrap().as_f64().unwrap();
    let p99 = parsed.get("p99_ms").unwrap().as_f64().unwrap();
    assert!(p50 > 0.0 && p99 >= p50, "percentiles ordered: p50 {p50} p99 {p99}");
}

#[test]
fn catalog_packing_limit_is_twelve() {
    // the Fig. 13 density baseline: 48000 mCPU node / 4000 mCPU request
    let Some(dir) = artifacts() else { return };
    let (cat, _) = load(&dir);
    for f in 0..cat.len() {
        assert_eq!(cat.request_packing_limit(f), 12);
    }
}

//! Prediction hot-path properties: the flattened batched engine must be
//! bit-identical to the scalar reference walk, the memoized capacity
//! sweep must change *counts* only (never a placement), and both must
//! hold under the full determinism matrix (shards 1/2/4 × queue
//! heap/wheel on the latency-golden scenario).
//!
//! The random-forest tests are self-contained; the golden-scenario tests
//! are artifact-gated like `e2e_sim.rs`.

use jiagu::catalog::Catalog;
use jiagu::engine::QueueKind;
use jiagu::model::FeatureMatrix;
use jiagu::runtime::{
    FlatForest, FlatScratch, ForestParams, NativeForest, NativeForestPredictor, Predictor, BLOCK,
};
use jiagu::sim::load_predictor;
use jiagu::util::rng::Rng;

fn random_forest(rng: &mut Rng, n_trees: usize, depth: usize, n_features: usize) -> ForestParams {
    let n_internal = (1usize << depth) - 1;
    let n_leaves = 1usize << depth;
    let params = ForestParams {
        n_trees,
        depth,
        n_features,
        feature: (0..n_trees)
            .map(|_| (0..n_internal).map(|_| rng.below(n_features as u64) as i32).collect())
            .collect(),
        threshold: (0..n_trees)
            .map(|_| (0..n_internal).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
            .collect(),
        leaf: (0..n_trees)
            .map(|_| (0..n_leaves).map(|_| rng.range_f64(-0.4, 0.4) as f32).collect())
            .collect(),
        mean: (0..n_features).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
        std: (0..n_features).map(|_| rng.range_f64(0.5, 2.0) as f32).collect(),
        test_error: 0.0,
        fit_seconds: 0.0,
    };
    params.validate().unwrap();
    params
}

/// The core tentpole contract, swept across forest shapes: every flat
/// prediction is bit-identical to the reference walk — including a
/// forest wider than `predict_one`'s 128-feature stack fast path and
/// batch sizes straddling the [`BLOCK`] boundary.
#[test]
fn flat_engine_is_bit_identical_to_reference_across_random_forests() {
    let mut rng = Rng::seed_from(0x9E3779);
    // (n_trees, depth, n_features); 150 features exercises the reference
    // walk's heap fallback as well
    for (n_trees, depth, n_features) in
        [(1, 1, 2), (7, 4, 11), (40, 7, 44), (16, 6, 150), (3, 9, 5)]
    {
        let params = random_forest(&mut rng, n_trees, depth, n_features);
        let forest = NativeForest::new(params.clone());
        let flat = FlatForest::from_params(&params);
        let mut scratch = FlatScratch::default();
        for n_rows in [1usize, BLOCK, BLOCK + 3] {
            let data: Vec<f32> = (0..n_rows * n_features)
                .map(|_| rng.range_f64(-10.0, 10.0) as f32)
                .collect();
            let got = flat.predict(&data, &mut scratch);
            assert_eq!(got.len(), n_rows);
            for (r, g) in got.iter().enumerate() {
                let want = forest.predict_one(&data[r * n_features..(r + 1) * n_features]);
                assert_eq!(
                    g.to_bits(),
                    want.to_bits(),
                    "forest ({n_trees},{depth},{n_features}), row {r} of {n_rows}"
                );
            }
        }
    }
}

/// The [`Predictor`] wiring on top of the flat engine: a borrowed
/// [`FeatureMatrix`] through `predict_batch` and the `Vec<Vec<f32>>`
/// compatibility path through `predict` must both reproduce the
/// reference walk bit for bit, and the stats must account every row.
#[test]
fn native_predictor_batch_and_rows_paths_agree_with_reference() {
    let mut rng = Rng::seed_from(0xB4D6E);
    let params = random_forest(&mut rng, 12, 5, 23);
    let predictor = NativeForestPredictor::new(params);
    let rows: Vec<Vec<f32>> = (0..90)
        .map(|_| (0..23).map(|_| rng.range_f64(-8.0, 8.0) as f32).collect())
        .collect();

    let via_rows = predictor.predict(&rows).unwrap();
    let m = FeatureMatrix::from_rows(23, &rows).unwrap();
    let via_batch = predictor.predict_batch(&m).unwrap();
    assert_eq!(via_rows.len(), 90);
    for (r, row) in rows.iter().enumerate() {
        let want = predictor.reference().predict_one(row);
        assert_eq!(via_rows[r].to_bits(), want.to_bits(), "rows path, row {r}");
        assert_eq!(via_batch[r].to_bits(), want.to_bits(), "batch path, row {r}");
    }
    let (calls, row_count, _) = predictor.stats().snapshot();
    assert_eq!(calls, 2, "one batched call per predict entry point");
    assert_eq!(row_count, 180, "every row accounted");

    // width mismatches are rejected, not mis-sliced
    let narrow = FeatureMatrix::from_rows(4, &[vec![0.0; 4]]).unwrap();
    assert!(predictor.predict_batch(&narrow).is_err());
}

fn setup() -> Option<(Catalog, std::path::PathBuf)> {
    let dir = jiagu::artifacts_dir();
    if !dir.join("functions.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some((Catalog::load(&dir.join("functions.json")).unwrap(), dir))
}

/// Acceptance criterion for the memoized sweep layer: on the golden
/// Poisson scenario the per-scheduler memo must actually fire — the
/// merged [`RunReport`](jiagu::sim::RunReport) surfaces nonzero hits —
/// while every placement-bearing metric stays exactly what the scenario
/// has always produced (replayed bit-identically below).
#[test]
fn golden_scenario_reports_nonzero_sweep_memo_hits() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let (cfg, workload) = jiagu::artifacts::latency_golden_scenario(&cat);
    let report = jiagu::sim::Simulation::new(cat, cfg, predictor)
        .run_workload(&workload)
        .unwrap();
    assert!(report.requests_served > 0);
    assert!(
        report.memo_hits > 0,
        "repeated mix signatures on the golden scenario must hit the sweep memo"
    );
    assert!(report.memo_misses > 0, "first sweep of each signature is a miss");
    assert!(report.slow_decisions > 0, "the memo only fires on the slow path");
}

/// The determinism matrix with the flat engine serving every prediction
/// and the sweep memo on the critical path: the golden scenario's merged
/// RunReport — memo counters included — must compare equal at shards
/// 1/2/4 under either Timeline implementation.
#[test]
fn golden_scenario_replays_identically_across_shards_and_queues() {
    let Some((cat, dir)) = setup() else { return };
    let predictor = load_predictor(&dir, true).unwrap();
    let mut reports = Vec::new();
    for shards in [1usize, 2, 4] {
        for queue in [QueueKind::Heap, QueueKind::Wheel] {
            let (mut cfg, workload) = jiagu::artifacts::latency_golden_scenario(&cat);
            cfg.shards = shards;
            cfg.queue = queue;
            let report = jiagu::controlplane::shard::ShardedControlPlane::new(
                cat.clone(),
                cfg,
                predictor.clone(),
            )
            .unwrap()
            .run_workload(&workload)
            .unwrap();
            reports.push((shards, queue, report));
        }
    }
    let (_, _, reference) = &reports[0];
    assert!(reference.requests_served > 0);
    assert!(reference.memo_hits > 0, "the sharded cells must hit their memos too");
    for (shards, queue, report) in &reports[1..] {
        assert_eq!(
            report, reference,
            "shards {shards} × queue {queue:?} diverged from shards 1 × heap"
        );
    }
}

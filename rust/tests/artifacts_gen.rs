//! Integration tests for the native artifact generator: byte-level
//! determinism for equal seeds, and a full round-trip through the same
//! loaders the benches/tests/examples use (`Catalog::load`,
//! `ForestParams::load`, `load_predictor(native)`).
//!
//! Unlike `golden.rs`/`e2e_sim.rs` these tests generate into a fresh
//! temp directory, so they are self-contained and never skip.

use jiagu::artifacts::{generate, GenConfig};
use jiagu::catalog::Catalog;
use jiagu::interference::{ground_truth_latency, node_utilisation, NodeMix};
use jiagu::runtime::{ForestParams, Predictor};
use jiagu::sim::load_predictor;
use jiagu::util::json::Json;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("jiagu-gen-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Small-but-meaningful budget: big enough that training finds real
/// structure (the generator's sanity bar requires it), small enough to
/// stay fast in debug builds.
fn tiny_config() -> GenConfig {
    GenConfig {
        seed: 11,
        train_rows: 1_500,
        test_rows: 250,
        n_trees: 12,
        depth: 7,
        golden_cases: 40,
        model_comparison: true,
        ..GenConfig::default()
    }
}

const DETERMINISTIC_FILES: [&str; 6] = [
    "meta.json",
    "functions.json",
    "forest.json",
    "interference_check.json",
    "predict_check.json",
    "latency_golden.json",
];

#[test]
fn same_seed_gives_byte_identical_artifacts() {
    let a = tmp_dir("det-a");
    let b = tmp_dir("det-b");
    let c = tmp_dir("det-c");
    generate(&a, &tiny_config()).unwrap();
    generate(&b, &tiny_config()).unwrap();
    generate(&c, &GenConfig { seed: 12, ..tiny_config() }).unwrap();
    for f in DETERMINISTIC_FILES {
        let x = std::fs::read(a.join(f)).unwrap();
        let y = std::fs::read(b.join(f)).unwrap();
        assert!(!x.is_empty(), "{f} must not be empty");
        assert_eq!(x, y, "{f} must be byte-identical for equal seeds");
    }
    // a different seed must actually move the data
    let x = std::fs::read(a.join("forest.json")).unwrap();
    let z = std::fs::read(c.join("forest.json")).unwrap();
    assert_ne!(x, z, "different seeds must give different forests");
    for d in [a, b, c] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn generated_artifacts_roundtrip_through_loaders() {
    let dir = tmp_dir("roundtrip");
    let report = generate(&dir, &tiny_config()).unwrap();
    assert_eq!(report.n_functions, 6);
    assert!(
        report.test_error.is_finite() && report.test_error < 0.5,
        "forest must fit the interference surface: err {:.3}",
        report.test_error
    );

    // catalog loads and validates through the production loader
    let cat = Catalog::load(&dir.join("functions.json")).unwrap();
    assert_eq!(cat.len(), 6);
    assert!(cat.id_of("rnn").is_some());

    // forest params load, validate, and agree with the meta contract
    let params = ForestParams::load(&dir.join("forest.json")).unwrap();
    assert_eq!(params.n_features, jiagu::model::N_FEATURES);
    assert!(params.test_error > 0.0, "test_error must be recorded");
    let meta = Json::parse_file(&dir.join("meta.json")).unwrap();
    assert_eq!(meta.get("n_trees").unwrap().as_usize().unwrap(), params.n_trees);
    assert_eq!(meta.get("depth").unwrap().as_usize().unwrap(), params.depth);

    // the native predictor over reloaded artifacts reproduces the
    // predict_check expectations exactly (f32 round-trips are lossless)
    let predictor = load_predictor(&dir, true).unwrap();
    let j = Json::parse_file(&dir.join("predict_check.json")).unwrap();
    let x: Vec<Vec<f32>> = j
        .get("x")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.f32_vec().unwrap())
        .collect();
    let want = j.get("expected_ms").unwrap().f32_vec().unwrap();
    let got = predictor.predict(&x).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let rel = (g - w).abs() / w.abs().max(1e-6);
        assert!(rel < 1e-6, "predict_check row {i}: {g} vs {w}");
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// Self-contained version of the byte-identical latency golden (the
/// repo-artifact variant lives in golden.rs): regenerating the fixed
/// per-request scenario from the *loaded* artifacts must reproduce
/// `latency_golden.json` exactly.
#[test]
fn latency_golden_replays_byte_identically_from_loaded_artifacts() {
    let dir = tmp_dir("latency");
    generate(&dir, &tiny_config()).unwrap();
    let cat = Catalog::load(&dir.join("functions.json")).unwrap();
    let forest = ForestParams::load(&dir.join("forest.json")).unwrap();
    let got = jiagu::artifacts::latency_golden(&cat, forest).unwrap();
    let want = std::fs::read_to_string(dir.join("latency_golden.json")).unwrap();
    assert_eq!(format!("{}\n", got.to_string()), want, "per-request golden must replay");
    let parsed = Json::parse(&want).unwrap();
    assert!(parsed.get("requests").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        parsed.get("qos_violations").unwrap().f64_vec().unwrap().len(),
        cat.len(),
        "one violation counter per function"
    );
    let _ = std::fs::remove_dir_all(dir);
}

/// The committed bench snapshots (`BENCH_*.json` at the repo root,
/// written by `make bench-snapshot`) must always parse through the
/// crate's own JSON reader and carry the expected schema — whether they
/// are the zeroed `bootstrap: true` placeholders or freshly regenerated
/// measurements.  Machine-dependent fields (wall seconds, events/sec)
/// must never appear: snapshots hold deterministic counts and
/// dimensionless ratios only.
#[test]
fn committed_bench_snapshots_parse_and_stay_machine_normalized() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    for (file, bench) in [
        ("BENCH_event_queue.json", "event_queue"),
        ("BENCH_forest_inference.json", "forest_inference"),
        ("BENCH_region_federation.json", "region_federation"),
        ("BENCH_router_hotpath.json", "router_hotpath"),
        ("BENCH_shard_scaling.json", "shard_scaling"),
        ("BENCH_trace_replay.json", "trace_replay"),
    ] {
        let snap = Json::parse_file(&root.join(file)).unwrap();
        assert_eq!(snap.get("bench").unwrap().as_str().unwrap(), bench, "{file}");
        snap.get("bootstrap").unwrap().as_bool().unwrap();
        let rows_key = if matches!(bench, "shard_scaling" | "region_federation") {
            "rows"
        } else {
            "scenarios"
        };
        let rows = snap.get(rows_key).unwrap().as_arr().unwrap();
        assert!(!rows.is_empty(), "{file}: empty {rows_key}");
        for row in rows {
            assert!(row.opt("wall_seconds").is_none(), "{file}: machine-dependent field");
            assert!(row.opt("events_per_sec").is_none(), "{file}: machine-dependent field");
            assert!(row.opt("ns_per_event").is_none(), "{file}: machine-dependent field");
        }
    }
    // the event-queue snapshot carries the wheel-vs-heap throughput ratios
    let eq = Json::parse_file(&root.join("BENCH_event_queue.json")).unwrap();
    let ratios = eq.get("wheel_over_heap_throughput").unwrap();
    for key in ["bulk_drain", "steady_churn", "million_churn"] {
        assert!(ratios.get(key).unwrap().as_f64().unwrap() >= 0.0, "ratio {key}");
    }
    // the forest-inference snapshot carries the flat-vs-reference ratios
    let fi = Json::parse_file(&root.join("BENCH_forest_inference.json")).unwrap();
    let ratios = fi.get("flat_over_reference_throughput").unwrap();
    for key in ["batch_1", "batch_32", "batch_1024"] {
        assert!(ratios.get(key).unwrap().as_f64().unwrap() >= 0.0, "ratio {key}");
    }
}

#[test]
fn generated_golden_vectors_match_the_rust_mirror() {
    // the same invariant golden.rs checks on repo artifacts, applied to a
    // fresh self-contained generation
    let dir = tmp_dir("golden");
    generate(&dir, &tiny_config()).unwrap();
    let cat = Catalog::load(&dir.join("functions.json")).unwrap();
    let cases = Json::parse_file(&dir.join("interference_check.json")).unwrap();
    let cases = cases.as_arr().unwrap();
    assert!(cases.len() >= 32);
    for case in cases {
        let names = case.get("functions").unwrap().str_vec().unwrap();
        let sat = case.get("sat").unwrap().f64_vec().unwrap();
        let cached = case.get("cached").unwrap().f64_vec().unwrap();
        let target_pos = case.get("target").unwrap().as_usize().unwrap();
        let entries: Vec<(usize, u32, u32)> = names
            .iter()
            .enumerate()
            .map(|(i, n)| (cat.id_of(n).unwrap(), sat[i] as u32, cached[i] as u32))
            .collect();
        let target = entries[target_pos].0;
        let mix = NodeMix::new(entries);
        let want = case.get("latency_ms").unwrap().as_f64().unwrap();
        let got = ground_truth_latency(&cat, &mix, target);
        assert!((got - want).abs() / want.max(1e-12) < 1e-12, "{got} vs {want}");
        let want_util = case.get("utilisation").unwrap().f64_vec().unwrap();
        for (g, w) in node_utilisation(&cat, &mix).iter().zip(&want_util) {
            assert!((g - w).abs() < 1e-12);
        }
    }
    let _ = std::fs::remove_dir_all(dir);
}

//! End-to-end properties of the multi-region federation
//! ([`jiagu::controlplane::region`]), mirroring the determinism matrix
//! CI re-checks through the CLI:
//!
//! * **crash-replay byte-identity** — the golden scenario with one
//!   region crashed at mid-horizon and replayed from its seed merges to
//!   the exact bytes of the uncrashed federation, at shard counts
//!   1/2/4 under both Timeline implementations (`--regions 2 --fail
//!   1@5000` vs `--regions 2` in the CI leg),
//! * a 1-region federation is the identity embedding of the plain
//!   unsharded simulation,
//! * heterogeneous node allotments are part of the semantics (they move
//!   bits) but replay deterministically,
//! * invalid layouts and failure specs are rejected up front with the
//!   typed errors the CLI surfaces.
//!
//! Registered in `Cargo.toml` as a `[[test]]` target (`autotests =
//! false`; `make check-test-targets` fails on unregistered files).

use jiagu::artifacts::{latency_golden_scenario, make_catalog};
use jiagu::catalog::Catalog;
use jiagu::config::RunConfig;
use jiagu::controlplane::region::{FederatedControlPlane, FederationStats};
use jiagu::controlplane::shard::ZeroNodeCell;
use jiagu::engine::QueueKind;
use jiagu::runtime::{ForestParams, NativeForestPredictor, Predictor};
use jiagu::sim::{RunReport, Simulation};
use jiagu::traces::Workload;
use std::sync::Arc;

fn stub_predictor() -> Arc<dyn Predictor> {
    Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
        jiagu::model::N_FEATURES,
        0.05,
        0.05,
    )))
}

fn golden(cat: &Catalog) -> (RunConfig, Workload) {
    latency_golden_scenario(cat)
}

fn run_federated(
    cat: &Catalog,
    cfg: RunConfig,
    wl: &Workload,
) -> (RunReport, FederationStats) {
    FederatedControlPlane::new(cat.clone(), cfg, stub_predictor())
        .unwrap()
        .run_workload(wl)
        .unwrap()
}

/// The PR's acceptance criterion, end to end: region 1 crashed at
/// mid-horizon (5000 ms of the 10 s golden horizon) and replayed from
/// its cell seed produces a merged report byte-identical to the
/// uncrashed federation — at shards 1/2/4 × queue heap/wheel, the same
/// matrix the CI determinism job compares through `jiagu run --json`.
#[test]
fn golden_scenario_crash_replay_is_byte_identical_across_shards_and_queues() {
    let cat = Catalog::from_functions(make_catalog(8, 0x5ca1e));
    let mut reference: Option<RunReport> = None;
    for shards in [1usize, 2, 4] {
        for queue in [QueueKind::Heap, QueueKind::Wheel] {
            let (mut cfg, wl) = golden(&cat);
            cfg.regions = vec![3, 3];
            cfg.shards = shards;
            cfg.queue = queue;

            let mut crashed_cfg = cfg.clone();
            crashed_cfg.failures = vec![(1, 5000.0)];

            let (clean, clean_stats) = run_federated(&cat, cfg, &wl);
            let (crashed, stats) = run_federated(&cat, crashed_cfg, &wl);
            assert_eq!(
                clean, crashed,
                "shards {shards} × {queue:?}: crash-replay moved report bytes"
            );
            assert_eq!(clean_stats.crashes, 0);
            assert_eq!(stats.crashes, 1, "shards {shards} × {queue:?}");
            assert!(stats.lost_events > 0, "the doomed run must lose real work");
            assert_eq!(stats.replayed_events, stats.lost_events);

            match &reference {
                None => {
                    assert!(clean.requests_served > 0, "scenario must route traffic");
                    assert_eq!(clean.cells, 2, "two regions merged");
                    reference = Some(clean);
                }
                Some(r) => assert_eq!(
                    *r, clean,
                    "shards {shards} × {queue:?} diverged from shards 1 × heap"
                ),
            }
        }
    }
}

/// A federation of one region is the identity embedding: same bytes as
/// the plain unsharded simulation of the same config (the region layer
/// drains the same 60 s fold chunks with the same seeds).
#[test]
fn single_region_federation_reproduces_the_unsharded_plane() {
    let cat = Catalog::from_functions(make_catalog(6, 0xfeed));
    let (mut cfg, wl) = golden(&cat);
    cfg.n_nodes = 6;
    cfg.regions = vec![6];
    let (federated, stats) = run_federated(&cat, cfg.clone(), &wl);
    cfg.regions = Vec::new();
    let plain = Simulation::new(cat, cfg, stub_predictor()).run_workload(&wl).unwrap();
    assert_eq!(federated, plain, "R = 1 must be the identity embedding");
    assert_eq!(stats.regions, 1);
    assert_eq!(stats.spilled_arrivals, 0, "one region has nowhere to spill");
}

/// Node allotments are semantics, not tuning: `[4, 2]` and `[3, 3]`
/// disagree, but each layout replays itself byte-for-byte.
#[test]
fn heterogeneous_allotments_move_bits_but_replay_deterministically() {
    let cat = Catalog::from_functions(make_catalog(8, 0x5ca1e));
    let run = |counts: Vec<usize>| {
        let (mut cfg, wl) = golden(&cat);
        cfg.regions = counts;
        run_federated(&cat, cfg, &wl)
    };
    let (balanced, _) = run(vec![3, 3]);
    let (skewed, _) = run(vec![4, 2]);
    assert!(balanced.requests_served > 0);
    assert_ne!(balanced, skewed, "the node split is part of the semantics");
    let (balanced2, stats2) = run(vec![3, 3]);
    let (skewed2, _) = run(vec![4, 2]);
    assert_eq!(balanced, balanced2, "same layout, same bytes");
    assert_eq!(skewed, skewed2, "same layout, same bytes");
    assert_eq!(stats2.regions, 2);
}

/// Invalid inputs fail construction with the typed errors the CLI
/// surfaces — never a run that silently does something else.
#[test]
fn federation_rejects_invalid_layouts_and_failure_specs() {
    let cat = Catalog::from_functions(make_catalog(6, 3));
    let build = |mutate: &dyn Fn(&mut RunConfig)| {
        let (mut cfg, _) = golden(&cat);
        cfg.regions = vec![3, 3];
        mutate(&mut cfg);
        FederatedControlPlane::new(cat.clone(), cfg, stub_predictor()).map(|_| ())
    };
    assert!(build(&|_| {}).is_ok());

    let err = build(&|cfg| cfg.regions = vec![6, 0]).unwrap_err();
    assert_eq!(err.root_cause(), ZeroNodeCell { cell: 1 }.to_string());

    assert!(build(&|cfg| cfg.failures = vec![(2, 1000.0)]).is_err(), "region out of range");
    assert!(build(&|cfg| cfg.failures = vec![(0, f64::NAN)]).is_err(), "NaN crash time");
    assert!(
        build(&|cfg| cfg.failures = vec![(0, 1.0), (0, 2.0)]).is_err(),
        "double crash of one region"
    );
    assert!(build(&|cfg| cfg.region_latency_ms = -1.0).is_err(), "negative latency");
}

//! Offline stand-in for the `anyhow` crate, exposing the 1.x API subset
//! this workspace uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build image resolves no crates.io index (see
//! `rust/src/util/rng.rs` for the same constraint on `rand`), so the real
//! crate cannot be fetched at build time. This vendored version keeps the
//! call sites source-compatible:
//!
//! * `Error` is an opaque message chain. `Display` shows the outermost
//!   message; the alternate form (`{:#}`) joins the whole chain with
//!   `": "`, matching anyhow's formatting contract that `main.rs` relies
//!   on for `error: {e:#}` output.
//! * Like the real crate, `Error` deliberately does **not** implement
//!   `std::error::Error` — that is what makes the blanket
//!   `From<E: std::error::Error>` conversion (and therefore `?` on
//!   foreign error types) coherent.

use std::fmt;

/// `Result<T, anyhow::Error>` with the same default-parameter shape as
/// the real crate, so `Result<T>` and `collect::<Result<Vec<_>>>()` work.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message chain; `chain[0]` is the outermost context, the last
/// element the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `Context` delegates to).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// results. The single `E: Into<Error>` bound covers both foreign
/// `std::error::Error` types (via the blanket `From` above) and
/// `anyhow::Error` itself (via the reflexive `From<T> for T`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any printable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_port(s: &str) -> Result<u16> {
        let port: u16 = s.parse().context("parsing port")?;
        ensure!(port > 0, "port must be nonzero, got {port}");
        Ok(port)
    }

    #[test]
    fn question_mark_converts_foreign_errors() {
        let err = parse_port("notanumber").unwrap_err();
        assert_eq!(format!("{err}"), "parsing port");
        assert!(format!("{err:#}").starts_with("parsing port: "));
    }

    #[test]
    fn ensure_and_bail_format_messages() {
        let err = parse_port("0").unwrap_err();
        assert_eq!(format!("{err}"), "port must be nonzero, got 0");
        fn f() -> Result<()> {
            bail!("boom {}", 42);
        }
        assert_eq!(format!("{}", f().unwrap_err()), "boom 42");
    }

    #[test]
    fn with_context_chains() {
        let base: Result<()> = Err(anyhow!("root"));
        let err = base.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{err}"), "outer 1");
        assert_eq!(format!("{err:#}"), "outer 1: root");
        assert_eq!(err.root_cause(), "root");
    }
}

//! Workload traces: per-function request-rate series driving the
//! simulator, plus the statistics behind Figs. 3 and 6.
//!
//! Substitution note (DESIGN.md): the paper replays Huawei Cloud
//! production traces.  We generate synthetic series with the properties
//! the evaluation depends on — diurnal swings compressed into the sim
//! horizon, heavy-tailed per-function scale, short-interval burstiness
//! (the Azure-trace CV >10 observation), and load spikes — from a seeded
//! RNG, four independent sets (A–D) from four seeds, mirroring the
//! paper's four regional trace sets.

use crate::catalog::Catalog;
use crate::util::rng::Rng;

/// One function's load series: RPS sampled once per second.
#[derive(Debug, Clone)]
pub struct FunctionTrace {
    pub rps: Vec<f64>,
}

impl FunctionTrace {
    pub fn duration_s(&self) -> usize {
        self.rps.len()
    }

    pub fn at(&self, second: usize) -> f64 {
        self.rps.get(second).copied().unwrap_or(0.0)
    }

    pub fn peak(&self) -> f64 {
        self.rps.iter().copied().fold(0.0, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.rps.is_empty() {
            0.0
        } else {
            self.rps.iter().sum::<f64>() / self.rps.len() as f64
        }
    }
}

/// A complete workload: one series per catalog function.
#[derive(Debug, Clone)]
pub struct TraceSet {
    pub name: String,
    pub functions: Vec<FunctionTrace>,
}

impl TraceSet {
    pub fn duration_s(&self) -> usize {
        self.functions.iter().map(|f| f.duration_s()).max().unwrap_or(0)
    }

    /// Load vector at `second` (one entry per function).
    pub fn loads_at(&self, second: usize) -> Vec<f64> {
        self.functions.iter().map(|f| f.at(second)).collect()
    }

    /// The event-engine form of this trace (emits one
    /// [`LoadEvent`] per per-second change).
    pub fn workload(&self) -> Workload {
        Workload::from_trace(self)
    }
}

// ---------------------------------------------------------------------------
// Event-engine workloads: load as a stream of LoadChange events.
// ---------------------------------------------------------------------------

/// One offered-load step: from `at_ms` on, `function` runs at `rps`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadEvent {
    pub at_ms: f64,
    pub function: usize,
    pub rps: f64,
}

/// A workload as the event engine consumes it: a time-sorted stream of
/// [`LoadEvent`]s at arbitrary (sub-second) resolution.  Per-second
/// [`TraceSet`]s convert losslessly via [`Workload::from_trace`]; the
/// sub-second generators ([`Workload::poisson`], [`Workload::spike_burst`],
/// [`Workload::diurnal`]) express load shapes the old 1 s tick loop could
/// not represent at all.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: String,
    pub n_functions: usize,
    /// Sorted by `at_ms` (stable: ties keep emission order, which the
    /// event queue's sequence numbers then preserve).
    pub events: Vec<LoadEvent>,
    pub duration_ms: f64,
}

impl Workload {
    /// Stable time-sort of freshly emitted events into a workload —
    /// shared by the generators here and the scenario fuzzer
    /// ([`crate::workload::fuzz`]), so every producer satisfies the same
    /// "sorted by `at_ms`, ties keep emission order" contract.
    pub(crate) fn finish(
        name: String,
        n_functions: usize,
        mut events: Vec<LoadEvent>,
        duration_ms: f64,
    ) -> Self {
        events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        Self { name, n_functions, events, duration_ms }
    }

    pub fn duration_s(&self) -> usize {
        (self.duration_ms / 1000.0).ceil() as usize
    }

    /// Convert a per-second trace, emitting an event only where a
    /// function's RPS actually changes (the engine holds loads between
    /// events).
    pub fn from_trace(trace: &TraceSet) -> Self {
        let mut events = Vec::new();
        for (f, ft) in trace.functions.iter().enumerate() {
            let mut prev = f64::NAN; // always emit the t=0 level
            for (t, rps) in ft.rps.iter().enumerate() {
                if prev.to_bits() != rps.to_bits() {
                    events.push(LoadEvent { at_ms: t as f64 * 1000.0, function: f, rps: *rps });
                    prev = *rps;
                }
            }
        }
        let duration_ms = trace.duration_s() as f64 * 1000.0;
        Self::finish(trace.name.clone(), trace.functions.len(), events, duration_ms)
    }

    /// Poisson arrivals binned at `bin_ms`: each bin's offered RPS is a
    /// Poisson draw around the function's mean rate, so short bins show
    /// the high-CV burstiness the Azure traces report — load the 1 s loop
    /// averaged away.
    pub fn poisson(cat: &Catalog, params: &PoissonParams, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let mut events = Vec::new();
        let bins = (params.duration_s as f64 * 1000.0 / params.bin_ms).ceil() as usize;
        let bin_s = params.bin_ms / 1000.0;
        for f in 0..cat.len() {
            let sat = cat.get(f).saturated_rps;
            // heavy-tailed mean concurrency per function
            let lambda = params.mean_concurrency * (0.3 + 1.4 * rng.f64() * rng.f64()) * sat;
            for b in 0..bins {
                let arrivals = rng.poisson(lambda * bin_s);
                events.push(LoadEvent {
                    at_ms: b as f64 * params.bin_ms,
                    function: f,
                    rps: arrivals as f64 / bin_s,
                });
            }
        }
        let duration_ms = params.duration_s as f64 * 1000.0;
        Self::finish(format!("poisson-{seed}"), cat.len(), events, duration_ms)
    }

    /// Sub-second spike/burst: a steady baseline with exponentially
    /// spaced bursts that multiply one function's load for 200–900 ms —
    /// shorter than one old tick, so the tick loop literally could not
    /// see them start and end.
    pub fn spike_burst(cat: &Catalog, params: &SpikeParams, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let mut events = Vec::new();
        let duration_ms = params.duration_s as f64 * 1000.0;
        for f in 0..cat.len() {
            let sat = cat.get(f).saturated_rps;
            let base = params.baseline_concurrency * sat;
            events.push(LoadEvent { at_ms: 0.0, function: f, rps: base });
            let mut t_ms = rng.exp(params.burst_rate_per_s) * 1000.0;
            while t_ms < duration_ms {
                let gain = rng.range_f64(2.0, params.max_gain.max(2.0));
                let len_ms = rng.range_f64(200.0, 900.0);
                events.push(LoadEvent { at_ms: t_ms, function: f, rps: base * gain });
                let end = (t_ms + len_ms).min(duration_ms);
                events.push(LoadEvent { at_ms: end, function: f, rps: base });
                t_ms = end + rng.exp(params.burst_rate_per_s) * 1000.0;
            }
        }
        Self::finish(format!("spike-{seed}"), cat.len(), events, duration_ms)
    }

    /// Azure-style diurnal envelope sampled sub-second: a compressed
    /// day/night sinusoid with multiplicative jitter re-drawn every
    /// `sample_ms`, so the envelope moves slowly while the instantaneous
    /// load stays bursty between autoscaler evaluations.
    pub fn diurnal(cat: &Catalog, params: &DiurnalParams, seed: u64) -> Self {
        let mut rng = Rng::seed_from(seed);
        let mut events = Vec::new();
        let duration_ms = params.duration_s as f64 * 1000.0;
        let samples = (duration_ms / params.sample_ms).ceil() as usize;
        for f in 0..cat.len() {
            let sat = cat.get(f).saturated_rps;
            let scale = params.peak_concurrency * (0.25 + 1.5 * rng.f64() * rng.f64()) * sat;
            let phase = rng.f64() * std::f64::consts::TAU;
            for s in 0..samples {
                let t_ms = s as f64 * params.sample_ms;
                let day = (t_ms / 1000.0 / params.day_period_s) * std::f64::consts::TAU;
                let envelope = 0.55 + 0.45 * (day + phase).sin();
                let jitter = (1.0 + rng.normal_ms(0.0, params.jitter_sigma)).max(0.05);
                events.push(LoadEvent {
                    at_ms: t_ms,
                    function: f,
                    rps: (scale * envelope * jitter).max(0.0),
                });
            }
        }
        Self::finish(format!("diurnal-{seed}"), cat.len(), events, duration_ms)
    }

    /// Restrict this workload to the functions `keep` accepts — the
    /// per-shard event routing of the sharded control plane
    /// ([`crate::controlplane::shard`]).  The filter is stable (relative
    /// event order is preserved, so the event queue's push-order
    /// tie-break sees the same ordering a full injection would), function
    /// ids stay **global** (`n_functions` is unchanged — cells own a
    /// sparse slice of the id space, not a re-indexed one), and the
    /// horizon (`duration_ms`) and name carry over so every cell reports
    /// the same trace identity and duration.
    pub fn restrict(&self, keep: impl Fn(usize) -> bool) -> Workload {
        Workload {
            name: self.name.clone(),
            n_functions: self.n_functions,
            events: self.events.iter().filter(|e| keep(e.function)).copied().collect(),
            duration_ms: self.duration_ms,
        }
    }

    /// Synthesize per-invocation request arrivals from this workload's
    /// load steps: per function, a Poisson process whose instantaneous
    /// rate follows the piecewise-constant RPS signal (exponential gaps
    /// re-drawn from the segment's rate; the process restarts at each
    /// step boundary, which the exponential's memorylessness makes
    /// harmless).  Each function draws from its own RNG derived from
    /// `seed`, so the streams are independent of iteration interleaving;
    /// the merged stream is stably sorted by arrival time, which the
    /// event queue's push-order tie-break then preserves.  Deterministic:
    /// equal seeds produce identical arrival vectors.
    ///
    /// A per-function safety cap ([`MAX_ARRIVALS_PER_FUNCTION`]) bounds
    /// the memory a pathological rate can claim; over-cap arrivals are
    /// dropped **and counted** (see
    /// [`Workload::synthesize_arrivals_counted`]) — never silently.
    pub fn synthesize_arrivals(&self, seed: u64) -> Vec<Arrival> {
        self.synthesize_arrivals_counted(seed).0
    }

    /// [`Workload::synthesize_arrivals`] plus the number of arrivals the
    /// per-function safety cap dropped, so callers can surface the loss
    /// (`RunReport::arrivals_dropped`) instead of truncating silently.
    /// The dropped tail is still *drawn* from the same per-function RNG
    /// the uncapped process would use — the kept prefix is bit-identical
    /// whether or not the cap engages, and the count is exact.
    pub fn synthesize_arrivals_counted(&self, seed: u64) -> (Vec<Arrival>, u64) {
        let mut arrivals: Vec<Arrival> = Vec::new();
        let mut dropped = 0u64;
        for f in 0..self.n_functions {
            let mut rng =
                Rng::seed_from(seed.wrapping_add((f as u64).wrapping_mul(0x9e3779b97f4a7c15)));
            // the function's load steps in time order (`events` is sorted;
            // a later same-instant step overrides an earlier one, matching
            // how the engine applies LoadChange events)
            let steps: Vec<&LoadEvent> =
                self.events.iter().filter(|e| e.function == f).collect();
            let mut count = 0usize;
            for (i, step) in steps.iter().enumerate() {
                let seg_end = steps
                    .get(i + 1)
                    .map(|n| n.at_ms)
                    .unwrap_or(self.duration_ms)
                    .min(self.duration_ms);
                let rate = step.rps;
                if rate <= 0.0 || !rate.is_finite() || !step.at_ms.is_finite() {
                    continue;
                }
                let mut t_ms = step.at_ms;
                loop {
                    t_ms += rng.exp(rate) * 1000.0;
                    if t_ms >= seg_end {
                        break;
                    }
                    if count >= MAX_ARRIVALS_PER_FUNCTION {
                        dropped += 1;
                        continue;
                    }
                    arrivals.push(Arrival { at_ms: t_ms, function: f });
                    count += 1;
                }
            }
        }
        arrivals.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
        (arrivals, dropped)
    }
}

/// Safety cap on synthesized arrivals per function (see
/// [`Workload::synthesize_arrivals`]).
pub const MAX_ARRIVALS_PER_FUNCTION: usize = 4 << 20;

/// One synthesized request arrival (the event-engine unit of work for
/// per-request routing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub at_ms: f64,
    pub function: usize,
}

/// Parameters for [`Workload::poisson`].
#[derive(Debug, Clone)]
pub struct PoissonParams {
    pub duration_s: usize,
    /// Sub-second bin width the arrival process is sampled at (ms).
    pub bin_ms: f64,
    /// Mean saturated-instance concurrency per function at the mean rate.
    pub mean_concurrency: f64,
}

impl Default for PoissonParams {
    fn default() -> Self {
        Self { duration_s: 120, bin_ms: 100.0, mean_concurrency: 6.0 }
    }
}

/// Parameters for [`Workload::spike_burst`].
#[derive(Debug, Clone)]
pub struct SpikeParams {
    pub duration_s: usize,
    /// Steady concurrency between bursts.
    pub baseline_concurrency: f64,
    /// Burst arrivals per second per function (exponential gaps).
    pub burst_rate_per_s: f64,
    /// Upper bound of the burst load multiplier (lower bound 2x).
    pub max_gain: f64,
}

impl Default for SpikeParams {
    fn default() -> Self {
        Self { duration_s: 120, baseline_concurrency: 2.0, burst_rate_per_s: 0.05, max_gain: 5.0 }
    }
}

/// Parameters for [`Workload::diurnal`].
#[derive(Debug, Clone)]
pub struct DiurnalParams {
    pub duration_s: usize,
    /// Jitter re-draw interval (ms).
    pub sample_ms: f64,
    /// Mean peak concurrency per function.
    pub peak_concurrency: f64,
    /// Compressed "day" period (s).
    pub day_period_s: f64,
    /// Per-sample multiplicative jitter σ.
    pub jitter_sigma: f64,
}

impl Default for DiurnalParams {
    fn default() -> Self {
        Self {
            duration_s: 300,
            sample_ms: 250.0,
            peak_concurrency: 12.0,
            day_period_s: 120.0,
            jitter_sigma: 0.15,
        }
    }
}

/// Parameters for the real-world-like generator.
#[derive(Debug, Clone)]
pub struct RealWorldParams {
    pub duration_s: usize,
    /// Mean peak concurrency (saturated instances at peak) per function.
    pub peak_concurrency: f64,
    /// "Diurnal" period compressed into the sim horizon (s).
    pub day_period_s: f64,
    /// Per-second multiplicative jitter σ.
    pub jitter_sigma: f64,
    /// Probability per second of a 2–4× burst starting (lasting 10–40 s).
    pub burst_prob: f64,
}

impl Default for RealWorldParams {
    fn default() -> Self {
        Self {
            duration_s: 1800,
            peak_concurrency: 24.0,
            day_period_s: 600.0,
            jitter_sigma: 0.12,
            burst_prob: 0.004,
        }
    }
}

/// Generate one of the A–D real-world-like trace sets.
pub fn realworld(cat: &Catalog, params: &RealWorldParams, seed: u64) -> TraceSet {
    let mut rng = Rng::seed_from(seed);
    let mut functions = Vec::with_capacity(cat.len());
    for f in 0..cat.len() {
        let sat_rps = cat.get(f).saturated_rps;
        // heavy-tailed per-function scale: some functions dominate
        let scale = params.peak_concurrency * (0.25 + 1.5 * rng.f64() * rng.f64());
        let phase = rng.f64() * std::f64::consts::TAU;
        let period = params.day_period_s * rng.range_f64(0.8, 1.25);
        let mut rps = Vec::with_capacity(params.duration_s);
        let mut burst_left = 0usize;
        let mut burst_gain = 1.0;
        for t in 0..params.duration_s {
            let diurnal = 0.55 + 0.45 * ((t as f64 / period) * std::f64::consts::TAU + phase).sin();
            if burst_left == 0 && rng.f64() < params.burst_prob {
                burst_left = rng.range_u64(10, 40) as usize;
                burst_gain = rng.range_f64(2.0, 4.0);
            }
            let burst = if burst_left > 0 {
                burst_left -= 1;
                burst_gain
            } else {
                1.0
            };
            let jitter = (1.0 + rng.normal_ms(0.0, params.jitter_sigma)).max(0.05);
            let v = (scale * diurnal * burst * jitter * sat_rps).max(0.0);
            rps.push(v);
        }
        functions.push(FunctionTrace { rps });
    }
    TraceSet { name: format!("trace-{seed}"), functions }
}

/// The four paper-style trace sets A–D.
pub fn paper_traces(cat: &Catalog, duration_s: usize) -> Vec<TraceSet> {
    let params = RealWorldParams { duration_s, ..Default::default() };
    ["A", "B", "C", "D"]
        .iter()
        .zip([101u64, 202, 303, 404])
        .map(|(name, seed)| {
            let mut t = realworld(cat, &params, seed);
            t.name = format!("Trace {name}");
            t
        })
        .collect()
}

/// Fig. 11 best case: a single function scaled up/down at a fixed period
/// ("timer trace").  Load alternates between `hi` and `lo` concurrency so
/// the autoscaler keeps creating instances of the *same* function — after
/// the first slow path, every scheduling hits the capacity table.
pub fn timer_trace(cat: &Catalog, duration_s: usize, period_s: usize) -> TraceSet {
    let mut functions = vec![FunctionTrace { rps: vec![0.0; duration_s] }; cat.len()];
    let sat = cat.get(0).saturated_rps;
    let rps = &mut functions[0].rps;
    for t in 0..duration_s {
        let phase = (t / period_s) % 2;
        rps[t] = if phase == 0 { 2.0 * sat } else { 10.0 * sat };
    }
    TraceSet { name: "timer".into(), functions }
}

/// Fig. 11 worst case: every function's concurrency flips between 0 and 1
/// with gaps longer than the keep-alive, so *every* cold start finds the
/// function absent from all capacity tables → slow path every time.
pub fn worstcase_trace(
    cat: &Catalog,
    duration_s: usize,
    gap_s: usize,
    on_s: usize,
) -> TraceSet {
    let mut functions = Vec::with_capacity(cat.len());
    for f in 0..cat.len() {
        let sat = cat.get(f).saturated_rps;
        let cycle = gap_s + on_s;
        // stagger functions so schedulings interleave
        let offset = f * cycle / cat.len().max(1);
        let mut rps = vec![0.0; duration_s];
        for (t, v) in rps.iter_mut().enumerate() {
            if (t + cycle - offset % cycle) % cycle < on_s {
                *v = 0.9 * sat; // exactly one instance expected
            }
        }
        functions.push(FunctionTrace { rps });
    }
    TraceSet { name: "worstcase".into(), functions }
}

// ---------------------------------------------------------------------------
// Trace statistics (Figs. 3 / 6).
// ---------------------------------------------------------------------------

/// Fig. 3: per-instance RPS of the hottest function over time, normalised
/// by its saturated RPS (the fluctuation the autoscaler chases).
pub fn per_instance_load_series(cat: &Catalog, trace: &TraceSet) -> Vec<f64> {
    let hottest = (0..trace.functions.len())
        .max_by(|a, b| {
            let ma = trace.functions[*a].mean();
            let mb = trace.functions[*b].mean();
            ma.partial_cmp(&mb).unwrap()
        })
        .unwrap_or(0);
    let sat = cat.get(hottest).saturated_rps;
    trace.functions[hottest]
        .rps
        .iter()
        .map(|rps| {
            let instances = (rps / sat).ceil().max(1.0);
            (rps / instances) / sat
        })
        .collect()
}

/// Fig. 6a: instance-weighted CDF of function concurrency.  Returns
/// (concurrency, cumulative instance fraction) points.
pub fn concurrency_cdf(cat: &Catalog, traces: &[TraceSet]) -> Vec<(u32, f64)> {
    // concurrency of a function = time-averaged expected instances
    let mut conc: Vec<u32> = Vec::new();
    for trace in traces {
        for (f, ft) in trace.functions.iter().enumerate() {
            let sat = cat.get(f).saturated_rps;
            let mean_inst = ft.rps.iter().map(|r| (r / sat).ceil()).sum::<f64>()
                / ft.rps.len().max(1) as f64;
            conc.push(mean_inst.round().max(0.0) as u32);
        }
    }
    conc.sort_unstable();
    let total: u64 = conc.iter().map(|c| *c as u64).sum();
    let mut out = Vec::new();
    let mut acc = 0u64;
    let mut i = 0;
    while i < conc.len() {
        let c = conc[i];
        while i < conc.len() && conc[i] == c {
            acc += conc[i] as u64;
            i += 1;
        }
        out.push((c, acc as f64 / total.max(1) as f64));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn realworld_is_deterministic_per_seed() {
        let cat = test_catalog();
        let p = RealWorldParams { duration_s: 100, ..Default::default() };
        let a = realworld(&cat, &p, 5);
        let b = realworld(&cat, &p, 5);
        assert_eq!(a.functions[0].rps, b.functions[0].rps);
        let c = realworld(&cat, &p, 6);
        assert_ne!(a.functions[0].rps, c.functions[0].rps);
    }

    #[test]
    fn realworld_loads_nonnegative_and_fluctuating() {
        let cat = test_catalog();
        let p = RealWorldParams { duration_s: 600, ..Default::default() };
        let t = realworld(&cat, &p, 1);
        for f in &t.functions {
            assert!(f.rps.iter().all(|v| *v >= 0.0));
            assert!(f.peak() > f.mean(), "series must fluctuate");
        }
    }

    #[test]
    fn timer_trace_alternates() {
        let cat = test_catalog();
        let t = timer_trace(&cat, 120, 30);
        assert!(t.functions[0].at(0) < t.functions[0].at(45));
        // only function 0 is active
        for f in 1..t.functions.len() {
            assert_eq!(t.functions[f].peak(), 0.0);
        }
    }

    #[test]
    fn worstcase_concurrency_is_zero_or_one() {
        let cat = test_catalog();
        let t = worstcase_trace(&cat, 600, 90, 20);
        for (f, ft) in t.functions.iter().enumerate() {
            let sat = cat.get(f).saturated_rps;
            for rps in &ft.rps {
                let exp = (rps / sat).ceil() as u32;
                assert!(exp <= 1, "worst case must expect 0 or 1 instances");
            }
            assert!(ft.peak() > 0.0, "every function must fire sometimes");
        }
    }

    #[test]
    fn workload_from_trace_replays_per_second_levels() {
        let cat = test_catalog();
        let p = RealWorldParams { duration_s: 50, ..Default::default() };
        let t = realworld(&cat, &p, 3);
        let wl = t.workload();
        assert_eq!(wl.n_functions, t.functions.len());
        assert_eq!(wl.duration_s(), 50);
        // fold the event stream back into per-second levels
        let mut loads = vec![0.0; wl.n_functions];
        let mut i = 0;
        for sec in 0..50usize {
            let now = sec as f64 * 1000.0;
            while i < wl.events.len() && wl.events[i].at_ms <= now {
                loads[wl.events[i].function] = wl.events[i].rps;
                i += 1;
            }
            assert_eq!(loads, t.loads_at(sec), "second {sec}");
        }
    }

    #[test]
    fn workload_events_sorted_and_deterministic() {
        let cat = test_catalog();
        for wl in [
            Workload::poisson(&cat, &PoissonParams::default(), 7),
            Workload::spike_burst(&cat, &SpikeParams::default(), 7),
            Workload::diurnal(&cat, &DiurnalParams { duration_s: 60, ..Default::default() }, 7),
        ] {
            assert!(!wl.events.is_empty());
            for w in wl.events.windows(2) {
                assert!(w[0].at_ms <= w[1].at_ms, "{}: events must be sorted", wl.name);
            }
            assert!(
                wl.events.iter().all(|e| e.rps >= 0.0 && e.function < wl.n_functions),
                "{}: events well-formed",
                wl.name
            );
        }
        let a = Workload::poisson(&cat, &PoissonParams::default(), 9);
        let b = Workload::poisson(&cat, &PoissonParams::default(), 9);
        assert_eq!(a.events, b.events, "same seed, same events");
    }

    #[test]
    fn poisson_workload_is_subsecond_and_bursty() {
        let cat = test_catalog();
        let params = PoissonParams { duration_s: 30, bin_ms: 100.0, ..Default::default() };
        let wl = Workload::poisson(&cat, &params, 11);
        assert!(
            wl.events.iter().any(|e| e.at_ms % 1000.0 != 0.0),
            "bins must land between whole seconds"
        );
        // within one second, a function's level must actually move
        let f0: Vec<f64> = wl
            .events
            .iter()
            .filter(|e| e.function == 0 && e.at_ms < 5000.0)
            .map(|e| e.rps)
            .collect();
        assert!(f0.iter().any(|r| *r != f0[0]), "sub-second variation expected");
    }

    #[test]
    fn spike_burst_returns_to_baseline_within_a_second() {
        let cat = test_catalog();
        let params = SpikeParams { duration_s: 60, burst_rate_per_s: 0.2, ..Default::default() };
        let wl = Workload::spike_burst(&cat, &params, 5);
        let sat = cat.get(0).saturated_rps;
        let base = params.baseline_concurrency * sat;
        let f0: Vec<&LoadEvent> = wl.events.iter().filter(|e| e.function == 0).collect();
        // pattern: base, then (burst, base) pairs each <= 900 ms long
        let mut saw_burst = false;
        for pair in f0.windows(2) {
            if pair[0].rps > base * 1.5 {
                saw_burst = true;
                let len = pair[1].at_ms - pair[0].at_ms;
                assert!(len <= 900.0 + 1e-9, "burst length {len} ms");
                assert!((pair[1].rps - base).abs() < 1e-9, "must return to baseline");
            }
        }
        assert!(saw_burst, "bursts must fire at rate 0.2/s over 60 s");
    }

    #[test]
    fn restrict_partitions_events_without_reordering() {
        let cat = test_catalog();
        let wl = Workload::poisson(&cat, &PoissonParams::default(), 21);
        let cells = 2usize;
        let parts: Vec<Workload> = (0..cells).map(|c| wl.restrict(|f| f % cells == c)).collect();
        for (c, p) in parts.iter().enumerate() {
            assert_eq!(p.name, wl.name);
            assert_eq!(p.n_functions, wl.n_functions, "ids stay global");
            assert_eq!(p.duration_ms, wl.duration_ms);
            assert!(p.events.iter().all(|e| e.function % cells == c));
            // stable: the cell's events appear in the original order
            let original: Vec<&LoadEvent> =
                wl.events.iter().filter(|e| e.function % cells == c).collect();
            assert_eq!(p.events.len(), original.len());
            for (a, b) in p.events.iter().zip(original) {
                assert_eq!(a, b);
            }
        }
        // the cells partition the event stream exactly
        assert_eq!(parts.iter().map(|p| p.events.len()).sum::<usize>(), wl.events.len());
    }

    #[test]
    fn arrival_synthesis_is_deterministic_and_sorted() {
        let cat = test_catalog();
        let params = PoissonParams { duration_s: 20, ..Default::default() };
        let wl = Workload::poisson(&cat, &params, 13);
        let a = wl.synthesize_arrivals(99);
        let b = wl.synthesize_arrivals(99);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed, same arrivals");
        let c = wl.synthesize_arrivals(100);
        assert_ne!(a, c, "seed must move the arrival stream");
        for w in a.windows(2) {
            assert!(w[0].at_ms <= w[1].at_ms, "arrivals must be time-sorted");
        }
        for r in &a {
            assert!(r.at_ms >= 0.0 && r.at_ms < wl.duration_ms);
            assert!(r.function < wl.n_functions);
        }
    }

    #[test]
    fn arrival_rate_tracks_the_load_signal() {
        let cat = test_catalog();
        // one function at a constant 50 rps for 100 s: expect ~5000
        // arrivals, none outside the active window
        let wl = Workload {
            name: "const".into(),
            n_functions: cat.len(),
            events: vec![
                LoadEvent { at_ms: 0.0, function: 0, rps: 50.0 },
                LoadEvent { at_ms: 100_000.0, function: 0, rps: 0.0 },
            ],
            duration_ms: 120_000.0,
        };
        let arrivals = wl.synthesize_arrivals(7);
        assert!(arrivals.iter().all(|a| a.function == 0), "only fn 0 is loaded");
        let n = arrivals.len() as f64;
        assert!((n - 5000.0).abs() < 300.0, "Poisson count ≈ rate × time, got {n}");
        assert!(
            arrivals.iter().all(|a| a.at_ms < 100_000.0),
            "no arrivals after the rate drops to zero"
        );
    }

    #[test]
    fn arrival_synthesis_skips_degenerate_rates() {
        let cat = test_catalog();
        let wl = Workload {
            name: "degenerate".into(),
            n_functions: cat.len(),
            events: vec![
                LoadEvent { at_ms: 0.0, function: 0, rps: f64::NAN },
                LoadEvent { at_ms: 0.0, function: 1, rps: f64::INFINITY },
                LoadEvent { at_ms: 0.0, function: 2, rps: -3.0 },
            ],
            duration_ms: 10_000.0,
        };
        assert!(wl.synthesize_arrivals(1).is_empty(), "degenerate rates produce nothing");
        assert_eq!(wl.synthesize_arrivals_counted(1).1, 0, "nothing dropped either");
    }

    #[test]
    fn over_cap_arrivals_are_counted_and_prefix_preserved() {
        // 450k rps × 10 s ≈ 4.5M draws against the ~4.2M per-function cap
        let wl = Workload {
            name: "flood".into(),
            n_functions: 1,
            events: vec![LoadEvent { at_ms: 0.0, function: 0, rps: 450_000.0 }],
            duration_ms: 10_000.0,
        };
        let (arrivals, dropped) = wl.synthesize_arrivals_counted(3);
        assert_eq!(arrivals.len(), MAX_ARRIVALS_PER_FUNCTION);
        assert!(dropped > 0, "the cap must engage and be counted");
        // the kept prefix is bit-identical to the plain API
        assert_eq!(arrivals, wl.synthesize_arrivals(3));
    }

    /// Restricting to a function with no load events is a valid cell
    /// assignment, not an error: the cell keeps the global id space and
    /// horizon, carries zero events, and synthesizes zero arrivals.
    #[test]
    fn restrict_keeps_zero_event_functions_structurally_alive() {
        let cat = test_catalog();
        // only function 0 ever receives load; function 1 exists but is idle
        let wl = Workload {
            name: "sparse".into(),
            n_functions: cat.len(),
            events: vec![LoadEvent { at_ms: 0.0, function: 0, rps: 10.0 }],
            duration_ms: 5_000.0,
        };
        let idle_cell = wl.restrict(|f| f == 1);
        assert_eq!(idle_cell.n_functions, wl.n_functions, "ids stay global");
        assert_eq!(idle_cell.duration_ms, wl.duration_ms, "horizon carries over");
        assert_eq!(idle_cell.name, wl.name, "trace identity carries over");
        assert!(idle_cell.events.is_empty(), "no load belongs to the idle function");
        let (arrivals, dropped) = idle_cell.synthesize_arrivals_counted(17);
        assert!(arrivals.is_empty(), "an idle cell synthesizes nothing");
        assert_eq!(dropped, 0);
    }

    /// The all-empty restriction (a cell that owns no functions) is the
    /// identity's absorbing element: structurally intact, zero events,
    /// and further restriction cannot resurrect anything.
    #[test]
    fn restrict_to_nothing_is_an_empty_but_well_formed_workload() {
        let cat = test_catalog();
        let wl = Workload::poisson(&cat, &PoissonParams::default(), 21);
        let empty = wl.restrict(|_| false);
        assert!(empty.events.is_empty());
        assert_eq!(empty.n_functions, wl.n_functions);
        assert_eq!(empty.duration_ms, wl.duration_ms);
        assert!(empty.synthesize_arrivals(9).is_empty());
        assert!(empty.restrict(|_| true).events.is_empty(), "absorbing under composition");
    }

    /// Composing two restrictions equals restricting to the predicate
    /// intersection, in either order — the algebraic fact that lets the
    /// federation layer restrict per region and then per cell.
    #[test]
    fn restrict_composed_twice_is_the_intersection() {
        let cat = test_catalog();
        let wl = Workload::poisson(&cat, &PoissonParams::default(), 34);
        let p = |f: usize| f % 2 == 0;
        let q = |f: usize| f < 3;
        let composed = wl.restrict(p).restrict(q);
        let swapped = wl.restrict(q).restrict(p);
        let intersection = wl.restrict(|f| p(f) && q(f));
        assert!(!intersection.events.is_empty(), "the overlap must carry traffic");
        assert_eq!(composed.events, intersection.events);
        assert_eq!(swapped.events, intersection.events, "composition commutes");
        assert_eq!(composed.n_functions, wl.n_functions);
        // arrivals agree too: synthesis commutes with restriction
        assert_eq!(
            composed.synthesize_arrivals(5),
            intersection.synthesize_arrivals(5)
        );
    }

    #[test]
    fn concurrency_cdf_monotone_to_one() {
        let cat = test_catalog();
        let traces = vec![realworld(
            &cat,
            &RealWorldParams { duration_s: 200, ..Default::default() },
            9,
        )];
        let cdf = concurrency_cdf(&cat, &traces);
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1 + 1e-12);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }
}

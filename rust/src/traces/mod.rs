//! Workload traces: per-function request-rate series driving the
//! simulator, plus the statistics behind Figs. 3 and 6.
//!
//! Substitution note (DESIGN.md): the paper replays Huawei Cloud
//! production traces.  We generate synthetic series with the properties
//! the evaluation depends on — diurnal swings compressed into the sim
//! horizon, heavy-tailed per-function scale, short-interval burstiness
//! (the Azure-trace CV >10 observation), and load spikes — from a seeded
//! RNG, four independent sets (A–D) from four seeds, mirroring the
//! paper's four regional trace sets.

use crate::catalog::Catalog;
use crate::util::rng::Rng;

/// One function's load series: RPS sampled once per second.
#[derive(Debug, Clone)]
pub struct FunctionTrace {
    pub rps: Vec<f64>,
}

impl FunctionTrace {
    pub fn duration_s(&self) -> usize {
        self.rps.len()
    }

    pub fn at(&self, second: usize) -> f64 {
        self.rps.get(second).copied().unwrap_or(0.0)
    }

    pub fn peak(&self) -> f64 {
        self.rps.iter().copied().fold(0.0, f64::max)
    }

    pub fn mean(&self) -> f64 {
        if self.rps.is_empty() {
            0.0
        } else {
            self.rps.iter().sum::<f64>() / self.rps.len() as f64
        }
    }
}

/// A complete workload: one series per catalog function.
#[derive(Debug, Clone)]
pub struct TraceSet {
    pub name: String,
    pub functions: Vec<FunctionTrace>,
}

impl TraceSet {
    pub fn duration_s(&self) -> usize {
        self.functions.iter().map(|f| f.duration_s()).max().unwrap_or(0)
    }

    /// Load vector at `second` (one entry per function).
    pub fn loads_at(&self, second: usize) -> Vec<f64> {
        self.functions.iter().map(|f| f.at(second)).collect()
    }
}

/// Parameters for the real-world-like generator.
#[derive(Debug, Clone)]
pub struct RealWorldParams {
    pub duration_s: usize,
    /// Mean peak concurrency (saturated instances at peak) per function.
    pub peak_concurrency: f64,
    /// "Diurnal" period compressed into the sim horizon (s).
    pub day_period_s: f64,
    /// Per-second multiplicative jitter σ.
    pub jitter_sigma: f64,
    /// Probability per second of a 2–4× burst starting (lasting 10–40 s).
    pub burst_prob: f64,
}

impl Default for RealWorldParams {
    fn default() -> Self {
        Self {
            duration_s: 1800,
            peak_concurrency: 24.0,
            day_period_s: 600.0,
            jitter_sigma: 0.12,
            burst_prob: 0.004,
        }
    }
}

/// Generate one of the A–D real-world-like trace sets.
pub fn realworld(cat: &Catalog, params: &RealWorldParams, seed: u64) -> TraceSet {
    let mut rng = Rng::seed_from(seed);
    let mut functions = Vec::with_capacity(cat.len());
    for f in 0..cat.len() {
        let sat_rps = cat.get(f).saturated_rps;
        // heavy-tailed per-function scale: some functions dominate
        let scale = params.peak_concurrency * (0.25 + 1.5 * rng.f64() * rng.f64());
        let phase = rng.f64() * std::f64::consts::TAU;
        let period = params.day_period_s * rng.range_f64(0.8, 1.25);
        let mut rps = Vec::with_capacity(params.duration_s);
        let mut burst_left = 0usize;
        let mut burst_gain = 1.0;
        for t in 0..params.duration_s {
            let diurnal = 0.55 + 0.45 * ((t as f64 / period) * std::f64::consts::TAU + phase).sin();
            if burst_left == 0 && rng.f64() < params.burst_prob {
                burst_left = rng.range_u64(10, 40) as usize;
                burst_gain = rng.range_f64(2.0, 4.0);
            }
            let burst = if burst_left > 0 {
                burst_left -= 1;
                burst_gain
            } else {
                1.0
            };
            let jitter = (1.0 + rng.normal_ms(0.0, params.jitter_sigma)).max(0.05);
            let v = (scale * diurnal * burst * jitter * sat_rps).max(0.0);
            rps.push(v);
        }
        functions.push(FunctionTrace { rps });
    }
    TraceSet { name: format!("trace-{seed}"), functions }
}

/// The four paper-style trace sets A–D.
pub fn paper_traces(cat: &Catalog, duration_s: usize) -> Vec<TraceSet> {
    let params = RealWorldParams { duration_s, ..Default::default() };
    ["A", "B", "C", "D"]
        .iter()
        .zip([101u64, 202, 303, 404])
        .map(|(name, seed)| {
            let mut t = realworld(cat, &params, seed);
            t.name = format!("Trace {name}");
            t
        })
        .collect()
}

/// Fig. 11 best case: a single function scaled up/down at a fixed period
/// ("timer trace").  Load alternates between `hi` and `lo` concurrency so
/// the autoscaler keeps creating instances of the *same* function — after
/// the first slow path, every scheduling hits the capacity table.
pub fn timer_trace(cat: &Catalog, duration_s: usize, period_s: usize) -> TraceSet {
    let mut functions = vec![FunctionTrace { rps: vec![0.0; duration_s] }; cat.len()];
    let sat = cat.get(0).saturated_rps;
    let rps = &mut functions[0].rps;
    for t in 0..duration_s {
        let phase = (t / period_s) % 2;
        rps[t] = if phase == 0 { 2.0 * sat } else { 10.0 * sat };
    }
    TraceSet { name: "timer".into(), functions }
}

/// Fig. 11 worst case: every function's concurrency flips between 0 and 1
/// with gaps longer than the keep-alive, so *every* cold start finds the
/// function absent from all capacity tables → slow path every time.
pub fn worstcase_trace(
    cat: &Catalog,
    duration_s: usize,
    gap_s: usize,
    on_s: usize,
) -> TraceSet {
    let mut functions = Vec::with_capacity(cat.len());
    for f in 0..cat.len() {
        let sat = cat.get(f).saturated_rps;
        let cycle = gap_s + on_s;
        // stagger functions so schedulings interleave
        let offset = f * cycle / cat.len().max(1);
        let mut rps = vec![0.0; duration_s];
        for (t, v) in rps.iter_mut().enumerate() {
            if (t + cycle - offset % cycle) % cycle < on_s {
                *v = 0.9 * sat; // exactly one instance expected
            }
        }
        functions.push(FunctionTrace { rps });
    }
    TraceSet { name: "worstcase".into(), functions }
}

// ---------------------------------------------------------------------------
// Trace statistics (Figs. 3 / 6).
// ---------------------------------------------------------------------------

/// Fig. 3: per-instance RPS of the hottest function over time, normalised
/// by its saturated RPS (the fluctuation the autoscaler chases).
pub fn per_instance_load_series(cat: &Catalog, trace: &TraceSet) -> Vec<f64> {
    let hottest = (0..trace.functions.len())
        .max_by(|a, b| {
            let ma = trace.functions[*a].mean();
            let mb = trace.functions[*b].mean();
            ma.partial_cmp(&mb).unwrap()
        })
        .unwrap_or(0);
    let sat = cat.get(hottest).saturated_rps;
    trace.functions[hottest]
        .rps
        .iter()
        .map(|rps| {
            let instances = (rps / sat).ceil().max(1.0);
            (rps / instances) / sat
        })
        .collect()
}

/// Fig. 6a: instance-weighted CDF of function concurrency.  Returns
/// (concurrency, cumulative instance fraction) points.
pub fn concurrency_cdf(cat: &Catalog, traces: &[TraceSet]) -> Vec<(u32, f64)> {
    // concurrency of a function = time-averaged expected instances
    let mut conc: Vec<u32> = Vec::new();
    for trace in traces {
        for (f, ft) in trace.functions.iter().enumerate() {
            let sat = cat.get(f).saturated_rps;
            let mean_inst = ft.rps.iter().map(|r| (r / sat).ceil()).sum::<f64>()
                / ft.rps.len().max(1) as f64;
            conc.push(mean_inst.round().max(0.0) as u32);
        }
    }
    conc.sort_unstable();
    let total: u64 = conc.iter().map(|c| *c as u64).sum();
    let mut out = Vec::new();
    let mut acc = 0u64;
    let mut i = 0;
    while i < conc.len() {
        let c = conc[i];
        while i < conc.len() && conc[i] == c {
            acc += conc[i] as u64;
            i += 1;
        }
        out.push((c, acc as f64 / total.max(1) as f64));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn realworld_is_deterministic_per_seed() {
        let cat = test_catalog();
        let p = RealWorldParams { duration_s: 100, ..Default::default() };
        let a = realworld(&cat, &p, 5);
        let b = realworld(&cat, &p, 5);
        assert_eq!(a.functions[0].rps, b.functions[0].rps);
        let c = realworld(&cat, &p, 6);
        assert_ne!(a.functions[0].rps, c.functions[0].rps);
    }

    #[test]
    fn realworld_loads_nonnegative_and_fluctuating() {
        let cat = test_catalog();
        let p = RealWorldParams { duration_s: 600, ..Default::default() };
        let t = realworld(&cat, &p, 1);
        for f in &t.functions {
            assert!(f.rps.iter().all(|v| *v >= 0.0));
            assert!(f.peak() > f.mean(), "series must fluctuate");
        }
    }

    #[test]
    fn timer_trace_alternates() {
        let cat = test_catalog();
        let t = timer_trace(&cat, 120, 30);
        assert!(t.functions[0].at(0) < t.functions[0].at(45));
        // only function 0 is active
        for f in 1..t.functions.len() {
            assert_eq!(t.functions[f].peak(), 0.0);
        }
    }

    #[test]
    fn worstcase_concurrency_is_zero_or_one() {
        let cat = test_catalog();
        let t = worstcase_trace(&cat, 600, 90, 20);
        for (f, ft) in t.functions.iter().enumerate() {
            let sat = cat.get(f).saturated_rps;
            for rps in &ft.rps {
                let exp = (rps / sat).ceil() as u32;
                assert!(exp <= 1, "worst case must expect 0 or 1 instances");
            }
            assert!(ft.peak() > 0.0, "every function must fire sometimes");
        }
    }

    #[test]
    fn concurrency_cdf_monotone_to_one() {
        let cat = test_catalog();
        let traces = vec![realworld(
            &cat,
            &RealWorldParams { duration_s: 200, ..Default::default() },
            9,
        )];
        let cdf = concurrency_cdf(&cat, &traces);
        assert!(!cdf.is_empty());
        for w in cdf.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1 + 1e-12);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-9);
    }
}

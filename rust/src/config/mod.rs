//! Run configuration: cluster size, scheduler choice, autoscaler tuning,
//! cold-start model and simulation horizon.  Loadable from a JSON file or
//! assembled programmatically; `rust/src/main.rs` maps CLI flags onto it.

use crate::autoscaler::AutoscalerConfig;
use crate::capacity::CapacityConfig;
use crate::engine::QueueKind;
use crate::policy::{DispatchPolicyKind, ScalingPolicyKind};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::path::Path;

/// Default partition count of the sharded control plane
/// ([`crate::controlplane::shard`]).  The partition layout — not the
/// worker-thread count — is what determines the merged report, so this
/// stays fixed while `shards` varies; the CI determinism matrix pins
/// exactly that invariance.
pub const DEFAULT_PARTITIONS: usize = 4;

/// Default uniform inter-region network latency (virtual ms) — the
/// response-time penalty of serving a request from a foreign region
/// after overflow rerouting ([`crate::controlplane::region`]).
pub const DEFAULT_REGION_LATENCY_MS: f64 = 25.0;

/// Parse one `"REGION@MS"` failure spec (shared by the `failures` JSON
/// key and the `--fail` CLI flag): region index, then the virtual crash
/// instant in milliseconds.
pub fn parse_fail_spec(s: &str) -> Result<(usize, f64)> {
    let (region, at_ms) = match s.split_once('@') {
        Some(parts) => parts,
        None => bail!("failure spec {s:?} must be REGION@MS"),
    };
    let region: usize = match region.trim().parse() {
        Ok(r) => r,
        Err(_) => bail!("failure spec {s:?}: region index must be an integer"),
    };
    let at_ms: f64 = match at_ms.trim().parse() {
        Ok(ms) => ms,
        Err(_) => bail!("failure spec {s:?}: crash time must be a number (ms)"),
    };
    if !at_ms.is_finite() || at_ms < 0.0 {
        bail!("failure spec {s:?}: crash time must be finite and >= 0");
    }
    Ok((region, at_ms))
}

/// Which scheduler drives a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Jiagu,
    Kubernetes,
    Gsight,
    Owl,
}

impl SchedulerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "jiagu" => Self::Jiagu,
            "kubernetes" | "k8s" => Self::Kubernetes,
            "gsight" => Self::Gsight,
            "owl" => Self::Owl,
            _ => bail!("unknown scheduler {s:?} (jiagu|k8s|gsight|owl)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Jiagu => "jiagu",
            Self::Kubernetes => "kubernetes",
            Self::Gsight => "gsight",
            Self::Owl => "owl",
        }
    }
}

/// Instance-initialisation latency model (Table 2 / Figs. 11b-c).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitModel {
    /// Container fork [Molecule, ASPLOS'22]: ~8.4 ms.
    Cfork,
    /// Plain Docker: ~85.5 ms.
    Docker,
    /// Fixed custom latency (ms).
    Fixed(f64),
}

impl InitModel {
    pub fn latency_ms(&self) -> f64 {
        match self {
            Self::Cfork => 8.4,
            Self::Docker => 85.5,
            Self::Fixed(ms) => *ms,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "cfork" => Self::Cfork,
            "docker" => Self::Docker,
            other => match other.parse::<f64>() {
                Ok(ms) => Self::Fixed(ms),
                Err(_) => bail!("unknown init model {s:?} (cfork|docker|<ms>)"),
            },
        })
    }
}

/// Deterministic control-plane cost model: the virtual-time price of
/// scheduling decisions and §4.3 asynchronous refreshes.
///
/// The event engine charges these *modelled* costs — derived from the
/// deterministic inference counts a decision/refresh performed — instead
/// of the measured wall clock, so event due times (and therefore the
/// whole popped event stream) replay bit-identically for a given seed.
/// The measured nanos are still carried on `Plan::decision_nanos` /
/// `DeferredUpdate::nanos` for live observability; they just never steer
/// virtual time.  Defaults are calibrated to the native forest's
/// measured order of magnitude (tens of microseconds per batched
/// inference, single-digit microseconds per table lookup).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostModel {
    /// Fixed critical-path cost of one scheduling decision (candidate
    /// ranking + capacity-table lookups), ns.
    pub decision_base_ns: u64,
    /// Cost of one batched model inference, ns (critical or asynchronous).
    pub inference_ns: u64,
    /// Fixed off-critical-path overhead of one asynchronous capacity
    /// refresh beyond its inferences, ns.
    pub refresh_base_ns: u64,
    /// Per-request dispatch overhead (routing decision + proxy hop) added
    /// to the interference-model service time of every routed request, ns.
    pub request_overhead_ns: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            decision_base_ns: 5_000,
            inference_ns: 25_000,
            refresh_base_ns: 10_000,
            request_overhead_ns: 20_000,
        }
    }
}

impl CostModel {
    /// Modelled critical-path cost of a decision that ran
    /// `critical_inferences` model inferences, ns.
    pub fn decision_ns(&self, critical_inferences: u64) -> u64 {
        self.decision_base_ns + critical_inferences * self.inference_ns
    }

    /// Same, in virtual milliseconds (what cold-start due times add).
    pub fn decision_ms(&self, critical_inferences: u64) -> f64 {
        self.decision_ns(critical_inferences) as f64 / 1e6
    }

    /// Modelled off-critical-path cost of one asynchronous refresh that
    /// ran `inferences` model inferences, ns.
    pub fn refresh_ns(&self, inferences: u64) -> u64 {
        self.refresh_base_ns + inferences * self.inference_ns
    }

    /// Same, in virtual milliseconds (the refresh's completion delay).
    pub fn refresh_ms(&self, inferences: u64) -> f64 {
        self.refresh_ns(inferences) as f64 / 1e6
    }

    /// Per-request dispatch overhead in virtual milliseconds (added to
    /// every routed request's service time).
    pub fn request_overhead_ms(&self) -> f64 {
        self.request_overhead_ns as f64 / 1e6
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub scheduler: SchedulerKind,
    pub n_nodes: usize,
    pub autoscaler: AutoscalerConfig,
    pub capacity: CapacityConfig,
    pub init_model: InitModel,
    /// Virtual seconds to simulate.
    pub duration_s: usize,
    /// Ground-truth measurement noise σ applied per QoS window.
    pub measurement_noise: f64,
    /// RNG seed for the simulator's noise streams.
    pub seed: u64,
    /// Seed of the per-invocation arrival synthesis
    /// ([`crate::traces::Workload::synthesize_arrivals_counted`]).  `None`
    /// (the default) derives it from `seed` — see
    /// [`crate::sim::ARRIVAL_SEED_SALT`].  The sharded control plane pins
    /// it explicitly on every cell so arrival streams (and therefore the
    /// per-cell `arrivals_dropped` counters) are a pure partition of the
    /// unsharded stream, independent of per-cell engine seeds.
    pub arrival_seed: Option<u64>,
    /// Deterministic virtual-time costs of decisions and refreshes.
    pub cost: CostModel,
    /// Autoscaler evaluation cadence in virtual ms (1 s mirrors the
    /// paper's testbed; sub-second workloads may want tighter loops).
    pub eval_interval_ms: f64,
    /// Per-request simulation: synthesize per-invocation arrivals from
    /// the workload's load steps and route every request individually
    /// (queueing + tail-latency attribution).  Off by default — the
    /// aggregate RPS model is much cheaper on multi-hour horizons — and
    /// orthogonal to every aggregate metric: the same seed produces the
    /// same density/QoS-window numbers with or without it.
    pub requests: bool,
    /// Worker threads of the sharded orchestrator
    /// ([`crate::controlplane::shard::ShardedControlPlane`]).  `0` (the
    /// default) runs the single unsharded control plane; any value ≥ 1
    /// runs the partitioned layout, with `shards` threads draining the
    /// partitions in parallel.  The merged report is byte-identical for
    /// every thread count — only wall-clock changes.
    pub shards: usize,
    /// Partition count of the sharded layout: functions (round-robin by
    /// id) and nodes (proportional split) are divided into this many
    /// independent control-plane cells.  Fixed independently of `shards`
    /// so the report depends only on the layout, never on parallelism;
    /// clamped to `min(n_functions, n_nodes)` at layout build time.
    pub partitions: usize,
    /// Which [`crate::engine::Timeline`] implementation orders the event
    /// stream (JSON key `queue`: `"heap"` or `"wheel"`).  Both satisfy
    /// the same `(due_ms, seq)` contract, so the choice never changes a
    /// byte of any report — the determinism matrix pins exactly that.
    pub queue: QueueKind,
    /// Per-region node counts of the federated control plane
    /// ([`crate::controlplane::region`]).  Empty (the default) runs the
    /// single-cluster path; `[a, b, ...]` runs one region per entry with
    /// that many nodes (JSON key `regions`; CLI `--regions N` splits
    /// `n_nodes` proportionally, `--regions a,b,c` is explicit).
    pub regions: Vec<usize>,
    /// Uniform off-diagonal inter-region network latency (virtual ms)
    /// added to the response time of every request served by a foreign
    /// region after overflow rerouting (JSON key `region_latency_ms`).
    pub region_latency_ms: f64,
    /// Deterministic failure plan: `(region, at_ms)` pairs, each killing
    /// one region at a virtual instant; the region is replayed from its
    /// cell seed and resumed at the crash horizon (JSON key `failures`,
    /// an array of `"REGION@MS"` strings; CLI `--fail REGION@MS[,...]`).
    pub failures: Vec<(usize, f64)>,
    /// Which request-dispatch strategy the [`crate::router::Router`] runs
    /// ([`crate::policy`]; JSON key `dispatch_policy`, CLI
    /// `--dispatch-policy`).  The default [`DispatchPolicyKind::Weighted`]
    /// reproduces the pre-policy-lab router byte-for-byte.
    pub dispatch_policy: DispatchPolicyKind,
    /// Which scaling strategy the [`crate::autoscaler::Autoscaler`]
    /// delegates its target/release decisions to ([`crate::policy`]; JSON
    /// key `scaling_policy`, CLI `--scaling-policy`).  The default
    /// [`ScalingPolicyKind::Baseline`] reproduces the pre-policy-lab
    /// dual-staged/keep-alive behaviour byte-for-byte.
    pub scaling_policy: ScalingPolicyKind,
    /// Internal (no JSON key): make each drain collect the fresh arrivals
    /// that cold-waited or queued, as overflow-rerouting candidates
    /// ([`crate::controlplane::EngineEvents::overflow_candidates`]).  Off
    /// by default — normal runs skip the per-request bookkeeping.
    pub collect_overflow: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            scheduler: SchedulerKind::Jiagu,
            n_nodes: 23, // paper: 24 machines, 1 control plane
            autoscaler: AutoscalerConfig::default(),
            capacity: CapacityConfig::default(),
            init_model: InitModel::Cfork,
            duration_s: 1800,
            measurement_noise: 0.05,
            seed: 42,
            arrival_seed: None,
            cost: CostModel::default(),
            eval_interval_ms: 1000.0,
            requests: false,
            shards: 0,
            partitions: DEFAULT_PARTITIONS,
            queue: QueueKind::Heap,
            regions: Vec::new(),
            region_latency_ms: DEFAULT_REGION_LATENCY_MS,
            failures: Vec::new(),
            dispatch_policy: DispatchPolicyKind::Weighted,
            scaling_policy: ScalingPolicyKind::Baseline,
            collect_overflow: false,
        }
    }
}

impl RunConfig {
    /// Paper variants (§7.1): Jiagu-45 (default), Jiagu-30, Jiagu-NoDS.
    pub fn jiagu_45() -> Self {
        Self::default()
    }

    pub fn jiagu_30() -> Self {
        let mut c = Self::default();
        c.autoscaler.release_duration_s = 30.0;
        c
    }

    pub fn jiagu_nods() -> Self {
        let mut c = Self::default();
        c.autoscaler.dual_staged = false;
        c.autoscaler.migration = false;
        c
    }

    pub fn with_scheduler(kind: SchedulerKind) -> Self {
        let mut c = Self::default();
        c.scheduler = kind;
        if kind != SchedulerKind::Jiagu {
            // dual-staged scaling is Jiagu's mechanism; baselines use the
            // traditional keep-alive autoscaler
            c.autoscaler.dual_staged = false;
            c.autoscaler.migration = false;
        }
        c
    }

    pub fn load(path: &Path) -> Result<Self> {
        let j = Json::parse_file(path)?;
        let mut c = Self::default();
        if let Some(v) = j.opt("scheduler") {
            c.scheduler = SchedulerKind::parse(v.as_str()?)?;
        }
        if let Some(v) = j.opt("n_nodes") {
            c.n_nodes = v.as_usize()?;
        }
        if let Some(v) = j.opt("duration_s") {
            c.duration_s = v.as_usize()?;
        }
        if let Some(v) = j.opt("seed") {
            c.seed = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("arrival_seed") {
            c.arrival_seed = Some(v.as_f64()? as u64);
        }
        if let Some(v) = j.opt("init_model") {
            c.init_model = InitModel::parse(v.as_str()?)?;
        }
        if let Some(v) = j.opt("release_duration_s") {
            c.autoscaler.release_duration_s = v.as_f64()?;
        }
        if let Some(v) = j.opt("keepalive_duration_s") {
            c.autoscaler.keepalive_duration_s = v.as_f64()?;
        }
        if let Some(v) = j.opt("dual_staged") {
            c.autoscaler.dual_staged = v.as_bool()?;
        }
        if let Some(v) = j.opt("migration") {
            c.autoscaler.migration = v.as_bool()?;
        }
        if let Some(v) = j.opt("max_candidates") {
            c.capacity.max_candidates = v.as_usize()? as u32;
        }
        if let Some(v) = j.opt("max_instances_per_node") {
            c.capacity.max_instances_per_node = v.as_usize()? as u32;
        }
        if let Some(v) = j.opt("measurement_noise") {
            c.measurement_noise = v.as_f64()?;
        }
        if let Some(v) = j.opt("decision_base_ns") {
            c.cost.decision_base_ns = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("inference_ns") {
            c.cost.inference_ns = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("refresh_base_ns") {
            c.cost.refresh_base_ns = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("eval_interval_ms") {
            c.eval_interval_ms = v.as_f64()?;
        }
        if let Some(v) = j.opt("request_overhead_ns") {
            c.cost.request_overhead_ns = v.as_f64()? as u64;
        }
        if let Some(v) = j.opt("requests") {
            c.requests = v.as_bool()?;
        }
        if let Some(v) = j.opt("shards") {
            c.shards = v.as_usize()?;
        }
        if let Some(v) = j.opt("partitions") {
            c.partitions = v.as_usize()?;
        }
        if let Some(v) = j.opt("queue") {
            let s = v.as_str()?;
            c.queue = match QueueKind::parse(s) {
                Some(kind) => kind,
                None => bail!("unknown queue kind {s:?} (heap|wheel)"),
            };
        }
        if let Some(v) = j.opt("regions") {
            c.regions =
                v.as_arr()?.iter().map(|n| n.as_usize()).collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.opt("region_latency_ms") {
            c.region_latency_ms = v.as_f64()?;
        }
        if let Some(v) = j.opt("failures") {
            c.failures = v
                .as_arr()?
                .iter()
                .map(|f| parse_fail_spec(f.as_str()?))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(v) = j.opt("dispatch_policy") {
            c.dispatch_policy = DispatchPolicyKind::parse(v.as_str()?)?;
        }
        if let Some(v) = j.opt("scaling_policy") {
            c.scaling_policy = ScalingPolicyKind::parse(v.as_str()?)?;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variants_match_paper() {
        assert_eq!(RunConfig::jiagu_30().autoscaler.release_duration_s, 30.0);
        assert_eq!(RunConfig::jiagu_45().autoscaler.release_duration_s, 45.0);
        assert!(!RunConfig::jiagu_nods().autoscaler.dual_staged);
        assert!(!RunConfig::with_scheduler(SchedulerKind::Gsight).autoscaler.dual_staged);
    }

    #[test]
    fn init_model_latencies() {
        assert_eq!(InitModel::Cfork.latency_ms(), 8.4);
        assert_eq!(InitModel::Docker.latency_ms(), 85.5);
        assert_eq!(InitModel::parse("12.5").unwrap().latency_ms(), 12.5);
        assert!(InitModel::parse("bogus").is_err());
    }

    #[test]
    fn cost_model_is_linear_in_inferences() {
        let c = CostModel {
            decision_base_ns: 1_000,
            inference_ns: 10_000,
            refresh_base_ns: 500,
            request_overhead_ns: 50_000,
        };
        assert_eq!(c.decision_ns(0), 1_000);
        assert_eq!(c.decision_ns(3), 31_000);
        assert!((c.decision_ms(3) - 0.031).abs() < 1e-12);
        assert_eq!(c.refresh_ns(2), 20_500);
        assert!((c.refresh_ms(0) - 0.0005).abs() < 1e-15);
        assert!((c.request_overhead_ms() - 0.05).abs() < 1e-15);
    }

    #[test]
    fn load_reads_shard_knobs_and_defaults_stay_unsharded() {
        let d = RunConfig::default();
        assert_eq!(d.shards, 0, "unsharded by default");
        assert_eq!(d.partitions, DEFAULT_PARTITIONS);
        let path = std::env::temp_dir().join("jiagu_cfg_shards_test.json");
        std::fs::write(&path, r#"{"shards": 2, "partitions": 8, "seed": 9}"#).unwrap();
        let c = RunConfig::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(c.shards, 2);
        assert_eq!(c.partitions, 8);
        assert_eq!(c.seed, 9);
        assert_eq!(c.arrival_seed, None, "arrival seed derives from seed by default");
    }

    #[test]
    fn load_reads_explicit_arrival_seed() {
        let path = std::env::temp_dir().join("jiagu_cfg_arrival_seed_test.json");
        std::fs::write(&path, r#"{"arrival_seed": 1234}"#).unwrap();
        let c = RunConfig::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(c.arrival_seed, Some(1234));
    }

    #[test]
    fn load_reads_queue_kind_and_rejects_unknown() {
        assert_eq!(RunConfig::default().queue, QueueKind::Heap);
        let path = std::env::temp_dir().join("jiagu_cfg_queue_test.json");
        std::fs::write(&path, r#"{"queue": "wheel"}"#).unwrap();
        let c = RunConfig::load(&path).unwrap();
        assert_eq!(c.queue, QueueKind::Wheel);
        std::fs::write(&path, r#"{"queue": "ring"}"#).unwrap();
        assert!(RunConfig::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_reads_region_knobs_and_fail_specs() {
        let d = RunConfig::default();
        assert!(d.regions.is_empty(), "single-cluster by default");
        assert!(d.failures.is_empty());
        assert_eq!(d.region_latency_ms, DEFAULT_REGION_LATENCY_MS);
        assert!(!d.collect_overflow);
        let path = std::env::temp_dir().join("jiagu_cfg_regions_test.json");
        std::fs::write(
            &path,
            r#"{"regions": [4, 2], "region_latency_ms": 12.5, "failures": ["1@5000"]}"#,
        )
        .unwrap();
        let c = RunConfig::load(&path).unwrap();
        assert_eq!(c.regions, vec![4, 2]);
        assert_eq!(c.region_latency_ms, 12.5);
        assert_eq!(c.failures, vec![(1, 5000.0)]);
        std::fs::write(&path, r#"{"failures": ["1+5000"]}"#).unwrap();
        assert!(RunConfig::load(&path).is_err(), "malformed fail spec must be rejected");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fail_spec_parses_and_rejects_garbage() {
        assert_eq!(parse_fail_spec("0@1500").unwrap(), (0, 1500.0));
        assert_eq!(parse_fail_spec(" 2 @ 250.5 ").unwrap(), (2, 250.5));
        for bad in ["", "1", "x@5", "1@y", "1@-3", "1@inf", "1@NaN"] {
            assert!(parse_fail_spec(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn load_reads_policy_kinds_and_defaults_reproduce_the_prerefactor_run() {
        let d = RunConfig::default();
        assert_eq!(d.dispatch_policy, DispatchPolicyKind::Weighted);
        assert_eq!(d.scaling_policy, ScalingPolicyKind::Baseline);
        let path = std::env::temp_dir().join("jiagu_cfg_policy_test.json");
        std::fs::write(
            &path,
            r#"{"dispatch_policy": "p2c", "scaling_policy": "harvesting"}"#,
        )
        .unwrap();
        let c = RunConfig::load(&path).unwrap();
        assert_eq!(c.dispatch_policy, DispatchPolicyKind::PowerOfTwo);
        assert_eq!(c.scaling_policy, ScalingPolicyKind::Harvesting);
        std::fs::write(&path, r#"{"dispatch_policy": "round-robin"}"#).unwrap();
        assert!(RunConfig::load(&path).is_err(), "unknown policy must be rejected");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn scheduler_kind_parse() {
        assert_eq!(SchedulerKind::parse("K8S").unwrap(), SchedulerKind::Kubernetes);
        assert!(SchedulerKind::parse("nope").is_err());
    }
}

//! From-scratch utility substrate.
//!
//! The build image resolves only the `xla` crate closure offline, so the
//! pieces a project would normally take as dependencies are implemented
//! here: a JSON parser/writer ([`json`]), deterministic PRNGs ([`rng`]),
//! and a tiny timing harness for the `cargo bench` binaries ([`bench`]).

pub mod bench;
pub mod json;
pub mod rng;

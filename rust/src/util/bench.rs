//! Tiny timing harness for the `cargo bench` binaries (harness = false).
//!
//! Hand-rolled criterion stand-in: warmup, fixed-duration measurement,
//! percentile summary, and aligned table output so every bench prints the
//! rows/series of the paper table or figure it regenerates.

use std::time::{Duration, Instant};

/// Timing summary of one benchmarked operation.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn p50_ms(&self) -> f64 {
        self.p50_ns / 1e6
    }

    pub fn p99_ms(&self) -> f64 {
        self.p99_ns / 1e6
    }
}

/// Measure `f` repeatedly for ~`budget` after `warmup` iterations.
pub fn bench<F: FnMut()>(warmup: u32, budget: Duration, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(1024);
    let start = Instant::now();
    while start.elapsed() < budget || samples.is_empty() {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() >= 200_000 {
            break;
        }
    }
    summarize(&samples)
}

/// Summarize raw nanosecond samples.
pub fn summarize(samples: &[f64]) -> Summary {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| {
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx]
    };
    Summary {
        iters: sorted.len() as u64,
        mean_ns: sorted.iter().sum::<f64>() / sorted.len() as f64,
        p50_ns: pct(0.50),
        p99_ns: pct(0.99),
        min_ns: sorted[0],
        max_ns: sorted[sorted.len() - 1],
    }
}

/// Aligned table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            line(row);
        }
    }
}

/// `fmt` helpers for table cells.
pub fn ms(v: f64) -> String {
    format!("{v:.3}ms")
}

pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

pub fn ratio(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0u64;
        let s = bench(2, Duration::from_millis(10), || n += 1);
        assert!(s.iters > 0);
        assert_eq!(n, s.iters + 2);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.max_ns);
    }

    #[test]
    fn summarize_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let s = summarize(&samples);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!((s.p50_ns - 50.0).abs() <= 1.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }
}

//! Deterministic PRNG (xoshiro256++ seeded via splitmix64) plus the
//! sampling helpers the trace generators and simulator need.
//!
//! Hand-rolled because the image resolves no `rand` crate offline; also
//! guarantees cross-run determinism for every seeded experiment.

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn seed_from(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the full state
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (n > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        // bounded via multiply-shift (Lemire); bias negligible for our n
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean/σ.
    pub fn normal_ms(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.normal()
    }

    /// Exponential with the given rate (inter-arrival sampling).
    pub fn exp(&mut self, rate: f64) -> f64 {
        -self.f64().max(1e-300).ln() / rate
    }

    /// Pareto (type I) with scale `xm > 0` and shape `alpha > 0`, via
    /// inverse-CDF sampling: heavy-tailed holding times and load
    /// multipliers for the adversarial scenario fuzzer
    /// (`workload::fuzz`).  Always returns a finite value ≥ `xm`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        // 1 - f64() lies in (0, 1]; clamp away from 0 so the power stays
        // finite even for tiny alpha
        let u = (1.0 - self.f64()).max(1e-300);
        xm * u.powf(-1.0 / alpha)
    }

    /// Poisson (Knuth for small λ, normal approximation for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            return self.normal_ms(lambda, lambda.sqrt()).round().max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::seed_from(11);
        for lambda in [0.5, 3.0, 20.0, 200.0] {
            let n = 5_000;
            let total: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.1,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn pareto_respects_scale_and_tail() {
        let mut r = Rng::seed_from(17);
        let n = 50_000;
        let (xm, alpha) = (1.0, 2.0);
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.pareto(xm, alpha);
            assert!(v.is_finite() && v >= xm, "pareto sample {v}");
            sum += v;
        }
        // E[X] = alpha * xm / (alpha - 1) = 2.0 for these parameters
        let mean = sum / n as f64;
        assert!((mean - 2.0).abs() < 0.15, "mean {mean}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::seed_from(13);
        let picked = r.choose_k(10, 4);
        assert_eq!(picked.len(), 4);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 4);
    }
}

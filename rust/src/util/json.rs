//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null).  Numbers are kept as f64 — all artifact
//! payloads are numeric matrices and metadata strings, well within f64's
//! exact-integer range.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.f64_vec()?.into_iter().map(|v| v as f32).collect())
    }

    pub fn i32_vec(&self) -> Result<Vec<i32>> {
        Ok(self.f64_vec()?.into_iter().map(|v| v as i32).collect())
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect()
    }

    /// 2-D numeric matrix as Vec<Vec<f64>>.
    pub fn f64_mat(&self) -> Result<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(|r| r.f64_vec()).collect()
    }

    // -- writer ---------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders for writer-side code (metrics dumps, reports).
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?} at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // surrogate pairs
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let lo =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at {}", self.i),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: find the full char in the source
                    let start = self.i - 1;
                    let s = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| anyhow!("invalid utf8 at {}", start))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i = start + ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        // writer → parser fixpoint
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn parses_utf8_strings() {
        let v = Json::parse("\"héllo wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld");
    }
}

//! The Jiagu pre-decision scheduler (§4), planning against a read-only
//! cluster view.
//!
//! * **Fast path**: the incoming function already has a capacity entry on
//!   a candidate node → decide by comparing `capacity` with the current
//!   instance count.  No model inference on the critical path.
//! * **Slow path**: no entry → one batched capacity sweep (one inference)
//!   on the critical path, then decide.  Sweeps for nodes that already
//!   exist warm the table; sweeps for nodes the plan itself adds stay
//!   plan-local so a dropped (dry-run) plan leaves no trace.
//! * **Asynchronous update** (§4.3): every committed placement/eviction
//!   makes the control plane call [`Scheduler::on_node_changed`], which
//!   recomputes the node's table *off* the critical path and hands the
//!   result back as a [`DeferredUpdate`].  Until the engine lands it via
//!   [`Scheduler::complete_deferred`], the fast path keeps reading the
//!   stale entries — the staleness window the paper accepts in exchange
//!   for a lookup-only critical path.
//! * **Concurrency-aware batching** (§4.4): a spike of `count` instances
//!   of one function is admitted with a single table check and triggers a
//!   single asynchronous update per touched node.

use super::{
    CandidateOrders, ClusterView, DeferredUpdate, Plan, PlanBuilder, Scheduler,
    SchedulerFeedback,
};
use crate::capacity::{self, CapacityConfig, CapacityTable, SweepCost, SweepMemo};
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, NodeId};
use crate::runtime::Predictor;
use anyhow::Result;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

pub struct JiaguScheduler {
    predictor: Arc<dyn Predictor>,
    cfg: CapacityConfig,
    tables: Vec<CapacityTable>,
    /// Count of fast/slow path decisions (Fig. 11/12 accounting).
    pub fast_decisions: u64,
    pub slow_decisions: u64,
    /// Functions under the §6 unpredictability fallback: scheduled
    /// conservatively on nodes dedicated to that function, packed only to
    /// the QoS-unaware request limit (no overcommitment).
    isolated: HashSet<FunctionId>,
    /// Incrementally-maintained candidate rankings (no per-eval re-sort).
    orders: CandidateOrders,
    /// Memo of completed capacity sweeps keyed by canonical mix signature
    /// (capacity is pure in `(target, mix)` for this scheduler's fixed
    /// catalog and config).  Per-scheduler — under sharding each cell owns
    /// its own memo, so hit/miss sequences are cell-local and the merged
    /// report stays independent of shard thread interleaving.
    memo: SweepMemo,
}

impl JiaguScheduler {
    pub fn new(predictor: Arc<dyn Predictor>, cfg: CapacityConfig, n_nodes: usize) -> Self {
        Self {
            predictor,
            cfg,
            tables: vec![CapacityTable::default(); n_nodes],
            fast_decisions: 0,
            slow_decisions: 0,
            isolated: HashSet::new(),
            orders: CandidateOrders::new(),
            memo: SweepMemo::default(),
        }
    }

    /// `(hits, misses)` of the capacity-sweep memo over this scheduler's
    /// lifetime.
    pub fn memo_counts(&self) -> (u64, u64) {
        self.memo.counts()
    }

    /// Apply / clear the §6 unpredictability fallback for a function.
    pub fn set_isolated(&mut self, f: FunctionId, isolated: bool) {
        if isolated {
            self.isolated.insert(f);
        } else {
            self.isolated.remove(&f);
        }
    }

    pub fn is_isolated(&self, f: FunctionId) -> bool {
        self.isolated.contains(&f)
    }

    /// Conservative path for unpredictable functions: plan only onto nodes
    /// hosting nothing but `function`, packed to the request limit.
    fn plan_isolated(
        &mut self,
        cat: &Catalog,
        pb: &mut PlanBuilder<'_>,
        function: FunctionId,
        count: u32,
    ) {
        let limit = cat.request_packing_limit(function);
        let mut remaining = count;
        while remaining > 0 {
            let node = (0..pb.n_nodes())
                .find(|n| {
                    let mix = pb.mix(*n);
                    let dedicated = mix
                        .entries
                        .iter()
                        .all(|(f, s, c)| *f == function || s + c == 0);
                    let total = pb.instances_on(*n) as u32;
                    dedicated && total < limit
                })
                .unwrap_or_else(|| pb.add_node());
            let fit = (limit - pb.instances_on(node) as u32).min(remaining);
            let fit = fit.max(1);
            for _ in 0..fit.min(remaining) {
                pb.place(function, node);
            }
            remaining -= fit.min(remaining);
        }
    }

    pub fn capacity_table(&self, node: NodeId) -> &CapacityTable {
        &self.tables[node]
    }

    pub fn config(&self) -> &CapacityConfig {
        &self.cfg
    }

    fn ensure_tables(&mut self, n_nodes: usize) {
        while self.tables.len() < n_nodes {
            self.tables.push(CapacityTable::default());
        }
    }

    /// Capacity of `function` on `node` under the planning view.  A table
    /// hit is the fast path; a miss is a slow-path sweep — answered from
    /// the mix-signature memo when possible, batched-inferred otherwise
    /// (`cost`/`slow` account for it).  Sweep results persist in the
    /// table for real nodes (§4.2 warm-up) and in `local` for nodes the
    /// plan itself adds.
    fn planned_capacity(
        &mut self,
        cat: &Catalog,
        pb: &PlanBuilder<'_>,
        node: NodeId,
        function: FunctionId,
        local: &mut HashMap<NodeId, u32>,
        cost: &mut SweepCost,
        slow: &mut bool,
    ) -> Result<u32> {
        if node < pb.base_nodes() {
            if let Some(e) = self.tables[node].get(function) {
                return Ok(e.capacity);
            }
        } else if let Some(cap) = local.get(&node) {
            return Ok(*cap);
        }
        let mix = pb.mix(node);
        // the sweep reports its own inference cost — never a delta of the
        // predictor's shared stats counters, which sibling shard threads
        // also bump (see compute_capacity_counted)
        let (cap, sweep_cost) = capacity::compute_capacity_memoized(
            cat,
            &mix,
            function,
            self.predictor.as_ref(),
            &self.cfg,
            &mut self.memo,
        )?;
        cost.absorb(sweep_cost);
        *slow = true;
        if node < pb.base_nodes() {
            let v = self.tables[node].version();
            self.tables[node].insert(function, cap, v);
        } else {
            local.insert(node, cap);
        }
        Ok(cap)
    }
}

impl Scheduler for JiaguScheduler {
    fn name(&self) -> &'static str {
        "jiagu"
    }

    fn apply_feedback(&mut self, feedback: SchedulerFeedback) {
        match feedback {
            SchedulerFeedback::Unpredictability { function, isolated } => {
                self.set_isolated(function, isolated);
            }
        }
    }

    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        count: u32,
        _now_ms: f64,
    ) -> Result<Plan> {
        self.ensure_tables(cluster.n_nodes());
        let t0 = Instant::now();
        let mut pb = PlanBuilder::new(cat, cluster);
        if self.isolated.contains(&function) {
            // §6 fallback: no prediction, dedicated nodes, request packing
            self.plan_isolated(cat, &mut pb, function, count);
            self.fast_decisions += 1;
            return Ok(pb.finish(false, 0, t0.elapsed().as_nanos() as u64));
        }
        let mut cost = SweepCost::default();
        let mut slow = false;
        let mut remaining = count;
        // ranked once per call from the incremental cache (a hit skips
        // the sort entirely); nodes the plan adds are appended instead of
        // re-sorting the whole order per retry
        let mut order = self.orders.take(&pb, function);
        let mut local: HashMap<NodeId, u32> = HashMap::new();

        'placing: while remaining > 0 {
            for i in 0..order.len() {
                let node = order[i];
                let (sat, cached) = pb.counts(node, function);
                let current = sat + cached;
                let cap = self.planned_capacity(
                    cat, &pb, node, function, &mut local, &mut cost, &mut slow,
                )?;
                if cap > current {
                    let fit = (cap - current).min(remaining);
                    for _ in 0..fit {
                        pb.place(function, node);
                    }
                    remaining -= fit;
                    if remaining == 0 {
                        break 'placing;
                    }
                }
            }
            // nothing fits anywhere: plan cluster growth (paper §6)
            let node = pb.add_node();
            order.push(node);
        }
        self.orders.give_back(function, order);

        if slow {
            self.slow_decisions += 1;
        } else {
            self.fast_decisions += 1;
        }
        let mut plan = pb.finish(slow, cost.inferences, t0.elapsed().as_nanos() as u64);
        plan.memo_hits = cost.memo_hits;
        plan.memo_misses = cost.memo_misses;
        Ok(plan)
    }

    /// Compute the node's asynchronous table refresh (§4.3) from the
    /// committed mix and return it as deferred work — entries become
    /// visible only when [`Scheduler::complete_deferred`] lands them.
    /// Entries are kept for (a) every function in the node's mix and (b)
    /// previously tabled functions still deployed *somewhere* in the
    /// cluster — their next arrival here then hits the fast path.
    /// Functions fully scaled to zero cluster-wide drop out (which is what
    /// makes the paper's 0↔1-concurrency worst case all slow paths).
    fn on_node_changed(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        _now_ms: f64,
    ) -> Result<Option<DeferredUpdate>> {
        self.ensure_tables(cluster.n_nodes());
        let t0 = Instant::now();
        let mix = cluster.mix(node);
        let version = self.tables[node].bump_version();
        let mut targets: HashSet<FunctionId> =
            mix.entries.iter().map(|(f, _, _)| *f).collect();
        for (f, _) in self.tables[node].iter() {
            if cluster.deployed_anywhere(*f) {
                targets.insert(*f);
            }
        }
        // sweep in function-id order: the memo's bounded clear makes
        // hit/miss sequences order-sensitive, and HashSet iteration order
        // is seeded per process — sorting keeps the refresh deterministic
        let mut targets: Vec<FunctionId> = targets.into_iter().collect();
        targets.sort_unstable();
        let mut entries = HashMap::new();
        let mut cost = SweepCost::default();
        for f in targets {
            let (cap, sweep_cost) = capacity::compute_capacity_memoized(
                cat,
                &mix,
                f,
                self.predictor.as_ref(),
                &self.cfg,
                &mut self.memo,
            )?;
            cost.absorb(sweep_cost);
            entries.insert(f, capacity::CapacityEntry { capacity: cap, mix_version: version });
        }
        Ok(Some(DeferredUpdate {
            node,
            nanos: t0.elapsed().as_nanos() as u64,
            inferences: cost.inferences,
            memo_hits: cost.memo_hits,
            memo_misses: cost.memo_misses,
            version,
            entries,
        }))
    }

    fn complete_deferred(&mut self, update: DeferredUpdate) {
        self.ensure_tables(update.node + 1);
        self.tables[update.node].apply_refresh(update.entries, update.version);
    }

    /// Conversion admission: one more *saturated* instance of `function`
    /// must stay within the node's capacity entry (slow-path sweep if the
    /// entry is missing).
    fn find_feasible_conversion(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        function: FunctionId,
    ) -> Result<bool> {
        self.ensure_tables(cluster.n_nodes());
        let (sat, _) = cluster.counts(node, function);
        let cap = match self.tables[node].get(function) {
            Some(e) => e.capacity,
            None => {
                let mix = cluster.mix(node);
                let (cap, _) = capacity::compute_capacity_memoized(
                    cat,
                    &mix,
                    function,
                    self.predictor.as_ref(),
                    &self.cfg,
                    &mut self.memo,
                )?;
                let v = self.tables[node].version();
                self.tables[node].insert(function, cap, v);
                cap
            }
        };
        Ok(sat < cap)
    }

    /// Cached instances beyond what the capacity entry would readmit are
    /// stranded: `sat + cached > capacity` ⇒ `sat + cached − max(cap, sat)`
    /// of them can never convert back on this node.
    fn stranded_cached(
        &mut self,
        _cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        function: FunctionId,
        sat: u32,
        cached: u32,
    ) -> Result<u32> {
        self.ensure_tables(cluster.n_nodes());
        let cap = match self.tables[node].get(function) {
            Some(e) => e.capacity,
            None => return Ok(0), // no entry yet: nothing known to strand
        };
        Ok((sat + cached).saturating_sub(cap.max(sat)))
    }

    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>> {
        self.ensure_tables(cluster.n_nodes());
        // split borrows: the ranking slice stays borrowed from `orders`
        // while the loop body warms `tables` and the sweep memo
        let Self { orders, tables, predictor, cfg, memo, .. } = self;
        for &node in orders.order(cluster, function) {
            if node == exclude {
                continue;
            }
            let (sat, cached) = cluster.counts(node, function);
            let current = sat + cached;
            let cap = match tables[node].get(function) {
                Some(e) => e.capacity,
                None => {
                    let mix = cluster.mix(node);
                    let (cap, _) = capacity::compute_capacity_memoized(
                        cat,
                        &mix,
                        function,
                        predictor.as_ref(),
                        cfg,
                        memo,
                    )?;
                    let v = tables[node].version();
                    tables[node].insert(function, cap, v);
                    cap
                }
            };
            if cap > current {
                return Ok(Some(node));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::super::Action;
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, InferenceStats, NativeForestPredictor};

    fn stub_predictor() -> Arc<dyn Predictor> {
        // stub forest predicts slowdown exp(0.05) = 1.05x solo — always
        // under the 1.2x QoS bound, so capacity = config cap
        Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
            crate::model::N_FEATURES,
            0.05,
            0.05,
        )))
    }

    #[test]
    fn first_schedule_is_slow_then_fast() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(2);
        let mut s = JiaguScheduler::new(stub_predictor(), CapacityConfig::default(), 2);
        let p1 = s.schedule(&cat, &cluster, 0, 1, 0.0).unwrap();
        assert_eq!(p1.path(), super::super::Path::Slow);
        assert_eq!(p1.placements_planned(), 1);
        let c1 = p1.commit(&cat, &mut cluster, 0.0);
        // the asynchronous refresh is deferred work: computed now (paying
        // its inferences off the critical path), landing only on complete
        let upd = s
            .on_node_changed(&cat, &cluster, c1.placements[0].node, 0.0)
            .unwrap()
            .unwrap();
        assert!(upd.inferences > 0, "async refresh still pays inferences");
        s.complete_deferred(upd);
        // table now warm: next call must be fast with zero critical inferences
        let p2 = s.schedule(&cat, &cluster, 0, 1, 1.0).unwrap();
        assert_eq!(p2.path(), super::super::Path::Fast);
        assert_eq!(p2.critical_inferences, 0);
    }

    #[test]
    fn spike_is_batched_single_update() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(2);
        let mut s = JiaguScheduler::new(stub_predictor(), CapacityConfig::default(), 2);
        let _ = s.schedule(&cat, &cluster, 0, 1, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
        let before_fast = s.fast_decisions;
        // spike of 5: one fast decision, placements all on one node
        let plan = s.schedule(&cat, &cluster, 0, 5, 1.0).unwrap();
        let committed = plan.commit(&cat, &mut cluster, 1.0);
        assert_eq!(committed.placements.len(), 5);
        assert_eq!(s.fast_decisions, before_fast + 1);
        assert_eq!(committed.touched_nodes().len(), 1, "batch lands on one node");
    }

    #[test]
    fn grows_cluster_when_full() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let cfg = CapacityConfig {
            max_candidates: 4,
            max_instances_per_node: 4,
            ..Default::default()
        };
        let mut s = JiaguScheduler::new(stub_predictor(), cfg, 1);
        let plan = s.schedule(&cat, &cluster, 0, 10, 0.0).unwrap();
        assert!(plan.nodes_added() >= 2, "needed extra nodes: {}", plan.nodes_added());
        let committed = plan.commit(&cat, &mut cluster, 0.0);
        assert_eq!(committed.placements.len(), 10);
        cluster.check_invariants().unwrap();
    }

    /// Predictor whose predicted latency grows with the node's total
    /// saturated count, so capacities shrink as neighbours move in —
    /// which makes capacity-table staleness observable.
    struct MixSensitivePredictor {
        stats: InferenceStats,
    }

    impl Predictor for MixSensitivePredictor {
        fn predict_batch(&self, batch: &crate::model::FeatureMatrix) -> Result<Vec<f32>> {
            self.stats.record(batch.n_rows(), 0);
            // row[0] = target solo latency, row[42] = total saturated on
            // the node; feasible while 1 + 0.04·tot ≤ 0.95 · 1.2 ⇒ tot ≤ 3
            Ok(batch.rows().map(|r| r[0] * (1.0 + 0.04 * r[42])).collect())
        }

        fn stats(&self) -> &InferenceStats {
            &self.stats
        }

        fn n_features(&self) -> usize {
            crate::model::N_FEATURES
        }
    }

    #[test]
    fn fast_path_reads_stale_table_until_deferred_update_lands() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let pred: Arc<dyn Predictor> =
            Arc::new(MixSensitivePredictor { stats: InferenceStats::default() });
        let mut s = JiaguScheduler::new(pred, CapacityConfig::default(), 1);

        // warm-up: one f0 instance; capacity(f0 | empty node) = 3
        let _ = s.schedule(&cat, &cluster, 0, 1, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
        let warm = s.on_node_changed(&cat, &cluster, 0, 0.0).unwrap().unwrap();
        s.complete_deferred(warm);
        assert_eq!(s.capacity_table(0).get(0).unwrap().capacity, 3);

        // two f1 neighbours move in; their refresh is *submitted* but not
        // yet completed — the table still claims capacity(f0) = 3
        let _ = s.schedule(&cat, &cluster, 1, 2, 1.0).unwrap().commit(&cat, &mut cluster, 1.0);
        let pending = s.on_node_changed(&cat, &cluster, 0, 1.0).unwrap().unwrap();
        assert_eq!(
            pending.entries.get(&0).unwrap().capacity,
            1,
            "the in-flight refresh already knows the shrunken capacity"
        );
        assert_eq!(s.capacity_table(0).get(0).unwrap().capacity, 3, "table still stale");

        // fast-path decision inside the staleness window: admits 2 more
        // f0 under the stale capacity 3 (a fresh table would refuse)
        let stale = s.schedule(&cat, &cluster, 0, 2, 2.0).unwrap();
        assert_eq!(stale.path(), super::super::Path::Fast);
        assert_eq!(stale.critical_inferences, 0);
        assert!(stale
            .actions
            .iter()
            .all(|a| matches!(a, Action::Place { node: 0, .. })));
        let _ = stale.commit(&cat, &mut cluster, 2.0);

        // the update lands: capacity(f0) = 1 < 3 running, so the next f0
        // can no longer fit and must grow the cluster
        s.complete_deferred(pending);
        assert_eq!(s.capacity_table(0).get(0).unwrap().capacity, 1);
        let after = s.schedule(&cat, &cluster, 0, 1, 3.0).unwrap();
        assert_eq!(after.nodes_added(), 1, "fresh capacity forces growth");
    }

    #[test]
    fn repeated_mix_signatures_hit_the_sweep_memo() {
        let cat = test_catalog();
        let cluster = Cluster::new(3); // three identical empty nodes
        let cfg = CapacityConfig {
            max_candidates: 2,
            max_instances_per_node: 2,
            ..Default::default()
        };
        let mut s = JiaguScheduler::new(stub_predictor(), cfg, 3);
        // 6 instances over nodes of capacity 2: the first empty-node sweep
        // misses, every further empty node shares the (f, []) signature
        let plan = s.schedule(&cat, &cluster, 0, 6, 0.0).unwrap();
        assert_eq!(plan.placements_planned(), 6);
        assert_eq!(plan.memo_misses, 1);
        assert_eq!(plan.memo_hits, 2);
        assert_eq!(plan.critical_inferences, 1, "only the miss paid an inference");
        assert_eq!(plan.path(), super::super::Path::Slow, "a memo hit is still a table miss");
        assert_eq!(s.memo_counts(), (2, 1));
    }

    #[test]
    fn feedback_toggles_isolation() {
        let cat = test_catalog();
        let cluster = Cluster::new(2);
        let mut s = JiaguScheduler::new(stub_predictor(), CapacityConfig::default(), 2);
        s.apply_feedback(SchedulerFeedback::Unpredictability { function: 1, isolated: true });
        assert!(s.is_isolated(1));
        // isolated planning never touches the model
        let plan = s.schedule(&cat, &cluster, 1, 3, 0.0).unwrap();
        assert_eq!(plan.critical_inferences, 0);
        assert_eq!(plan.placements_planned(), 3);
        s.apply_feedback(SchedulerFeedback::Unpredictability { function: 1, isolated: false });
        assert!(!s.is_isolated(1));
    }
}

//! The Jiagu pre-decision scheduler (§4).
//!
//! * **Fast path**: the incoming function already has a capacity entry on
//!   a candidate node → decide by comparing `capacity` with the current
//!   instance count.  No model inference on the critical path.
//! * **Slow path**: no entry → one batched capacity sweep (one inference)
//!   on the critical path, then decide.
//! * **Asynchronous update** (§4.3): every placement/eviction triggers a
//!   full-table recompute *off* the critical path; entries therefore
//!   already encode neighbour QoS validation, so placement never needs a
//!   synchronous validation step.
//! * **Concurrency-aware batching** (§4.4): a spike of `count` instances
//!   of one function is admitted with a single table check and triggers a
//!   single asynchronous update.

use super::{candidate_order, Placement, ScheduleResult, Scheduler};
use crate::capacity::{self, CapacityConfig, CapacityTable};
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, NodeId};
use crate::runtime::Predictor;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

pub struct JiaguScheduler {
    predictor: Arc<dyn Predictor>,
    cfg: CapacityConfig,
    tables: Vec<CapacityTable>,
    /// Count of fast/slow path decisions (Fig. 11/12 accounting).
    pub fast_decisions: u64,
    pub slow_decisions: u64,
    /// Functions under the §6 unpredictability fallback: scheduled
    /// conservatively on nodes dedicated to that function, packed only to
    /// the QoS-unaware request limit (no overcommitment).
    isolated: std::collections::HashSet<FunctionId>,
}

impl JiaguScheduler {
    pub fn new(predictor: Arc<dyn Predictor>, cfg: CapacityConfig, n_nodes: usize) -> Self {
        Self {
            predictor,
            cfg,
            tables: vec![CapacityTable::default(); n_nodes],
            fast_decisions: 0,
            slow_decisions: 0,
            isolated: std::collections::HashSet::new(),
        }
    }

    /// Apply / clear the §6 unpredictability fallback for a function.
    pub fn set_isolated(&mut self, f: FunctionId, isolated: bool) {
        if isolated {
            self.isolated.insert(f);
        } else {
            self.isolated.remove(&f);
        }
    }

    pub fn is_isolated(&self, f: FunctionId) -> bool {
        self.isolated.contains(&f)
    }

    /// Conservative path for unpredictable functions: place only on nodes
    /// hosting nothing but `function`, packed to the request limit.
    fn schedule_isolated(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        function: FunctionId,
        count: u32,
        now_ms: f64,
        res: &mut ScheduleResult,
    ) {
        let limit = cat.request_packing_limit(function);
        let mut remaining = count;
        while remaining > 0 {
            let node = (0..cluster.n_nodes())
                .find(|n| {
                    let mix = cluster.mix(*n);
                    let dedicated = mix
                        .entries
                        .iter()
                        .all(|(f, s, c)| *f == function || s + c == 0);
                    let total = cluster.nodes[*n].instances.len() as u32;
                    dedicated && total < limit
                })
                .unwrap_or_else(|| {
                    res.nodes_added += 1;
                    cluster.add_node()
                });
            if self.tables.len() < cluster.n_nodes() {
                self.ensure_tables(cluster.n_nodes());
            }
            let fit = (limit - cluster.nodes[node].instances.len() as u32).min(remaining);
            let fit = fit.max(1);
            for _ in 0..fit.min(remaining) {
                let id = cluster.place(cat, function, node, now_ms);
                res.placements.push(Placement { instance: id, node });
            }
            remaining -= fit.min(remaining);
        }
    }

    pub fn capacity_table(&self, node: NodeId) -> &CapacityTable {
        &self.tables[node]
    }

    pub fn config(&self) -> &CapacityConfig {
        &self.cfg
    }

    fn ensure_tables(&mut self, n_nodes: usize) {
        while self.tables.len() < n_nodes {
            self.tables.push(CapacityTable::default());
        }
    }

    /// Asynchronous update body: recompute the node's capacity table
    /// under its current mix.  Entries are kept for (a) every function in
    /// the node's mix and (b) previously tabled functions still deployed
    /// *somewhere* in the cluster — their next arrival here then hits the
    /// fast path.  Functions fully scaled to zero cluster-wide drop out
    /// (which is what makes the paper's 0↔1-concurrency worst case all
    /// slow paths).  Returns (nanos, inferences).
    fn async_update(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
    ) -> Result<(u64, u64)> {
        let t0 = Instant::now();
        let (calls0, _, _) = self.predictor.stats().snapshot();
        let mix = cluster.mix(node);
        let version = self.tables[node].bump_version();
        let mut targets: Vec<crate::catalog::FunctionId> =
            mix.entries.iter().map(|(f, _, _)| *f).collect();
        for (f, _) in self.tables[node].iter() {
            if !targets.contains(f) && cluster.deployed_anywhere(*f) {
                targets.push(*f);
            }
        }
        let mut entries = std::collections::HashMap::new();
        for f in targets {
            let cap =
                capacity::compute_capacity(cat, &mix, f, self.predictor.as_ref(), &self.cfg)?;
            entries.insert(f, capacity::CapacityEntry { capacity: cap, mix_version: version });
        }
        self.tables[node].replace(entries);
        let (calls1, _, _) = self.predictor.stats().snapshot();
        Ok((t0.elapsed().as_nanos() as u64, calls1 - calls0))
    }
}

impl Scheduler for JiaguScheduler {
    fn name(&self) -> &'static str {
        "jiagu"
    }

    fn as_jiagu_mut(&mut self) -> Option<&mut JiaguScheduler> {
        Some(self)
    }

    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        function: FunctionId,
        count: u32,
        now_ms: f64,
    ) -> Result<ScheduleResult> {
        self.ensure_tables(cluster.n_nodes());
        let mut res = ScheduleResult::default();
        let t0 = Instant::now();
        if self.isolated.contains(&function) {
            // §6 fallback: no prediction, dedicated nodes, request packing
            self.schedule_isolated(cat, cluster, function, count, now_ms, &mut res);
            self.fast_decisions += 1;
            res.decision_nanos = t0.elapsed().as_nanos() as u64;
            return Ok(res);
        }
        let mut remaining = count;
        let mut touched: Vec<NodeId> = Vec::new();

        'placing: while remaining > 0 {
            for node in candidate_order(cluster, function) {
                let (sat, cached) = cluster.counts(node, function);
                let current = sat + cached;
                // fast path: existing entry admits (current + batch)?
                let cap = match self.tables[node].get(function) {
                    Some(e) => e.capacity,
                    None => {
                        // slow path: one batched sweep on the critical path
                        let mix = cluster.mix(node);
                        let (c0, _, _) = self.predictor.stats().snapshot();
                        let cap = capacity::compute_capacity(
                            cat,
                            &mix,
                            function,
                            self.predictor.as_ref(),
                            &self.cfg,
                        )?;
                        let (c1, _, _) = self.predictor.stats().snapshot();
                        res.critical_inferences += c1 - c0;
                        res.slow_path_used = true;
                        let v = self.tables[node].version();
                        self.tables[node].insert(function, cap, v);
                        cap
                    }
                };
                if cap > current {
                    let fit = (cap - current).min(remaining);
                    for _ in 0..fit {
                        let id = cluster.place(cat, function, node, now_ms);
                        res.placements.push(Placement { instance: id, node });
                    }
                    remaining -= fit;
                    if !touched.contains(&node) {
                        touched.push(node);
                    }
                    if remaining == 0 {
                        break 'placing;
                    }
                }
            }
            // nothing fits anywhere: grow the cluster (paper §6)
            let _node = cluster.add_node();
            self.ensure_tables(cluster.n_nodes());
            res.nodes_added += 1;
        }

        if res.slow_path_used {
            self.slow_decisions += 1;
        } else {
            self.fast_decisions += 1;
        }
        res.decision_nanos = t0.elapsed().as_nanos() as u64;

        // one asynchronous update per touched node — off the critical path
        for node in touched {
            self.tables[node].bump_version();
            let (nanos, inf) = self.async_update(cat, cluster, node)?;
            res.async_nanos += nanos;
            res.async_inferences += inf;
        }
        Ok(res)
    }

    fn on_node_changed(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        _now_ms: f64,
    ) -> Result<u64> {
        self.ensure_tables(cluster.n_nodes());
        self.tables[node].bump_version();
        let (nanos, _) = self.async_update(cat, cluster, node)?;
        Ok(nanos)
    }

    /// Conversion admission: one more *saturated* instance of `function`
    /// must stay within the node's capacity entry (slow-path sweep if the
    /// entry is missing).
    fn find_feasible_conversion(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        function: FunctionId,
    ) -> Result<bool> {
        self.ensure_tables(cluster.n_nodes());
        let (sat, _) = cluster.counts(node, function);
        let cap = match self.tables[node].get(function) {
            Some(e) => e.capacity,
            None => {
                let mix = cluster.mix(node);
                let cap = capacity::compute_capacity(
                    cat,
                    &mix,
                    function,
                    self.predictor.as_ref(),
                    &self.cfg,
                )?;
                let v = self.tables[node].version();
                self.tables[node].insert(function, cap, v);
                cap
            }
        };
        Ok(sat < cap)
    }

    /// Cached instances beyond what the capacity entry would readmit are
    /// stranded: `sat + cached > capacity` ⇒ `sat + cached − max(cap, sat)`
    /// of them can never convert back on this node.
    fn stranded_cached(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        node: NodeId,
        function: FunctionId,
        sat: u32,
        cached: u32,
    ) -> Result<u32> {
        self.ensure_tables(node + 1);
        let cap = match self.tables[node].get(function) {
            Some(e) => e.capacity,
            None => return Ok(0), // no entry yet: nothing known to strand
        };
        Ok((sat + cached).saturating_sub(cap.max(sat)))
    }

    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>> {
        self.ensure_tables(cluster.n_nodes());
        for node in candidate_order(cluster, function) {
            if node == exclude {
                continue;
            }
            let (sat, cached) = cluster.counts(node, function);
            let current = sat + cached;
            let cap = match self.tables[node].get(function) {
                Some(e) => e.capacity,
                None => {
                    let mix = cluster.mix(node);
                    let cap = capacity::compute_capacity(
                        cat,
                        &mix,
                        function,
                        self.predictor.as_ref(),
                        &self.cfg,
                    )?;
                    let v = self.tables[node].version();
                    self.tables[node].insert(function, cap, v);
                    cap
                }
            };
            if cap > current {
                return Ok(Some(node));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};

    fn stub_predictor() -> Arc<dyn Predictor> {
        // stub forest predicts slowdown exp(0.05) = 1.05x solo — always
        // under the 1.2x QoS bound, so capacity = config cap
        Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
            crate::model::N_FEATURES,
            0.05,
            0.05,
        )))
    }

    #[test]
    fn first_schedule_is_slow_then_fast() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(2);
        let mut s = JiaguScheduler::new(stub_predictor(), CapacityConfig::default(), 2);
        let r1 = s.schedule(&cat, &mut cluster, 0, 1, 0.0).unwrap();
        assert_eq!(r1.path(), super::super::Path::Slow);
        assert_eq!(r1.placements.len(), 1);
        // table now warm: next call must be fast with zero critical inferences
        let r2 = s.schedule(&cat, &mut cluster, 0, 1, 1.0).unwrap();
        assert_eq!(r2.path(), super::super::Path::Fast);
        assert_eq!(r2.critical_inferences, 0);
        assert!(r2.async_inferences > 0, "async update still runs");
    }

    #[test]
    fn spike_is_batched_single_update() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(2);
        let mut s = JiaguScheduler::new(stub_predictor(), CapacityConfig::default(), 2);
        s.schedule(&cat, &mut cluster, 0, 1, 0.0).unwrap();
        let before_fast = s.fast_decisions;
        // spike of 5: one fast decision, placements all on one node
        let r = s.schedule(&cat, &mut cluster, 0, 5, 1.0).unwrap();
        assert_eq!(r.placements.len(), 5);
        assert_eq!(s.fast_decisions, before_fast + 1);
        let nodes: std::collections::HashSet<_> =
            r.placements.iter().map(|p| p.node).collect();
        assert_eq!(nodes.len(), 1, "batch lands on one node");
    }

    #[test]
    fn grows_cluster_when_full() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let cfg = CapacityConfig {
            max_candidates: 4,
            max_instances_per_node: 4,
            ..Default::default()
        };
        let mut s = JiaguScheduler::new(stub_predictor(), cfg, 1);
        let r = s.schedule(&cat, &mut cluster, 0, 10, 0.0).unwrap();
        assert_eq!(r.placements.len(), 10);
        assert!(r.nodes_added >= 2, "needed extra nodes: {}", r.nodes_added);
        cluster.check_invariants().unwrap();
    }
}

//! Instance schedulers: Jiagu (pre-decision) and the paper's baselines.
//!
//! | Scheduler | Decision basis | Model inference on critical path? |
//! |---|---|---|
//! | [`JiaguScheduler`] | capacity-table lookup (fast path) / one batched sweep (slow path) | fast path: none |
//! | [`GsightScheduler`] | per-decision QoS validation | every decision |
//! | [`OwlScheduler`] | historical pairwise colocation table, ≤2 functions/node | none (profiled offline) |
//! | [`KubernetesScheduler`] | requested-resource bin packing | none (QoS-unaware) |
//!
//! All decisions are timed with a monotonic clock; the simulator injects
//! the measured wall-clock cost into the virtual cold-start timeline, so
//! the Fig. 11/12 scheduling-cost comparisons measure *real code*, not
//! modelled constants.

mod gsight;
mod jiagu;
mod kubernetes;
mod owl;

pub use gsight::GsightScheduler;
pub use jiagu::JiaguScheduler;
pub use kubernetes::KubernetesScheduler;
pub use owl::OwlScheduler;

use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, InstanceId, NodeId};
use anyhow::Result;

/// Which code path produced a decision (Figs. 11/12 accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// Capacity-table lookup only (Jiagu).
    Fast,
    /// Model inference on the critical path.
    Slow,
    /// No model at all (K8s / Owl).
    Heuristic,
}

/// One placed instance.
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    pub instance: InstanceId,
    pub node: NodeId,
}

/// Outcome of one scheduling call (possibly placing several instances —
/// concurrency-aware batching schedules a whole spike at once).
#[derive(Debug, Clone, Default)]
pub struct ScheduleResult {
    pub placements: Vec<Placement>,
    /// Worst path taken across the call.
    pub slow_path_used: bool,
    /// Wall-clock nanoseconds on the scheduling critical path.
    pub decision_nanos: u64,
    /// Wall-clock nanoseconds spent off the critical path (asynchronous
    /// capacity-table updates).
    pub async_nanos: u64,
    /// Model inferences on the critical path.
    pub critical_inferences: u64,
    /// Model inferences off the critical path (asynchronous updates).
    pub async_inferences: u64,
    /// Nodes added because nothing fit.
    pub nodes_added: u32,
}

impl ScheduleResult {
    pub fn path(&self) -> Path {
        if self.critical_inferences > 0 || self.slow_path_used {
            Path::Slow
        } else {
            Path::Fast
        }
    }
}

/// A scheduler places new instances onto nodes and keeps whatever internal
/// state it needs in sync with cluster events.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Downcast hook: the simulator toggles the §6 unpredictability
    /// fallback, which only the Jiagu scheduler implements.
    fn as_jiagu_mut(&mut self) -> Option<&mut JiaguScheduler> {
        None
    }

    /// Place `count` new instances of `function`.  Implementations may
    /// grow the cluster if nothing fits.  Instances are created in the
    /// `Starting` state; the caller drives init completion.
    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        function: FunctionId,
        count: u32,
        now_ms: f64,
    ) -> Result<ScheduleResult>;

    /// Notify that a node's mix changed outside scheduling (eviction,
    /// release, reactivate, migration) so internal state can refresh.
    /// Returns nanoseconds of off-critical-path work performed.
    fn on_node_changed(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        now_ms: f64,
    ) -> Result<u64>;

    /// Pick a node able to host one more saturated instance of `function`
    /// (used by the autoscaler's on-demand migration).  Must not place.
    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>>;

    /// Can `node` convert one cached instance of `function` back to
    /// saturated without violating QoS (logical cold start admission)?
    /// QoS-unaware schedulers admit unconditionally.
    fn find_feasible_conversion(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _function: FunctionId,
    ) -> Result<bool> {
        Ok(true)
    }

    /// How many of `cached` cached instances of `function` on `node` are
    /// *stranded* — could no longer be converted back to saturated because
    /// the node's capacity shrank (§5 on-demand migration).  QoS-unaware
    /// schedulers never strand instances.
    fn stranded_cached(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _function: FunctionId,
        _sat: u32,
        _cached: u32,
    ) -> Result<u32> {
        Ok(0)
    }
}

/// Shared helper: order candidate nodes for a function — nodes already
/// hosting it first (likely fast path + locality, §6 node filter), then by
/// total instances descending (pack tighter), empty nodes last.
pub(crate) fn candidate_order(
    cluster: &Cluster,
    function: FunctionId,
) -> Vec<NodeId> {
    let mut nodes: Vec<NodeId> = (0..cluster.n_nodes()).collect();
    nodes.sort_by_key(|n| {
        let (sat, cached) = cluster.counts(*n, function);
        let hosts = sat + cached > 0;
        let total = cluster.nodes[*n].instances.len();
        // hosting nodes first (0), then non-empty (1), then empty (2);
        // within a class, fuller nodes first
        let class = if hosts { 0 } else if total > 0 { 1 } else { 2 };
        (class, usize::MAX - total)
    });
    nodes
}

//! Instance schedulers behind the **plan/commit** API: Jiagu
//! (pre-decision) and the paper's baselines.
//!
//! | Scheduler | Decision basis | Model inference on critical path? |
//! |---|---|---|
//! | [`JiaguScheduler`] | capacity-table lookup (fast path) / one batched sweep (slow path) | fast path: none |
//! | [`GsightScheduler`] | per-decision QoS validation | every decision |
//! | [`OwlScheduler`] | historical pairwise colocation table, ≤2 functions/node | none (profiled offline) |
//! | [`KubernetesScheduler`] | requested-resource bin packing | none (QoS-unaware) |
//!
//! ## Plan / commit
//!
//! [`Scheduler::schedule`] never mutates the cluster.  It plans against a
//! read-only [`Cluster`] through a [`PlanBuilder`] — the builder overlays
//! the placements and node additions planned so far onto the immutable
//! cluster, so multi-instance batches still see their own effects — and
//! returns a [`Plan`] of typed [`Action`]s plus critical-path cost
//! accounting.  [`Plan::commit`] replays the actions onto the cluster and
//! yields the realised [`CommittedPlan`]; a plan that is never committed
//! leaves the *cluster* untouched, making what-if probes and
//! deterministic replay possible.  (Scheduler-internal state still moves
//! during planning — slow-path sweeps warm capacity tables and decision
//! counters advance — so dry-runs are free for the cluster, not for the
//! cost accounting.)
//!
//! ## Asynchronous updates are deferred work
//!
//! Jiagu's §4.3 capacity-table refresh runs *off* the critical path.  The
//! API models that honestly: after the control plane commits a mutation
//! touching a node it calls [`Scheduler::on_node_changed`], which
//! *computes* the refresh (billing its wall-clock off-path) and returns a
//! [`DeferredUpdate`] — the new table entries are **not yet visible**.
//! The event engine completes the update via
//! [`Scheduler::complete_deferred`] at `now + modelled cost` in virtual
//! time (`config::CostModel`, linear in the refresh's inference count —
//! deterministic, so replays stay bit-identical); until then every
//! fast-path decision genuinely reads the stale table, which is the
//! staleness window the paper defends (§4.3) and Figs. 11/12 price.
//!
//! ## Typed feedback
//!
//! The §6 online-accuracy verdicts reach the scheduler through
//! [`Scheduler::apply_feedback`] ([`SchedulerFeedback`]) instead of a
//! concrete-type downcast, so alternative QoS-aware schedulers can opt
//! into the unpredictability fallback without the engine knowing them.
//!
//! All decisions are still timed with a monotonic clock
//! (`Plan::decision_nanos`, for live profiling), but the virtual
//! cold-start timeline charges the *modelled* per-inference cost from
//! `config::CostModel` — the inference counts are real and
//! deterministic, the wall clock is not, and determinism of the event
//! stream wins (see `controlplane` for the full argument).

mod gsight;
mod jiagu;
mod kubernetes;
mod owl;

pub use gsight::GsightScheduler;
pub use jiagu::JiaguScheduler;
pub use kubernetes::KubernetesScheduler;
pub use owl::OwlScheduler;

use crate::capacity::CapacityEntry;
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, InstanceId, NodeId};
use crate::interference::NodeMix;
use anyhow::Result;
use std::collections::HashMap;

/// Which code path produced a decision (Figs. 11/12 accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// Capacity-table lookup only (Jiagu).
    Fast,
    /// Model inference on the critical path.
    Slow,
    /// No model at all (K8s / Owl).
    Heuristic,
}

/// One placed instance (the realised form of [`Action::Place`]).
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    pub instance: InstanceId,
    pub node: NodeId,
}

/// One typed scheduling decision inside a [`Plan`].  Node ids refer to the
/// cluster the plan was computed against; ids at or past its node count
/// denote nodes the plan itself adds (in [`Action::AddNode`] order).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Grow the cluster by one node (the paper requests new servers when
    /// nothing fits, §6).
    AddNode,
    /// Start one instance of `function` on `node`.
    Place { function: FunctionId, node: NodeId },
}

/// Outcome of one `schedule` call: the typed decisions plus critical-path
/// cost accounting.  Nothing has happened to the cluster yet — commit the
/// plan (or drop it for a dry run).
#[must_use = "a Plan changes nothing until committed"]
#[derive(Debug, Clone, Default)]
pub struct Plan {
    pub actions: Vec<Action>,
    /// Whether any model inference ran on the critical path.
    pub slow_path_used: bool,
    /// Wall-clock nanoseconds on the scheduling critical path.
    pub decision_nanos: u64,
    /// Model inferences on the critical path.
    pub critical_inferences: u64,
    /// Capacity sweeps this decision answered from the mix-signature memo
    /// (each hit is a whole batched inference avoided).
    pub memo_hits: u64,
    /// Capacity sweeps this decision ran because the memo missed.
    pub memo_misses: u64,
    /// Node count of the cluster the plan was computed against — virtual
    /// node ids start here, and `commit` refuses a cluster whose size no
    /// longer matches (stale plans must not remap onto the wrong nodes).
    base_nodes: usize,
}

impl Plan {
    pub fn path(&self) -> Path {
        if self.critical_inferences > 0 || self.slow_path_used {
            Path::Slow
        } else {
            Path::Fast
        }
    }

    /// Number of `Place` actions in the plan.
    pub fn placements_planned(&self) -> usize {
        self.actions
            .iter()
            .filter(|a| matches!(a, Action::Place { .. }))
            .count()
    }

    /// Number of `AddNode` actions in the plan.
    pub fn nodes_added(&self) -> u32 {
        self.actions.iter().filter(|a| **a == Action::AddNode).count() as u32
    }

    /// Actuate the plan: replay its actions onto `cluster` (which must be
    /// the cluster the plan was computed against, unchanged since).  New
    /// instances are created in the `Starting` state; the caller drives
    /// init completion and the per-node asynchronous refreshes.
    ///
    /// # Panics
    ///
    /// Panics if the cluster's node count no longer matches the one the
    /// plan was computed against — committing a stale plan would silently
    /// remap its `AddNode` placements onto unrelated nodes.
    pub fn commit(self, cat: &Catalog, cluster: &mut Cluster, now_ms: f64) -> CommittedPlan {
        assert!(
            self.actions.is_empty() || cluster.n_nodes() == self.base_nodes,
            "plan computed against {} nodes committed to a cluster with {}",
            self.base_nodes,
            cluster.n_nodes()
        );
        let base = self.base_nodes;
        let mut new_nodes: Vec<NodeId> = Vec::new();
        let mut placements = Vec::with_capacity(self.placements_planned());
        for action in &self.actions {
            match action {
                Action::AddNode => new_nodes.push(cluster.add_node()),
                Action::Place { function, node } => {
                    let node = if *node < base {
                        *node
                    } else {
                        new_nodes[*node - base]
                    };
                    let id = cluster.place(cat, *function, node, now_ms);
                    placements.push(Placement { instance: id, node });
                }
            }
        }
        CommittedPlan { plan: self, placements }
    }
}

/// A committed [`Plan`] plus the instances it actually created.
#[derive(Debug, Clone)]
pub struct CommittedPlan {
    pub plan: Plan,
    pub placements: Vec<Placement>,
}

impl CommittedPlan {
    /// Nodes the committed plan placed onto, deduplicated in first-touch
    /// order — each wants one asynchronous refresh (§4.4 batching).
    pub fn touched_nodes(&self) -> Vec<NodeId> {
        let mut nodes = Vec::new();
        for p in &self.placements {
            if !nodes.contains(&p.node) {
                nodes.push(p.node);
            }
        }
        nodes
    }
}

/// Typed feedback from the control plane to a scheduler (replaces the old
/// `as_jiagu_mut` concrete-type downcast).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SchedulerFeedback {
    /// §6 online-accuracy verdict: `isolated = true` moves `function`
    /// under the conservative unpredictability fallback, `false` lifts it.
    Unpredictability { function: FunctionId, isolated: bool },
}

/// An asynchronous capacity-table refresh in flight (§4.3): computed from
/// a snapshot of the node's mix, billed off the critical path, and
/// invisible until [`Scheduler::complete_deferred`] lands it.
#[derive(Debug, Clone)]
pub struct DeferredUpdate {
    pub node: NodeId,
    /// Wall-clock nanoseconds the off-critical-path computation took —
    /// the engine turns this into the virtual completion delay.
    pub nanos: u64,
    /// Model inferences the computation spent.
    pub inferences: u64,
    /// Per-function sweeps inside the refresh answered from the memo.
    pub memo_hits: u64,
    /// Per-function sweeps that missed the memo and ran the predictor.
    pub memo_misses: u64,
    /// Node-mix version the refresh was computed under (stale refreshes
    /// that complete out of order are dropped).
    pub version: u64,
    /// The recomputed capacity entries.
    pub entries: HashMap<FunctionId, CapacityEntry>,
}

/// Read-only cluster facts schedulers plan against — implemented by the
/// live [`Cluster`] and by [`PlanBuilder`] (cluster + planned overlay), so
/// the same policy code serves both planning and feasibility probes.
pub trait ClusterView {
    fn n_nodes(&self) -> usize;
    /// (saturated+starting, cached) counts of `function` on `node`.
    fn counts(&self, node: NodeId, function: FunctionId) -> (u32, u32);
    /// Total instances on `node`, any state.
    fn instances_on(&self, node: NodeId) -> usize;
    /// The interference mix of `node` (entries sorted by function id).
    fn mix(&self, node: NodeId) -> NodeMix;
    /// Requested (milli-CPU, memory MB) totals on `node`.
    fn requested(&self, node: NodeId) -> (u64, u64);
    /// Whether any instance (any state, any node) of `f` exists.
    fn deployed_anywhere(&self, f: FunctionId) -> bool;
    /// Cache stamp for incrementally-maintained candidate orders:
    /// `Some((order_epoch, n_nodes))` when the view's ordering facts are
    /// exactly the committed cluster's (the live [`Cluster`], or a
    /// [`PlanBuilder`] with no planned actions yet — identical by
    /// construction), `None` when planned actions make the view
    /// plan-local and uncacheable.
    fn order_stamp(&self) -> Option<(u64, usize)>;
}

impl ClusterView for Cluster {
    fn n_nodes(&self) -> usize {
        Cluster::n_nodes(self)
    }

    fn counts(&self, node: NodeId, function: FunctionId) -> (u32, u32) {
        Cluster::counts(self, node, function)
    }

    fn instances_on(&self, node: NodeId) -> usize {
        self.nodes[node].instances.len()
    }

    fn mix(&self, node: NodeId) -> NodeMix {
        Cluster::mix(self, node)
    }

    fn requested(&self, node: NodeId) -> (u64, u64) {
        let n = &self.nodes[node];
        (n.requested_milli_cpu, n.requested_mem_mb)
    }

    fn deployed_anywhere(&self, f: FunctionId) -> bool {
        Cluster::deployed_anywhere(self, f)
    }

    fn order_stamp(&self) -> Option<(u64, usize)> {
        Some((self.order_epoch(), Cluster::n_nodes(self)))
    }
}

/// The scheduler's working state during one `schedule` call: an immutable
/// [`Cluster`] plus the placements and node additions planned so far.
/// Recording a placement updates the overlay, so later decisions in the
/// same plan observe earlier ones exactly as committed state would.
pub struct PlanBuilder<'a> {
    cat: &'a Catalog,
    cluster: &'a Cluster,
    actions: Vec<Action>,
    /// Per-node planned saturated additions (keyed sparsely; covers
    /// planned virtual nodes too).
    planned: HashMap<NodeId, HashMap<FunctionId, u32>>,
    extra_nodes: usize,
}

impl<'a> PlanBuilder<'a> {
    pub fn new(cat: &'a Catalog, cluster: &'a Cluster) -> Self {
        Self {
            cat,
            cluster,
            actions: Vec::new(),
            planned: HashMap::new(),
            extra_nodes: 0,
        }
    }

    /// Nodes that exist in the underlying cluster (ids below this are
    /// real; ids at or above are planned by this builder).
    pub fn base_nodes(&self) -> usize {
        self.cluster.n_nodes()
    }

    /// Plan one node addition; returns the id the node will get.
    pub fn add_node(&mut self) -> NodeId {
        self.actions.push(Action::AddNode);
        let id = self.cluster.n_nodes() + self.extra_nodes;
        self.extra_nodes += 1;
        id
    }

    /// Plan one placement of `function` on `node`.
    pub fn place(&mut self, function: FunctionId, node: NodeId) {
        self.actions.push(Action::Place { function, node });
        *self
            .planned
            .entry(node)
            .or_default()
            .entry(function)
            .or_insert(0) += 1;
    }

    /// Placements planned so far.
    pub fn placed(&self) -> u32 {
        self.planned
            .values()
            .map(|m| m.values().sum::<u32>())
            .sum()
    }

    /// Seal the plan with its critical-path accounting.
    pub fn finish(
        self,
        slow_path_used: bool,
        critical_inferences: u64,
        decision_nanos: u64,
    ) -> Plan {
        Plan {
            actions: self.actions,
            slow_path_used,
            decision_nanos,
            critical_inferences,
            // memo accounting is stamped by the scheduler after sealing
            // (only Jiagu's sweeps have a memo to report)
            memo_hits: 0,
            memo_misses: 0,
            base_nodes: self.cluster.n_nodes(),
        }
    }
}

impl ClusterView for PlanBuilder<'_> {
    fn n_nodes(&self) -> usize {
        self.cluster.n_nodes() + self.extra_nodes
    }

    fn counts(&self, node: NodeId, function: FunctionId) -> (u32, u32) {
        let (sat, cached) = if node < self.cluster.n_nodes() {
            self.cluster.counts(node, function)
        } else {
            (0, 0)
        };
        let extra = self
            .planned
            .get(&node)
            .and_then(|m| m.get(&function))
            .copied()
            .unwrap_or(0);
        (sat + extra, cached)
    }

    fn instances_on(&self, node: NodeId) -> usize {
        let base = if node < self.cluster.n_nodes() {
            self.cluster.nodes[node].instances.len()
        } else {
            0
        };
        let extra: u32 = self
            .planned
            .get(&node)
            .map(|m| m.values().sum())
            .unwrap_or(0);
        base + extra as usize
    }

    fn mix(&self, node: NodeId) -> NodeMix {
        let mut entries = if node < self.cluster.n_nodes() {
            self.cluster.mix(node).entries
        } else {
            Vec::new()
        };
        if let Some(extra) = self.planned.get(&node) {
            for (f, add) in extra {
                match entries.iter_mut().find(|(g, _, _)| g == f) {
                    Some(e) => e.1 += *add,
                    None => entries.push((*f, *add, 0)),
                }
            }
            entries.sort_unstable_by_key(|(f, _, _)| *f);
        }
        NodeMix::new(entries)
    }

    fn requested(&self, node: NodeId) -> (u64, u64) {
        let (mut cpu, mut mem) = if node < self.cluster.n_nodes() {
            let n = &self.cluster.nodes[node];
            (n.requested_milli_cpu, n.requested_mem_mb)
        } else {
            (0, 0)
        };
        if let Some(extra) = self.planned.get(&node) {
            for (f, add) in extra {
                let spec = self.cat.get(*f);
                cpu += *add as u64 * spec.milli_cpu;
                mem += *add as u64 * spec.mem_mb;
            }
        }
        (cpu, mem)
    }

    fn deployed_anywhere(&self, f: FunctionId) -> bool {
        self.cluster.deployed_anywhere(f)
            || self
                .planned
                .values()
                .any(|m| m.get(&f).copied().unwrap_or(0) > 0)
    }

    fn order_stamp(&self) -> Option<(u64, usize)> {
        if self.actions.is_empty() {
            // an overlay with nothing planned reports exactly the facts
            // the committed cluster does
            self.cluster.order_stamp()
        } else {
            None
        }
    }
}

/// A scheduler plans new instance placements against a read-only cluster
/// view and keeps whatever internal state it needs in sync with committed
/// cluster events.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Plan the placement of `count` new instances of `function`.
    /// Implementations may plan cluster growth if nothing fits.  The
    /// cluster is untouched; the caller commits (or drops) the plan.
    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        count: u32,
        now_ms: f64,
    ) -> Result<Plan>;

    /// Apply typed control-plane feedback (§6 unpredictability verdicts).
    /// Schedulers without the corresponding mechanism ignore it.
    fn apply_feedback(&mut self, _feedback: SchedulerFeedback) {}

    /// Notify that `node`'s committed mix changed (placement, eviction,
    /// release, reactivate, migration).  Stateful schedulers compute their
    /// asynchronous refresh *now* (off the critical path, from the current
    /// mix) and return it as [`DeferredUpdate`] for the engine to land at
    /// its virtual completion time; stateless schedulers return `None`.
    fn on_node_changed(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        node: NodeId,
        now_ms: f64,
    ) -> Result<Option<DeferredUpdate>>;

    /// Land a refresh previously returned by
    /// [`Scheduler::on_node_changed`] — only now do its entries become
    /// visible to the fast path.
    fn complete_deferred(&mut self, _update: DeferredUpdate) {}

    /// Pick a node able to host one more saturated instance of `function`
    /// (used by the autoscaler's on-demand migration).  Must not plan or
    /// place.
    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>>;

    /// Can `node` convert one cached instance of `function` back to
    /// saturated without violating QoS (logical cold start admission)?
    /// QoS-unaware schedulers admit unconditionally.
    fn find_feasible_conversion(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _function: FunctionId,
    ) -> Result<bool> {
        Ok(true)
    }

    /// How many of `cached` cached instances of `function` on `node` are
    /// *stranded* — could no longer be converted back to saturated because
    /// the node's capacity shrank (§5 on-demand migration).  QoS-unaware
    /// schedulers never strand instances.
    fn stranded_cached(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _function: FunctionId,
        _sat: u32,
        _cached: u32,
    ) -> Result<u32> {
        Ok(0)
    }
}

/// Full recompute of the candidate ranking for one function — nodes
/// already hosting it first (likely fast path + locality, §6 node
/// filter), then by total instances descending (pack tighter), empty
/// nodes last.  Works over any [`ClusterView`], so planning overlays rank
/// identically to the committed cluster.
///
/// The sort key is a function of `counts(n, f)` (summed) and
/// `instances_on(n)` **only**, and [`Cluster`]'s order epoch advances
/// exactly when one of those can move — if this key ever grows another
/// input, the epoch bumps in `cluster/` must grow with it or
/// [`CandidateOrders`] serves stale rankings.
fn ranked_nodes<C: ClusterView + ?Sized>(view: &C, function: FunctionId) -> Vec<NodeId> {
    let mut nodes: Vec<NodeId> = (0..view.n_nodes()).collect();
    nodes.sort_by_key(|n| {
        let (sat, cached) = view.counts(*n, function);
        let hosts = sat + cached > 0;
        let total = view.instances_on(*n);
        // hosting nodes first (0), then non-empty (1), then empty (2);
        // within a class, fuller nodes first
        let class = if hosts { 0 } else if total > 0 { 1 } else { 2 };
        (class, usize::MAX - total)
    });
    nodes
}

/// Incrementally-maintained per-function candidate orders — the
/// million-entity replacement for recomputing `candidate_order` as a
/// fresh `Vec` on every eval.  An order is recomputed only when the
/// view's [`ClusterView::order_stamp`] moved; when the cluster merely
/// grew, the new nodes are appended in place (a new node is empty, so the
/// stable full re-sort would put it at exactly that tail position — empty
/// nodes tie and stay in id order); any other change (removal included)
/// invalidates the slot.  [`Self::order`] hands out a borrowed slice;
/// nothing is allocated or sorted on a cache hit.
#[derive(Debug, Default)]
pub(crate) struct CandidateOrders {
    slots: Vec<OrderSlot>,
}

#[derive(Debug, Default)]
struct OrderSlot {
    /// `(order_epoch, n_nodes)` stamp of the view `nodes` ranks; `None`
    /// when the slot holds nothing reusable (never filled, taken and not
    /// returned, or computed against an uncacheable mid-plan overlay).
    stamp: Option<(u64, usize)>,
    nodes: Vec<NodeId>,
}

impl CandidateOrders {
    pub fn new() -> Self {
        Self::default()
    }

    /// The candidate order of `function` under `view`, as a borrowed
    /// slice valid until the next call on this cache.
    pub fn order<C: ClusterView + ?Sized>(
        &mut self,
        view: &C,
        function: FunctionId,
    ) -> &[NodeId] {
        self.refresh(view, function);
        &self.slots[function].nodes
    }

    /// Like [`Self::order`], but moves the buffer out, so planning loops
    /// can keep ranking while the scheduler (and this cache with it) is
    /// mutably borrowed, and may **append** plan-virtual node ids to it.
    /// Hand the buffer back with [`Self::give_back`]; appending is the
    /// only permitted mutation, so the cached prefix survives the trip.
    pub fn take<C: ClusterView + ?Sized>(
        &mut self,
        view: &C,
        function: FunctionId,
    ) -> Vec<NodeId> {
        self.refresh(view, function);
        std::mem::take(&mut self.slots[function].nodes)
    }

    /// Return a buffer obtained from [`Self::take`].  The appended tail
    /// (plan-virtual nodes) is truncated away; if the take-time stamp was
    /// cacheable, the surviving prefix is still exactly that stamp's
    /// order, so the slot revalidates without a re-sort.
    pub fn give_back(&mut self, function: FunctionId, mut nodes: Vec<NodeId>) {
        let slot = &mut self.slots[function];
        match slot.stamp {
            Some((_, n)) => nodes.truncate(n),
            None => nodes.clear(),
        }
        slot.nodes = nodes;
    }

    fn refresh<C: ClusterView + ?Sized>(&mut self, view: &C, function: FunctionId) {
        if self.slots.len() <= function {
            self.slots.resize_with(function + 1, OrderSlot::default);
        }
        let slot = &mut self.slots[function];
        let now = view.order_stamp();
        match (slot.stamp, now) {
            // hit: nothing order-affecting moved since the stamp (the
            // length check rejects a buffer taken and never given back)
            (Some(s), Some(n)) if s == n && slot.nodes.len() == n.1 => {}
            // append-on-grow: same epoch, nodes only added
            (Some((e0, n0)), Some((e1, n1)))
                if e0 == e1 && n0 < n1 && slot.nodes.len() == n0 =>
            {
                slot.nodes.extend(n0..n1);
                slot.stamp = now;
            }
            _ => {
                slot.nodes = ranked_nodes(view, function);
                slot.stamp = now;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn plan_builder_overlays_placements_and_nodes() {
        let cat = test_catalog();
        let cluster = Cluster::new(1);
        let mut pb = PlanBuilder::new(&cat, &cluster);
        assert_eq!(pb.n_nodes(), 1);
        pb.place(0, 0);
        pb.place(0, 0);
        let v = pb.add_node();
        assert_eq!(v, 1);
        pb.place(1, v);
        assert_eq!(pb.n_nodes(), 2);
        assert_eq!(pb.counts(0, 0), (2, 0));
        assert_eq!(pb.counts(v, 1), (1, 0));
        assert_eq!(pb.instances_on(0), 2);
        assert_eq!(pb.mix(0).entries, vec![(0, 2, 0)]);
        assert_eq!(pb.mix(v).entries, vec![(1, 1, 0)]);
        assert!(pb.deployed_anywhere(1));
        let spec = cat.get(0);
        assert_eq!(pb.requested(0), (2 * spec.milli_cpu, 2 * spec.mem_mb));
        assert_eq!(pb.placed(), 3);
        // the underlying cluster never moved
        assert_eq!(cluster.instances_len(), 0);
    }

    #[test]
    fn commit_replays_actions_and_maps_virtual_nodes() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let mut pb = PlanBuilder::new(&cat, &cluster);
        pb.place(0, 0);
        let v = pb.add_node();
        pb.place(2, v);
        let plan = pb.finish(false, 0, 0);
        assert_eq!(plan.placements_planned(), 2);
        assert_eq!(plan.nodes_added(), 1);
        let committed = plan.commit(&cat, &mut cluster, 5.0);
        assert_eq!(cluster.n_nodes(), 2);
        assert_eq!(committed.placements.len(), 2);
        assert_eq!(committed.placements[0].node, 0);
        assert_eq!(committed.placements[1].node, 1);
        assert_eq!(cluster.counts(1, 2), (1, 0));
        assert_eq!(committed.touched_nodes(), vec![0, 1]);
        cluster.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "plan computed against")]
    fn stale_plan_refuses_commit_after_cluster_growth() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let mut pb = PlanBuilder::new(&cat, &cluster);
        let v = pb.add_node();
        pb.place(0, v);
        let plan = pb.finish(false, 0, 0);
        cluster.add_node(); // cluster changed since planning
        let _ = plan.commit(&cat, &mut cluster, 0.0);
    }

    /// Randomized place/evict/grow sequences: the cached order must be
    /// indistinguishable from a fresh recompute at every step (the
    /// append-on-grow and invalidate-on-change paths both get exercised).
    #[test]
    fn candidate_orders_match_fresh_recompute_under_mutation() {
        use crate::cluster::InstanceId;
        use crate::util::rng::Rng;
        let cat = test_catalog();
        let mut cluster = Cluster::new(3);
        let mut orders = CandidateOrders::new();
        let mut rng = Rng::seed_from(11);
        let mut live: Vec<InstanceId> = Vec::new();
        for step in 0..300usize {
            match rng.below(8) {
                0 | 1 | 2 => {
                    let f = rng.below(cat.len() as u64) as usize;
                    let n = rng.below(cluster.n_nodes() as u64) as usize;
                    let id = cluster.place(&cat, f, n, step as f64);
                    cluster.mark_ready(id, step as f64);
                    live.push(id);
                }
                3 if !live.is_empty() => {
                    let i = rng.below(live.len() as u64) as usize;
                    let id = live.swap_remove(i);
                    cluster.evict(&cat, id);
                }
                4 => {
                    cluster.add_node();
                }
                _ => {} // cache-hit rounds: nothing moves
            }
            for f in 0..cat.len() {
                assert_eq!(
                    orders.order(&cluster, f),
                    ranked_nodes(&cluster, f).as_slice(),
                    "step {step} fn {f}: cached order diverged"
                );
            }
        }
    }

    #[test]
    fn take_give_back_truncates_plan_virtual_nodes() {
        let cat = test_catalog();
        let cluster = Cluster::new(2);
        let mut orders = CandidateOrders::new();
        let mut taken = orders.take(&cluster, 0);
        let fresh = ranked_nodes(&cluster, 0);
        assert_eq!(taken, fresh);
        // a planning loop appends virtual node ids past the real ones
        taken.push(2);
        taken.push(3);
        orders.give_back(0, taken);
        assert_eq!(orders.order(&cluster, 0), fresh.as_slice());
    }

    /// A `PlanBuilder` with planned actions is uncacheable (`None` stamp):
    /// ranking against it must see the overlay, and ranking against the
    /// committed cluster right after must not reuse the overlay's order.
    #[test]
    fn mid_plan_overlays_are_uncacheable_but_correct() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(3);
        // make node 2 the fullest so it ranks first for a newcomer
        for _ in 0..2 {
            let id = cluster.place(&cat, 1, 2, 0.0);
            cluster.mark_ready(id, 0.0);
        }
        let mut orders = CandidateOrders::new();
        let mut pb = PlanBuilder::new(&cat, &cluster);
        assert!(pb.order_stamp().is_some(), "empty overlay is cacheable");
        pb.place(0, 0);
        assert_eq!(pb.order_stamp(), None, "planned actions poison the stamp");
        assert_eq!(orders.order(&pb, 0), ranked_nodes(&pb, 0).as_slice());
        // node 0 now hosts fn 0 in the overlay, so it ranks first there…
        assert_eq!(orders.order(&pb, 0)[0], 0);
        // …but the committed cluster never saw the placement
        assert_eq!(orders.order(&cluster, 0), ranked_nodes(&cluster, 0).as_slice());
        assert_eq!(orders.order(&cluster, 0)[0], 2);
    }

    #[test]
    fn dropped_plan_is_a_free_dry_run() {
        let cat = test_catalog();
        let cluster = Cluster::new(2);
        let mut pb = PlanBuilder::new(&cat, &cluster);
        for _ in 0..5 {
            pb.place(0, 0);
        }
        let plan = pb.finish(false, 0, 0);
        drop(plan);
        assert_eq!(cluster.instances_len(), 0);
        assert_eq!(cluster.n_nodes(), 2);
    }
}

//! Owl-style baseline [SoCC'22]: historical-information scheduling.
//!
//! Owl profiles *pairs* of functions at varying instance counts on
//! dedicated servers and records the co-location limits it observed; at
//! schedule time it only consults that history (fast), and it never
//! colocates more than **two distinct functions** per node — the
//! limitation the paper calls out in Fig. 13.  Admission runs over
//! [`ClusterView`], so a planned batch respects its own placements.
//!
//! Port notes: real Owl measures pairs on real hardware.  Our substrate's
//! "profiling run" queries the ground-truth interference model with
//! measurement noise — the same information a dedicated profiling node
//! would produce — and is memoized into the pair table.  Profiling cost
//! is counted (`profiling_samples`) for Table 1's O(n²k) scaling.

use super::{CandidateOrders, ClusterView, DeferredUpdate, Plan, PlanBuilder, Scheduler};
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, NodeId};
use crate::interference::{self, NodeMix};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::time::Instant;

pub struct OwlScheduler {
    /// max feasible count of `a` colocated with `b_count` instances of
    /// `b`: `pair_cap[(a, b)][b_count] = max a_count` (0 = none).
    pair_cap: HashMap<(FunctionId, FunctionId), Vec<u32>>,
    /// Solo capacity per function.
    solo_cap: HashMap<FunctionId, u32>,
    /// Ground-truth queries spent profiling (Table 1 accounting).
    pub profiling_samples: u64,
    max_count: u32,
    noise_sigma: f64,
    /// Same admission margin the QoS-aware schedulers use: a profiled
    /// colocation is feasible when measured latency <= headroom x QoS.
    qos_headroom: f64,
    rng: Rng,
    /// Incrementally-maintained candidate rankings (no per-instance
    /// re-sort when nothing moved).
    orders: CandidateOrders,
}

impl OwlScheduler {
    pub fn new(seed: u64) -> Self {
        Self {
            pair_cap: HashMap::new(),
            solo_cap: HashMap::new(),
            profiling_samples: 0,
            max_count: 28,
            noise_sigma: 0.05,
            qos_headroom: 0.95,
            rng: Rng::seed_from(seed),
            orders: CandidateOrders::new(),
        }
    }

    /// "Measure" a colocation on a profiling node: ground truth + noise.
    fn measure(&mut self, cat: &Catalog, mix: &NodeMix, target: FunctionId) -> f64 {
        self.profiling_samples += 1;
        let truth = interference::ground_truth_latency(cat, mix, target);
        truth * (1.0 + self.rng.normal_ms(0.0, self.noise_sigma))
    }

    fn profile_solo(&mut self, cat: &Catalog, f: FunctionId) -> u32 {
        if let Some(c) = self.solo_cap.get(&f) {
            return *c;
        }
        let mut cap = 0;
        for n in 1..=self.max_count {
            let mix = NodeMix::new(vec![(f, n, 0)]);
            if self.measure(cat, &mix, f) <= self.qos_headroom * cat.get(f).qos_latency_ms {
                cap = n;
            } else {
                break;
            }
        }
        self.solo_cap.insert(f, cap);
        cap
    }

    /// Max feasible `a_count` for each `b_count` in 0..=max (profiled once
    /// per ordered pair — the O(n²k) table).
    fn profile_pair(&mut self, cat: &Catalog, a: FunctionId, b: FunctionId) {
        if self.pair_cap.contains_key(&(a, b)) {
            return;
        }
        let mut caps = Vec::with_capacity(self.max_count as usize + 1);
        for b_count in 0..=self.max_count {
            let mut cap = 0;
            for a_count in 1..=self.max_count {
                let mix = NodeMix::new(vec![(a, a_count, 0), (b, b_count, 0)]);
                let a_ok =
                    self.measure(cat, &mix, a) <= self.qos_headroom * cat.get(a).qos_latency_ms;
                let b_ok = b_count == 0
                    || self.measure(cat, &mix, b)
                        <= self.qos_headroom * cat.get(b).qos_latency_ms;
                if a_ok && b_ok {
                    cap = a_count;
                } else {
                    break;
                }
            }
            caps.push(cap);
        }
        self.pair_cap.insert((a, b), caps);
    }

    /// Historical feasibility of adding one `function` instance to a node.
    /// None = colocation combination outside Owl's history model
    /// (>2 distinct functions).
    fn admits<C: ClusterView>(
        &mut self,
        cat: &Catalog,
        view: &C,
        node: NodeId,
        f: FunctionId,
    ) -> Option<bool> {
        let mix = view.mix(node);
        let mut others: Vec<(FunctionId, u32)> = mix
            .entries
            .iter()
            .filter(|(g, s, c)| *g != f && s + c > 0)
            .map(|(g, s, c)| (*g, s + c))
            .collect();
        let (sat, cached) = view.counts(node, f);
        let mine = sat + cached;
        match others.len() {
            0 => {
                let cap = self.profile_solo(cat, f);
                Some(mine < cap)
            }
            1 => {
                let (g, g_count) = others.pop().unwrap();
                self.profile_pair(cat, f, g);
                let caps = &self.pair_cap[&(f, g)];
                let g_idx = (g_count.min(self.max_count)) as usize;
                Some(mine < caps[g_idx])
            }
            _ => None, // Owl never schedules >2 distinct functions together
        }
    }
}

impl Scheduler for OwlScheduler {
    fn name(&self) -> &'static str {
        "owl"
    }

    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        count: u32,
        _now_ms: f64,
    ) -> Result<Plan> {
        let t0 = Instant::now();
        let mut pb = PlanBuilder::new(cat, cluster);
        for _ in 0..count {
            // take/give_back: `admits` needs `&mut self` (profiling is
            // memoized), so the ranking buffer moves out of the cache for
            // the duration of the scan
            let order = self.orders.take(&pb, function);
            let mut chosen = None;
            for &node in &order {
                if self.admits(cat, &pb, node, function) == Some(true) {
                    chosen = Some(node);
                    break;
                }
            }
            self.orders.give_back(function, order);
            let node = chosen.unwrap_or_else(|| pb.add_node());
            pb.place(function, node);
        }
        Ok(pb.finish(false, 0, t0.elapsed().as_nanos() as u64))
    }

    fn on_node_changed(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _now_ms: f64,
    ) -> Result<Option<DeferredUpdate>> {
        Ok(None)
    }

    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>> {
        let order = self.orders.take(cluster, function);
        let mut found = None;
        for &node in &order {
            if node != exclude && self.admits(cat, cluster, node, function) == Some(true) {
                found = Some(node);
                break;
            }
        }
        self.orders.give_back(function, order);
        Ok(found)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    fn schedule_commit(
        s: &mut OwlScheduler,
        cat: &Catalog,
        cluster: &mut Cluster,
        f: FunctionId,
        count: u32,
        now_ms: f64,
    ) -> super::super::CommittedPlan {
        let plan = s.schedule(cat, cluster, f, count, now_ms).unwrap();
        plan.commit(cat, cluster, now_ms)
    }

    #[test]
    fn never_colocates_three_functions() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let mut s = OwlScheduler::new(7);
        schedule_commit(&mut s, &cat, &mut cluster, 0, 2, 0.0);
        schedule_commit(&mut s, &cat, &mut cluster, 1, 2, 0.0);
        schedule_commit(&mut s, &cat, &mut cluster, 2, 2, 0.0);
        for n in 0..cluster.n_nodes() {
            let distinct = cluster.mix(n).entries.len();
            assert!(distinct <= 2, "node {n} has {distinct} functions");
        }
    }

    #[test]
    fn profiling_is_memoized() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let mut s = OwlScheduler::new(7);
        schedule_commit(&mut s, &cat, &mut cluster, 0, 3, 0.0);
        let after_first = s.profiling_samples;
        assert!(after_first > 0);
        schedule_commit(&mut s, &cat, &mut cluster, 0, 3, 1.0);
        assert_eq!(s.profiling_samples, after_first, "solo profile reused");
    }

    #[test]
    fn respects_profiled_capacity() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let mut s = OwlScheduler::new(7);
        // schedule far more than one node's capacity; Owl must spill
        let committed = schedule_commit(&mut s, &cat, &mut cluster, 0, 40, 0.0);
        assert_eq!(committed.placements.len(), 40);
        assert!(cluster.n_nodes() >= 2);
        let cap = s.solo_cap[&0];
        for n in 0..cluster.n_nodes() {
            let (sat, _) = cluster.counts(n, 0);
            assert!(sat <= cap, "node {n}: {sat} > profiled cap {cap}");
        }
    }
}

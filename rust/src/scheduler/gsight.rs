//! Gsight-style baseline: a model-based QoS-aware scheduler that runs
//! inference **on the critical path of every decision** (the comparison
//! point for Figs. 11/12 and Table 2).
//!
//! Port notes: Gsight [SC'21] predicts per-instance performance under
//! partial interference with an incremental global model and validates
//! candidate placements at schedule time.  Our port keeps that decision
//! structure — per-instance scheduling, QoS validation of the target plus
//! all colocated functions via a synchronous batched inference per
//! candidate node — while sharing Jiagu's predictor so the *policy*
//! difference (when inference happens), not model quality, drives the
//! comparison (same substitution the paper made with its own port).
//! Planning runs over [`ClusterView`], so every instance of a batch sees
//! the ones planned before it exactly as committed state.

use super::{CandidateOrders, ClusterView, DeferredUpdate, Plan, PlanBuilder, Scheduler};
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, NodeId};
use crate::interference::NodeMix;
use crate::model::features::FeatureBuilder;
use crate::model::FeatureMatrix;
use crate::runtime::Predictor;
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

pub struct GsightScheduler {
    predictor: Arc<dyn Predictor>,
    /// Per-node instance cap from actual memory (same bound Jiagu uses).
    pub max_instances_per_node: u32,
    /// Same admission margin Jiagu's capacity sweep applies.
    pub qos_headroom: f64,
    /// Incrementally-maintained candidate rankings (no per-pick re-sort
    /// when the cluster is unchanged).
    orders: CandidateOrders,
}

impl GsightScheduler {
    /// Candidate nodes validated per decision (one batched inference).
    const CANDIDATE_FANOUT: usize = 24;

    pub fn new(predictor: Arc<dyn Predictor>) -> Self {
        Self {
            predictor,
            max_instances_per_node: 40,
            qos_headroom: 0.95,
            orders: CandidateOrders::new(),
        }
    }

    /// Feature rows + QoS bounds for "mix + one more saturated instance
    /// of `function`" on one node.
    fn candidate_rows(
        &self,
        cat: &Catalog,
        mix: &NodeMix,
        function: FunctionId,
        rows: &mut FeatureMatrix,
        qos: &mut Vec<f64>,
    ) -> usize {
        let mut entries = mix.entries.clone();
        match entries.iter_mut().find(|(f, _, _)| *f == function) {
            Some(e) => e.1 += 1,
            None => entries.push((function, 1, 0)),
        }
        let candidate = NodeMix::new(entries);
        let builder = FeatureBuilder::new(cat, &candidate);
        let mut n = 0;
        for (f, sat, _) in &candidate.entries {
            if *sat == 0 {
                continue;
            }
            builder.row_into_matrix(*f, rows);
            qos.push(self.qos_headroom * cat.get(*f).qos_latency_ms);
            n += 1;
        }
        n
    }

    /// Validate the top candidate nodes with **one** batched inference
    /// (the port's per-decision cost is therefore ~1 model call — the
    /// structure the paper's 21.78 ms average reflects) and return the
    /// first feasible node plus the number of inferences spent (0 when no
    /// candidate exists, 1 otherwise).  Counted locally, never read off
    /// the predictor's shared stats — sibling shard threads bump those
    /// concurrently (see `capacity::compute_capacity_counted`).
    fn pick_node<C: ClusterView>(
        &mut self,
        cat: &Catalog,
        view: &C,
        function: FunctionId,
        exclude: Option<NodeId>,
    ) -> Result<(Option<NodeId>, u64)> {
        let max_per_node = self.max_instances_per_node;
        let mut candidates: Vec<NodeId> = self
            .orders
            .order(view, function)
            .iter()
            .copied()
            .filter(|n| Some(*n) != exclude)
            .filter(|n| (view.instances_on(*n) as u32) < max_per_node)
            .take(Self::CANDIDATE_FANOUT)
            .collect();
        if candidates.is_empty() {
            return Ok((None, 0));
        }
        let mut rows = FeatureMatrix::new(crate::model::N_FEATURES);
        let mut qos = Vec::new();
        let mut spans = Vec::new();
        for node in &candidates {
            let n = self.candidate_rows(cat, &view.mix(*node), function, &mut rows, &mut qos);
            spans.push(n);
        }
        let preds = self.predictor.predict_batch(&rows)?;
        let mut off = 0;
        for (i, n) in spans.iter().enumerate() {
            let ok = (off..off + n).all(|j| (preds[j] as f64) <= qos[j]);
            if ok {
                return Ok((Some(candidates.swap_remove(i)), 1));
            }
            off += n;
        }
        Ok((None, 1))
    }
}

impl Scheduler for GsightScheduler {
    fn name(&self) -> &'static str {
        "gsight"
    }

    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        count: u32,
        _now_ms: f64,
    ) -> Result<Plan> {
        let t0 = Instant::now();
        let mut pb = PlanBuilder::new(cat, cluster);
        let mut critical = 0u64;
        // per-instance decisions: no pre-decision, no batching
        for _ in 0..count {
            let (picked, inferences) = self.pick_node(cat, &pb, function, None)?;
            critical += inferences;
            let node = match picked {
                Some(n) => n,
                None => {
                    let node = pb.add_node();
                    // still validate (solo on an empty node is trivially
                    // feasible, but the policy pays the inference)
                    let (_, revalidate) = self.pick_node(cat, &pb, function, None)?;
                    critical += revalidate;
                    node
                }
            };
            pb.place(function, node);
        }
        Ok(pb.finish(true, critical, t0.elapsed().as_nanos() as u64))
    }

    fn on_node_changed(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _now_ms: f64,
    ) -> Result<Option<DeferredUpdate>> {
        Ok(None) // stateless: nothing to refresh
    }

    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>> {
        Ok(self.pick_node(cat, cluster, function, Some(exclude))?.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};

    #[test]
    fn every_decision_pays_inference() {
        let cat = test_catalog();
        // slowdown 1.05x solo: always admits
        let pred: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
            ForestParams::synthetic_stub(crate::model::N_FEATURES, 0.05, 0.05),
        ));
        let mut cluster = Cluster::new(2);
        let mut s = GsightScheduler::new(pred);
        let plan = s.schedule(&cat, &cluster, 0, 4, 0.0).unwrap();
        // one inference per instance minimum (no pre-decision batching)
        assert!(plan.critical_inferences >= 4, "got {}", plan.critical_inferences);
        assert_eq!(plan.path(), super::super::Path::Slow);
        let committed = plan.commit(&cat, &mut cluster, 0.0);
        assert_eq!(committed.placements.len(), 4);
    }

    #[test]
    fn rejects_overloaded_node_and_spills() {
        let cat = test_catalog();
        // predictor that always predicts QoS violation (huge log-slowdown)
        let pred: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
            ForestParams::synthetic_stub(crate::model::N_FEATURES, 20.0, 20.0),
        ));
        let mut cluster = Cluster::new(1);
        let mut s = GsightScheduler::new(pred);
        let plan = s.schedule(&cat, &cluster, 0, 2, 0.0).unwrap();
        // nothing validates, so each instance forces a fresh node
        assert_eq!(plan.nodes_added(), 2);
        let committed = plan.commit(&cat, &mut cluster, 0.0);
        assert_eq!(committed.placements.len(), 2);
        assert_eq!(cluster.n_nodes(), 3);
    }
}

//! Kubernetes-style baseline: QoS-unaware bin packing by *configured
//! resource requests* (the production default the paper normalises
//! density = 1.0 against).
//!
//! MostAllocated-style packing: among nodes with room for the request,
//! pick the one with the highest requested-CPU utilisation, so instances
//! pack tightly and the density baseline is exactly the request-based
//! packing limit.  Packing runs over [`ClusterView`], so a planned batch
//! stacks onto its own placements before spilling to the next node.

use super::{ClusterView, DeferredUpdate, Plan, PlanBuilder, Scheduler};
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, NodeId};
use anyhow::Result;
use std::time::Instant;

#[derive(Default)]
pub struct KubernetesScheduler;

impl KubernetesScheduler {
    pub fn new() -> Self {
        Self
    }

    fn fits<C: ClusterView>(
        cat: &Catalog,
        view: &C,
        node: NodeId,
        function: FunctionId,
    ) -> bool {
        let spec = cat.get(function);
        let (cpu, mem) = view.requested(node);
        cpu + spec.milli_cpu <= cat.node_milli_cpu && mem + spec.mem_mb <= cat.node_mem_mb
    }

    fn pick<C: ClusterView>(cat: &Catalog, view: &C, function: FunctionId) -> Option<NodeId> {
        (0..view.n_nodes())
            .filter(|n| Self::fits(cat, view, *n, function))
            .max_by_key(|n| view.requested(*n).0)
    }
}

impl Scheduler for KubernetesScheduler {
    fn name(&self) -> &'static str {
        "kubernetes"
    }

    fn schedule(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        count: u32,
        _now_ms: f64,
    ) -> Result<Plan> {
        let t0 = Instant::now();
        let mut pb = PlanBuilder::new(cat, cluster);
        for _ in 0..count {
            let node = match Self::pick(cat, &pb, function) {
                Some(n) => n,
                None => pb.add_node(),
            };
            pb.place(function, node);
        }
        Ok(pb.finish(false, 0, t0.elapsed().as_nanos() as u64))
    }

    fn on_node_changed(
        &mut self,
        _cat: &Catalog,
        _cluster: &Cluster,
        _node: NodeId,
        _now_ms: f64,
    ) -> Result<Option<DeferredUpdate>> {
        Ok(None)
    }

    fn find_feasible_node(
        &mut self,
        cat: &Catalog,
        cluster: &Cluster,
        function: FunctionId,
        exclude: NodeId,
    ) -> Result<Option<NodeId>> {
        Ok((0..cluster.n_nodes())
            .filter(|n| *n != exclude && Self::fits(cat, cluster, *n, function))
            .max_by_key(|n| cluster.nodes[*n].requested_milli_cpu))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn packs_exactly_request_limit_per_node() {
        let cat = test_catalog();
        let mut cluster = Cluster::new(1);
        let mut s = KubernetesScheduler::new();
        let plan = s.schedule(&cat, &cluster, 0, 25, 0.0).unwrap();
        let committed = plan.commit(&cat, &mut cluster, 0.0);
        assert_eq!(committed.placements.len(), 25);
        // 12 per node (48000/4000) -> 25 instances need 3 nodes
        assert_eq!(cluster.n_nodes(), 3);
        assert_eq!(cluster.nodes[0].instances.len(), 12);
        assert_eq!(cluster.nodes[1].instances.len(), 12);
        assert_eq!(cluster.nodes[2].instances.len(), 1);
    }

    #[test]
    fn respects_memory_bound() {
        let mut cat = test_catalog();
        // make memory the binding resource: 128GB/20GB = 6 per node
        for f in &mut cat.functions {
            f.mem_mb = 20 * 1024;
        }
        let mut cluster = Cluster::new(1);
        let mut s = KubernetesScheduler::new();
        let _ = s.schedule(&cat, &cluster, 1, 7, 0.0).unwrap().commit(&cat, &mut cluster, 0.0);
        assert_eq!(cluster.nodes[0].instances.len(), 6);
        assert_eq!(cluster.n_nodes(), 2);
    }
}

//! Evaluation metrics: density, QoS violation rate, scheduling cost and
//! cold-start accounting — the quantities behind Figs. 11–14 and Table 2.

use crate::catalog::{Catalog, FunctionId};

/// Streaming percentile estimator: exact over a retained sample vector
/// (sample counts here are small enough to keep everything).
#[derive(Debug, Default, Clone)]
pub struct Samples {
    values: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx]
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

/// Function-density tracker (Fig. 13).
///
/// Density = instance-seconds ÷ active-node-seconds, i.e. the
/// time-weighted average number of deployed instances per in-use node;
/// the benches normalise it by the K8s scheduler's value (= 1.0).
#[derive(Debug, Default)]
pub struct DensityTracker {
    instance_seconds: f64,
    node_seconds: f64,
}

impl DensityTracker {
    /// Record one tick: `instances` deployed (any state), `active_nodes`
    /// hosting at least one instance, over `dt` seconds.
    pub fn record(&mut self, instances: usize, active_nodes: usize, dt_s: f64) {
        self.instance_seconds += instances as f64 * dt_s;
        self.node_seconds += active_nodes as f64 * dt_s;
    }

    pub fn density(&self) -> f64 {
        if self.node_seconds == 0.0 {
            0.0
        } else {
            self.instance_seconds / self.node_seconds
        }
    }
}

/// QoS violation accounting (Fig. 14a): per function, requests served vs
/// requests whose window latency exceeded the QoS bound.
#[derive(Debug, Default)]
pub struct QosTracker {
    /// per function: (violating requests, total requests)
    per_function: Vec<(f64, f64)>,
}

impl QosTracker {
    pub fn new(n_functions: usize) -> Self {
        Self { per_function: vec![(0.0, 0.0); n_functions] }
    }

    /// Record a measurement window: `requests` served by function `f` at
    /// measured `latency_ms` against its QoS bound.
    pub fn record(&mut self, cat: &Catalog, f: FunctionId, requests: f64, latency_ms: f64) {
        let e = &mut self.per_function[f];
        e.1 += requests;
        if latency_ms > cat.get(f).qos_latency_ms {
            e.0 += requests;
        }
    }

    /// Violation rate of one function.
    pub fn rate(&self, f: FunctionId) -> f64 {
        let (v, t) = self.per_function[f];
        if t == 0.0 {
            0.0
        } else {
            v / t
        }
    }

    /// Overall violation rate (request-weighted, the paper's metric).
    pub fn overall(&self) -> f64 {
        let (v, t) = self
            .per_function
            .iter()
            .fold((0.0, 0.0), |(av, at), (v, t)| (av + v, at + t));
        if t == 0.0 {
            0.0
        } else {
            v / t
        }
    }
}

/// Scheduling + cold-start cost accounting (Figs. 11/12, Table 2).
/// Asynchronous (off-critical-path) refresh costs are tracked by the
/// control-plane engine, not here — they never touch a cold start.
///
/// Decision costs are the *modelled* virtual-time costs the event
/// engine charged (deterministic; see `config::CostModel`); cold-start
/// latency is attributed at event resolution — completion time minus
/// request time — by the `ColdStartComplete` events, not inferred from
/// per-plan constants.
#[derive(Debug, Default)]
pub struct CostTracker {
    /// Modelled critical-path decision cost per scheduling call (ms).
    pub scheduling_ms: Samples,
    /// Cold-start latency per completed instance (request→ready, ms).
    pub cold_start_ms: Samples,
    /// Model inferences on the critical path.
    pub critical_inferences: u64,
    /// Scheduling calls.
    pub calls: u64,
    /// Individual instances cold-started.
    pub instances_started: u64,
    /// Fast-path / slow-path decision counts.
    pub fast_decisions: u64,
    pub slow_decisions: u64,
}

impl CostTracker {
    /// Record one committed plan with its modelled critical-path decision
    /// cost in virtual milliseconds.
    pub fn record_schedule(
        &mut self,
        committed: &crate::scheduler::CommittedPlan,
        decision_ms: f64,
    ) {
        let plan = &committed.plan;
        self.scheduling_ms.push(decision_ms);
        self.calls += 1;
        self.critical_inferences += plan.critical_inferences;
        if plan.path() == crate::scheduler::Path::Slow {
            self.slow_decisions += 1;
        } else {
            self.fast_decisions += 1;
        }
        self.instances_started += committed.placements.len() as u64;
    }

    /// Record one completed cold start at event resolution.
    pub fn record_cold_start(&mut self, latency_ms: f64) {
        self.cold_start_ms.push(latency_ms);
    }

    /// Inferences per scheduling call (Figs. 11a/12 middle series).
    pub fn inferences_per_schedule(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.critical_inferences as f64 / self.calls as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn density_weighted_by_duration() {
        let mut d = DensityTracker::default();
        d.record(10, 2, 30.0); // 5 per node for 30 s
        d.record(20, 2, 10.0); // 10 per node for 10 s
        // (10*30 + 20*10) / (2*30 + 2*10) = 500/80 = 6.25
        assert!((d.density() - 6.25).abs() < 1e-12);
    }

    #[test]
    fn qos_rates() {
        let cat = test_catalog();
        let mut q = QosTracker::new(cat.len());
        let qos0 = cat.get(0).qos_latency_ms;
        q.record(&cat, 0, 90.0, qos0 * 0.9); // ok
        q.record(&cat, 0, 10.0, qos0 * 1.5); // violated
        assert!((q.rate(0) - 0.1).abs() < 1e-12);
        assert!((q.overall() - 0.1).abs() < 1e-12);
        assert_eq!(q.rate(1), 0.0);
    }

    #[test]
    fn cost_tracker_splits_decision_and_completion_accounting() {
        use crate::scheduler::{Action, CommittedPlan, Placement, Plan};
        let mut c = CostTracker::default();
        let mut plan = Plan::default();
        plan.actions = vec![Action::Place { function: 0, node: 0 }];
        plan.slow_path_used = true;
        plan.decision_nanos = 123_456; // measured; must NOT drive the samples
        plan.critical_inferences = 2;
        let committed = CommittedPlan {
            plan,
            placements: vec![Placement { instance: 0, node: 0 }],
        };
        c.record_schedule(&committed, 0.055);
        assert_eq!(c.calls, 1);
        assert_eq!(c.slow_decisions, 1);
        assert_eq!(c.instances_started, 1);
        assert_eq!(c.scheduling_ms.values(), &[0.055]);
        assert!(c.cold_start_ms.is_empty(), "cold starts attribute at completion");
        c.record_cold_start(8.455);
        assert_eq!(c.cold_start_ms.values(), &[8.455]);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::default();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((s.percentile(0.5) - 50.0).abs() <= 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-12);
    }
}

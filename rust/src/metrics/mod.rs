//! Evaluation metrics: density, QoS violation rate, scheduling cost,
//! cold-start accounting — the quantities behind Figs. 11–14 and Table 2
//! — plus the per-request tail-latency histogram of the event-driven
//! routing model.
//!
//! ## Per-request latency
//!
//! [`LatencyHistogram`] is a **fixed-bin** histogram (bin width and bin
//! count chosen at construction, an overflow bucket beyond): recording is
//! O(1), the memory is constant, and — unlike a retained sample vector —
//! the serialised form is identical for identical request streams, which
//! is what lets the golden test assert *byte-identical* histogram JSON
//! across replays and regenerations.  Percentiles are read from bin
//! upper edges (the overflow bucket reports the maximum recorded value),
//! so p50/p95/p99 are conservative to one bin width and fully
//! deterministic.  [`RequestTracker`] folds the engine's per-request
//! records into the histogram plus per-function QoS-violation counts.

use crate::catalog::{Catalog, FunctionId};
use crate::util::json::{arr, num, obj, Json};
use anyhow::{ensure, Result};

/// Streaming percentile estimator: exact over a retained sample vector
/// (sample counts here are small enough to keep everything).
///
/// `PartialEq` compares the raw vectors in insertion order, which is what
/// lets `RunReport` keep its bit-identical-replay contract after samples
/// became part of the report's mergeable sufficient statistics.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Samples {
    values: Vec<f64>,
}

impl Samples {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx]
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Append another sample set (partition merge).  Concatenation is
    /// exactly associative, and every derived quantity is recomputed from
    /// the final vector: percentiles sort (order-insensitive), the mean
    /// sums left-to-right over the concatenation — deterministic for a
    /// pinned merge order.
    pub fn extend_from(&mut self, other: &Samples) {
        self.values.extend_from_slice(&other.values);
    }
}

/// Default per-request histogram bin width (ms).
pub const LATENCY_BIN_MS: f64 = 4.0;
/// Default per-request histogram bin count (covers 0–1024 ms; slower
/// requests land in the overflow bucket).
pub const LATENCY_BINS: usize = 256;

/// Fixed-bin latency histogram (see the module docs for the determinism
/// rationale).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyHistogram {
    bin_ms: f64,
    bins: Vec<u64>,
    overflow: u64,
    invalid: u64,
    count: u64,
    max_ms: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new(LATENCY_BIN_MS, LATENCY_BINS)
    }
}

impl LatencyHistogram {
    pub fn new(bin_ms: f64, n_bins: usize) -> Self {
        assert!(bin_ms > 0.0 && bin_ms.is_finite(), "bin width must be positive");
        assert!(n_bins > 0, "need at least one bin");
        Self { bin_ms, bins: vec![0; n_bins], overflow: 0, invalid: 0, count: 0, max_ms: 0.0 }
    }

    /// Record one latency sample.
    ///
    /// Degenerate samples — NaN, ±∞, negative — are clamped into the
    /// explicit **invalid** bin: they bump `count` and `invalid` but
    /// never touch the regular bins, the overflow bucket (which is
    /// reserved for *valid* latencies beyond the binned range) or
    /// `max_ms`.  A non-zero `invalid` count is therefore a loud,
    /// attributable signal that an upstream latency computation produced
    /// garbage, instead of a silently mis-binned percentile.
    pub fn record(&mut self, ms: f64) {
        self.count += 1;
        if !ms.is_finite() || ms < 0.0 {
            self.invalid += 1;
            return;
        }
        self.max_ms = self.max_ms.max(ms);
        let idx = (ms / self.bin_ms) as usize;
        if idx < self.bins.len() {
            self.bins[idx] += 1;
        } else {
            self.overflow += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn bin_ms(&self) -> f64 {
        self.bin_ms
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    /// Degenerate samples recorded (NaN/±∞/negative) — see
    /// [`LatencyHistogram::record`].
    pub fn invalid(&self) -> u64 {
        self.invalid
    }

    /// Largest finite latency recorded.
    pub fn max_ms(&self) -> f64 {
        self.max_ms
    }

    /// The `p`-quantile, read as the upper edge of the bin where the
    /// cumulative count first reaches `ceil(p · count)`; quantiles that
    /// fall into the overflow bucket report [`LatencyHistogram::max_ms`].
    /// 0.0 on an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in self.bins.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return (i + 1) as f64 * self.bin_ms;
            }
        }
        self.max_ms
    }

    /// Fold another histogram into this one (partition merge).  Requires
    /// identical binning (bit-equal `bin_ms`, same bin count); bins,
    /// overflow and count add, `max_ms` takes the maximum.  All integer
    /// sums + a max, so the operation is exactly associative **and**
    /// commutative — merged shard reports are byte-identical however the
    /// partitions were grouped.
    pub fn merge(&mut self, other: &LatencyHistogram) -> Result<()> {
        ensure!(
            self.bin_ms.to_bits() == other.bin_ms.to_bits()
                && self.bins.len() == other.bins.len(),
            "histogram merge needs identical binning: {} x {} vs {} x {}",
            self.bin_ms,
            self.bins.len(),
            other.bin_ms,
            other.bins.len()
        );
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.overflow += other.overflow;
        self.invalid += other.invalid;
        self.count += other.count;
        self.max_ms = self.max_ms.max(other.max_ms);
        Ok(())
    }

    /// Serialise for the golden vectors: every field is integral or an
    /// exactly round-tripping f64, so equal histograms give equal bytes.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("bin_ms", num(self.bin_ms)),
            ("bins", arr(self.bins.iter().map(|c| num(*c as f64)))),
            ("overflow", num(self.overflow as f64)),
            ("invalid", num(self.invalid as f64)),
            ("count", num(self.count as f64)),
            ("max_ms", num(self.max_ms)),
            ("p50_ms", num(self.percentile(0.50))),
            ("p95_ms", num(self.percentile(0.95))),
            ("p99_ms", num(self.percentile(0.99))),
        ])
    }
}

/// Per-request QoS accounting: latency histogram + per-function counts
/// of requests whose total latency (cold-start wait + queueing + service)
/// exceeded the function's QoS bound.
#[derive(Debug, Default)]
pub struct RequestTracker {
    pub hist: LatencyHistogram,
    /// Per function: requests whose latency exceeded the QoS bound.
    pub violations: Vec<u64>,
    /// Per function: requests attributed.
    pub requests: Vec<u64>,
    /// Arrivals whose first dispatch parked on a cold-wait queue.
    pub cold_waits: u64,
}

impl RequestTracker {
    pub fn new(n_functions: usize) -> Self {
        Self {
            hist: LatencyHistogram::default(),
            violations: vec![0; n_functions],
            requests: vec![0; n_functions],
            cold_waits: 0,
        }
    }

    /// Fold one attributed request.
    pub fn record(&mut self, cat: &Catalog, f: FunctionId, latency_ms: f64) {
        self.hist.record(latency_ms);
        self.requests[f] += 1;
        if latency_ms > cat.get(f).qos_latency_ms {
            self.violations[f] += 1;
        }
    }
}

/// Function-density tracker (Fig. 13).
///
/// Density = instance-seconds ÷ active-node-seconds, i.e. the
/// time-weighted average number of deployed instances per in-use node;
/// the benches normalise it by the K8s scheduler's value (= 1.0).
#[derive(Debug, Default)]
pub struct DensityTracker {
    instance_seconds: f64,
    node_seconds: f64,
}

impl DensityTracker {
    /// Record one tick: `instances` deployed (any state), `active_nodes`
    /// hosting at least one instance, over `dt` seconds.
    pub fn record(&mut self, instances: usize, active_nodes: usize, dt_s: f64) {
        self.instance_seconds += instances as f64 * dt_s;
        self.node_seconds += active_nodes as f64 * dt_s;
    }

    pub fn density(&self) -> f64 {
        if self.node_seconds == 0.0 {
            0.0
        } else {
            self.instance_seconds / self.node_seconds
        }
    }

    /// The ratio's numerator — the mergeable sufficient statistic (sums
    /// of integral instance counts × whole-second dts, so partition sums
    /// are exact in f64).
    pub fn instance_seconds(&self) -> f64 {
        self.instance_seconds
    }

    /// The ratio's denominator (see [`DensityTracker::instance_seconds`]).
    pub fn node_seconds(&self) -> f64 {
        self.node_seconds
    }
}

/// QoS violation accounting (Fig. 14a): per function, requests served vs
/// requests whose window latency exceeded the QoS bound.
#[derive(Debug, Default)]
pub struct QosTracker {
    /// per function: (violating requests, total requests)
    per_function: Vec<(f64, f64)>,
}

impl QosTracker {
    pub fn new(n_functions: usize) -> Self {
        Self { per_function: vec![(0.0, 0.0); n_functions] }
    }

    /// Record a measurement window: `requests` served by function `f` at
    /// measured `latency_ms` against its QoS bound.
    pub fn record(&mut self, cat: &Catalog, f: FunctionId, requests: f64, latency_ms: f64) {
        let e = &mut self.per_function[f];
        e.1 += requests;
        if latency_ms > cat.get(f).qos_latency_ms {
            e.0 += requests;
        }
    }

    /// Violation rate of one function.
    pub fn rate(&self, f: FunctionId) -> f64 {
        let (v, t) = self.per_function[f];
        if t == 0.0 {
            0.0
        } else {
            v / t
        }
    }

    /// Per-function violating-request counts (merge numerators).
    pub fn violating(&self) -> Vec<f64> {
        self.per_function.iter().map(|(v, _)| *v).collect()
    }

    /// Per-function total-request counts (merge denominators).
    pub fn totals(&self) -> Vec<f64> {
        self.per_function.iter().map(|(_, t)| *t).collect()
    }

    /// Overall violation rate (request-weighted, the paper's metric).
    pub fn overall(&self) -> f64 {
        let (v, t) = self
            .per_function
            .iter()
            .fold((0.0, 0.0), |(av, at), (v, t)| (av + v, at + t));
        if t == 0.0 {
            0.0
        } else {
            v / t
        }
    }
}

/// Scheduling + cold-start cost accounting (Figs. 11/12, Table 2).
/// Asynchronous (off-critical-path) refresh costs are tracked by the
/// control-plane engine, not here — they never touch a cold start.
///
/// Decision costs are the *modelled* virtual-time costs the event
/// engine charged (deterministic; see `config::CostModel`); cold-start
/// latency is attributed at event resolution — completion time minus
/// request time — by the `ColdStartComplete` events, not inferred from
/// per-plan constants.
#[derive(Debug, Default)]
pub struct CostTracker {
    /// Modelled critical-path decision cost per scheduling call (ms).
    pub scheduling_ms: Samples,
    /// Cold-start latency per completed instance (request→ready, ms).
    pub cold_start_ms: Samples,
    /// Model inferences on the critical path.
    pub critical_inferences: u64,
    /// Critical-path capacity sweeps answered from the scheduler's
    /// mix-signature memo (each one an inference avoided).
    pub memo_hits: u64,
    /// Critical-path capacity sweeps that missed the memo.
    pub memo_misses: u64,
    /// Scheduling calls.
    pub calls: u64,
    /// Individual instances cold-started.
    pub instances_started: u64,
    /// Fast-path / slow-path decision counts.
    pub fast_decisions: u64,
    pub slow_decisions: u64,
}

impl CostTracker {
    /// Record one committed plan with its modelled critical-path decision
    /// cost in virtual milliseconds.
    pub fn record_schedule(
        &mut self,
        committed: &crate::scheduler::CommittedPlan,
        decision_ms: f64,
    ) {
        let plan = &committed.plan;
        self.scheduling_ms.push(decision_ms);
        self.calls += 1;
        self.critical_inferences += plan.critical_inferences;
        self.memo_hits += plan.memo_hits;
        self.memo_misses += plan.memo_misses;
        if plan.path() == crate::scheduler::Path::Slow {
            self.slow_decisions += 1;
        } else {
            self.fast_decisions += 1;
        }
        self.instances_started += committed.placements.len() as u64;
    }

    /// Record one completed cold start at event resolution.
    pub fn record_cold_start(&mut self, latency_ms: f64) {
        self.cold_start_ms.push(latency_ms);
    }

    /// Inferences per scheduling call (Figs. 11a/12 middle series).
    pub fn inferences_per_schedule(&self) -> f64 {
        if self.calls == 0 {
            0.0
        } else {
            self.critical_inferences as f64 / self.calls as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn density_weighted_by_duration() {
        let mut d = DensityTracker::default();
        d.record(10, 2, 30.0); // 5 per node for 30 s
        d.record(20, 2, 10.0); // 10 per node for 10 s
        // (10*30 + 20*10) / (2*30 + 2*10) = 500/80 = 6.25
        assert!((d.density() - 6.25).abs() < 1e-12);
    }

    #[test]
    fn qos_rates() {
        let cat = test_catalog();
        let mut q = QosTracker::new(cat.len());
        let qos0 = cat.get(0).qos_latency_ms;
        q.record(&cat, 0, 90.0, qos0 * 0.9); // ok
        q.record(&cat, 0, 10.0, qos0 * 1.5); // violated
        assert!((q.rate(0) - 0.1).abs() < 1e-12);
        assert!((q.overall() - 0.1).abs() < 1e-12);
        assert_eq!(q.rate(1), 0.0);
    }

    #[test]
    fn cost_tracker_splits_decision_and_completion_accounting() {
        use crate::scheduler::{Action, CommittedPlan, Placement, Plan};
        let mut c = CostTracker::default();
        let mut plan = Plan::default();
        plan.actions = vec![Action::Place { function: 0, node: 0 }];
        plan.slow_path_used = true;
        plan.decision_nanos = 123_456; // measured; must NOT drive the samples
        plan.critical_inferences = 2;
        plan.memo_hits = 3;
        plan.memo_misses = 2;
        let committed = CommittedPlan {
            plan,
            placements: vec![Placement { instance: 0, node: 0 }],
        };
        c.record_schedule(&committed, 0.055);
        assert_eq!(c.calls, 1);
        assert_eq!(c.slow_decisions, 1);
        assert_eq!(c.instances_started, 1);
        assert_eq!((c.memo_hits, c.memo_misses), (3, 2));
        assert_eq!(c.scheduling_ms.values(), &[0.055]);
        assert!(c.cold_start_ms.is_empty(), "cold starts attribute at completion");
        c.record_cold_start(8.455);
        assert_eq!(c.cold_start_ms.values(), &[8.455]);
    }

    #[test]
    fn latency_histogram_bins_percentiles_and_overflow() {
        let mut h = LatencyHistogram::new(10.0, 10); // covers 0–100 ms
        assert_eq!(h.percentile(0.99), 0.0, "empty histogram reads 0");
        for v in [1.0, 2.0, 5.0, 11.0, 250.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.bins()[0], 3);
        assert_eq!(h.bins()[1], 1);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.max_ms(), 250.0);
        // rank(0.5 · 5) = 3 → third sample sits in bin 0 → upper edge 10
        assert_eq!(h.percentile(0.50), 10.0);
        // p99 rank = 5 → overflow → max recorded value
        assert_eq!(h.percentile(0.99), 250.0);
        // degenerate inputs land in the explicit invalid bin — counted,
        // attributable, and never mixed into the overflow bucket
        h.record(f64::NAN);
        h.record(-1.0);
        assert_eq!(h.count(), 7);
        assert_eq!(h.overflow(), 1);
        assert_eq!(h.invalid(), 2);
        assert_eq!(h.max_ms(), 250.0);
    }

    #[test]
    fn latency_histogram_isolates_invalid_samples() {
        let mut h = LatencyHistogram::new(10.0, 10);
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -0.001, -1e300] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.invalid(), 5);
        assert_eq!(h.overflow(), 0, "overflow is reserved for valid out-of-range samples");
        assert!(h.bins().iter().all(|b| *b == 0), "bins stay untouched");
        assert_eq!(h.max_ms(), 0.0, "max never tracks garbage");
        // valid samples recorded afterwards are unaffected
        h.record(5.0);
        h.record(15.0);
        assert_eq!(h.bins()[0], 1);
        assert_eq!(h.bins()[1], 1);
        assert_eq!(h.invalid(), 5);
        assert_eq!(h.max_ms(), 15.0);
        // the invalid bin merges additively like every other counter
        let mut other = LatencyHistogram::new(10.0, 10);
        other.record(f64::NAN);
        other.record(25.0);
        let mut m = h.clone();
        m.merge(&other).unwrap();
        assert_eq!(m.invalid(), 6);
        assert_eq!(m.count(), 9);
        // and the JSON surface carries it explicitly
        let j = m.to_json();
        assert_eq!(j.get("invalid").unwrap().as_usize().unwrap(), 6);
    }

    #[test]
    fn latency_histogram_json_is_deterministic() {
        let build = || {
            let mut h = LatencyHistogram::new(2.0, 8);
            for v in [0.5, 3.2, 7.9, 100.0] {
                h.record(v);
            }
            h
        };
        let (a, b) = (build(), build());
        assert_eq!(a, b);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        // round-trips through the JSON layer
        let parsed = crate::util::json::Json::parse(&a.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("count").unwrap().as_usize().unwrap(), 4);
        assert_eq!(parsed.get("bins").unwrap().f64_vec().unwrap().len(), 8);
    }

    #[test]
    fn latency_histogram_merge_is_exact_and_rejects_mismatched_bins() {
        let fill = |vals: &[f64]| {
            let mut h = LatencyHistogram::new(10.0, 4);
            for v in vals {
                h.record(*v);
            }
            h
        };
        let (a, b) = (fill(&[1.0, 15.0, 500.0]), fill(&[2.0, 35.0]));
        // the union histogram, recorded in one pass, is the ground truth
        let union = fill(&[1.0, 15.0, 500.0, 2.0, 35.0]);
        let mut m = a.clone();
        m.merge(&b).unwrap();
        assert_eq!(m, union, "merge must equal single-pass recording");
        // commutative: b ⊕ a gives the same histogram
        let mut m2 = b.clone();
        m2.merge(&a).unwrap();
        assert_eq!(m2, union);
        // mismatched binning is an error, not silent corruption
        let mut narrow = LatencyHistogram::new(5.0, 4);
        assert!(narrow.merge(&a).is_err());
        let mut short = LatencyHistogram::new(10.0, 3);
        assert!(short.merge(&a).is_err());
    }

    #[test]
    fn samples_extend_concatenates_in_order() {
        let mut a = Samples::default();
        a.push(3.0);
        let mut b = Samples::default();
        b.push(1.0);
        b.push(2.0);
        a.extend_from(&b);
        assert_eq!(a.values(), &[3.0, 1.0, 2.0]);
        assert_eq!(a.percentile(1.0), 3.0);
    }

    #[test]
    fn qos_tracker_exposes_merge_numerators_and_denominators() {
        let cat = test_catalog();
        let mut q = QosTracker::new(cat.len());
        let qos0 = cat.get(0).qos_latency_ms;
        q.record(&cat, 0, 90.0, qos0 * 0.9);
        q.record(&cat, 0, 10.0, qos0 * 1.5);
        assert_eq!(q.violating(), vec![10.0, 0.0, 0.0, 0.0]);
        assert_eq!(q.totals(), vec![100.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn request_tracker_counts_violations_per_function() {
        let cat = test_catalog();
        let mut t = RequestTracker::new(cat.len());
        let qos0 = cat.get(0).qos_latency_ms;
        t.record(&cat, 0, qos0 * 0.5);
        t.record(&cat, 0, qos0 * 2.0);
        t.record(&cat, 1, cat.get(1).qos_latency_ms * 0.9);
        assert_eq!(t.requests, vec![2, 1, 0, 0]);
        assert_eq!(t.violations, vec![1, 0, 0, 0]);
        assert_eq!(t.hist.count(), 3);
    }

    #[test]
    fn percentiles_exact() {
        let mut s = Samples::default();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((s.percentile(0.5) - 50.0).abs() <= 1.0);
        assert_eq!(s.percentile(1.0), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-12);
    }
}

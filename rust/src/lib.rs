//! # Jiagu — QoS-aware serverless scheduling, reproduced
//!
//! Reproduction of *"Jiagu: Optimizing Serverless Computing Resource
//! Utilization with Harmonized Efficiency and Practicability"* (2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serverless control plane: pre-decision
//!   scheduler with per-node [`capacity`] tables, [`autoscaler`] with
//!   dual-staged scaling, request [`router`], [`cluster`] state, baseline
//!   schedulers, a millisecond-resolution discrete-event core
//!   ([`engine`] + [`controlplane`]), the [`sim`]ulator,
//!   per-second/sub-second workload generators ([`traces`]), the
//!   [`workload`] lab (streaming trace replay, adversarial scenario
//!   fuzzer, differential QoS harness) and the [`policy`] lab (pluggable
//!   dispatch/scaling strategies ranked on the latency histogram).
//! * **L2 (JAX, build time)** — the latency predictor compute graph,
//!   AOT-lowered to HLO text at `make artifacts`.
//! * **L1 (Pallas, build time)** — the random-forest traversal kernel.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through PJRT (`xla` crate) and serves batched predictions
//! to the scheduler.
//!
//! Start with [`sim::Simulation`] (end-to-end) or `examples/quickstart.rs`.

// Style lints this offline codebase accepts wholesale: the CI clippy gate
// (`cargo clippy -- -D warnings`, lib + bins — the scope ROADMAP's tier-1
// cares about) pins whatever clippy the build image ships, so the allow
// list stays coarse rather than churning per toolchain.
#![allow(
    clippy::new_without_default,
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_range_contains,
    clippy::type_complexity
)]

pub mod artifacts;
pub mod autoscaler;
pub mod capacity;
pub mod catalog;
pub mod cluster;
pub mod config;
pub mod controlplane;
pub mod engine;
pub mod interference;
pub mod metrics;
pub mod model;
pub mod policy;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod traces;
pub mod util;
pub mod workload;

/// Repo-relative artifacts directory used by examples/benches/tests.
///
/// Resolution order:
/// 1. `JIAGU_ARTIFACTS` (if set and non-empty), verbatim;
/// 2. walking up from the current directory, the first `artifacts/`
///    containing `meta.json` or `functions.json`;
/// 3. the repository root's `artifacts/` — the walk stops at the first
///    ancestor holding a `.git`, so a target/ or bench working directory
///    inside the repo resolves to the same place `make artifacts` writes
///    to even before anything was generated;
/// 4. plain `"artifacts"` relative to the current directory.
///
/// Never panics: an unreadable current directory degrades to case 4.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("JIAGU_ARTIFACTS") {
        if !dir.is_empty() {
            return dir.into();
        }
    }
    let cwd = match std::env::current_dir() {
        Ok(d) => d,
        Err(_) => return "artifacts".into(),
    };
    let mut cur = cwd.as_path();
    loop {
        let cand = cur.join("artifacts");
        if cand.join("meta.json").exists() || cand.join("functions.json").exists() {
            return cand;
        }
        if cur.join(".git").exists() {
            // repo root: this is where the generators write; stop here
            // rather than walking into unrelated parent directories.
            return cand;
        }
        match cur.parent() {
            Some(parent) => cur = parent,
            None => return "artifacts".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::{Mutex, OnceLock};

    /// Env-var mutation is process-global; serialise the tests that touch
    /// `JIAGU_ARTIFACTS` so parallel test threads cannot interleave.
    fn env_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    /// Run `f` with `JIAGU_ARTIFACTS` set to `value` (or unset for
    /// `None`), restoring whatever the process had before — CI sets the
    /// variable for the whole test run and later tests must still see it.
    fn with_env(value: Option<&str>, f: impl FnOnce()) {
        let _guard = env_lock().lock().unwrap();
        let prior = std::env::var("JIAGU_ARTIFACTS").ok();
        match value {
            Some(v) => std::env::set_var("JIAGU_ARTIFACTS", v),
            None => std::env::remove_var("JIAGU_ARTIFACTS"),
        }
        f();
        match prior {
            Some(v) => std::env::set_var("JIAGU_ARTIFACTS", v),
            None => std::env::remove_var("JIAGU_ARTIFACTS"),
        }
    }

    #[test]
    fn artifacts_dir_honours_env_override() {
        with_env(Some("/tmp/jiagu-override"), || {
            assert_eq!(
                super::artifacts_dir(),
                std::path::PathBuf::from("/tmp/jiagu-override")
            );
        });
    }

    #[test]
    fn artifacts_dir_ignores_empty_env_and_never_panics() {
        with_env(Some(""), || {
            // empty override falls through to the walk; whatever it
            // resolves to must end in `artifacts`
            assert_eq!(super::artifacts_dir().file_name().unwrap(), "artifacts");
        });
    }

    #[test]
    fn artifacts_dir_stops_at_repo_root() {
        with_env(None, check_stops_at_repo_root);
    }

    fn check_stops_at_repo_root() {
        let dir = super::artifacts_dir();
        // inside this repo the walk must not escape past the .git root:
        // the result is an `artifacts` dir whose parent is an ancestor of
        // (or equal to) the current directory.
        let cwd = std::env::current_dir().unwrap();
        let parent = dir.parent().unwrap();
        assert!(
            cwd.starts_with(parent) || parent.as_os_str() == "",
            "artifacts dir {dir:?} must sit on the cwd's ancestor chain"
        );
    }
}

//! # Jiagu — QoS-aware serverless scheduling, reproduced
//!
//! Reproduction of *"Jiagu: Optimizing Serverless Computing Resource
//! Utilization with Harmonized Efficiency and Practicability"* (2024) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the serverless control plane: pre-decision
//!   scheduler with per-node [`capacity`] tables, [`autoscaler`] with
//!   dual-staged scaling, request [`router`], [`cluster`] state, baseline
//!   schedulers, a discrete-event [`sim`]ulator and trace generators.
//! * **L2 (JAX, build time)** — the latency predictor compute graph,
//!   AOT-lowered to HLO text at `make artifacts`.
//! * **L1 (Pallas, build time)** — the random-forest traversal kernel.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through PJRT (`xla` crate) and serves batched predictions
//! to the scheduler.
//!
//! Start with [`sim::Simulation`] (end-to-end) or `examples/quickstart.rs`.

pub mod autoscaler;
pub mod capacity;
pub mod catalog;
pub mod cluster;
pub mod config;
pub mod interference;
pub mod metrics;
pub mod model;
pub mod router;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod traces;
pub mod util;

/// Repo-relative artifacts directory fallback used by examples/benches.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("JIAGU_ARTIFACTS") {
        return dir.into();
    }
    // walk up from cwd until an `artifacts/` directory is found
    let mut cur = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = cur.join("artifacts");
        if cand.join("meta.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return "artifacts".into();
        }
    }
}

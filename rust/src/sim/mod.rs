//! Discrete-event cluster simulation — the testbed substitute.
//!
//! Virtual time advances in 1-second ticks driven by a trace.  Each tick:
//!
//! 1. due cold starts complete (instances flip Starting → Saturated and
//!    join the routing set),
//! 2. the autoscaler evaluates every function (dual-staged scaling),
//! 3. QoS is measured: for every (node, function) with saturated
//!    instances, the ground-truth interference model yields the window's
//!    P90 latency (plus measurement noise), judged against the QoS bound,
//! 4. density/cost metrics accumulate.
//!
//! **Scheduling cost is real, not modelled**: scheduler decisions execute
//! the actual capacity-table / PJRT-inference code and their measured
//! wall-clock time is injected into the virtual cold-start timeline
//! (DESIGN.md "Scheduling-cost measurement model").  Only the instance
//! *init* latency (cfork 8.4 ms / docker 85.5 ms) is a constant from the
//! literature.

use crate::autoscaler::Autoscaler;
use crate::catalog::Catalog;
use crate::cluster::{Cluster, InstanceId};
use crate::config::{RunConfig, SchedulerKind};
use crate::interference;
use crate::metrics::{CostTracker, DensityTracker, QosTracker};
use crate::model::AccuracyMonitor;
use crate::router::Router;
use crate::runtime::Predictor;
use crate::scheduler::{
    GsightScheduler, JiaguScheduler, KubernetesScheduler, OwlScheduler, Scheduler,
};
use crate::traces::TraceSet;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Aggregated outcome of one simulated run.
#[derive(Debug)]
pub struct RunReport {
    pub scheduler: String,
    pub trace: String,
    pub duration_s: usize,
    pub density: f64,
    pub qos_violation_rate: f64,
    pub per_function_violation: Vec<f64>,
    pub scheduling_ms_mean: f64,
    pub scheduling_ms_p99: f64,
    pub cold_start_ms_mean: f64,
    pub cold_start_ms_p99: f64,
    pub inferences_per_schedule: f64,
    pub critical_inferences: u64,
    pub async_inferences: u64,
    pub schedule_calls: u64,
    pub instances_started: u64,
    pub fast_decisions: u64,
    pub slow_decisions: u64,
    pub logical_cold_starts: u64,
    pub real_after_release: u64,
    pub migrations: u64,
    pub released: u64,
    pub evicted: u64,
    pub peak_nodes: usize,
    pub async_nanos: u64,
    /// Functions under the §6 unpredictability fallback at run end.
    pub isolated_functions: Vec<usize>,
}

impl RunReport {
    /// Fraction of re-route-driven scale-ups served logically (Fig. 14b).
    pub fn logical_fraction(&self) -> f64 {
        let total = self.logical_cold_starts + self.real_after_release;
        if total == 0 {
            1.0
        } else {
            self.logical_cold_starts as f64 / total as f64
        }
    }
}

/// The simulation driver.
pub struct Simulation {
    pub cat: Catalog,
    pub cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
}

impl Simulation {
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Self {
        Self { cat, cfg, predictor }
    }

    fn make_scheduler(&self) -> Box<dyn Scheduler> {
        match self.cfg.scheduler {
            SchedulerKind::Jiagu => Box::new(JiaguScheduler::new(
                self.predictor.clone(),
                self.cfg.capacity.clone(),
                self.cfg.n_nodes,
            )),
            SchedulerKind::Kubernetes => Box::new(KubernetesScheduler::new()),
            SchedulerKind::Gsight => Box::new(GsightScheduler::new(self.predictor.clone())),
            SchedulerKind::Owl => Box::new(OwlScheduler::new(self.cfg.seed ^ 0x071)),
        }
    }

    /// Run the full trace; returns the aggregated report.
    pub fn run(&self, trace: &TraceSet) -> Result<RunReport> {
        let mut cluster = Cluster::new(self.cfg.n_nodes);
        let mut router = Router::new();
        let mut sched = self.make_scheduler();
        let mut autoscaler = Autoscaler::new(self.cfg.autoscaler.clone(), self.cat.len());
        let mut rng = Rng::seed_from(self.cfg.seed);

        let mut density = DensityTracker::default();
        let mut qos = QosTracker::new(self.cat.len());
        let mut costs = CostTracker::default();
        let mut pending: Vec<(f64, InstanceId)> = Vec::new(); // (ready_ms, id)
        // §6 online accuracy monitoring: every `monitor_every` ticks the
        // deployed model's prediction for each active (node, function) is
        // compared against the measured window latency; functions whose
        // error will not converge fall back to isolated scheduling.
        let mut monitor = AccuracyMonitor::new(self.cat.len());
        let monitor_every = 30usize;
        let mut logical_cold_starts = 0u64;
        let mut real_after_release = 0u64;
        let mut migrations = 0u64;
        let mut released = 0u64;
        let mut evicted = 0u64;
        let mut async_nanos = 0u64;
        let mut peak_nodes = self.cfg.n_nodes;
        let init_ms = self.cfg.init_model.latency_ms();

        let duration = trace.duration_s().min(self.cfg.duration_s);
        for t in 0..duration {
            let now_ms = t as f64 * 1000.0;

            // 1. complete due cold starts
            pending.retain(|(ready_ms, id)| {
                if *ready_ms <= now_ms {
                    if let Some(inst) = cluster.instance(*id) {
                        let f = inst.function;
                        cluster.mark_ready(*id, now_ms);
                        router.add(f, *id);
                    }
                    false
                } else {
                    true
                }
            });

            // 2. autoscaler tick (may schedule -> real decisions timed)
            let loads = trace.loads_at(t);
            let outcome = autoscaler.tick(
                &self.cat,
                &mut cluster,
                &mut router,
                sched.as_mut(),
                &loads,
                now_ms,
            )?;
            logical_cold_starts += outcome.logical_cold_starts as u64;
            real_after_release += outcome.real_after_release as u64;
            migrations += outcome.migrations as u64;
            released += outcome.released as u64;
            evicted += (outcome.evicted + outcome.evicted_direct) as u64;
            for res in &outcome.schedule_results {
                costs.record_schedule(res, init_ms);
                async_nanos += res.async_nanos;
                let ready_ms = now_ms + res.decision_nanos as f64 / 1e6 + init_ms;
                for p in &res.placements {
                    pending.push((ready_ms, p.instance));
                }
            }

            // 3. QoS measurement per (node, function) window
            let monitor_tick = t % monitor_every == monitor_every - 1;
            for node in 0..cluster.n_nodes() {
                let mix = cluster.mix(node);
                if mix.is_empty() {
                    continue;
                }
                for (f, sat, _) in &mix.entries {
                    if *sat == 0 {
                        continue;
                    }
                    let truth = interference::ground_truth_latency(&self.cat, &mix, *f);
                    let measured =
                        truth * (1.0 + rng.normal_ms(0.0, self.cfg.measurement_noise));
                    // requests this window ≈ serving share of the live load
                    let serving_total = router.serving_count(*f).max(1) as f64;
                    let requests = loads[*f] * (*sat as f64 / serving_total).min(1.0);
                    if requests > 0.0 {
                        qos.record(&self.cat, *f, requests, measured);
                    }
                    if monitor_tick {
                        let row = crate::model::feature_row(&self.cat, &mix, *f);
                        if let Ok(pred) = self.predictor.predict(std::slice::from_ref(&row)) {
                            monitor.record(*f, pred[0] as f64, measured);
                        }
                    }
                }
            }
            if monitor_tick {
                if let Some(jiagu) = sched.as_jiagu_mut() {
                    for f in 0..self.cat.len() {
                        jiagu.set_isolated(f, monitor.is_unpredictable(f));
                    }
                }
            }

            // 4. density accounting
            let active_nodes =
                (0..cluster.n_nodes()).filter(|n| !cluster.node_empty(*n)).count();
            density.record(cluster.instances_len(), active_nodes.max(1), 1.0);
            peak_nodes = peak_nodes.max(cluster.n_nodes());
        }

        let per_function_violation =
            (0..self.cat.len()).map(|f| qos.rate(f)).collect();
        let isolated_functions = monitor.unpredictable();
        Ok(RunReport {
            scheduler: sched.name().to_string(),
            trace: trace.name.clone(),
            duration_s: duration,
            density: density.density(),
            qos_violation_rate: qos.overall(),
            per_function_violation,
            scheduling_ms_mean: costs.scheduling_ms.mean(),
            scheduling_ms_p99: costs.scheduling_ms.percentile(0.99),
            cold_start_ms_mean: costs.cold_start_ms.mean(),
            cold_start_ms_p99: costs.cold_start_ms.percentile(0.99),
            inferences_per_schedule: costs.inferences_per_schedule(),
            critical_inferences: costs.critical_inferences,
            async_inferences: costs.async_inferences,
            schedule_calls: costs.calls,
            instances_started: costs.instances_started,
            fast_decisions: costs.fast_decisions,
            slow_decisions: costs.slow_decisions,
            logical_cold_starts,
            real_after_release,
            migrations,
            released,
            evicted,
            peak_nodes,
            async_nanos,
            isolated_functions,
        })
    }
}

/// Convenience: build the simulation's predictor from artifacts — PJRT
/// when compiled in (`--features pjrt`) and not overridden, otherwise the
/// pure-Rust forest.  Both run the same flattened trees; a build without
/// the feature logs once and serves the native forest so every example,
/// bench and test stays runnable on the artifacts `jiagu-gen-artifacts`
/// produces natively.
pub fn load_predictor(artifacts: &std::path::Path, native: bool) -> Result<Arc<dyn Predictor>> {
    #[cfg(feature = "pjrt")]
    if !native {
        return Ok(Arc::new(crate::runtime::PjrtPredictor::load(artifacts)?));
    }
    if !native {
        eprintln!(
            "note: built without the `pjrt` feature; serving predictions from the native forest"
        );
    }
    let params = crate::runtime::ForestParams::load(&artifacts.join("forest.json"))?;
    Ok(Arc::new(crate::runtime::NativeForestPredictor::new(params)))
}

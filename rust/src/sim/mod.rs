//! Discrete-event cluster simulation — the testbed substitute.
//!
//! A run is one drain of the control plane's deterministic event queue:
//! the workload's `LoadChange` events are injected up front,
//! [`ControlPlane::run_until`] pops every event in `(due_ms, seq)` order
//! — cold starts completing at their exact `sched_cost + init_ms` due
//! times, §4.3 refreshes landing at their modelled sub-millisecond
//! delays, autoscaler evaluations and QoS monitor ticks on their
//! cadences — and [`Simulation::run_workload`] folds the accumulated
//! [`EngineEvents`] into the [`RunReport`].
//!
//! **Virtual-time cost is modelled, deterministically**: decision and
//! refresh costs charged to the timeline come from
//! [`CostModel`](crate::config::CostModel) — linear in the
//! deterministic inference counts the scheduler actually performed — so
//! the entire report (latency percentiles included) is bit-identical
//! across replays of the same seed.  Measured wall-clock nanos remain
//! available on `Plan`/`DeferredUpdate` for live profiling; only the
//! instance *init* latency (cfork 8.4 ms / docker 85.5 ms) is a
//! constant from the literature.
//!
//! With `cfg.requests = true` the run additionally synthesizes
//! per-invocation arrivals from the workload's load steps and routes
//! every request individually (see [`crate::router`]); the report then
//! carries the fixed-bin latency histogram, p50/p95/p99 and per-function
//! QoS-violation counts — all equally bit-identical across replays.

use crate::catalog::Catalog;
use crate::config::{CostModel, RunConfig};
use crate::controlplane::{ControlPlane, EngineEvents};
use crate::metrics::{
    CostTracker, DensityTracker, LatencyHistogram, QosTracker, RequestTracker, Samples,
};
use crate::runtime::Predictor;
use crate::traces::{TraceSet, Workload};
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// Salt XOR-ed into `cfg.seed` for the per-invocation arrival stream
/// (`cfg.requests = true`), keeping it independent of the simulator's
/// other seeded streams while still replaying per seed.
pub const ARRIVAL_SEED_SALT: u64 = 0x0a21_71a1;

/// Effective seed of the per-invocation arrival synthesis for `cfg`:
/// the explicit [`RunConfig::arrival_seed`] override when present,
/// otherwise the run seed salted with [`ARRIVAL_SEED_SALT`].  The
/// sharded control plane pins this value onto every cell, so all cells
/// thin the *same* underlying arrival stream regardless of their
/// cell-local engine seeds — which is what makes per-cell
/// `arrivals_dropped` counters sum to the unsharded count under any
/// partition layout.
pub fn effective_arrival_seed(cfg: &RunConfig) -> u64 {
    cfg.arrival_seed.unwrap_or(cfg.seed ^ ARRIVAL_SEED_SALT)
}

/// Aggregated outcome of one simulated run.  Every field is derived
/// from the deterministic event stream, so two runs with the same seed
/// compare equal (`PartialEq`) bit for bit.
///
/// Reports are **mergeable**: alongside the derived aggregates (ratios,
/// means, percentiles) the report carries their *sufficient statistics*
/// — per-function count tables, raw sample vectors, the fixed-bin
/// histogram, the density ratio's numerator/denominator — and
/// [`RunReport::merge`] folds another partition's report in by combining
/// those and recomputing every derived field.  All combination steps are
/// integer/concatenation/scatter operations (see the field docs), so the
/// merge is exactly associative; the sharded control plane
/// ([`crate::controlplane::shard`]) exploits that to fuse per-partition
/// reports in a pinned order into bytes identical for any thread count.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub scheduler: String,
    pub trace: String,
    pub duration_s: usize,
    /// Control-plane cells folded into this report: 1 for an unsharded
    /// run, the partition count after a sharded merge, the region count
    /// after a federated merge.  A pure function of the layout (never of
    /// thread count or failure injection); merges by addition.
    pub cells: u64,
    /// Sorted global function ids this report's cell(s) own.  A fresh
    /// single-plane report owns the whole catalog; shard/region drivers
    /// overwrite it with their cell's slice of the id space before
    /// merging.  [`RunReport::merge`] rejects overlapping ownership —
    /// the function-id remapping check that keeps per-function
    /// scatter-adds exact — and unions the sets.
    pub owned_functions: Vec<usize>,
    /// Events popped and handled by the control plane(s) — the
    /// throughput denominator `benches/shard_scaling.rs` reports.
    pub events_processed: u64,
    pub density: f64,
    pub qos_violation_rate: f64,
    pub per_function_violation: Vec<f64>,
    /// Modelled critical-path decision cost (virtual ms).
    pub scheduling_ms_mean: f64,
    pub scheduling_ms_p99: f64,
    /// Cold-start latency attributed at event resolution: completion
    /// time − request time in virtual ms.
    pub cold_start_ms_mean: f64,
    pub cold_start_ms_p99: f64,
    pub inferences_per_schedule: f64,
    pub critical_inferences: u64,
    pub async_inferences: u64,
    /// Capacity sweeps (critical path + async refresh) answered from the
    /// scheduler's mix-signature memo — inferences avoided outright.
    pub memo_hits: u64,
    /// Capacity sweeps that missed the memo and ran the batched inference.
    pub memo_misses: u64,
    pub schedule_calls: u64,
    pub instances_started: u64,
    pub fast_decisions: u64,
    pub slow_decisions: u64,
    pub logical_cold_starts: u64,
    pub real_after_release: u64,
    pub migrations: u64,
    pub released: u64,
    pub evicted: u64,
    pub peak_nodes: usize,
    /// Modelled off-critical-path refresh cost (ns, deterministic).
    pub async_nanos: u64,
    /// Functions under the §6 unpredictability fallback at run end.
    pub isolated_functions: Vec<usize>,
    /// Per-request model (`cfg.requests = true`; all-zero otherwise):
    /// requests attributed (cold-start wait + queueing + service).
    /// Requests still queued or cold-waiting when the horizon ends are
    /// *not* attributed — see [`RunReport::stranded_requests`].
    pub requests_served: u64,
    /// Per-request latency percentiles read from the fixed-bin histogram
    /// (upper bin edges — conservative to one bin width).
    pub request_p50_ms: f64,
    pub request_p95_ms: f64,
    pub request_p99_ms: f64,
    /// Per function: requests attributed (the denominator for
    /// per-function violation rates).
    pub request_counts: Vec<u64>,
    /// Per function: requests whose total latency exceeded the QoS bound.
    pub request_qos_violations: Vec<u64>,
    /// Arrivals whose first dispatch found no serving instance (parked
    /// on a cold-wait queue before being served).
    pub cold_wait_requests: u64,
    /// Unserved demand at the horizon: requests still cold-waiting plus
    /// requests queued on instances but never admitted.  Their latency
    /// is unknowable, so they are counted here instead of silently
    /// dropped — `requests_served + stranded_requests` equals the
    /// arrivals the horizon let in.
    pub stranded_requests: u64,
    /// Arrivals the synthesis safety cap
    /// ([`crate::traces::MAX_ARRIVALS_PER_FUNCTION`]) dropped before
    /// injection — surfaced here (and by the CLI) so a capped run is
    /// never mistaken for a fully-served one; merges by addition.
    pub arrivals_dropped: u64,
    /// Highest per-node in-flight request count observed.
    pub peak_node_in_flight: u32,
    /// Highest cluster-wide in-flight request count observed at monitor
    /// samples and drain ends (a *sampled* gauge, unlike the continuous
    /// per-node high-water mark above, so the two are not comparable).
    pub peak_in_flight: u32,
    /// The full fixed-bin latency histogram (golden-vector surface);
    /// merges bin-wise ([`LatencyHistogram::merge`]).
    pub latency_hist: LatencyHistogram,
    // ---- mergeable sufficient statistics --------------------------------
    /// Per function: QoS-window requests that violated the bound (the
    /// numerator behind `per_function_violation`).  Functions are owned
    /// by exactly one partition, so merging is an exact scatter-add.
    pub qos_violating: Vec<f64>,
    /// Per function: total QoS-window requests (the denominator).
    pub qos_totals: Vec<f64>,
    /// Density numerator: instance-seconds (integral values, so
    /// partition sums are exact in f64).
    pub instance_seconds: f64,
    /// Density denominator: active-node-seconds.
    pub node_seconds: f64,
    /// Raw per-call decision costs behind `scheduling_ms_mean`/`_p99`;
    /// merges by concatenation in the pinned partition order.
    pub scheduling_samples: Samples,
    /// Raw per-instance cold-start latencies behind `cold_start_ms_*`.
    pub cold_start_samples: Samples,
}

impl RunReport {
    /// Fraction of re-route-driven scale-ups served logically (Fig. 14b).
    pub fn logical_fraction(&self) -> f64 {
        let total = self.logical_cold_starts + self.real_after_release;
        if total == 0 {
            1.0
        } else {
            self.logical_cold_starts as f64 / total as f64
        }
    }

    /// Fold another partition's report into this one.
    ///
    /// Combination rules, chosen so the operation is exactly associative
    /// and — up to the pinned merge order the sharded control plane uses
    /// — order-insensitive:
    ///
    /// * **counters** (`u64`) add;
    /// * **per-function tables** scatter-add (each function is owned by
    ///   exactly one partition, so at most one operand is non-zero);
    /// * **sample vectors** concatenate; **histograms** add bin-wise;
    /// * **extents of disjoint sub-clusters** combine by their natural
    ///   union: cluster-wide sizes/gauges (`peak_nodes`,
    ///   `peak_in_flight`) add partition peaks, the per-node gauge
    ///   (`peak_node_in_flight`) takes the max;
    /// * every **derived field** (ratios, means, percentiles) is then
    ///   recomputed from the combined sufficient statistics — never
    ///   averaged from the operands' derived values.
    ///
    /// Errors when the reports are not merge-compatible (different
    /// scheduler/trace/horizon, catalog size, or histogram binning).
    /// Every check runs before the first mutation (the histogram merge
    /// validates its binning up front), so `self` is unchanged on error.
    pub fn merge(&mut self, other: &RunReport) -> Result<()> {
        ensure!(
            self.scheduler == other.scheduler,
            "merge across schedulers: {} vs {}",
            self.scheduler,
            other.scheduler
        );
        ensure!(
            self.trace == other.trace,
            "merge across traces: {} vs {}",
            self.trace,
            other.trace
        );
        ensure!(
            self.duration_s == other.duration_s,
            "merge across horizons: {} vs {} s",
            self.duration_s,
            other.duration_s
        );
        ensure!(
            self.qos_totals.len() == other.qos_totals.len()
                && self.qos_violating.len() == other.qos_violating.len()
                && self.request_counts.len() == other.request_counts.len()
                && self.request_qos_violations.len() == other.request_qos_violations.len(),
            "merge across catalog sizes"
        );
        // Function-id remapping check: the operands must own disjoint
        // global id sets, or the per-function scatter-adds below would
        // silently double-count a function's traffic.  Both vectors are
        // kept sorted, so a two-pointer walk finds any collision.
        let (mut i, mut j) = (0, 0);
        while i < self.owned_functions.len() && j < other.owned_functions.len() {
            match self.owned_functions[i].cmp(&other.owned_functions[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => bail!(
                    "merge operands both own function {}: global-id remapping bug",
                    self.owned_functions[i]
                ),
            }
        }
        self.latency_hist.merge(&other.latency_hist)?;
        // counters
        self.events_processed += other.events_processed;
        self.critical_inferences += other.critical_inferences;
        self.async_inferences += other.async_inferences;
        self.memo_hits += other.memo_hits;
        self.memo_misses += other.memo_misses;
        self.schedule_calls += other.schedule_calls;
        self.instances_started += other.instances_started;
        self.fast_decisions += other.fast_decisions;
        self.slow_decisions += other.slow_decisions;
        self.logical_cold_starts += other.logical_cold_starts;
        self.real_after_release += other.real_after_release;
        self.migrations += other.migrations;
        self.released += other.released;
        self.evicted += other.evicted;
        self.async_nanos += other.async_nanos;
        self.requests_served += other.requests_served;
        self.cold_wait_requests += other.cold_wait_requests;
        self.stranded_requests += other.stranded_requests;
        self.arrivals_dropped += other.arrivals_dropped;
        // disjoint sub-cluster extents
        self.peak_nodes += other.peak_nodes;
        self.peak_in_flight += other.peak_in_flight;
        self.peak_node_in_flight = self.peak_node_in_flight.max(other.peak_node_in_flight);
        self.cells += other.cells;
        self.owned_functions.extend_from_slice(&other.owned_functions);
        self.owned_functions.sort_unstable();
        // per-function tables (scatter: one owner per function)
        for (a, b) in self.qos_violating.iter_mut().zip(&other.qos_violating) {
            *a += b;
        }
        for (a, b) in self.qos_totals.iter_mut().zip(&other.qos_totals) {
            *a += b;
        }
        for (a, b) in self.request_counts.iter_mut().zip(&other.request_counts) {
            *a += b;
        }
        for (a, b) in self.request_qos_violations.iter_mut().zip(&other.request_qos_violations) {
            *a += b;
        }
        // remaining sufficient statistics
        self.instance_seconds += other.instance_seconds;
        self.node_seconds += other.node_seconds;
        self.scheduling_samples.extend_from(&other.scheduling_samples);
        self.cold_start_samples.extend_from(&other.cold_start_samples);
        self.isolated_functions.extend_from_slice(&other.isolated_functions);
        self.isolated_functions.sort_unstable();
        self.isolated_functions.dedup();
        self.recompute_derived();
        Ok(())
    }

    /// Recompute every derived aggregate from the sufficient statistics.
    /// The single source of the derivation formulas: `run_workload` calls
    /// this to finalise a fresh report and `merge` to re-derive after
    /// combining, so a one-partition merge is the exact identity.
    fn recompute_derived(&mut self) {
        self.density = if self.node_seconds == 0.0 {
            0.0
        } else {
            self.instance_seconds / self.node_seconds
        };
        let (v, t) = self
            .qos_violating
            .iter()
            .zip(&self.qos_totals)
            .fold((0.0, 0.0), |(av, at), (v, t)| (av + v, at + t));
        self.qos_violation_rate = if t == 0.0 { 0.0 } else { v / t };
        self.per_function_violation = self
            .qos_violating
            .iter()
            .zip(&self.qos_totals)
            .map(|(v, t)| if *t == 0.0 { 0.0 } else { v / t })
            .collect();
        self.scheduling_ms_mean = self.scheduling_samples.mean();
        self.scheduling_ms_p99 = self.scheduling_samples.percentile(0.99);
        self.cold_start_ms_mean = self.cold_start_samples.mean();
        self.cold_start_ms_p99 = self.cold_start_samples.percentile(0.99);
        self.inferences_per_schedule = if self.schedule_calls == 0 {
            0.0
        } else {
            self.critical_inferences as f64 / self.schedule_calls as f64
        };
        self.request_p50_ms = self.latency_hist.percentile(0.50);
        self.request_p95_ms = self.latency_hist.percentile(0.95);
        self.request_p99_ms = self.latency_hist.percentile(0.99);
    }
}

/// The simulation driver: inject a workload, drain the event queue,
/// fold the emitted [`EngineEvents`] into the aggregate report.
pub struct Simulation {
    pub cat: Catalog,
    pub cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
}

impl Simulation {
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Self {
        Self { cat, cfg, predictor }
    }

    /// Run a per-second trace (converted to its event-stream form).
    pub fn run(&self, trace: &TraceSet) -> Result<RunReport> {
        self.run_workload(&trace.workload())
    }

    /// Run any event-stream workload; returns the aggregated report.
    ///
    /// The horizon is drained in fold chunks so the accumulated
    /// [`EngineEvents`] (QoS windows, committed plans, samples) stay
    /// bounded by the chunk length instead of growing with the run.
    pub fn run_workload(&self, workload: &Workload) -> Result<RunReport> {
        /// Fold granularity (virtual ms): long enough to amortise the
        /// fold, short enough to keep per-chunk event Vecs small.
        const FOLD_CHUNK_MS: f64 = 60_000.0;

        let mut cp =
            ControlPlane::new(self.cat.clone(), self.cfg.clone(), self.predictor.clone());
        cp.inject_workload(workload);
        let mut builder = ReportBuilder::new(&self.cat, &self.cfg);
        if self.cfg.requests {
            // per-invocation arrivals derive from the arrival seed (by
            // default the run seed, salted so the stream differs from
            // every other seeded stream) — same cfg + workload ⇒
            // byte-identical arrival vector
            let (arrivals, dropped) =
                workload.synthesize_arrivals_counted(effective_arrival_seed(&self.cfg));
            builder.add_arrivals_dropped(dropped);
            cp.inject_arrivals(&arrivals);
        }
        let duration = workload.duration_s().min(self.cfg.duration_s);
        let horizon_ms = duration as f64 * 1000.0;
        let mut until = 0.0f64;
        while until < horizon_ms {
            until = (until + FOLD_CHUNK_MS).min(horizon_ms);
            let ev: EngineEvents = cp.run_until(until)?;
            builder.absorb(&ev);
        }

        let isolated = cp.monitor().unpredictable();
        Ok(builder.finish(cp.scheduler_name(), &workload.name, duration, isolated))
    }
}

/// Incremental fold of drained [`EngineEvents`] chunks into the
/// sufficient statistics behind a [`RunReport`].
///
/// Extracted from [`Simulation::run_workload`] so every driver that
/// drains a control plane in chunks — the batch simulation here, the
/// streaming trace replay in [`crate::workload::replay`] — folds
/// identically: `absorb` each drained chunk, then `finish` into the
/// report.  Chunking is a memory bound, not a semantic one; the
/// absorbed statistics depend only on the concatenation of the chunks'
/// event streams.
pub struct ReportBuilder {
    cat: Catalog,
    cost: CostModel,
    costs: CostTracker,
    qos: QosTracker,
    density: DensityTracker,
    reqs: RequestTracker,
    peak_node_in_flight: u32,
    peak_in_flight: u32,
    stranded_requests: u64,
    peak_nodes: usize,
    logical_cold_starts: u64,
    real_after_release: u64,
    migrations: u64,
    released: u64,
    evicted: u64,
    async_nanos: u64,
    async_inferences: u64,
    /// Memo outcomes of async-refresh sweeps (critical-path ones arrive
    /// through `costs`; the two sets are disjoint).
    memo_hits: u64,
    memo_misses: u64,
    events_processed: u64,
    arrivals_dropped: u64,
}

impl ReportBuilder {
    pub fn new(cat: &Catalog, cfg: &RunConfig) -> Self {
        Self {
            cat: cat.clone(),
            cost: cfg.cost,
            costs: CostTracker::default(),
            qos: QosTracker::new(cat.len()),
            density: DensityTracker::default(),
            reqs: RequestTracker::new(cat.len()),
            peak_node_in_flight: 0,
            peak_in_flight: 0,
            stranded_requests: 0,
            peak_nodes: cfg.n_nodes,
            logical_cold_starts: 0,
            real_after_release: 0,
            migrations: 0,
            released: 0,
            evicted: 0,
            async_nanos: 0,
            async_inferences: 0,
            memo_hits: 0,
            memo_misses: 0,
            events_processed: 0,
            arrivals_dropped: 0,
        }
    }

    /// Count arrivals dropped before injection (the synthesis safety
    /// cap, or the replay horizon clip).
    pub fn add_arrivals_dropped(&mut self, n: u64) {
        self.arrivals_dropped += n;
    }

    /// Fold one drained chunk's events into the statistics.
    pub fn absorb(&mut self, ev: &EngineEvents) {
        for committed in &ev.scheduled {
            self.costs.record_schedule(
                committed,
                self.cost.decision_ms(committed.plan.critical_inferences),
            );
        }
        for latency in &ev.cold_start_latency_ms {
            self.costs.record_cold_start(*latency);
        }
        for w in &ev.qos {
            self.qos.record(&self.cat, w.function, w.requests, w.measured_ms);
        }
        for r in &ev.requests {
            self.reqs.record(&self.cat, r.function, r.latency_ms);
        }
        self.reqs.cold_waits += ev.cold_waits;
        self.peak_node_in_flight = self.peak_node_in_flight.max(ev.peak_node_in_flight);
        self.peak_in_flight = self.peak_in_flight.max(ev.in_flight);
        // the final chunk's gauges = unserved demand at the horizon:
        // cold-waiters plus requests queued but never admitted
        self.stranded_requests = ev.waiting + ev.queued;
        for s in &ev.samples {
            self.density.record(s.instances, s.active_nodes.max(1), 1.0);
            self.peak_nodes = self.peak_nodes.max(s.n_nodes);
            self.peak_in_flight = self.peak_in_flight.max(s.in_flight);
        }
        self.peak_nodes = self.peak_nodes.max(ev.n_nodes);
        self.logical_cold_starts += ev.logical_cold_starts as u64;
        self.real_after_release += ev.real_after_release as u64;
        self.migrations += ev.migrations as u64;
        self.released += ev.released as u64;
        self.evicted += (ev.evicted + ev.evicted_direct) as u64;
        self.async_nanos += ev.async_nanos;
        self.async_inferences += ev.async_inferences;
        self.memo_hits += ev.memo_hits;
        self.memo_misses += ev.memo_misses;
        self.events_processed += ev.events_processed;
    }

    /// Build the final report from the absorbed statistics.
    ///
    /// Sufficient statistics first; every derived aggregate (ratios,
    /// means, percentiles) comes from `recompute_derived` — the same
    /// code path `RunReport::merge` re-derives with, so merging a
    /// single-partition report is the exact identity.
    pub fn finish(
        self,
        scheduler: &str,
        trace: &str,
        duration_s: usize,
        isolated_functions: Vec<usize>,
    ) -> RunReport {
        let mut report = RunReport {
            scheduler: scheduler.to_string(),
            trace: trace.to_string(),
            duration_s,
            cells: 1,
            owned_functions: (0..self.cat.len()).collect(),
            events_processed: self.events_processed,
            density: 0.0,
            qos_violation_rate: 0.0,
            per_function_violation: Vec::new(),
            scheduling_ms_mean: 0.0,
            scheduling_ms_p99: 0.0,
            cold_start_ms_mean: 0.0,
            cold_start_ms_p99: 0.0,
            inferences_per_schedule: 0.0,
            critical_inferences: self.costs.critical_inferences,
            async_inferences: self.async_inferences,
            memo_hits: self.costs.memo_hits + self.memo_hits,
            memo_misses: self.costs.memo_misses + self.memo_misses,
            schedule_calls: self.costs.calls,
            instances_started: self.costs.instances_started,
            fast_decisions: self.costs.fast_decisions,
            slow_decisions: self.costs.slow_decisions,
            logical_cold_starts: self.logical_cold_starts,
            real_after_release: self.real_after_release,
            migrations: self.migrations,
            released: self.released,
            evicted: self.evicted,
            peak_nodes: self.peak_nodes,
            async_nanos: self.async_nanos,
            isolated_functions,
            requests_served: self.reqs.hist.count(),
            request_p50_ms: 0.0,
            request_p95_ms: 0.0,
            request_p99_ms: 0.0,
            request_counts: self.reqs.requests,
            request_qos_violations: self.reqs.violations,
            cold_wait_requests: self.reqs.cold_waits,
            stranded_requests: self.stranded_requests,
            arrivals_dropped: self.arrivals_dropped,
            peak_node_in_flight: self.peak_node_in_flight,
            peak_in_flight: self.peak_in_flight,
            latency_hist: self.reqs.hist,
            qos_violating: self.qos.violating(),
            qos_totals: self.qos.totals(),
            instance_seconds: self.density.instance_seconds(),
            node_seconds: self.density.node_seconds(),
            scheduling_samples: self.costs.scheduling_ms,
            cold_start_samples: self.costs.cold_start_ms,
        };
        report.recompute_derived();
        report
    }
}

/// Convenience: build the simulation's predictor from artifacts — PJRT
/// when compiled in (`--features pjrt`) and not overridden, otherwise the
/// pure-Rust forest.  Both run the same flattened trees; a build without
/// the feature logs once and serves the native forest so every example,
/// bench and test stays runnable on the artifacts `jiagu-gen-artifacts`
/// produces natively.
pub fn load_predictor(artifacts: &std::path::Path, native: bool) -> Result<Arc<dyn Predictor>> {
    #[cfg(feature = "pjrt")]
    if !native {
        return Ok(Arc::new(crate::runtime::PjrtPredictor::load(artifacts)?));
    }
    if !native {
        eprintln!(
            "note: built without the `pjrt` feature; serving predictions from the native forest"
        );
    }
    let params = crate::runtime::ForestParams::load(&artifacts.join("forest.json"))?;
    Ok(Arc::new(crate::runtime::NativeForestPredictor::new(params)))
}

//! Discrete-event cluster simulation — the testbed substitute.
//!
//! Virtual time advances in 1-second ticks driven by a trace.  Each tick
//! is one [`ControlPlane::step`]: deferred capacity refreshes land, due
//! cold starts complete, the autoscaler plans + commits scale decisions
//! (dual-staged scaling), QoS is measured per (node, function) window
//! against the ground-truth interference model, and the emitted
//! [`TickEvents`] are folded here into the [`RunReport`].
//!
//! **Scheduling cost is real, not modelled**: scheduler decisions execute
//! the actual capacity-table / PJRT-inference code and their measured
//! wall-clock time is injected into the virtual cold-start timeline
//! (DESIGN.md "Scheduling-cost measurement model").  Only the instance
//! *init* latency (cfork 8.4 ms / docker 85.5 ms) is a constant from the
//! literature.

use crate::catalog::Catalog;
use crate::config::RunConfig;
use crate::controlplane::{ControlPlane, TickEvents};
use crate::metrics::{CostTracker, DensityTracker, QosTracker};
use crate::runtime::Predictor;
use crate::traces::TraceSet;
use anyhow::Result;
use std::sync::Arc;

/// Aggregated outcome of one simulated run.
#[derive(Debug)]
pub struct RunReport {
    pub scheduler: String,
    pub trace: String,
    pub duration_s: usize,
    pub density: f64,
    pub qos_violation_rate: f64,
    pub per_function_violation: Vec<f64>,
    pub scheduling_ms_mean: f64,
    pub scheduling_ms_p99: f64,
    pub cold_start_ms_mean: f64,
    pub cold_start_ms_p99: f64,
    pub inferences_per_schedule: f64,
    pub critical_inferences: u64,
    pub async_inferences: u64,
    pub schedule_calls: u64,
    pub instances_started: u64,
    pub fast_decisions: u64,
    pub slow_decisions: u64,
    pub logical_cold_starts: u64,
    pub real_after_release: u64,
    pub migrations: u64,
    pub released: u64,
    pub evicted: u64,
    pub peak_nodes: usize,
    pub async_nanos: u64,
    /// Functions under the §6 unpredictability fallback at run end.
    pub isolated_functions: Vec<usize>,
}

impl RunReport {
    /// Fraction of re-route-driven scale-ups served logically (Fig. 14b).
    pub fn logical_fraction(&self) -> f64 {
        let total = self.logical_cold_starts + self.real_after_release;
        if total == 0 {
            1.0
        } else {
            self.logical_cold_starts as f64 / total as f64
        }
    }
}

/// The simulation driver: a thin loop over [`ControlPlane::step`] that
/// folds each tick's [`TickEvents`] into the aggregate report.
pub struct Simulation {
    pub cat: Catalog,
    pub cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
}

impl Simulation {
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Self {
        Self { cat, cfg, predictor }
    }

    /// Run the full trace; returns the aggregated report.
    pub fn run(&self, trace: &TraceSet) -> Result<RunReport> {
        let mut cp =
            ControlPlane::new(self.cat.clone(), self.cfg.clone(), self.predictor.clone());

        let mut density = DensityTracker::default();
        let mut qos = QosTracker::new(self.cat.len());
        let mut costs = CostTracker::default();
        let mut logical_cold_starts = 0u64;
        let mut real_after_release = 0u64;
        let mut migrations = 0u64;
        let mut released = 0u64;
        let mut evicted = 0u64;
        let mut async_nanos = 0u64;
        let mut async_inferences = 0u64;
        let mut peak_nodes = self.cfg.n_nodes;
        let init_ms = self.cfg.init_model.latency_ms();

        let duration = trace.duration_s().min(self.cfg.duration_s);
        for t in 0..duration {
            let now_ms = t as f64 * 1000.0;
            let loads = trace.loads_at(t);
            let ev: TickEvents = cp.step(now_ms, &loads)?;
            for committed in &ev.scheduled {
                costs.record_schedule(committed, init_ms);
            }
            for w in &ev.qos {
                qos.record(&self.cat, w.function, w.requests, w.measured_ms);
            }
            logical_cold_starts += ev.logical_cold_starts as u64;
            real_after_release += ev.real_after_release as u64;
            migrations += ev.migrations as u64;
            released += ev.released as u64;
            evicted += (ev.evicted + ev.evicted_direct) as u64;
            async_nanos += ev.async_nanos;
            async_inferences += ev.async_inferences;
            density.record(ev.instances, ev.active_nodes.max(1), 1.0);
            peak_nodes = peak_nodes.max(ev.n_nodes);
        }

        let per_function_violation =
            (0..self.cat.len()).map(|f| qos.rate(f)).collect();
        let isolated_functions = cp.monitor().unpredictable();
        Ok(RunReport {
            scheduler: cp.scheduler_name().to_string(),
            trace: trace.name.clone(),
            duration_s: duration,
            density: density.density(),
            qos_violation_rate: qos.overall(),
            per_function_violation,
            scheduling_ms_mean: costs.scheduling_ms.mean(),
            scheduling_ms_p99: costs.scheduling_ms.percentile(0.99),
            cold_start_ms_mean: costs.cold_start_ms.mean(),
            cold_start_ms_p99: costs.cold_start_ms.percentile(0.99),
            inferences_per_schedule: costs.inferences_per_schedule(),
            critical_inferences: costs.critical_inferences,
            async_inferences,
            schedule_calls: costs.calls,
            instances_started: costs.instances_started,
            fast_decisions: costs.fast_decisions,
            slow_decisions: costs.slow_decisions,
            logical_cold_starts,
            real_after_release,
            migrations,
            released,
            evicted,
            peak_nodes,
            async_nanos,
            isolated_functions,
        })
    }
}

/// Convenience: build the simulation's predictor from artifacts — PJRT
/// when compiled in (`--features pjrt`) and not overridden, otherwise the
/// pure-Rust forest.  Both run the same flattened trees; a build without
/// the feature logs once and serves the native forest so every example,
/// bench and test stays runnable on the artifacts `jiagu-gen-artifacts`
/// produces natively.
pub fn load_predictor(artifacts: &std::path::Path, native: bool) -> Result<Arc<dyn Predictor>> {
    #[cfg(feature = "pjrt")]
    if !native {
        return Ok(Arc::new(crate::runtime::PjrtPredictor::load(artifacts)?));
    }
    if !native {
        eprintln!(
            "note: built without the `pjrt` feature; serving predictions from the native forest"
        );
    }
    let params = crate::runtime::ForestParams::load(&artifacts.join("forest.json"))?;
    Ok(Arc::new(crate::runtime::NativeForestPredictor::new(params)))
}

//! Pluggable dispatch & scaling policies — the **policy lab**.
//!
//! Jiagu's density wins come from the policies layered on its
//! deterministic core; this module factors them out of the engine so new
//! strategies (including learned ones, cf. the DRL scheduling survey)
//! can be slotted in without touching the event loop:
//!
//! * [`DispatchPolicy`] — which serving instance receives one request.
//!   Factored out of the router's pick loop; the router keeps the
//!   cold-queue gate (an empty serving set never reaches a policy and
//!   consumes no randomness) and the verdict typing (idle pick →
//!   `Routed`, busy pick → `Saturated`), so every policy shares the same
//!   queueing semantics and differs only in *which* instance it picks.
//! * [`ScalingPolicy`] — how many instances a function should have and
//!   how long a serving surplus must sustain before instances are
//!   released.  Factored out of the autoscaler's release/keep-alive
//!   logic (dual-staged scaling, §5 of the paper).
//!
//! ## Implementations
//!
//! Dispatch ([`DispatchPolicyKind`]):
//!
//! * `weighted` (default) — the original `1 / (1 + in_flight)` weighted
//!   draw, **byte-identical** to the pre-refactor router: one `f64` RNG
//!   draw per pick, identical weight arithmetic and threshold walk.
//! * `p2c` — power-of-two-choices: two uniform index draws, the lower
//!   in-flight gauge wins (ties keep the first draw).  Two RNG draws per
//!   pick, always — even over a single instance — so the draw count is a
//!   pure function of the dispatch sequence.
//! * `locality` — capacity-table-affine: the weighted draw, scaled per
//!   node by the headroom the scheduler's asynchronously refreshed
//!   capacity tables report (pushed in via
//!   [`DispatchPolicy::on_capacity_hint`] when a deferred update lands
//!   in virtual time).  Before the first refresh lands it degrades to
//!   plain load weighting.
//! * `sita` — SITA-style size-interval routing: functions are split
//!   into short/long bands by their catalog solo-latency estimate at
//!   construction; short-band functions use deterministic
//!   join-shortest-queue (ties → lowest instance id), long-band
//!   functions round-robin so one elephant cannot camp on the shortest
//!   queue.  Consumes **no** RNG.
//!
//! Scaling ([`ScalingPolicyKind`]):
//!
//! * `baseline` (default) — the original behaviour: target =
//!   `ceil(rps / saturated_rps)`, release trigger = `release_duration_s`
//!   (dual-staged) or `keepalive_duration_s` (keep-alive only).
//! * `harvesting` — overcommit à la idle-resource harvesting: an idle
//!   surplus is *lent* (kept warm for the full keep-alive duration —
//!   reserved capacity co-located functions may convert cheaply) while
//!   no co-located function shows QoS pressure, and *reclaimed* at the
//!   faster release trigger as soon as the QoS monitor reports a recent
//!   violation for the function or any of its node neighbours.  Scale-up
//!   targets are identical to `baseline`, so harvesting can only keep
//!   instances longer, never under-provision.
//!
//! ## Determinism contract (the seeding rules)
//!
//! Policies draw randomness **only** from the seeded [`Rng`] handed into
//! [`DispatchPolicy::pick`] (the router's own pick stream, derived from
//! `RunConfig.seed`).  A policy may consume any fixed number of draws
//! per pick — including zero — but the count must be a pure function of
//! the pick sequence, never of wall-clock state, hash iteration order or
//! thread count.  Policy-internal state (round-robin cursors, capacity
//! hints, QoS pressure timestamps) must be driven exclusively by the
//! deterministic event stream.  `docs/DETERMINISM.md` specifies the full
//! replay contract; `rust/tests/policy_props.rs` pins every policy to
//! byte-identical replays across shards 1/2/4 × heap/wheel timelines.
//!
//! ## Adding a policy
//!
//! Implement the trait, add a [`DispatchPolicyKind`] /
//! [`ScalingPolicyKind`] variant (with `parse`/`name` entries), and
//! construct it in [`make_dispatch_policy`] / [`make_scaling_policy`] —
//! config, CLI, the diff harness's policy matrix and the determinism
//! tests pick the variant up from the kind enums.  See
//! `docs/POLICIES.md` for the full walkthrough and the ranking workflow.

use crate::autoscaler::AutoscalerConfig;
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{InstanceId, NodeId};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::fmt;

/// Read-only view of one pick's candidates: the function's serving set
/// plus the router's load columns (indexed by dense instance/node id).
/// The serving set is guaranteed non-empty — the router answers
/// `ColdQueued` itself before consulting any policy.
#[derive(Debug)]
pub struct CandidateView<'a> {
    /// The function being routed.
    pub function: FunctionId,
    /// Serving (saturated) instances of the function, non-empty.
    pub serving: &'a [InstanceId],
    /// Per-instance in-flight gauges, indexed by instance id.
    pub in_flight: &'a [u32],
    /// Per-instance home node, indexed by instance id.
    pub node_of: &'a [NodeId],
    /// Per-node in-flight totals, indexed by node id.
    pub node_in_flight: &'a [u32],
}

impl CandidateView<'_> {
    /// In-flight gauge of `id` (0 for an untracked slot — the same guard
    /// the pre-refactor pick loop used).
    pub fn in_flight_of(&self, id: InstanceId) -> u32 {
        self.in_flight.get(id as usize).copied().unwrap_or(0)
    }

    /// Home node of `id` (node 0 for an untracked slot).
    pub fn node(&self, id: InstanceId) -> NodeId {
        self.node_of.get(id as usize).copied().unwrap_or(0)
    }

    /// In-flight total of `node` (0 for an unseen node).
    pub fn node_load(&self, node: NodeId) -> u32 {
        self.node_in_flight.get(node).copied().unwrap_or(0)
    }
}

/// One request-dispatch strategy.  Object-safe; `&mut self` so policies
/// may keep deterministic internal state (cursors, hints).
pub trait DispatchPolicy: fmt::Debug + Send {
    /// Stable policy name (matches [`DispatchPolicyKind::name`]).
    fn name(&self) -> &'static str;

    /// Pick one instance out of `view.serving` (non-empty).  Randomness
    /// comes only from `rng` — see the module docs' seeding rules.  The
    /// router turns the returned id into the typed `Routed`/`Saturated`
    /// verdict, so the idle-vs-busy rule is shared by every policy.
    fn pick(&mut self, view: &CandidateView<'_>, rng: &mut Rng) -> InstanceId;

    /// Capacity-table hint for `node`: the sum of the node's
    /// per-function capacities from the scheduler's asynchronously
    /// refreshed table, pushed when the deferred update lands in virtual
    /// time.  Default: ignored.
    fn on_capacity_hint(&mut self, _node: NodeId, _capacity: f64) {}
}

/// One autoscaling strategy: scale-up targets plus release sensitivity.
/// Object-safe; `&mut self` so policies may keep deterministic
/// per-function state (QoS pressure timestamps).
pub trait ScalingPolicy: fmt::Debug + Send {
    /// Stable policy name (matches [`ScalingPolicyKind::name`]).
    fn name(&self) -> &'static str;

    /// Target instance count for `f` at modeled load `rps`.
    fn target_instances(&mut self, cat: &Catalog, f: FunctionId, rps: f64) -> u32;

    /// Seconds a serving surplus must sustain before instances are
    /// released (dual-staged) or evicted (keep-alive only).
    /// `neighbours` is the sorted set of functions co-located with `f`'s
    /// serving instances — computed only on the (off-hot-path) surplus
    /// branch.
    fn release_trigger_s(
        &mut self,
        cfg: &AutoscalerConfig,
        f: FunctionId,
        neighbours: &[FunctionId],
        now_ms: f64,
    ) -> f64;

    /// QoS observation feed from the monitor: one sample per (node,
    /// function) window, `violated` when the measured latency exceeded
    /// the function's QoS target.  Consumes no randomness.  Default:
    /// ignored.
    fn observe_qos(&mut self, _f: FunctionId, _violated: bool, _now_ms: f64) {}
}

// ---------------------------------------------------------------------------
// kinds (config / CLI surface)
// ---------------------------------------------------------------------------

/// Selectable dispatch policies (`--dispatch-policy`, config key
/// `dispatch_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicyKind {
    /// The original `1 / (1 + in_flight)` weighted draw (default).
    Weighted,
    /// Power-of-two-choices.
    PowerOfTwo,
    /// Capacity-table-affine locality weighting.
    Locality,
    /// SITA-style size-interval routing.
    Sita,
}

impl DispatchPolicyKind {
    /// Every dispatch policy, default first (the diff harness's policy
    /// matrix iterates this).
    pub const ALL: [Self; 4] = [Self::Weighted, Self::PowerOfTwo, Self::Locality, Self::Sita];

    /// Parse a CLI/JSON name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "weighted" => Self::Weighted,
            "p2c" | "power-of-two" | "poweroftwo" => Self::PowerOfTwo,
            "locality" => Self::Locality,
            "sita" => Self::Sita,
            _ => bail!("unknown dispatch policy {s:?} (weighted|p2c|locality|sita)"),
        })
    }

    /// Canonical name (round-trips through [`DispatchPolicyKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Self::Weighted => "weighted",
            Self::PowerOfTwo => "p2c",
            Self::Locality => "locality",
            Self::Sita => "sita",
        }
    }
}

/// Selectable scaling policies (`--scaling-policy`, config key
/// `scaling_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingPolicyKind {
    /// The original release/keep-alive behaviour (default).
    Baseline,
    /// Harvesting overcommit: lend idle surplus, reclaim on QoS
    /// pressure.
    Harvesting,
}

impl ScalingPolicyKind {
    /// Every scaling policy, default first.
    pub const ALL: [Self; 2] = [Self::Baseline, Self::Harvesting];

    /// Parse a CLI/JSON name.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "baseline" => Self::Baseline,
            "harvesting" => Self::Harvesting,
            _ => bail!("unknown scaling policy {s:?} (baseline|harvesting)"),
        })
    }

    /// Canonical name (round-trips through [`ScalingPolicyKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            Self::Baseline => "baseline",
            Self::Harvesting => "harvesting",
        }
    }
}

/// Construct a boxed dispatch policy.  Fallible because SITA derives its
/// size intervals from the catalog and rejects degenerate duration
/// estimates (see [`InvalidDurationEstimate`]).
pub fn make_dispatch_policy(
    kind: DispatchPolicyKind,
    cat: &Catalog,
) -> Result<Box<dyn DispatchPolicy>> {
    Ok(match kind {
        DispatchPolicyKind::Weighted => Box::new(WeightedPolicy::new()),
        DispatchPolicyKind::PowerOfTwo => Box::new(PowerOfTwoPolicy),
        DispatchPolicyKind::Locality => Box::new(LocalityPolicy::new()),
        DispatchPolicyKind::Sita => Box::new(SitaDispatch::from_catalog(cat)?),
    })
}

/// Construct a boxed scaling policy.
pub fn make_scaling_policy(kind: ScalingPolicyKind) -> Box<dyn ScalingPolicy> {
    match kind {
        ScalingPolicyKind::Baseline => Box::new(BaselineScaling),
        ScalingPolicyKind::Harvesting => Box::new(HarvestingScaling::new()),
    }
}

// ---------------------------------------------------------------------------
// dispatch policies
// ---------------------------------------------------------------------------

/// The original weighted pick: probability ∝ `1 / (1 + in_flight)`.
///
/// Byte-identical to the pre-refactor `Router::pick` hot loop: one
/// `f64` draw, weights accumulated in the same order into a reusable
/// scratch buffer, the same threshold walk with the same last-instance
/// fallback.  `rust/tests/policy_props.rs` locks this against an inline
/// copy of the pre-refactor algorithm.
#[derive(Debug, Default)]
pub struct WeightedPolicy {
    /// Reusable weight buffer (never observable).
    scratch: Vec<f64>,
}

impl WeightedPolicy {
    /// A fresh weighted policy.
    pub fn new() -> Self {
        Self::default()
    }
}

impl DispatchPolicy for WeightedPolicy {
    fn name(&self) -> &'static str {
        "weighted"
    }

    fn pick(&mut self, view: &CandidateView<'_>, rng: &mut Rng) -> InstanceId {
        let u = rng.f64();
        self.scratch.clear();
        let mut total = 0.0;
        for &id in view.serving {
            let n = view.in_flight.get(id as usize).copied().unwrap_or(0);
            let w = 1.0 / (1.0 + n as f64);
            total += w;
            self.scratch.push(w);
        }
        let mut r = u * total;
        let mut picked = *view.serving.last().expect("serving set is non-empty");
        for (&id, w) in view.serving.iter().zip(&self.scratch) {
            r -= w;
            if r <= 0.0 {
                picked = id;
                break;
            }
        }
        picked
    }
}

/// Power-of-two-choices: draw two uniform candidates, keep the one with
/// the lower in-flight gauge (ties keep the first draw).  Exactly two
/// RNG draws per pick regardless of the serving-set size, so the draw
/// count stays a pure function of the dispatch sequence.  Both draws
/// index into `view.serving`, so the pick can never leave the serving
/// set — pinned by `rust/tests/policy_props.rs`.
#[derive(Debug, Default)]
pub struct PowerOfTwoPolicy;

impl DispatchPolicy for PowerOfTwoPolicy {
    fn name(&self) -> &'static str {
        "p2c"
    }

    fn pick(&mut self, view: &CandidateView<'_>, rng: &mut Rng) -> InstanceId {
        let n = view.serving.len() as u64;
        let a = view.serving[rng.below(n) as usize];
        let b = view.serving[rng.below(n) as usize];
        if view.in_flight_of(b) < view.in_flight_of(a) {
            b
        } else {
            a
        }
    }
}

/// Capacity-table-affine locality weighting: the weighted draw, scaled
/// by per-node headroom from the scheduler's capacity tables.
///
/// Weight of instance `i` on node `m`:
/// `1/(1 + in_flight_i) * (1 + max(0, hint_m − node_in_flight_m))` —
/// instances on nodes whose refreshed capacity tables report spare
/// admission headroom draw proportionally more traffic.  Hints land via
/// [`DispatchPolicy::on_capacity_hint`] when a deferred capacity update
/// completes in virtual time (so the hint stream is deterministic);
/// until the first hint arrives every headroom term is `1` and the
/// policy degrades to plain load weighting.  One RNG draw per pick,
/// like `weighted`.
#[derive(Debug, Default)]
pub struct LocalityPolicy {
    /// Per-node capacity hints (latest deferred-update totals).
    hints: Vec<f64>,
    /// Reusable weight buffer (never observable).
    scratch: Vec<f64>,
}

impl LocalityPolicy {
    /// A locality policy with no hints yet (plain load weighting).
    pub fn new() -> Self {
        Self::default()
    }
}

impl DispatchPolicy for LocalityPolicy {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn pick(&mut self, view: &CandidateView<'_>, rng: &mut Rng) -> InstanceId {
        let u = rng.f64();
        self.scratch.clear();
        let mut total = 0.0;
        for &id in view.serving {
            let node = view.node(id);
            let headroom =
                (self.hints.get(node).copied().unwrap_or(0.0) - view.node_load(node) as f64)
                    .max(0.0);
            let w = (1.0 + headroom) / (1.0 + view.in_flight_of(id) as f64);
            total += w;
            self.scratch.push(w);
        }
        let mut r = u * total;
        let mut picked = *view.serving.last().expect("serving set is non-empty");
        for (&id, w) in view.serving.iter().zip(&self.scratch) {
            r -= w;
            if r <= 0.0 {
                picked = id;
                break;
            }
        }
        picked
    }

    fn on_capacity_hint(&mut self, node: NodeId, capacity: f64) {
        // guard like `Router::per_instance_rps`: a non-finite or negative
        // hint degrades to "no headroom", never to NaN weights
        let clean = if capacity.is_finite() { capacity.max(0.0) } else { 0.0 };
        if self.hints.len() <= node {
            self.hints.resize(node + 1, 0.0);
        }
        self.hints[node] = clean;
    }
}

/// Typed construction error for [`SitaDispatch`]: a catalog function
/// whose solo-latency duration estimate is non-finite or non-positive.
///
/// SITA derives its size-interval boundaries from these estimates; the
/// pre-fix behaviour silently routed every such function to interval 0
/// (the NaN/zero comparison landed it in the short band), hiding a
/// poisoned catalog.  Construction now fails loudly instead — pinned by
/// a regression test in `rust/tests/policy_props.rs`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InvalidDurationEstimate {
    /// The offending function id.
    pub function: FunctionId,
    /// Its `solo_latency_ms` estimate as found in the catalog.
    pub estimate_ms: f64,
}

impl fmt::Display for InvalidDurationEstimate {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "sita size intervals need finite positive duration estimates; \
             function {} has solo_latency_ms = {}",
            self.function, self.estimate_ms
        )
    }
}

impl std::error::Error for InvalidDurationEstimate {}

/// SITA-style size-interval routing.
///
/// Classic SITA segregates *request sizes* onto disjoint servers; in
/// this model every request of a function costs one saturated-rate
/// interval, so the size signal lives in the catalog: functions are
/// banded by their `solo_latency_ms` estimate at construction (strictly
/// below the upper median → short band).  Short-band functions use
/// join-shortest-queue (deterministic; ties break to the lowest
/// instance id), long-band functions round-robin over their serving set
/// so an elephant spreads instead of camping on one queue.  Consumes no
/// RNG — determinism holds because the pick is a pure function of the
/// queue state and the per-function cursor.
#[derive(Debug)]
pub struct SitaDispatch {
    /// Per-function band: `false` = short (JSQ), `true` = long (RR).
    long_band: Vec<bool>,
    /// Per-function round-robin cursors for the long band.
    cursor: Vec<usize>,
}

impl SitaDispatch {
    /// Derive the size intervals from the catalog's solo-latency
    /// estimates.  Fails with [`InvalidDurationEstimate`] on any
    /// non-finite or non-positive estimate (the regression this
    /// constructor exists to catch).
    pub fn from_catalog(cat: &Catalog) -> Result<Self, InvalidDurationEstimate> {
        let mut estimates = Vec::with_capacity(cat.len());
        for f in 0..cat.len() {
            let est = cat.get(f).solo_latency_ms;
            if !est.is_finite() || est <= 0.0 {
                return Err(InvalidDurationEstimate { function: f, estimate_ms: est });
            }
            estimates.push(est);
        }
        let mut sorted = estimates.clone();
        sorted.sort_by(f64::total_cmp);
        // upper median: with an empty catalog there is no boundary and
        // no function either, so any placeholder works
        let boundary = sorted.get(sorted.len() / 2).copied().unwrap_or(0.0);
        let long_band = estimates.iter().map(|&e| e >= boundary).collect();
        Ok(Self { long_band, cursor: vec![0; cat.len()] })
    }

    /// Whether `f` routes through the long (round-robin) band.
    pub fn is_long_band(&self, f: FunctionId) -> bool {
        self.long_band.get(f).copied().unwrap_or(false)
    }
}

impl DispatchPolicy for SitaDispatch {
    fn name(&self) -> &'static str {
        "sita"
    }

    fn pick(&mut self, view: &CandidateView<'_>, _rng: &mut Rng) -> InstanceId {
        let f = view.function;
        if self.is_long_band(f) {
            if self.cursor.len() <= f {
                self.cursor.resize(f + 1, 0);
            }
            let c = &mut self.cursor[f];
            let picked = view.serving[*c % view.serving.len()];
            *c = (*c + 1) % view.serving.len();
            return picked;
        }
        // short band: join-shortest-queue, ties to the lowest id
        let mut best = view.serving[0];
        let mut best_q = view.in_flight_of(best);
        for &id in &view.serving[1..] {
            let q = view.in_flight_of(id);
            if q < best_q || (q == best_q && id < best) {
                best = id;
                best_q = q;
            }
        }
        best
    }
}

// ---------------------------------------------------------------------------
// scaling policies
// ---------------------------------------------------------------------------

/// The original autoscaler behaviour: target `ceil(rps/saturated_rps)`,
/// release after `release_duration_s` (dual-staged) or
/// `keepalive_duration_s` (keep-alive only) of sustained surplus.
#[derive(Debug, Default)]
pub struct BaselineScaling;

impl ScalingPolicy for BaselineScaling {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn target_instances(&mut self, cat: &Catalog, f: FunctionId, rps: f64) -> u32 {
        if rps <= 0.0 {
            0
        } else {
            (rps / cat.get(f).saturated_rps).ceil() as u32
        }
    }

    fn release_trigger_s(
        &mut self,
        cfg: &AutoscalerConfig,
        _f: FunctionId,
        _neighbours: &[FunctionId],
        _now_ms: f64,
    ) -> f64 {
        if cfg.dual_staged {
            cfg.release_duration_s
        } else {
            cfg.keepalive_duration_s
        }
    }
}

/// Milliseconds after a function's last observed QoS violation during
/// which its co-located lenders must reclaim their surplus.
pub const HARVEST_PRESSURE_TTL_MS: f64 = 3_000.0;

/// Harvesting overcommit: lend idle reserved capacity, reclaim it on
/// QoS pressure.
///
/// Scale-up targets are identical to [`BaselineScaling`] — harvesting
/// never under-provisions.  The release trigger is where it differs:
/// while neither the function nor any co-located neighbour has a QoS
/// violation within [`HARVEST_PRESSURE_TTL_MS`], a surplus is held for
/// the full `keepalive_duration_s` (the lend: warm reserved capacity
/// stays convertible); a recent violation drops the trigger back to
/// `release_duration_s` (the reclaim).  Since `keepalive ≥ release` by
/// configuration, harvesting can only *delay* releases relative to
/// baseline — on the golden scenario (whose 10 s horizon never sustains
/// either trigger) it is behaviourally identical, which
/// `rust/tests/policy_props.rs` pins as full-report equality.
#[derive(Debug, Default)]
pub struct HarvestingScaling {
    /// Per-function virtual time of the last observed QoS violation
    /// (`-inf` when never violated).
    last_pressure_ms: Vec<f64>,
}

impl HarvestingScaling {
    /// A harvesting policy with no pressure observed yet.
    pub fn new() -> Self {
        Self::default()
    }

    fn pressured(&self, f: FunctionId, now_ms: f64) -> bool {
        matches!(self.last_pressure_ms.get(f),
                 Some(&t) if now_ms - t <= HARVEST_PRESSURE_TTL_MS)
    }
}

impl ScalingPolicy for HarvestingScaling {
    fn name(&self) -> &'static str {
        "harvesting"
    }

    fn target_instances(&mut self, cat: &Catalog, f: FunctionId, rps: f64) -> u32 {
        if rps <= 0.0 {
            0
        } else {
            (rps / cat.get(f).saturated_rps).ceil() as u32
        }
    }

    fn release_trigger_s(
        &mut self,
        cfg: &AutoscalerConfig,
        f: FunctionId,
        neighbours: &[FunctionId],
        now_ms: f64,
    ) -> f64 {
        if !cfg.dual_staged {
            // keep-alive-only mode has no release stage to stretch
            return cfg.keepalive_duration_s;
        }
        let reclaim =
            self.pressured(f, now_ms) || neighbours.iter().any(|&g| self.pressured(g, now_ms));
        if reclaim {
            cfg.release_duration_s
        } else {
            cfg.keepalive_duration_s
        }
    }

    fn observe_qos(&mut self, f: FunctionId, violated: bool, now_ms: f64) {
        if !violated {
            return;
        }
        if self.last_pressure_ms.len() <= f {
            self.last_pressure_ms.resize(f + 1, f64::NEG_INFINITY);
        }
        self.last_pressure_ms[f] = now_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    fn view<'a>(
        serving: &'a [InstanceId],
        in_flight: &'a [u32],
        node_of: &'a [NodeId],
        node_in_flight: &'a [u32],
    ) -> CandidateView<'a> {
        CandidateView { function: 0, serving, in_flight, node_of, node_in_flight }
    }

    #[test]
    fn kinds_parse_roundtrip_and_reject_unknown() {
        for k in DispatchPolicyKind::ALL {
            assert_eq!(DispatchPolicyKind::parse(k.name()).unwrap(), k);
        }
        for k in ScalingPolicyKind::ALL {
            assert_eq!(ScalingPolicyKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(DispatchPolicyKind::parse("P2C").unwrap(), DispatchPolicyKind::PowerOfTwo);
        assert!(DispatchPolicyKind::parse("rr").is_err());
        assert!(ScalingPolicyKind::parse("borrow").is_err());
    }

    #[test]
    fn weighted_matches_the_reference_threshold_walk() {
        // the exact pre-refactor arithmetic, inline
        let serving: Vec<InstanceId> = vec![3, 5, 9];
        let mut in_flight = vec![0u32; 10];
        in_flight[3] = 4;
        in_flight[9] = 1;
        let nodes = vec![0usize; 10];
        let node_load = vec![0u32; 4];
        let mut policy = WeightedPolicy::new();
        let mut rng = Rng::seed_from(0xfeed);
        let mut reference_rng = Rng::seed_from(0xfeed);
        for _ in 0..256 {
            let picked =
                policy.pick(&view(&serving, &in_flight, &nodes, &node_load), &mut rng);
            let u = reference_rng.f64();
            let weights: Vec<f64> =
                serving.iter().map(|&id| 1.0 / (1.0 + in_flight[id as usize] as f64)).collect();
            let total: f64 = weights.iter().sum();
            let mut r = u * total;
            let mut expect = *serving.last().unwrap();
            for (&id, w) in serving.iter().zip(&weights) {
                r -= w;
                if r <= 0.0 {
                    expect = id;
                    break;
                }
            }
            assert_eq!(picked, expect);
        }
    }

    #[test]
    fn p2c_prefers_the_lighter_of_two_draws_and_stays_in_set() {
        let serving: Vec<InstanceId> = vec![1, 2, 6];
        let mut in_flight = vec![0u32; 8];
        in_flight[1] = 50;
        in_flight[2] = 50;
        let nodes = vec![0usize; 8];
        let node_load = vec![0u32; 2];
        let mut policy = PowerOfTwoPolicy;
        let mut rng = Rng::seed_from(7);
        let mut idle_hits = 0u32;
        for _ in 0..512 {
            let picked = policy.pick(&view(&serving, &in_flight, &nodes, &node_load), &mut rng);
            assert!(serving.contains(&picked), "picked {picked} outside the serving set");
            if picked == 6 {
                idle_hits += 1;
            }
        }
        // the idle instance wins every pair it appears in: ~5/9 of picks
        assert!(idle_hits > 200, "idle instance must win its pairs: {idle_hits}/512");
    }

    #[test]
    fn p2c_draw_count_is_fixed_even_for_one_instance() {
        let serving: Vec<InstanceId> = vec![4];
        let in_flight = vec![0u32; 5];
        let nodes = vec![0usize; 5];
        let node_load = vec![0u32; 1];
        let mut policy = PowerOfTwoPolicy;
        let mut a = Rng::seed_from(11);
        let mut b = Rng::seed_from(11);
        policy.pick(&view(&serving, &in_flight, &nodes, &node_load), &mut a);
        // the same stream advanced by exactly two below() draws
        b.below(1);
        b.below(1);
        assert_eq!(a.next_u64(), b.next_u64(), "p2c must always consume two draws");
    }

    #[test]
    fn locality_follows_capacity_headroom_and_guards_bad_hints() {
        let serving: Vec<InstanceId> = vec![0, 1];
        let in_flight = vec![0u32; 2];
        let nodes = vec![0usize, 1usize];
        let node_load = vec![0u32, 0u32];
        let mut policy = LocalityPolicy::new();
        // node 1 advertises big headroom; NaN/negative hints are inert
        policy.on_capacity_hint(1, 40.0);
        policy.on_capacity_hint(0, f64::NAN);
        let mut rng = Rng::seed_from(3);
        let mut hits = [0u32; 2];
        for _ in 0..400 {
            let picked = policy.pick(&view(&serving, &in_flight, &nodes, &node_load), &mut rng);
            hits[picked as usize] += 1;
        }
        assert!(
            hits[1] > hits[0] * 5,
            "headroom node must dominate (weights 41 vs 1): {hits:?}"
        );
        policy.on_capacity_hint(0, -7.0);
        assert_eq!(policy.hints[0], 0.0, "negative hints clamp to zero");
    }

    #[test]
    fn sita_bands_split_on_the_median_and_route_jsq_vs_rr() {
        // derive the expected split from the catalog itself: strictly
        // below the upper-median solo latency → short band
        let cat = test_catalog();
        let policy = SitaDispatch::from_catalog(&cat).unwrap();
        let solos: Vec<f64> =
            (0..cat.len()).map(|f| cat.get(f).solo_latency_ms).collect();
        let mut sorted = solos.clone();
        sorted.sort_by(f64::total_cmp);
        let boundary = sorted[sorted.len() / 2];
        let mut short_fns = Vec::new();
        let mut long_fns = Vec::new();
        for (f, &solo) in solos.iter().enumerate() {
            assert_eq!(policy.is_long_band(f), solo >= boundary, "band of fn {f}");
            if solo >= boundary {
                long_fns.push(f);
            } else {
                short_fns.push(f);
            }
        }
        assert_eq!(short_fns.len(), 2, "4 functions split evenly on the median");
        assert_eq!(long_fns.len(), 2);

        let serving: Vec<InstanceId> = vec![2, 5, 7];
        let mut in_flight = vec![0u32; 8];
        in_flight[2] = 3;
        in_flight[7] = 3;
        let nodes = vec![0usize; 8];
        let node_load = vec![0u32; 1];
        let mut rng = Rng::seed_from(1);
        let mut policy = SitaDispatch::from_catalog(&cat).unwrap();
        // short band: JSQ picks the only idle instance
        let mut v = view(&serving, &in_flight, &nodes, &node_load);
        v.function = short_fns[0];
        assert_eq!(policy.pick(&v, &mut rng), 5);
        // JSQ tie: lowest instance id wins
        in_flight[5] = 3;
        let mut v = view(&serving, &in_flight, &nodes, &node_load);
        v.function = short_fns[0];
        assert_eq!(policy.pick(&v, &mut rng), 2);
        // long band: round-robin ignores queue lengths
        let mut v = view(&serving, &in_flight, &nodes, &node_load);
        v.function = long_fns[0];
        let rr: Vec<InstanceId> = (0..4).map(|_| policy.pick(&v, &mut rng)).collect();
        assert_eq!(rr, vec![2, 5, 7, 2]);
        // and consumed no RNG at all
        assert_eq!(Rng::seed_from(1).next_u64(), rng.next_u64());
    }

    #[test]
    fn sita_rejects_degenerate_duration_estimates() {
        for bad in [f64::NAN, 0.0, -3.0, f64::INFINITY] {
            let mut funcs = test_catalog().functions.clone();
            funcs[2].solo_latency_ms = bad;
            let cat = Catalog::from_functions(funcs);
            let err = SitaDispatch::from_catalog(&cat).unwrap_err();
            assert_eq!(err.function, 2);
            if bad.is_nan() {
                assert!(err.estimate_ms.is_nan());
            } else {
                assert_eq!(err.estimate_ms, bad);
            }
            assert!(err.to_string().contains("function 2"), "{err}");
        }
    }

    #[test]
    fn baseline_trigger_matches_the_prerefactor_constants() {
        let mut p = BaselineScaling;
        let mut cfg = AutoscalerConfig::default();
        assert_eq!(p.release_trigger_s(&cfg, 0, &[], 0.0), 45.0);
        cfg.dual_staged = false;
        assert_eq!(p.release_trigger_s(&cfg, 0, &[], 0.0), 60.0);
        let cat = test_catalog();
        // target formula unchanged: ceil(rps / saturated_rps), 0 at rest
        assert_eq!(p.target_instances(&cat, 0, 0.0), 0);
        let sat = cat.get(0).saturated_rps;
        assert_eq!(p.target_instances(&cat, 0, sat * 2.5), 3);
    }

    #[test]
    fn harvesting_lends_idle_surplus_and_reclaims_on_pressure() {
        let mut p = HarvestingScaling::new();
        let cfg = AutoscalerConfig::default();
        // no pressure anywhere: lend (keep-alive trigger)
        assert_eq!(p.release_trigger_s(&cfg, 0, &[1, 2], 10_000.0), 60.0);
        // a co-located neighbour violates QoS: reclaim promptly
        p.observe_qos(2, true, 9_500.0);
        assert_eq!(p.release_trigger_s(&cfg, 0, &[1, 2], 10_000.0), 45.0);
        // pressure ages out after the TTL
        assert_eq!(
            p.release_trigger_s(&cfg, 0, &[1, 2], 9_500.0 + HARVEST_PRESSURE_TTL_MS + 1.0),
            60.0
        );
        // non-violating samples leave no pressure
        p.observe_qos(1, false, 20_000.0);
        assert_eq!(p.release_trigger_s(&cfg, 0, &[1], 20_001.0), 60.0);
        // self-pressure reclaims too
        p.observe_qos(0, true, 30_000.0);
        assert_eq!(p.release_trigger_s(&cfg, 0, &[], 30_001.0), 45.0);
        // targets are exactly baseline's
        let cat = test_catalog();
        let mut b = BaselineScaling;
        for rps in [0.0, 1.0, 17.3, 500.0] {
            assert_eq!(p.target_instances(&cat, 1, rps), b.target_instances(&cat, 1, rps));
        }
    }
}

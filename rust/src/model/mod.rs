//! Model-facing glue: the feature builder shared with the Python trainer.
//!
//! `features::feature_row` mirrors `python/compile/datagen.py::
//! feature_vector` exactly (layout documented in `artifacts/meta.json`);
//! `rust/tests/golden.rs` cross-checks rows against the golden vectors
//! emitted at `make artifacts`.

pub mod features;
pub mod monitor;

pub use features::{feature_row, FeatureBuilder, FeatureMatrix, N_FEATURES};
pub use monitor::AccuracyMonitor;

//! Online prediction-accuracy monitoring and the unpredictability
//! fallback (§6): "if the prediction error does not converge after
//! several iterations, Jiagu disables overcommitment and uses [a]
//! traditional conservative QoS-unaware policy to schedule the instances
//! of the unpredictable function on separate nodes".
//!
//! The simulator feeds (predicted, measured) pairs per function; the
//! monitor keeps an exponential moving average of relative error and
//! flags functions whose error stays above threshold once enough samples
//! accumulated.  A flagged function can recover (the paper retrains
//! periodically): if the EMA drops back under half the threshold it is
//! un-flagged.

use crate::catalog::FunctionId;

/// Per-function online error state.
#[derive(Debug, Clone, Copy)]
struct ErrState {
    ema: f64,
    samples: u64,
    flagged: bool,
}

impl Default for ErrState {
    fn default() -> Self {
        Self { ema: 0.0, samples: 0, flagged: false }
    }
}

/// Tracks per-function prediction error and unpredictability flags.
#[derive(Debug)]
pub struct AccuracyMonitor {
    state: Vec<ErrState>,
    /// EMA smoothing factor.
    pub alpha: f64,
    /// Error level above which a function is deemed unpredictable.
    pub threshold: f64,
    /// Minimum samples before a function may be flagged.
    pub min_samples: u64,
}

impl AccuracyMonitor {
    pub fn new(n_functions: usize) -> Self {
        Self {
            state: vec![ErrState::default(); n_functions],
            alpha: 0.15,
            threshold: 0.35,
            min_samples: 5,
        }
    }

    /// Record one (predicted, measured) observation for `f`.
    pub fn record(&mut self, f: FunctionId, predicted_ms: f64, measured_ms: f64) {
        if measured_ms <= 0.0 {
            return;
        }
        let err = (predicted_ms - measured_ms).abs() / measured_ms;
        let s = &mut self.state[f];
        s.samples += 1;
        s.ema = if s.samples == 1 { err } else { s.ema + self.alpha * (err - s.ema) };
        if s.samples >= self.min_samples {
            if s.ema > self.threshold {
                s.flagged = true;
            } else if s.ema < 0.5 * self.threshold {
                // hysteresis: recover only once clearly back in band
                s.flagged = false;
            }
        }
    }

    /// Current error EMA of `f`.
    pub fn error(&self, f: FunctionId) -> f64 {
        self.state[f].ema
    }

    pub fn samples(&self, f: FunctionId) -> u64 {
        self.state[f].samples
    }

    /// Whether `f` should fall back to conservative isolated scheduling.
    pub fn is_unpredictable(&self, f: FunctionId) -> bool {
        self.state[f].flagged
    }

    /// All currently flagged functions.
    pub fn unpredictable(&self) -> Vec<FunctionId> {
        self.state
            .iter()
            .enumerate()
            .filter(|(_, s)| s.flagged)
            .map(|(f, _)| f)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accurate_function_never_flags() {
        let mut m = AccuracyMonitor::new(2);
        for _ in 0..50 {
            m.record(0, 102.0, 100.0);
        }
        assert!(!m.is_unpredictable(0));
        assert!(m.error(0) < 0.05);
    }

    #[test]
    fn diverging_function_flags_after_min_samples() {
        let mut m = AccuracyMonitor::new(1);
        for i in 0..20 {
            m.record(0, 60.0, 100.0); // 40% error
            if (i as u64) < m.min_samples - 1 {
                assert!(!m.is_unpredictable(0), "needs min samples first");
            }
        }
        assert!(m.is_unpredictable(0));
        assert_eq!(m.unpredictable(), vec![0]);
    }

    #[test]
    fn flag_recovers_with_hysteresis() {
        let mut m = AccuracyMonitor::new(1);
        for _ in 0..20 {
            m.record(0, 50.0, 100.0);
        }
        assert!(m.is_unpredictable(0));
        // model retrained: error drops — must fall under half threshold
        for _ in 0..60 {
            m.record(0, 99.0, 100.0);
        }
        assert!(!m.is_unpredictable(0));
    }

    #[test]
    fn zero_or_negative_measurements_ignored() {
        let mut m = AccuracyMonitor::new(1);
        m.record(0, 50.0, 0.0);
        assert_eq!(m.samples(0), 0);
    }
}

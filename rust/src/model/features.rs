//! The 44-dim predictor feature row (contract with the Python trainer).
//!
//! ```text
//! [ P_solo(A), R_A[13], C_A_sat, C_A_cached,
//!   Σ_i C_i_sat·R_i [13], Σ_i C_i_cached·R_i [13],
//!   Σ C_i_sat, Σ C_i_cached ]
//! ```

use crate::catalog::{Catalog, FunctionId};
use crate::interference::NodeMix;
use anyhow::{ensure, Result};

/// Total feature dimensionality (1 + 13 + 2 + 13 + 13 + 2).
pub const N_FEATURES: usize = 44;

const N_PROFILE: usize = 13;

/// A borrowed row-major feature batch: one flat `Vec<f32>` of
/// `n_rows x n_features` values instead of one heap `Vec` per row.
///
/// This is the shape the prediction hot path works in end to end: the
/// capacity sweep appends rows straight from [`FeatureBuilder`] (no
/// per-row allocation),
/// [`Predictor::predict_batch`](crate::runtime::Predictor::predict_batch)
/// borrows the flat buffer, and the flat forest engine
/// ([`crate::runtime::FlatForest`]) standardises and traverses it in row
/// blocks.  The buffer is reusable: `clear` keeps the capacity, so a
/// steady-state sweep allocates nothing.
#[derive(Debug, Clone, Default)]
pub struct FeatureMatrix {
    data: Vec<f32>,
    n_features: usize,
}

impl FeatureMatrix {
    pub fn new(n_features: usize) -> Self {
        Self { data: Vec::new(), n_features }
    }

    /// Pre-size for `rows` rows.
    pub fn with_capacity(n_features: usize, rows: usize) -> Self {
        Self { data: Vec::with_capacity(n_features * rows), n_features }
    }

    /// Build from per-row `Vec`s (the compatibility path for callers that
    /// load rows from JSON or tests that hold `Vec<Vec<f32>>`).
    pub fn from_rows(n_features: usize, rows: &[Vec<f32>]) -> Result<Self> {
        let mut m = Self::with_capacity(n_features, rows.len());
        for row in rows {
            ensure!(
                row.len() == n_features,
                "feature row has {} dims, matrix expects {}",
                row.len(),
                n_features
            );
            m.data.extend_from_slice(row);
        }
        Ok(m)
    }

    /// Drop all rows, keeping the allocation for reuse.
    pub fn clear(&mut self) {
        self.data.clear();
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_rows(&self) -> usize {
        debug_assert_eq!(self.data.len() % self.n_features.max(1), 0);
        if self.n_features == 0 {
            0
        } else {
            self.data.len() / self.n_features
        }
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row `i` as a slice view into the flat buffer.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Iterate all rows as slices.
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.n_features)
    }

    /// The flat row-major buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Append one row by copying a slice.
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.n_features);
        self.data.extend_from_slice(row);
    }

    /// Append one row produced by `fill`, which must push exactly
    /// `n_features` values — the allocation-free producer hook
    /// [`FeatureBuilder::row_into_matrix`] uses.
    pub fn push_row_with(&mut self, fill: impl FnOnce(&mut Vec<f32>)) {
        let start = self.data.len();
        fill(&mut self.data);
        debug_assert_eq!(self.data.len() - start, self.n_features, "row width mismatch");
    }
}

/// Build one feature row for (node mix, target function).
pub fn feature_row(cat: &Catalog, mix: &NodeMix, target: FunctionId) -> Vec<f32> {
    let b = FeatureBuilder::new(cat, mix);
    b.row(target)
}

/// Reusable builder: aggregates the mix once, then emits one row per
/// target function — the capacity sweep asks for many rows over the same
/// mix, so the O(mix) aggregation is hoisted out of the per-row path.
pub struct FeatureBuilder<'a> {
    cat: &'a Catalog,
    mix: &'a NodeMix,
    agg_sat: [f64; N_PROFILE],
    agg_cached: [f64; N_PROFILE],
    tot_sat: f64,
    tot_cached: f64,
}

impl<'a> FeatureBuilder<'a> {
    pub fn new(cat: &'a Catalog, mix: &'a NodeMix) -> Self {
        let mut agg_sat = [0.0; N_PROFILE];
        let mut agg_cached = [0.0; N_PROFILE];
        let mut tot_sat = 0.0;
        let mut tot_cached = 0.0;
        for (fid, sat, cached) in &mix.entries {
            let prof = &cat.get(*fid).profile;
            for j in 0..N_PROFILE {
                agg_sat[j] += *sat as f64 * prof[j];
                agg_cached[j] += *cached as f64 * prof[j];
            }
            tot_sat += *sat as f64;
            tot_cached += *cached as f64;
        }
        Self { cat, mix, agg_sat, agg_cached, tot_sat, tot_cached }
    }

    /// Counts of the target function within the mix (0 if absent).
    fn target_counts(&self, target: FunctionId) -> (f64, f64) {
        self.mix
            .entries
            .iter()
            .find(|(fid, _, _)| *fid == target)
            .map(|(_, s, c)| (*s as f64, *c as f64))
            .unwrap_or((0.0, 0.0))
    }

    /// Emit the row for `target` into a fresh Vec.
    pub fn row(&self, target: FunctionId) -> Vec<f32> {
        let mut out = Vec::with_capacity(N_FEATURES);
        self.row_into(target, &mut out);
        out
    }

    /// Emit the row for `target` into `out` (cleared first) — the
    /// allocation-free variant for callers that want one standalone row.
    pub fn row_into(&self, target: FunctionId, out: &mut Vec<f32>) {
        out.clear();
        self.write_row(target, out);
        debug_assert_eq!(out.len(), N_FEATURES);
    }

    /// Append the row for `target` onto a [`FeatureMatrix`] — the batch
    /// hot-path variant the capacity sweep uses: no temporary `Vec`, the
    /// values land directly in the matrix's flat buffer.
    pub fn row_into_matrix(&self, target: FunctionId, m: &mut FeatureMatrix) {
        debug_assert_eq!(m.n_features(), N_FEATURES);
        m.push_row_with(|out| self.write_row(target, out));
    }

    /// The single row writer behind both emit paths (identical f32
    /// conversions in identical order, so the two paths are bit-equal).
    fn write_row(&self, target: FunctionId, out: &mut Vec<f32>) {
        let spec = self.cat.get(target);
        let (t_sat, t_cached) = self.target_counts(target);
        out.push(spec.solo_latency_ms as f32);
        out.extend(spec.profile.iter().map(|v| *v as f32));
        out.push(t_sat as f32);
        out.push(t_cached as f32);
        out.extend(self.agg_sat.iter().map(|v| *v as f32));
        out.extend(self.agg_cached.iter().map(|v| *v as f32));
        out.push(self.tot_sat as f32);
        out.push(self.tot_cached as f32);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{tests::test_spec, Catalog};

    fn cat2() -> Catalog {
        Catalog::from_functions(vec![test_spec("a", 50.0), test_spec("b", 20.0)])
    }

    #[test]
    fn row_has_contract_dims_and_solo_head() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(0, 3, 1), (1, 2, 0)]);
        let row = feature_row(&cat, &mix, 0);
        assert_eq!(row.len(), N_FEATURES);
        assert_eq!(row[0], cat.get(0).solo_latency_ms as f32);
        // target concurrency slots
        assert_eq!(row[14], 3.0);
        assert_eq!(row[15], 1.0);
        // totals at the tail
        assert_eq!(row[N_FEATURES - 2], 5.0);
        assert_eq!(row[N_FEATURES - 1], 1.0);
    }

    #[test]
    fn absent_target_has_zero_concurrency() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(1, 4, 2)]);
        let row = feature_row(&cat, &mix, 0);
        assert_eq!(row[14], 0.0);
        assert_eq!(row[15], 0.0);
        // but the aggregate still sees the neighbours
        assert_eq!(row[N_FEATURES - 2], 4.0);
    }

    #[test]
    fn builder_rows_match_one_shot() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(0, 2, 1), (1, 5, 3)]);
        let b = FeatureBuilder::new(&cat, &mix);
        for t in 0..2 {
            assert_eq!(b.row(t), feature_row(&cat, &mix, t));
        }
    }

    #[test]
    fn matrix_rows_are_bit_equal_to_vec_rows() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(0, 2, 1), (1, 5, 3)]);
        let b = FeatureBuilder::new(&cat, &mix);
        let mut m = FeatureMatrix::new(N_FEATURES);
        for t in 0..2 {
            b.row_into_matrix(t, &mut m);
        }
        assert_eq!(m.n_rows(), 2);
        for t in 0..2 {
            assert_eq!(m.row(t), feature_row(&cat, &mix, t).as_slice());
        }
        // reuse keeps the allocation and drops the rows
        m.clear();
        assert!(m.is_empty());
        b.row_into_matrix(1, &mut m);
        assert_eq!(m.n_rows(), 1);
        assert_eq!(m.row(0), feature_row(&cat, &mix, 1).as_slice());
    }

    #[test]
    fn matrix_from_rows_roundtrips_and_rejects_ragged_input() {
        let rows = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let m = FeatureMatrix::from_rows(2, &rows).unwrap();
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.data(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.rows().collect::<Vec<_>>(), vec![&[1.0f32, 2.0][..], &[3.0, 4.0][..]]);
        assert!(FeatureMatrix::from_rows(3, &rows).is_err(), "ragged rows must be rejected");
    }
}

//! The 44-dim predictor feature row (contract with the Python trainer).
//!
//! ```text
//! [ P_solo(A), R_A[13], C_A_sat, C_A_cached,
//!   Σ_i C_i_sat·R_i [13], Σ_i C_i_cached·R_i [13],
//!   Σ C_i_sat, Σ C_i_cached ]
//! ```

use crate::catalog::{Catalog, FunctionId};
use crate::interference::NodeMix;

/// Total feature dimensionality (1 + 13 + 2 + 13 + 13 + 2).
pub const N_FEATURES: usize = 44;

const N_PROFILE: usize = 13;

/// Build one feature row for (node mix, target function).
pub fn feature_row(cat: &Catalog, mix: &NodeMix, target: FunctionId) -> Vec<f32> {
    let b = FeatureBuilder::new(cat, mix);
    b.row(target)
}

/// Reusable builder: aggregates the mix once, then emits one row per
/// target function — the capacity sweep asks for many rows over the same
/// mix, so the O(mix) aggregation is hoisted out of the per-row path.
pub struct FeatureBuilder<'a> {
    cat: &'a Catalog,
    mix: &'a NodeMix,
    agg_sat: [f64; N_PROFILE],
    agg_cached: [f64; N_PROFILE],
    tot_sat: f64,
    tot_cached: f64,
}

impl<'a> FeatureBuilder<'a> {
    pub fn new(cat: &'a Catalog, mix: &'a NodeMix) -> Self {
        let mut agg_sat = [0.0; N_PROFILE];
        let mut agg_cached = [0.0; N_PROFILE];
        let mut tot_sat = 0.0;
        let mut tot_cached = 0.0;
        for (fid, sat, cached) in &mix.entries {
            let prof = &cat.get(*fid).profile;
            for j in 0..N_PROFILE {
                agg_sat[j] += *sat as f64 * prof[j];
                agg_cached[j] += *cached as f64 * prof[j];
            }
            tot_sat += *sat as f64;
            tot_cached += *cached as f64;
        }
        Self { cat, mix, agg_sat, agg_cached, tot_sat, tot_cached }
    }

    /// Counts of the target function within the mix (0 if absent).
    fn target_counts(&self, target: FunctionId) -> (f64, f64) {
        self.mix
            .entries
            .iter()
            .find(|(fid, _, _)| *fid == target)
            .map(|(_, s, c)| (*s as f64, *c as f64))
            .unwrap_or((0.0, 0.0))
    }

    /// Emit the row for `target` into a fresh Vec.
    pub fn row(&self, target: FunctionId) -> Vec<f32> {
        let mut out = Vec::with_capacity(N_FEATURES);
        self.row_into(target, &mut out);
        out
    }

    /// Emit the row for `target` into `out` (cleared first) — the
    /// allocation-free hot-path variant used by the capacity sweep.
    pub fn row_into(&self, target: FunctionId, out: &mut Vec<f32>) {
        out.clear();
        let spec = self.cat.get(target);
        let (t_sat, t_cached) = self.target_counts(target);
        out.push(spec.solo_latency_ms as f32);
        out.extend(spec.profile.iter().map(|v| *v as f32));
        out.push(t_sat as f32);
        out.push(t_cached as f32);
        out.extend(self.agg_sat.iter().map(|v| *v as f32));
        out.extend(self.agg_cached.iter().map(|v| *v as f32));
        out.push(self.tot_sat as f32);
        out.push(self.tot_cached as f32);
        debug_assert_eq!(out.len(), N_FEATURES);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{tests::test_spec, Catalog};

    fn cat2() -> Catalog {
        Catalog::from_functions(vec![test_spec("a", 50.0), test_spec("b", 20.0)])
    }

    #[test]
    fn row_has_contract_dims_and_solo_head() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(0, 3, 1), (1, 2, 0)]);
        let row = feature_row(&cat, &mix, 0);
        assert_eq!(row.len(), N_FEATURES);
        assert_eq!(row[0], cat.get(0).solo_latency_ms as f32);
        // target concurrency slots
        assert_eq!(row[14], 3.0);
        assert_eq!(row[15], 1.0);
        // totals at the tail
        assert_eq!(row[N_FEATURES - 2], 5.0);
        assert_eq!(row[N_FEATURES - 1], 1.0);
    }

    #[test]
    fn absent_target_has_zero_concurrency() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(1, 4, 2)]);
        let row = feature_row(&cat, &mix, 0);
        assert_eq!(row[14], 0.0);
        assert_eq!(row[15], 0.0);
        // but the aggregate still sees the neighbours
        assert_eq!(row[N_FEATURES - 2], 4.0);
    }

    #[test]
    fn builder_rows_match_one_shot() {
        let cat = cat2();
        let mix = NodeMix::new(vec![(0, 2, 1), (1, 5, 3)]);
        let b = FeatureBuilder::new(&cat, &mix);
        for t in 0..2 {
            assert_eq!(b.row(t), feature_row(&cat, &mix, t));
        }
    }
}

//! Capacity tables — the heart of pre-decision scheduling (§4.2–§4.4).
//!
//! For every node, and for every function deployed there, Jiagu
//! precomputes a **capacity**: the maximum number of that function's
//! saturated instances that can run on the node such that *every*
//! colocated function's predicted P90 latency still meets its QoS
//! (asynchronous-update refinement, §4.3) — evaluated with the current
//! neighbour counts held fixed (Fig. 7).
//!
//! The capacity sweep batches all `(candidate concurrency × colocated
//! function)` feature rows into a single predictor invocation
//! (concurrency-aware refinement, §4.4; Fig. 17b shows batched inference
//! is nearly flat in the row count), so computing one function's capacity
//! costs *one* model inference.

use crate::catalog::{Catalog, FunctionId};
use crate::interference::NodeMix;
use crate::model::features::FeatureBuilder;
use crate::runtime::Predictor;
use anyhow::Result;
use std::collections::HashMap;

/// Tunables for the capacity computation.
#[derive(Debug, Clone)]
pub struct CapacityConfig {
    /// Highest candidate concurrency swept per function. Bounds the
    /// batched sweep; physical memory also caps deployment density.
    pub max_candidates: u32,
    /// Hard per-node instance cap from *actual* memory use (overcommitted
    /// nodes still cannot exceed physical memory).
    pub max_instances_per_node: u32,
    /// Admission margin: a candidate is feasible when predicted latency
    /// <= `qos_headroom` x QoS bound.  The paper predicts the p90 tail
    /// "accordingly" to keep violations < 10%; with a mean-latency
    /// predictor the equivalent is leaving headroom for prediction error
    /// + measurement noise at the packing boundary.
    pub qos_headroom: f64,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        Self { max_candidates: 22, max_instances_per_node: 40, qos_headroom: 0.95 }
    }
}

/// One capacity entry: "`capacity` instances of this function fit under
/// the neighbour mix observed at `mix_version`".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityEntry {
    pub capacity: u32,
    /// Node-mix version the entry was computed under (staleness tracking).
    pub mix_version: u64,
}

/// Per-node capacity table plus a monotonically increasing mix version.
///
/// The version counts placement/eviction/release events on the node; an
/// entry computed at an older version is *stale* but still used by the
/// fast path (the asynchronous update refreshes it off the critical
/// path — that staleness window is the design's accepted risk, §4.3).
#[derive(Debug, Clone, Default)]
pub struct CapacityTable {
    entries: HashMap<FunctionId, CapacityEntry>,
    version: u64,
    /// Mix version of the last asynchronous refresh that landed; refreshes
    /// completing out of order against an older mix are dropped.
    applied_version: u64,
}

impl CapacityTable {
    pub fn get(&self, f: FunctionId) -> Option<CapacityEntry> {
        self.entries.get(&f).copied()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Record a node-mix change (placement, eviction, release, ...).
    pub fn bump_version(&mut self) -> u64 {
        self.version += 1;
        self.version
    }

    pub fn insert(&mut self, f: FunctionId, capacity: u32, mix_version: u64) {
        self.entries.insert(f, CapacityEntry { capacity, mix_version });
    }

    pub fn remove(&mut self, f: FunctionId) {
        self.entries.remove(&f);
    }

    /// Land an asynchronous refresh computed under `version`: replace the
    /// whole table, unless a refresh from a newer mix already landed (late
    /// completions of superseded updates are dropped — the fast path must
    /// never regress to an older view than the one it already has).
    /// Entries written synchronously *after* the refresh's snapshot
    /// (slow-path inserts, `mix_version >= version`) are carried over when
    /// the snapshot does not know them, so an in-flight refresh never
    /// erases knowledge the critical path already paid an inference for.
    pub fn apply_refresh(
        &mut self,
        mut entries: HashMap<FunctionId, CapacityEntry>,
        version: u64,
    ) {
        if version < self.applied_version {
            return;
        }
        for (f, e) in &self.entries {
            if e.mix_version >= version {
                entries.entry(*f).or_insert(*e);
            }
        }
        self.entries = entries;
        self.applied_version = version;
    }

    pub fn is_stale(&self, f: FunctionId) -> bool {
        self.get(f).map(|e| e.mix_version != self.version).unwrap_or(true)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&FunctionId, &CapacityEntry)> {
        self.entries.iter()
    }
}

/// Compute the capacity of `target` on a node with mix `mix`.
///
/// Sweeps candidate concurrency `1..=max` in one batched inference: for
/// each candidate `c`, predicts the latency of every function that would
/// have saturated instances (target at `c`, neighbours unchanged), and
/// returns the largest `c` whose predictions *all* meet QoS, scanning
/// upward until the first infeasible candidate (ground-truth interference
/// is monotone in concurrency; the predictor tracks it closely).
///
/// Returns 0 if even one instance violates someone's QoS.
pub fn compute_capacity(
    cat: &Catalog,
    mix: &NodeMix,
    target: FunctionId,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
) -> Result<u32> {
    Ok(compute_capacity_counted(cat, mix, target, predictor, cfg)?.0)
}

/// [`compute_capacity`] plus the number of batched predictor invocations
/// the sweep cost: 0 when the room check short-circuits, 1 otherwise.
///
/// The count is returned by the sweep itself rather than read back off
/// the predictor's shared [`InferenceStats`](crate::runtime::InferenceStats)
/// counters: those are process-global, so a snapshot delta would absorb
/// inferences run by *sibling* control planes when shards execute on
/// parallel threads — and the count feeds `CostModel` due times, where
/// any cross-thread bleed would make the event stream thread-count-
/// dependent.
pub fn compute_capacity_counted(
    cat: &Catalog,
    mix: &NodeMix,
    target: FunctionId,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
) -> Result<(u32, u64)> {
    // neighbour entries with the target removed
    let neighbours: Vec<(FunctionId, u32, u32)> = mix
        .entries
        .iter()
        .filter(|(f, _, _)| *f != target)
        .copied()
        .collect();
    let target_cached = mix
        .entries
        .iter()
        .find(|(f, _, _)| *f == target)
        .map(|(_, _, c)| *c)
        .unwrap_or(0);
    let neighbour_sat: u32 = neighbours.iter().map(|(_, s, _)| *s).sum();
    let neighbour_cached: u32 = neighbours.iter().map(|(_, _, c)| *c).sum();
    let room = cfg
        .max_instances_per_node
        .saturating_sub(neighbour_sat + neighbour_cached + target_cached);
    let max_c = cfg.max_candidates.min(room);
    if max_c == 0 {
        return Ok((0, 0));
    }

    // functions whose QoS must hold: target + all neighbours with sat > 0
    let mut qos_targets: Vec<FunctionId> = vec![target];
    qos_targets.extend(neighbours.iter().filter(|(_, s, _)| *s > 0).map(|(f, _, _)| *f));

    // one batched inference over (candidate, qos-target) rows
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(max_c as usize * qos_targets.len());
    let mut candidate_mix = NodeMix::new(
        neighbours
            .iter()
            .copied()
            .chain(std::iter::once((target, 0, target_cached)))
            .collect(),
    );
    let target_slot = candidate_mix.entries.len() - 1;
    let mut row = Vec::with_capacity(crate::model::N_FEATURES);
    for c in 1..=max_c {
        candidate_mix.entries[target_slot].1 = c;
        let builder = FeatureBuilder::new(cat, &candidate_mix);
        for f in &qos_targets {
            builder.row_into(*f, &mut row);
            rows.push(row.clone());
        }
    }
    let preds = predictor.predict(&rows)?;

    // largest feasible prefix
    let per_c = qos_targets.len();
    let mut capacity = 0u32;
    'outer: for c in 1..=max_c {
        let base = (c - 1) as usize * per_c;
        for (i, f) in qos_targets.iter().enumerate() {
            if preds[base + i] as f64 > cfg.qos_headroom * cat.get(*f).qos_latency_ms {
                break 'outer;
            }
        }
        capacity = c;
    }
    Ok((capacity, 1))
}

/// Recompute the full capacity table of a node (asynchronous update body):
/// one capacity sweep per function present in the mix.
pub fn compute_all_capacities(
    cat: &Catalog,
    mix: &NodeMix,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
    mix_version: u64,
) -> Result<HashMap<FunctionId, CapacityEntry>> {
    let mut out = HashMap::new();
    for (f, _, _) in &mix.entries {
        let cap = compute_capacity(cat, mix, *f, predictor, cfg)?;
        out.insert(*f, CapacityEntry { capacity: cap, mix_version });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::interference;
    use crate::runtime::InferenceStats;

    /// Oracle predictor: returns ground-truth latency (no model error).
    pub(crate) struct OraclePredictor {
        pub cat: Catalog,
        pub stats: InferenceStats,
    }

    impl OraclePredictor {
        pub fn new(cat: Catalog) -> Self {
            Self { cat, stats: InferenceStats::default() }
        }

        /// Decode a feature row back into a prediction via ground truth.
        /// Rows were built by FeatureBuilder, so we recover the target by
        /// matching solo latency (unique per function in test catalogs)
        /// and re-derive the mix from the aggregate profile — instead we
        /// cheat: the row's aggregate totals are enough because the test
        /// catalog profiles are all-ones, making aggregates ambiguous.
        /// So this oracle is only used through `predict_mix` below.
        fn target_of(&self, row: &[f32]) -> FunctionId {
            let solo = row[0] as f64;
            (0..self.cat.len())
                .min_by(|a, b| {
                    let da = (self.cat.get(*a).solo_latency_ms - solo).abs();
                    let db = (self.cat.get(*b).solo_latency_ms - solo).abs();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
        }
    }

    impl Predictor for OraclePredictor {
        fn predict(&self, rows: &[Vec<f32>]) -> Result<Vec<f32>> {
            // Reconstruct per-row latency from (target sat/cached counts +
            // totals) assuming a *single-function* or known-mix node; the
            // capacity tests below only use single-function sweeps where
            // the row describes the full mix exactly.
            self.stats.record(rows.len(), 0);
            Ok(rows
                .iter()
                .map(|row| {
                    let target = self.target_of(row);
                    let t_sat = row[14] as u32;
                    let t_cached = row[15] as u32;
                    let tot_sat = row[42] as u32;
                    let tot_cached = row[43] as u32;
                    // everything that isn't the target is "other" — model
                    // it as more instances of the same target function
                    // (exact for single-function mixes).
                    let mix = NodeMix::new(vec![(
                        target,
                        t_sat + (tot_sat - t_sat),
                        t_cached + (tot_cached - t_cached),
                    )]);
                    interference::ground_truth_latency(&self.cat, &mix, target) as f32
                })
                .collect())
        }

        fn stats(&self) -> &InferenceStats {
            &self.stats
        }

        fn n_features(&self) -> usize {
            crate::model::N_FEATURES
        }
    }

    #[test]
    fn single_function_capacity_matches_ground_truth() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig { qos_headroom: 1.0, ..Default::default() };
        for f in 0..cat.len() {
            let mix = NodeMix::new(vec![(f, 1, 0)]);
            let cap = compute_capacity(&cat, &mix, f, &oracle, &cfg).unwrap();
            // check against brute-force ground truth
            let mut truth = 0;
            for c in 1..=cfg.max_candidates {
                let m = NodeMix::new(vec![(f, c, 0)]);
                if interference::ground_truth_latency(&cat, &m, f)
                    <= cat.get(f).qos_latency_ms
                {
                    truth = c;
                } else {
                    break;
                }
            }
            assert_eq!(cap, truth, "function {f}");
            assert!(cap >= 1, "QoS=1.2x solo must admit at least 1 instance");
        }
    }

    #[test]
    fn capacity_is_one_inference_per_function() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig::default();
        let mix = NodeMix::new(vec![(0, 2, 0)]);
        compute_capacity(&cat, &mix, 0, &oracle, &cfg).unwrap();
        let (calls, rows, _) = oracle.stats.snapshot();
        assert_eq!(calls, 1, "sweep must be a single batched inference");
        assert!(rows >= cfg.max_candidates as u64 / 2);
    }

    #[test]
    fn counted_sweep_reports_inference_cost_without_shared_counters() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let mix = NodeMix::new(vec![(0, 2, 0)]);
        let (cap, inf) =
            compute_capacity_counted(&cat, &mix, 0, &oracle, &CapacityConfig::default()).unwrap();
        assert_eq!(inf, 1, "one batched inference per sweep");
        assert!(cap >= 1);
        // the returned count must equal what actually hit the predictor
        assert_eq!(oracle.stats.snapshot().0, 1);
        // no room: the sweep short-circuits without paying an inference
        let no_room = CapacityConfig { max_instances_per_node: 0, ..Default::default() };
        let (cap0, inf0) = compute_capacity_counted(&cat, &mix, 0, &oracle, &no_room).unwrap();
        assert_eq!((cap0, inf0), (0, 0));
        assert_eq!(oracle.stats.snapshot().0, 1, "predictor untouched");
    }

    #[test]
    fn room_cap_limits_capacity() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig { max_instances_per_node: 3, ..Default::default() };
        let mix = NodeMix::new(vec![(0, 1, 0)]);
        let cap = compute_capacity(&cat, &mix, 0, &oracle, &cfg).unwrap();
        assert!(cap <= 3);
    }

    #[test]
    fn refresh_preserves_newer_synchronous_inserts() {
        let mut table = CapacityTable::default();
        let v = table.bump_version(); // the refresh's snapshot version
        // while the refresh is in flight, the critical path slow-paths a
        // new function onto the node at the current version
        table.insert(7, 4, table.version());
        let mut refresh = HashMap::new();
        refresh.insert(0, CapacityEntry { capacity: 2, mix_version: v });
        table.apply_refresh(refresh, v);
        assert_eq!(table.get(0).unwrap().capacity, 2);
        assert_eq!(
            table.get(7).unwrap().capacity,
            4,
            "a post-snapshot slow-path insert must survive the refresh"
        );
    }

    #[test]
    fn refresh_ordering_drops_superseded_updates() {
        let mut table = CapacityTable::default();
        let v1 = table.bump_version();
        let v2 = table.bump_version();
        let mut newer = HashMap::new();
        newer.insert(0, CapacityEntry { capacity: 2, mix_version: v2 });
        table.apply_refresh(newer, v2);
        let mut older = HashMap::new();
        older.insert(0, CapacityEntry { capacity: 9, mix_version: v1 });
        table.apply_refresh(older, v1);
        assert_eq!(
            table.get(0).unwrap().capacity,
            2,
            "a superseded refresh must not clobber a newer one"
        );
    }

    #[test]
    fn table_staleness_tracking() {
        let mut table = CapacityTable::default();
        let v = table.bump_version();
        table.insert(0, 5, v);
        assert!(!table.is_stale(0));
        table.bump_version();
        assert!(table.is_stale(0));
        assert!(table.is_stale(1), "missing entry is stale");
    }
}

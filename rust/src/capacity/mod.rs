//! Capacity tables — the heart of pre-decision scheduling (§4.2–§4.4).
//!
//! For every node, and for every function deployed there, Jiagu
//! precomputes a **capacity**: the maximum number of that function's
//! saturated instances that can run on the node such that *every*
//! colocated function's predicted P90 latency still meets its QoS
//! (asynchronous-update refinement, §4.3) — evaluated with the current
//! neighbour counts held fixed (Fig. 7).
//!
//! The capacity sweep batches all `(candidate concurrency × colocated
//! function)` feature rows into a single predictor invocation
//! (concurrency-aware refinement, §4.4; Fig. 17b shows batched inference
//! is nearly flat in the row count), so computing one function's capacity
//! costs *one* model inference.
//!
//! On top of the batched sweep sits [`SweepMemo`]: capacity is a pure
//! function of `(target, node mix)` for a fixed catalog and config, and
//! real workloads revisit the same mixes constantly (every empty node
//! looks identical; steady-state nodes cycle through a handful of
//! signatures).  The memo answers repeated sweeps from a canonical
//! mix-signature key without touching the predictor at all — see
//! [`compute_capacity_memoized`].

use crate::catalog::{Catalog, FunctionId};
use crate::interference::NodeMix;
use crate::model::features::FeatureBuilder;
use crate::model::FeatureMatrix;
use crate::runtime::Predictor;
use anyhow::Result;
use std::collections::HashMap;

/// Tunables for the capacity computation.
#[derive(Debug, Clone)]
pub struct CapacityConfig {
    /// Highest candidate concurrency swept per function. Bounds the
    /// batched sweep; physical memory also caps deployment density.
    pub max_candidates: u32,
    /// Hard per-node instance cap from *actual* memory use (overcommitted
    /// nodes still cannot exceed physical memory).
    pub max_instances_per_node: u32,
    /// Admission margin: a candidate is feasible when predicted latency
    /// <= `qos_headroom` x QoS bound.  The paper predicts the p90 tail
    /// "accordingly" to keep violations < 10%; with a mean-latency
    /// predictor the equivalent is leaving headroom for prediction error
    /// + measurement noise at the packing boundary.
    pub qos_headroom: f64,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        Self { max_candidates: 22, max_instances_per_node: 40, qos_headroom: 0.95 }
    }
}

/// One capacity entry: "`capacity` instances of this function fit under
/// the neighbour mix observed at `mix_version`".
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CapacityEntry {
    pub capacity: u32,
    /// Node-mix version the entry was computed under (staleness tracking).
    pub mix_version: u64,
}

/// Per-node capacity table plus a monotonically increasing mix version.
///
/// The version counts placement/eviction/release events on the node; an
/// entry computed at an older version is *stale* but still used by the
/// fast path (the asynchronous update refreshes it off the critical
/// path — that staleness window is the design's accepted risk, §4.3).
#[derive(Debug, Clone, Default)]
pub struct CapacityTable {
    entries: HashMap<FunctionId, CapacityEntry>,
    version: u64,
    /// Mix version of the last asynchronous refresh that landed; refreshes
    /// completing out of order against an older mix are dropped.
    applied_version: u64,
}

impl CapacityTable {
    pub fn get(&self, f: FunctionId) -> Option<CapacityEntry> {
        self.entries.get(&f).copied()
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    /// Record a node-mix change (placement, eviction, release, ...).
    pub fn bump_version(&mut self) -> u64 {
        self.version += 1;
        self.version
    }

    pub fn insert(&mut self, f: FunctionId, capacity: u32, mix_version: u64) {
        self.entries.insert(f, CapacityEntry { capacity, mix_version });
    }

    pub fn remove(&mut self, f: FunctionId) {
        self.entries.remove(&f);
    }

    /// Land an asynchronous refresh computed under `version`: replace the
    /// whole table, unless a refresh from a newer mix already landed (late
    /// completions of superseded updates are dropped — the fast path must
    /// never regress to an older view than the one it already has).
    /// Entries written synchronously *after* the refresh's snapshot
    /// (slow-path inserts, `mix_version >= version`) are carried over when
    /// the snapshot does not know them, so an in-flight refresh never
    /// erases knowledge the critical path already paid an inference for.
    pub fn apply_refresh(
        &mut self,
        mut entries: HashMap<FunctionId, CapacityEntry>,
        version: u64,
    ) {
        if version < self.applied_version {
            return;
        }
        for (f, e) in &self.entries {
            if e.mix_version >= version {
                entries.entry(*f).or_insert(*e);
            }
        }
        self.entries = entries;
        self.applied_version = version;
    }

    pub fn is_stale(&self, f: FunctionId) -> bool {
        self.get(f).map(|e| e.mix_version != self.version).unwrap_or(true)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&FunctionId, &CapacityEntry)> {
        self.entries.iter()
    }
}

/// Compute the capacity of `target` on a node with mix `mix`.
///
/// Sweeps candidate concurrency `1..=max` in one batched inference: for
/// each candidate `c`, predicts the latency of every function that would
/// have saturated instances (target at `c`, neighbours unchanged), and
/// returns the largest `c` whose predictions *all* meet QoS, scanning
/// upward until the first infeasible candidate (ground-truth interference
/// is monotone in concurrency; the predictor tracks it closely).
///
/// Returns 0 if even one instance violates someone's QoS.
pub fn compute_capacity(
    cat: &Catalog,
    mix: &NodeMix,
    target: FunctionId,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
) -> Result<u32> {
    Ok(compute_capacity_counted(cat, mix, target, predictor, cfg)?.0)
}

/// [`compute_capacity`] plus the number of batched predictor invocations
/// the sweep cost: 0 when the room check short-circuits, 1 otherwise.
///
/// The count is returned by the sweep itself rather than read back off
/// the predictor's shared [`InferenceStats`](crate::runtime::InferenceStats)
/// counters: those are process-global, so a snapshot delta would absorb
/// inferences run by *sibling* control planes when shards execute on
/// parallel threads — and the count feeds `CostModel` due times, where
/// any cross-thread bleed would make the event stream thread-count-
/// dependent.
pub fn compute_capacity_counted(
    cat: &Catalog,
    mix: &NodeMix,
    target: FunctionId,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
) -> Result<(u32, u64)> {
    // neighbour entries with the target removed
    let neighbours: Vec<(FunctionId, u32, u32)> = mix
        .entries
        .iter()
        .filter(|(f, _, _)| *f != target)
        .copied()
        .collect();
    let target_cached = mix
        .entries
        .iter()
        .find(|(f, _, _)| *f == target)
        .map(|(_, _, c)| *c)
        .unwrap_or(0);
    let neighbour_sat: u32 = neighbours.iter().map(|(_, s, _)| *s).sum();
    let neighbour_cached: u32 = neighbours.iter().map(|(_, _, c)| *c).sum();
    let room = cfg
        .max_instances_per_node
        .saturating_sub(neighbour_sat + neighbour_cached + target_cached);
    let max_c = cfg.max_candidates.min(room);
    if max_c == 0 {
        return Ok((0, 0));
    }

    // functions whose QoS must hold: target + all neighbours with sat > 0
    let mut qos_targets: Vec<FunctionId> = vec![target];
    qos_targets.extend(neighbours.iter().filter(|(_, s, _)| *s > 0).map(|(f, _, _)| *f));

    // one batched inference over (candidate, qos-target) rows, packed
    // row-major into a single flat buffer — no per-row allocation
    let mut rows =
        FeatureMatrix::with_capacity(crate::model::N_FEATURES, max_c as usize * qos_targets.len());
    let mut candidate_mix = NodeMix::new(
        neighbours
            .iter()
            .copied()
            .chain(std::iter::once((target, 0, target_cached)))
            .collect(),
    );
    let target_slot = candidate_mix.entries.len() - 1;
    for c in 1..=max_c {
        candidate_mix.entries[target_slot].1 = c;
        let builder = FeatureBuilder::new(cat, &candidate_mix);
        for f in &qos_targets {
            builder.row_into_matrix(*f, &mut rows);
        }
    }
    let preds = predictor.predict_batch(&rows)?;

    // largest feasible prefix
    let per_c = qos_targets.len();
    let mut capacity = 0u32;
    'outer: for c in 1..=max_c {
        let base = (c - 1) as usize * per_c;
        for (i, f) in qos_targets.iter().enumerate() {
            if preds[base + i] as f64 > cfg.qos_headroom * cat.get(*f).qos_latency_ms {
                break 'outer;
            }
        }
        capacity = c;
    }
    Ok((capacity, 1))
}

/// Aggregate cost of one (or several summed) memoized capacity sweeps.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SweepCost {
    /// Batched predictor invocations actually executed.
    pub inferences: u64,
    /// Sweeps answered from the memo without running the predictor.
    pub memo_hits: u64,
    /// Sweeps that missed the memo and paid `inferences` for it.
    pub memo_misses: u64,
}

impl SweepCost {
    /// Fold another sweep's cost into this one (plain counter addition).
    pub fn absorb(&mut self, other: SweepCost) {
        self.inferences += other.inferences;
        self.memo_hits += other.memo_hits;
        self.memo_misses += other.memo_misses;
    }
}

/// Canonical memo key: target + mix entries sorted by function id.
/// [`NodeMix::new`] does *not* sort its entries (the sweep relies on slot
/// positions), so two logically identical mixes can arrive with different
/// entry orders — sorting here makes them share one memo slot.
type MemoKey = (FunctionId, Vec<(FunctionId, u32, u32)>);

/// Default bound on live memo entries before a deterministic wholesale
/// clear (mirrors `scheduler::CandidateOrders`' epoch scheme): large
/// enough that steady-state golden scenarios never clear, small enough
/// that a pathological mix churn cannot grow the map without bound.
pub const SWEEP_MEMO_CAPACITY: usize = 4096;

/// Memo of completed capacity sweeps, keyed by canonical mix signature.
///
/// Capacity is a pure function of `(target, mix)` once the catalog and
/// [`CapacityConfig`] are fixed — and both are fixed for the lifetime of a
/// scheduler instance, which is exactly the lifetime of this memo.  A hit
/// therefore returns the *identical* capacity the sweep would have
/// computed, so placements (and every determinism contract downstream of
/// them) are unchanged; only the inference count drops.
///
/// When the map reaches its bound it is cleared outright and the epoch
/// bumped — a deterministic, data-independent policy (no LRU clocks, no
/// hash-order eviction), so shards and reruns always observe the same
/// hit/miss sequence.
#[derive(Debug, Clone)]
pub struct SweepMemo {
    entries: HashMap<MemoKey, u32>,
    capacity: usize,
    epoch: u64,
    hits: u64,
    misses: u64,
}

impl Default for SweepMemo {
    fn default() -> Self {
        Self::with_capacity(SWEEP_MEMO_CAPACITY)
    }
}

impl SweepMemo {
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            entries: HashMap::new(),
            capacity: capacity.max(1),
            epoch: 0,
            hits: 0,
            misses: 0,
        }
    }

    fn key(target: FunctionId, mix: &NodeMix) -> MemoKey {
        let mut entries = mix.entries.clone();
        entries.sort_unstable();
        (target, entries)
    }

    /// Live entries in the current epoch.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Number of wholesale clears so far.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// `(hits, misses)` over the memo's lifetime (epochs included).
    pub fn counts(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    fn lookup(&mut self, key: &MemoKey) -> Option<u32> {
        match self.entries.get(key).copied() {
            Some(cap) => {
                self.hits += 1;
                Some(cap)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    fn insert(&mut self, key: MemoKey, capacity: u32) {
        if self.entries.len() >= self.capacity {
            self.entries.clear();
            self.epoch += 1;
        }
        self.entries.insert(key, capacity);
    }
}

/// [`compute_capacity_counted`] behind a [`SweepMemo`]: a hit returns the
/// cached capacity with zero inferences; a miss runs the batched sweep
/// and memoizes the result.  Either way the outcome is recorded on the
/// predictor's shared [`InferenceStats`](crate::runtime::InferenceStats)
/// memo counters (observability) *and* returned in the [`SweepCost`]
/// (per-sweep accounting that feeds reports — deliberately not read back
/// off the shared counters, same rationale as `compute_capacity_counted`).
pub fn compute_capacity_memoized(
    cat: &Catalog,
    mix: &NodeMix,
    target: FunctionId,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
    memo: &mut SweepMemo,
) -> Result<(u32, SweepCost)> {
    let key = SweepMemo::key(target, mix);
    if let Some(capacity) = memo.lookup(&key) {
        predictor.stats().record_memo(true);
        return Ok((capacity, SweepCost { inferences: 0, memo_hits: 1, memo_misses: 0 }));
    }
    let (capacity, inferences) = compute_capacity_counted(cat, mix, target, predictor, cfg)?;
    memo.insert(key, capacity);
    predictor.stats().record_memo(false);
    Ok((capacity, SweepCost { inferences, memo_hits: 0, memo_misses: 1 }))
}

/// Recompute the full capacity table of a node (asynchronous update body):
/// one capacity sweep per function present in the mix.
pub fn compute_all_capacities(
    cat: &Catalog,
    mix: &NodeMix,
    predictor: &dyn Predictor,
    cfg: &CapacityConfig,
    mix_version: u64,
) -> Result<HashMap<FunctionId, CapacityEntry>> {
    let mut out = HashMap::new();
    for (f, _, _) in &mix.entries {
        let cap = compute_capacity(cat, mix, *f, predictor, cfg)?;
        out.insert(*f, CapacityEntry { capacity: cap, mix_version });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::interference;
    use crate::runtime::InferenceStats;

    /// Oracle predictor: returns ground-truth latency (no model error).
    pub(crate) struct OraclePredictor {
        pub cat: Catalog,
        pub stats: InferenceStats,
    }

    impl OraclePredictor {
        pub fn new(cat: Catalog) -> Self {
            Self { cat, stats: InferenceStats::default() }
        }

        /// Decode a feature row back into a prediction via ground truth.
        /// Rows were built by FeatureBuilder, so we recover the target by
        /// matching solo latency (unique per function in test catalogs)
        /// and re-derive the mix from the aggregate profile — instead we
        /// cheat: the row's aggregate totals are enough because the test
        /// catalog profiles are all-ones, making aggregates ambiguous.
        /// So this oracle is only used through `predict_mix` below.
        fn target_of(&self, row: &[f32]) -> FunctionId {
            let solo = row[0] as f64;
            (0..self.cat.len())
                .min_by(|a, b| {
                    let da = (self.cat.get(*a).solo_latency_ms - solo).abs();
                    let db = (self.cat.get(*b).solo_latency_ms - solo).abs();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap()
        }
    }

    impl Predictor for OraclePredictor {
        fn predict_batch(&self, batch: &FeatureMatrix) -> Result<Vec<f32>> {
            // Reconstruct per-row latency from (target sat/cached counts +
            // totals) assuming a *single-function* or known-mix node; the
            // capacity tests below only use single-function sweeps where
            // the row describes the full mix exactly.
            self.stats.record(batch.n_rows(), 0);
            Ok(batch
                .rows()
                .map(|row| {
                    let target = self.target_of(row);
                    let t_sat = row[14] as u32;
                    let t_cached = row[15] as u32;
                    let tot_sat = row[42] as u32;
                    let tot_cached = row[43] as u32;
                    // everything that isn't the target is "other" — model
                    // it as more instances of the same target function
                    // (exact for single-function mixes).
                    let mix = NodeMix::new(vec![(
                        target,
                        t_sat + (tot_sat - t_sat),
                        t_cached + (tot_cached - t_cached),
                    )]);
                    interference::ground_truth_latency(&self.cat, &mix, target) as f32
                })
                .collect())
        }

        fn stats(&self) -> &InferenceStats {
            &self.stats
        }

        fn n_features(&self) -> usize {
            crate::model::N_FEATURES
        }
    }

    #[test]
    fn single_function_capacity_matches_ground_truth() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig { qos_headroom: 1.0, ..Default::default() };
        for f in 0..cat.len() {
            let mix = NodeMix::new(vec![(f, 1, 0)]);
            let cap = compute_capacity(&cat, &mix, f, &oracle, &cfg).unwrap();
            // check against brute-force ground truth
            let mut truth = 0;
            for c in 1..=cfg.max_candidates {
                let m = NodeMix::new(vec![(f, c, 0)]);
                if interference::ground_truth_latency(&cat, &m, f)
                    <= cat.get(f).qos_latency_ms
                {
                    truth = c;
                } else {
                    break;
                }
            }
            assert_eq!(cap, truth, "function {f}");
            assert!(cap >= 1, "QoS=1.2x solo must admit at least 1 instance");
        }
    }

    #[test]
    fn capacity_is_one_inference_per_function() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig::default();
        let mix = NodeMix::new(vec![(0, 2, 0)]);
        compute_capacity(&cat, &mix, 0, &oracle, &cfg).unwrap();
        let (calls, rows, _) = oracle.stats.snapshot();
        assert_eq!(calls, 1, "sweep must be a single batched inference");
        assert!(rows >= cfg.max_candidates as u64 / 2);
    }

    #[test]
    fn counted_sweep_reports_inference_cost_without_shared_counters() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let mix = NodeMix::new(vec![(0, 2, 0)]);
        let (cap, inf) =
            compute_capacity_counted(&cat, &mix, 0, &oracle, &CapacityConfig::default()).unwrap();
        assert_eq!(inf, 1, "one batched inference per sweep");
        assert!(cap >= 1);
        // the returned count must equal what actually hit the predictor
        assert_eq!(oracle.stats.snapshot().0, 1);
        // no room: the sweep short-circuits without paying an inference
        let no_room = CapacityConfig { max_instances_per_node: 0, ..Default::default() };
        let (cap0, inf0) = compute_capacity_counted(&cat, &mix, 0, &oracle, &no_room).unwrap();
        assert_eq!((cap0, inf0), (0, 0));
        assert_eq!(oracle.stats.snapshot().0, 1, "predictor untouched");
    }

    #[test]
    fn memoized_sweep_hits_on_repeated_mix_and_matches_counted() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig::default();
        let mut memo = SweepMemo::default();
        let mix = NodeMix::new(vec![(0, 2, 0), (1, 1, 0)]);
        let (cap1, cost1) =
            compute_capacity_memoized(&cat, &mix, 0, &oracle, &cfg, &mut memo).unwrap();
        assert_eq!(cost1, SweepCost { inferences: 1, memo_hits: 0, memo_misses: 1 });
        // same logical mix, different entry order — must share the slot
        let permuted = NodeMix::new(vec![(1, 1, 0), (0, 2, 0)]);
        let (cap2, cost2) =
            compute_capacity_memoized(&cat, &permuted, 0, &oracle, &cfg, &mut memo).unwrap();
        assert_eq!(cost2, SweepCost { inferences: 0, memo_hits: 1, memo_misses: 0 });
        assert_eq!(cap1, cap2, "a hit must return the identical capacity");
        // bit-for-bit against the unmemoized sweep
        let (plain, _) = compute_capacity_counted(&cat, &mix, 0, &oracle, &cfg).unwrap();
        assert_eq!(cap1, plain);
        // only the miss touched the predictor; both outcomes were recorded
        assert_eq!(oracle.stats.snapshot().0, 2, "one sweep + one plain check");
        assert_eq!(oracle.stats.memo_snapshot(), (1, 1));
        assert_eq!(memo.counts(), (1, 1));
    }

    #[test]
    fn memo_bound_triggers_deterministic_clear_with_epoch_bump() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig::default();
        let mut memo = SweepMemo::with_capacity(2);
        for sat in 1..=3u32 {
            let mix = NodeMix::new(vec![(0, sat, 0)]);
            compute_capacity_memoized(&cat, &mix, 0, &oracle, &cfg, &mut memo).unwrap();
        }
        assert_eq!(memo.epoch(), 1, "third distinct key must clear the full map");
        assert_eq!(memo.len(), 1, "only the post-clear insert survives");
        // a re-sweep of an evicted key recomputes — and still agrees
        let mix = NodeMix::new(vec![(0, 1, 0)]);
        let (cap, cost) =
            compute_capacity_memoized(&cat, &mix, 0, &oracle, &cfg, &mut memo).unwrap();
        assert_eq!(cost.memo_misses, 1);
        assert_eq!(cap, compute_capacity(&cat, &mix, 0, &oracle, &cfg).unwrap());
    }

    #[test]
    fn room_cap_limits_capacity() {
        let cat = test_catalog();
        let oracle = OraclePredictor::new(cat.clone());
        let cfg = CapacityConfig { max_instances_per_node: 3, ..Default::default() };
        let mix = NodeMix::new(vec![(0, 1, 0)]);
        let cap = compute_capacity(&cat, &mix, 0, &oracle, &cfg).unwrap();
        assert!(cap <= 3);
    }

    #[test]
    fn refresh_preserves_newer_synchronous_inserts() {
        let mut table = CapacityTable::default();
        let v = table.bump_version(); // the refresh's snapshot version
        // while the refresh is in flight, the critical path slow-paths a
        // new function onto the node at the current version
        table.insert(7, 4, table.version());
        let mut refresh = HashMap::new();
        refresh.insert(0, CapacityEntry { capacity: 2, mix_version: v });
        table.apply_refresh(refresh, v);
        assert_eq!(table.get(0).unwrap().capacity, 2);
        assert_eq!(
            table.get(7).unwrap().capacity,
            4,
            "a post-snapshot slow-path insert must survive the refresh"
        );
    }

    #[test]
    fn refresh_ordering_drops_superseded_updates() {
        let mut table = CapacityTable::default();
        let v1 = table.bump_version();
        let v2 = table.bump_version();
        let mut newer = HashMap::new();
        newer.insert(0, CapacityEntry { capacity: 2, mix_version: v2 });
        table.apply_refresh(newer, v2);
        let mut older = HashMap::new();
        older.insert(0, CapacityEntry { capacity: 9, mix_version: v1 });
        table.apply_refresh(older, v1);
        assert_eq!(
            table.get(0).unwrap().capacity,
            2,
            "a superseded refresh must not clobber a newer one"
        );
    }

    #[test]
    fn table_staleness_tracking() {
        let mut table = CapacityTable::default();
        let v = table.bump_version();
        table.insert(0, 5, v);
        assert!(!table.is_stale(0));
        table.bump_version();
        assert!(table.is_stale(0));
        assert!(table.is_stale(1), "missing entry is stale");
    }
}

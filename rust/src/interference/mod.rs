//! Ground-truth interference model — the simulator's stand-in for real
//! hardware contention.
//!
//! Bit-for-bit mirror of `python/compile/datagen.py` (f64, same literals):
//! the Python side generates the predictor's *training labels* from this
//! formula (plus independent measurement noise); this Rust side generates
//! the *runtime truth* the scheduler's predictions are judged against.
//! `tests/interference_golden.rs` cross-checks the two against
//! `artifacts/interference_check.json`.
//!
//! Model (see DESIGN.md "Substitutions"):
//!
//! ```text
//! u_r       = Σ_f (sat_f + 0.10·cached_f) · pressure_f[r] / capacity_r
//! g(u)      = 0.18·u² + [u > 0.8] · 2.2·(u − 0.8)²
//! acc       = Σ_r sens[r] · g(u_r)
//! slowdown  = 1 + acc + 0.55·acc²
//! latency   = base_latency · slowdown
//! ```

use crate::catalog::{Catalog, FunctionId};

/// Table-3 profile metric names (order matters — feature layout contract).
pub const PROFILE_METRICS: [&str; 13] = [
    "mcpu",
    "instructions",
    "ipc",
    "ctx_switches",
    "mlp",
    "l1d_mpki",
    "l1i_mpki",
    "l2_mpki",
    "llc_mpki",
    "dtlb_mpki",
    "itlb_mpki",
    "branch_mpki",
    "mem_bw",
];

/// Hidden contended node resources.
pub const RESOURCES: [&str; 6] = ["cpu", "membw", "llc", "l1", "tlb", "branch"];

/// Per-resource node capacity in abstract pressure units.
pub const RESOURCE_CAPACITY: [f64; 6] = [48.0, 48.0, 48.0, 48.0, 48.0, 48.0];

/// Pressure of a cached (routed-around) instance relative to saturated.
pub const CACHED_PRESSURE_FACTOR: f64 = 0.10;

/// Per-resource contention penalty `g(u)`.
#[inline]
pub fn penalty(u: f64) -> f64 {
    let mut base = 0.18 * u * u;
    let knee = u - 0.8;
    if knee > 0.0 {
        base += 2.2 * knee * knee;
    }
    base
}

/// Latency multiplier given per-resource utilisation and sensitivity.
pub fn slowdown(util: &[f64], sens: &[f64]) -> f64 {
    debug_assert_eq!(util.len(), sens.len());
    let mut acc = 0.0;
    for (u, s) in util.iter().zip(sens) {
        acc += s * penalty(*u);
    }
    1.0 + acc + 0.55 * acc * acc
}

/// Utilisation of a node hosting a single saturated instance (solo run).
pub fn utilisation_single(pressure: &[f64]) -> Vec<f64> {
    pressure
        .iter()
        .zip(RESOURCE_CAPACITY.iter())
        .map(|(p, c)| p / c)
        .collect()
}

/// A node mix: per-function saturated/cached instance counts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NodeMix {
    /// (function, saturated count, cached count); functions are unique.
    pub entries: Vec<(FunctionId, u32, u32)>,
}

impl NodeMix {
    pub fn new(entries: Vec<(FunctionId, u32, u32)>) -> Self {
        Self { entries }
    }

    pub fn is_empty(&self) -> bool {
        self.entries.iter().all(|(_, s, c)| *s == 0 && *c == 0)
    }

    pub fn total_sat(&self) -> u32 {
        self.entries.iter().map(|(_, s, _)| *s).sum()
    }

    pub fn total_cached(&self) -> u32 {
        self.entries.iter().map(|(_, _, c)| *c).sum()
    }
}

/// Per-resource utilisation of a node under `mix`.
pub fn node_utilisation(cat: &Catalog, mix: &NodeMix) -> Vec<f64> {
    let n_res = cat.resources.len();
    let mut util = vec![0.0; n_res];
    for (fid, sat, cached) in &mix.entries {
        let spec = cat.get(*fid);
        let weight = *sat as f64 + cat.cached_pressure_factor * *cached as f64;
        for r in 0..n_res {
            util[r] += weight * spec.pressure[r];
        }
    }
    for r in 0..n_res {
        util[r] /= cat.resource_capacity[r];
    }
    util
}

/// Ground-truth P90 latency (ms) of `target` under `mix` (deterministic;
/// the simulator layers sampling noise on top).
pub fn ground_truth_latency(cat: &Catalog, mix: &NodeMix, target: FunctionId) -> f64 {
    let util = node_utilisation(cat, mix);
    let spec = cat.get(target);
    spec.base_latency_ms * slowdown(&util, &spec.sensitivity)
}

/// Whether every function with saturated instances in `mix` meets QoS
/// under the ground-truth model (used by tests and the oracle scheduler).
pub fn mix_meets_qos(cat: &Catalog, mix: &NodeMix) -> bool {
    mix.entries.iter().all(|(fid, sat, _)| {
        *sat == 0 || ground_truth_latency(cat, mix, *fid) <= cat.get(*fid).qos_latency_ms
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_is_monotonic_and_kneed() {
        assert_eq!(penalty(0.0), 0.0);
        assert!(penalty(0.5) < penalty(0.8));
        // knee: slope increases sharply past 0.8
        let below = penalty(0.8) - penalty(0.7);
        let above = penalty(1.1) - penalty(1.0);
        assert!(above > 3.0 * below);
    }

    #[test]
    fn slowdown_at_zero_load_is_one() {
        assert_eq!(slowdown(&[0.0; 6], &[1.0; 6]), 1.0);
    }

    #[test]
    fn slowdown_superlinear_in_acc() {
        let s1 = slowdown(&[0.5; 6], &[0.5; 6]);
        let s2 = slowdown(&[1.0; 6], &[0.5; 6]);
        // doubling utilisation more than doubles the excess slowdown
        assert!((s2 - 1.0) > 2.0 * (s1 - 1.0));
    }

    #[test]
    fn cached_instances_contribute_fractional_pressure() {
        let cat = crate::catalog::Catalog::from_functions(vec![
            crate::catalog::tests::test_spec("a", 50.0),
        ]);
        let sat = node_utilisation(&cat, &NodeMix::new(vec![(0, 10, 0)]));
        let mixed = node_utilisation(&cat, &NodeMix::new(vec![(0, 10, 5)]));
        let more = node_utilisation(&cat, &NodeMix::new(vec![(0, 10, 10)]));
        assert!(mixed[0] > sat[0]);
        // 10 cached instances == 1 saturated-instance equivalent (factor 0.10)
        assert!((more[0] - (sat[0] + sat[0] / 10.0)).abs() < 1e-12);
    }

    #[test]
    fn latency_grows_with_density() {
        let cat = crate::catalog::Catalog::from_functions(vec![
            crate::catalog::tests::test_spec("a", 50.0),
        ]);
        let mut prev = 0.0;
        for n in 1..20 {
            let lat = ground_truth_latency(&cat, &NodeMix::new(vec![(0, n, 0)]), 0);
            assert!(lat > prev, "latency must increase with colocation");
            prev = lat;
        }
    }
}

//! Flattened SoA forest engine — the batched prediction hot path.
//!
//! [`FlatForest`] lowers [`ForestParams`]' per-tree `Vec<Vec<_>>` tensors
//! into three contiguous arrays (all trees' split features, thresholds
//! and leaves back to back, one fixed stride per tree — the trees are
//! perfect, so every tree occupies exactly `2^D − 1` internal slots and
//! `2^D` leaf slots):
//!
//! ```text
//! feature:   [ tree0[0..2^D-1] | tree1[..] | ... ]   stride = 2^D − 1
//! threshold: [ tree0[0..2^D-1] | tree1[..] | ... ]   stride = 2^D − 1
//! leaf:      [ tree0[0..2^D]   | tree1[..] | ... ]   stride = 2^D
//! ```
//!
//! Traversal is the branchless level-order walk
//! `idx = 2*idx + 1 + (x > thr) as usize`, run **tree-major over row
//! blocks**: for each block of up to [`BLOCK`] rows the engine
//! standardises the block once, then walks tree 0 over every row, tree 1
//! over every row, and so on — each tree's threshold/leaf lines are
//! loaded once per block instead of once per row, which is what makes
//! the batched capacity sweep cheap (§4.4, Fig. 17b).
//!
//! **Bit-identity contract.**  Every prediction is bit-identical to the
//! reference [`NativeForest::predict_one`](super::NativeForest) walk,
//! because each row performs *exactly* the same float operations in the
//! same order: standardise `(v − mean) / std` (a division — never a
//! reciprocal multiply), accumulate leaf values into an `f64` in tree
//! order `t = 0..T`, finish with
//! `row[0] * ((acc / T as f64).exp() as f32)`.  Reordering only happens
//! *across* rows, which share no state.  `rust/tests/predictor_props.rs`
//! asserts the equality over seeded random forests; the determinism
//! contracts (golden reports, shard/queue matrix, fuzz smoke) therefore
//! hold unchanged with this engine serving every prediction.

use super::forest_params::ForestParams;

/// Rows standardised and traversed per block: big enough to amortise the
/// per-tree tensor loads, small enough that a block of standardised rows
/// (`BLOCK × n_features` f32) plus accumulators stays cache-resident.
pub const BLOCK: usize = 64;

/// Reusable per-call buffers for [`FlatForest::predict_into`] — hold one
/// per thread (the native predictor keeps one behind a mutex) and the
/// steady-state batch path allocates nothing.
#[derive(Debug, Default, Clone)]
pub struct FlatScratch {
    /// Standardised feature block, row-major `[rows_in_block × F]`.
    std_rows: Vec<f32>,
    /// Per-row leaf-sum accumulators for the current block.
    acc: Vec<f64>,
    /// Raw (un-standardised) feature 0 of each block row — the solo
    /// latency the final prediction scales.
    solo: Vec<f32>,
}

/// The flattened forest: same parameters as [`ForestParams`], contiguous
/// layout, batched evaluation.
#[derive(Debug, Clone)]
pub struct FlatForest {
    n_trees: usize,
    depth: usize,
    n_features: usize,
    n_internal: usize,
    n_leaves: usize,
    /// `[T × (2^D − 1)]` split feature indices, level order per tree.
    feature: Vec<i32>,
    /// `[T × (2^D − 1)]` standardised split thresholds.
    threshold: Vec<f32>,
    /// `[T × 2^D]` leaf values (log-slowdown space).
    leaf: Vec<f32>,
    /// `[F]` standardisation mean.
    mean: Vec<f32>,
    /// `[F]` standardisation std — kept as-is and *divided* by, so the
    /// standardise expression matches the reference walk bit for bit.
    std: Vec<f32>,
}

impl FlatForest {
    pub fn from_params(p: &ForestParams) -> Self {
        Self {
            n_trees: p.n_trees,
            depth: p.depth,
            n_features: p.n_features,
            n_internal: p.n_internal(),
            n_leaves: 1 << p.depth,
            feature: p.flat_feature(),
            threshold: p.flat_threshold(),
            leaf: p.flat_leaf(),
            mean: p.mean.clone(),
            std: p.std.clone(),
        }
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    pub fn n_trees(&self) -> usize {
        self.n_trees
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Predict a whole row-major batch (`rows × n_features` flat values)
    /// into `out` (cleared first), reusing `scratch` across calls.
    ///
    /// # Panics
    ///
    /// Panics (debug) if `data.len()` is not a multiple of `n_features`.
    pub fn predict_into(&self, data: &[f32], scratch: &mut FlatScratch, out: &mut Vec<f32>) {
        let f = self.n_features;
        debug_assert_eq!(data.len() % f, 0, "flat batch width mismatch");
        let n_rows = data.len() / f;
        out.clear();
        out.reserve(n_rows);
        scratch.std_rows.resize(BLOCK * f, 0.0);
        scratch.acc.resize(BLOCK, 0.0);
        scratch.solo.resize(BLOCK, 0.0);

        let mut base = 0;
        while base < n_rows {
            let rows_here = BLOCK.min(n_rows - base);
            // standardise the block once; remember each row's raw solo head
            for r in 0..rows_here {
                let row = &data[(base + r) * f..(base + r + 1) * f];
                scratch.solo[r] = row[0];
                let dst = &mut scratch.std_rows[r * f..(r + 1) * f];
                for i in 0..f {
                    dst[i] = (row[i] - self.mean[i]) / self.std[i];
                }
                scratch.acc[r] = 0.0;
            }
            // tree-major: each tree's threshold/leaf lines stay hot across
            // the whole block; per-row accumulation order stays t = 0..T,
            // exactly the reference walk's order
            for t in 0..self.n_trees {
                let feat = &self.feature[t * self.n_internal..(t + 1) * self.n_internal];
                let thr = &self.threshold[t * self.n_internal..(t + 1) * self.n_internal];
                let leaf = &self.leaf[t * self.n_leaves..(t + 1) * self.n_leaves];
                for r in 0..rows_here {
                    let x = &scratch.std_rows[r * f..(r + 1) * f];
                    let mut idx = 0usize;
                    for _ in 0..self.depth {
                        let split = x[feat[idx] as usize];
                        let go_right = split > thr[idx];
                        idx = 2 * idx + 1 + go_right as usize;
                    }
                    scratch.acc[r] += leaf[idx - self.n_internal] as f64;
                }
            }
            for r in 0..rows_here {
                let slowdown = (scratch.acc[r] / self.n_trees as f64).exp() as f32;
                out.push(scratch.solo[r] * slowdown);
            }
            base += rows_here;
        }
    }

    /// Convenience wrapper allocating the output (tests, benches).
    pub fn predict(&self, data: &[f32], scratch: &mut FlatScratch) -> Vec<f32> {
        let mut out = Vec::new();
        self.predict_into(data, scratch, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeForest;
    use crate::util::rng::Rng;

    fn random_forest(rng: &mut Rng, n_trees: usize, depth: usize, n_features: usize) -> ForestParams {
        let n_internal = (1usize << depth) - 1;
        let n_leaves = 1usize << depth;
        let params = ForestParams {
            n_trees,
            depth,
            n_features,
            feature: (0..n_trees)
                .map(|_| (0..n_internal).map(|_| rng.below(n_features as u64) as i32).collect())
                .collect(),
            threshold: (0..n_trees)
                .map(|_| (0..n_internal).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect())
                .collect(),
            leaf: (0..n_trees)
                .map(|_| (0..n_leaves).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect())
                .collect(),
            mean: (0..n_features).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect(),
            std: (0..n_features).map(|_| rng.range_f64(0.5, 2.0) as f32).collect(),
            test_error: 0.0,
            fit_seconds: 0.0,
        };
        params.validate().unwrap();
        params
    }

    #[test]
    fn flat_matches_reference_bit_for_bit_across_block_boundaries() {
        let mut rng = Rng::seed_from(0xF1A7);
        let params = random_forest(&mut rng, 9, 5, 17);
        let forest = NativeForest::new(params.clone());
        let flat = FlatForest::from_params(&params);
        let mut scratch = FlatScratch::default();
        // sizes straddling the block boundary: 1, BLOCK-1, BLOCK, BLOCK+1, 3*BLOCK+5
        for n in [1usize, BLOCK - 1, BLOCK, BLOCK + 1, 3 * BLOCK + 5] {
            let data: Vec<f32> =
                (0..n * 17).map(|_| rng.range_f64(-10.0, 10.0) as f32).collect();
            let got = flat.predict(&data, &mut scratch);
            for (r, g) in got.iter().enumerate() {
                let want = forest.predict_one(&data[r * 17..(r + 1) * 17]);
                assert_eq!(g.to_bits(), want.to_bits(), "row {r} of {n}");
            }
        }
    }

    #[test]
    fn scratch_reuse_does_not_leak_state_between_batches() {
        let mut rng = Rng::seed_from(0xF1A8);
        let params = random_forest(&mut rng, 4, 3, 6);
        let flat = FlatForest::from_params(&params);
        let forest = NativeForest::new(params);
        let mut scratch = FlatScratch::default();
        let big: Vec<f32> = (0..100 * 6).map(|_| rng.range_f64(-5.0, 5.0) as f32).collect();
        let _ = flat.predict(&big, &mut scratch);
        let small: Vec<f32> = (0..2 * 6).map(|_| rng.range_f64(-5.0, 5.0) as f32).collect();
        let got = flat.predict(&small, &mut scratch);
        assert_eq!(got.len(), 2);
        for r in 0..2 {
            assert_eq!(
                got[r].to_bits(),
                forest.predict_one(&small[r * 6..(r + 1) * 6]).to_bits()
            );
        }
    }
}

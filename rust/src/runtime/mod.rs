//! Predictor runtime: loads the trained forest and serves batched
//! inference to the scheduler.
//!
//! Two interchangeable backends sit behind [`Predictor`]: the pure-Rust
//! path (always available, the default build — served by the flattened
//! batched [`FlatForest`] engine, with the scalar [`NativeForest`] walk
//! kept as the bit-identical reference) and the PJRT/XLA path below
//! (behind the off-by-default `pjrt` feature).
//!
//! `make artifacts` (Python, build time only) lowers the L2 JAX graph —
//! feature standardisation → Pallas forest traversal → exp — to **HLO
//! text**, one module per batch-size variant (`model_b{1,8,64,256}.hlo.txt`).
//! This module compiles each variant once on the PJRT CPU client at
//! startup, uploads the forest parameters to device buffers once, and then
//! serves predictions by padding each request batch up to the smallest
//! compiled variant that fits.
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

mod flat;
mod forest_params;
mod native;
mod predictor;

pub use flat::{FlatForest, FlatScratch, BLOCK};
pub use forest_params::ForestParams;
pub use native::NativeForest;
#[cfg(feature = "pjrt")]
pub use predictor::PjrtPredictor;
pub use predictor::{NativeForestPredictor, Predictor};

use std::sync::atomic::{AtomicU64, Ordering};

/// Global counters for model-inference accounting (Figs. 11/12 report
/// inferences-per-schedule; the schedulers bump these).  The memo pair
/// tracks the capacity-sweep memoization layer: a hit means a whole
/// batched sweep was answered from cache without touching the predictor.
#[derive(Debug, Default)]
pub struct InferenceStats {
    /// Number of predictor invocations (each is one batched PJRT execute).
    pub calls: AtomicU64,
    /// Total rows across all invocations.
    pub rows: AtomicU64,
    /// Cumulative wall-clock nanoseconds spent inside the predictor.
    pub nanos: AtomicU64,
    /// Capacity sweeps answered from the mix-signature memo (no inference).
    pub memo_hits: AtomicU64,
    /// Capacity sweeps that missed the memo and ran the batched inference.
    pub memo_misses: AtomicU64,
}

impl InferenceStats {
    pub fn record(&self, rows: usize, nanos: u64) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Record one memoized-sweep lookup outcome.
    pub fn record_memo(&self, hit: bool) {
        if hit {
            self.memo_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.memo_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.calls.load(Ordering::Relaxed),
            self.rows.load(Ordering::Relaxed),
            self.nanos.load(Ordering::Relaxed),
        )
    }

    /// `(memo_hits, memo_misses)` across every memoized sweep so far.
    pub fn memo_snapshot(&self) -> (u64, u64) {
        (
            self.memo_hits.load(Ordering::Relaxed),
            self.memo_misses.load(Ordering::Relaxed),
        )
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.rows.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
        self.memo_hits.store(0, Ordering::Relaxed);
        self.memo_misses.store(0, Ordering::Relaxed);
    }
}

//! Pure-Rust forest evaluation.
//!
//! Semantically identical to the PJRT path (validated against
//! `artifacts/predict_check.json`).  Used as (a) a perf baseline for the
//! runtime benches, (b) a dependency-free predictor for unit tests and
//! proptest so the full coordinator can be exercised without artifacts.

use super::forest_params::ForestParams;

/// Traverses the perfect-tree tensors directly on the CPU.
#[derive(Debug, Clone)]
pub struct NativeForest {
    params: ForestParams,
    n_internal: usize,
}

impl NativeForest {
    pub fn new(params: ForestParams) -> Self {
        let n_internal = params.n_internal();
        Self { params, n_internal }
    }

    pub fn params(&self) -> &ForestParams {
        &self.params
    }

    /// Predict latency (ms) for a batch of raw (un-standardised) feature
    /// rows, each of length `n_features`.
    pub fn predict(&self, rows: &[Vec<f32>]) -> Vec<f32> {
        rows.iter().map(|r| self.predict_one(r)).collect()
    }

    pub fn predict_one(&self, row: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), self.params.n_features);
        // standardise once; stack buffer for the common small dims, heap
        // fallback past it (a fixed [0f32; 128] would panic on wider
        // feature spaces — Gsight-style instance-granularity rows are 404)
        let mut small = [0f32; 128];
        let mut large: Vec<f32>;
        let x: &mut [f32] = if row.len() <= small.len() {
            &mut small[..row.len()]
        } else {
            large = vec![0f32; row.len()];
            &mut large
        };
        for i in 0..row.len() {
            x[i] = (row[i] - self.params.mean[i]) / self.params.std[i];
        }
        let mut acc = 0f64;
        for t in 0..self.params.n_trees {
            let feat = &self.params.feature[t];
            let thr = &self.params.threshold[t];
            let mut idx = 0usize;
            for _ in 0..self.params.depth {
                let f = feat[idx] as usize;
                let go_right = x[f] > thr[idx];
                idx = 2 * idx + 1 + go_right as usize;
            }
            acc += self.params.leaf[t][idx - self.n_internal] as f64;
        }
        // leaves are log-slowdown; latency = solo (raw feature 0) * exp(.)
        row[0] * (acc / self.params.n_trees as f64).exp() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predict_one_handles_forests_wider_than_the_stack_buffer() {
        // regression: a fixed [0f32; 128] standardise buffer panicked on
        // any forest with n_features > 128
        let n_features = 200;
        let forest = NativeForest::new(ForestParams::synthetic_stub(n_features, 0.1, 0.1));
        let row: Vec<f32> = (0..n_features).map(|i| i as f32).collect();
        let got = forest.predict_one(&row);
        // stump splits feature 0 at 0.0; row[0] = 0.0 is not > 0.0, so
        // every tree lands on the `lo` leaf: 0.0 * exp(0.1) = 0.0
        assert_eq!(got, 0.0);
        let mut row = row;
        row[0] = 10.0;
        let want = 10.0f32 * (0.1f64).exp() as f32;
        assert_eq!(forest.predict_one(&row).to_bits(), want.to_bits());
    }
}


//! The predictors behind the [`Predictor`] trait: the always-available
//! pure-Rust [`NativeForestPredictor`] and (behind the off-by-default
//! `pjrt` feature) the PJRT-backed `PjrtPredictor`:
//! compile-once, pad-and-execute-batched.

use super::flat::{FlatForest, FlatScratch};
use super::forest_params::ForestParams;
use super::native::NativeForest;
use super::InferenceStats;
use crate::model::FeatureMatrix;
use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context};
#[cfg(feature = "pjrt")]
use std::path::Path;

use std::time::Instant;

/// A latency predictor: raw feature rows in, P90 latency (ms) out.
///
/// Two implementations: `PjrtPredictor` (the production path — AOT HLO
/// through the PJRT CPU client, behind the `pjrt` feature) and
/// [`NativeForestPredictor`] (tests / perf baseline / default build).
///
/// [`Predictor::predict_batch`] is the hot-path entry point: it borrows a
/// row-major [`FeatureMatrix`], so the capacity sweep hands over one flat
/// buffer instead of a `Vec` per row.  [`Predictor::predict`] adapts
/// per-row `Vec`s for callers that hold them (JSON-loaded check vectors,
/// tests) by packing them into a matrix first.
pub trait Predictor: Send + Sync {
    /// Batched prediction over a borrowed row-major matrix; one output
    /// per input row.
    fn predict_batch(&self, batch: &FeatureMatrix) -> Result<Vec<f32>>;

    /// Compatibility adapter: batched prediction over per-row `Vec`s.
    fn predict(&self, rows: &[Vec<f32>]) -> Result<Vec<f32>> {
        self.predict_batch(&FeatureMatrix::from_rows(self.n_features(), rows)?)
    }

    /// Inference accounting shared with the schedulers.
    fn stats(&self) -> &InferenceStats;

    fn n_features(&self) -> usize;
}

impl Predictor for NativeForestPredictor {
    fn predict_batch(&self, batch: &FeatureMatrix) -> Result<Vec<f32>> {
        anyhow::ensure!(
            batch.n_features() == self.flat.n_features(),
            "feature matrix is {}-wide, forest expects {}",
            batch.n_features(),
            self.flat.n_features()
        );
        let t0 = Instant::now();
        let mut out = Vec::new();
        {
            let mut scratch = self.scratch.lock().unwrap();
            self.flat.predict_into(batch.data(), &mut scratch, &mut out);
        }
        self.stats.record(batch.n_rows(), t0.elapsed().as_nanos() as u64);
        Ok(out)
    }

    fn stats(&self) -> &InferenceStats {
        &self.stats
    }

    fn n_features(&self) -> usize {
        self.forest.params().n_features
    }
}

/// The pure-Rust forest wrapped with inference accounting.  Serving runs
/// on the flattened SoA engine ([`FlatForest`]); the reference
/// [`NativeForest`] walk is kept alongside for equality tests and as the
/// baseline the `forest_inference` bench measures against.  The two are
/// bit-identical by construction (see [`super::flat`]).
pub struct NativeForestPredictor {
    forest: NativeForest,
    flat: FlatForest,
    /// Reusable standardise/accumulate buffers for the flat engine.
    /// `Predictor` takes `&self` and must stay `Sync`; uncontended mutex
    /// acquisition is noise next to a batched traversal, and each control
    /// plane shard drives its predictions sequentially anyway.
    scratch: std::sync::Mutex<FlatScratch>,
    stats: InferenceStats,
}

impl NativeForestPredictor {
    pub fn new(params: ForestParams) -> Self {
        let flat = FlatForest::from_params(&params);
        Self {
            forest: NativeForest::new(params),
            flat,
            scratch: std::sync::Mutex::new(FlatScratch::default()),
            stats: InferenceStats::default(),
        }
    }

    /// The reference traversal this predictor's flat engine must match.
    pub fn reference(&self) -> &NativeForest {
        &self.forest
    }
}

/// One compiled batch-size variant.
#[cfg(feature = "pjrt")]
struct Variant {
    batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The production predictor: executes the AOT HLO modules on the PJRT CPU
/// client.  Thread-safe behind a mutex (PJRT executions are serialized per
/// client anyway on the single-device CPU backend).
#[cfg(feature = "pjrt")]
pub struct PjrtPredictor {
    client: xla::PjRtClient,
    variants: Vec<Variant>, // sorted ascending by batch
    /// Device buffers for (mean, std, feature, threshold, leaf), uploaded
    /// once and shared by every variant; only the feature batch is
    /// transferred per call.
    fixed: Vec<xla::PjRtBuffer>,
    /// Host literals backing `fixed`.  MUST outlive the buffers: the
    /// TfrtCpuClient copies literals host->device *asynchronously* on a
    /// worker thread; dropping the literal before the copy lands is a
    /// use-after-free (observed as a flaky SIGSEGV in
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral`).
    fixed_literals: Vec<xla::Literal>,
    params: ForestParams,
    stats: InferenceStats,
    lock: std::sync::Mutex<()>,
}

// SAFETY: the `xla` crate wraps PJRT handles in `Rc` + raw pointers and is
// therefore not auto-Send/Sync, but the underlying PJRT CPU client is
// thread-safe and this type upholds the required discipline itself:
// `client`/`variants` are only touched (a) in `load`/`swap_forest`, which
// take exclusive access, and (b) in `run`, which is serialised behind
// `lock`.  The internal `Rc` refcounts are never mutated concurrently
// because no `PjRtClient` clone ever escapes this struct.
#[cfg(feature = "pjrt")]
unsafe impl Send for PjrtPredictor {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for PjrtPredictor {}

#[cfg(feature = "pjrt")]
impl PjrtPredictor {
    /// Load `forest.json` + every `model_b*.hlo.txt` under `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let params = ForestParams::load(&artifacts_dir.join("forest.json"))?;
        let meta = crate::util::json::Json::parse_file(&artifacts_dir.join("meta.json"))
            .context("reading meta.json — run `make artifacts` first")?;
        let batches: Vec<usize> = meta
            .get("batch_variants")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        let client = xla::PjRtClient::cpu()?;
        // NOTE: compile *all* modules before the first host->device
        // transfer — interleaving `buffer_from_host_literal` with
        // `compile` segfaults inside xla_extension 0.5.1 (empirically
        // reproducible; the buffers clobber state the compiler reuses).
        let mut variants = Vec::new();
        for b in batches {
            let path = artifacts_dir.join(format!("model_b{b}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            variants.push(Variant { batch: b, exe });
        }
        variants.sort_by_key(|v| v.batch);
        if variants.is_empty() {
            bail!("no model_b*.hlo.txt variants found in {}", artifacts_dir.display());
        }
        let (fixed, fixed_literals) = Self::upload_fixed(&client, &params)?;
        Ok(Self {
            client,
            variants,
            fixed,
            fixed_literals,
            params,
            stats: InferenceStats::default(),
            lock: std::sync::Mutex::new(()),
        })
    }

    /// Upload (mean, std, feature, threshold, leaf) once. HLO parameter
    /// order follows `model.predict_latency`: x, mean, std, feature,
    /// threshold, leaf — `fixed` holds params 1..5.  Returns the buffers
    /// *and* the backing literals, which the caller must keep alive (see
    /// `fixed_literals`).
    fn upload_fixed(
        client: &xla::PjRtClient,
        p: &ForestParams,
    ) -> Result<(Vec<xla::PjRtBuffer>, Vec<xla::Literal>)> {
        let n_int = p.n_internal() as i64;
        let n_leaf = (1i64 << p.depth) as i64;
        let t = p.n_trees as i64;
        let lits = vec![
            xla::Literal::vec1(&p.mean),
            xla::Literal::vec1(&p.std),
            xla::Literal::vec1(&p.flat_feature()).reshape(&[t, n_int])?,
            xla::Literal::vec1(&p.flat_threshold()).reshape(&[t, n_int])?,
            xla::Literal::vec1(&p.flat_leaf()).reshape(&[t, n_leaf])?,
        ];
        let bufs = lits
            .iter()
            .map(|l| Ok(client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        Ok((bufs, lits))
    }

    pub fn params(&self) -> &ForestParams {
        &self.params
    }

    /// Batch sizes of the compiled variants (ascending).
    pub fn batch_variants(&self) -> Vec<usize> {
        self.variants.iter().map(|v| v.batch).collect()
    }

    /// Hot-swap a retrained forest (the paper's periodic retraining, §6):
    /// re-upload parameter buffers without recompiling the executables.
    pub fn swap_forest(&mut self, params: ForestParams) -> Result<()> {
        anyhow::ensure!(
            params.n_trees == self.params.n_trees
                && params.depth == self.params.depth
                && params.n_features == self.params.n_features,
            "retrained forest must keep the compiled shapes"
        );
        let (fixed, fixed_literals) = Self::upload_fixed(&self.client, &params)?;
        // drop the old buffers only after the new upload is in flight;
        // the old literals stay alive until this assignment completes
        self.fixed = fixed;
        self.fixed_literals = fixed_literals;
        self.params = params;
        Ok(())
    }

    /// Execute one batch over the compiled variants with **greedy
    /// chunking**: take the largest variant that fits the remainder, so
    /// an 84-row sweep runs as 64+16+8(pad 4) instead of one padded
    /// 256-row call.  (§Perf: this cut the capacity sweep ~2.6x — padding
    /// waste dominated the PJRT execution time.)
    fn run(&self, batch: &FeatureMatrix) -> Result<Vec<f32>> {
        let f = self.params.n_features;
        anyhow::ensure!(batch.n_features() == f, "feature matrix has wrong dim");
        let n_rows = batch.n_rows();
        let mut out = Vec::with_capacity(n_rows);
        let mut off = 0;
        while off < n_rows {
            let remaining = n_rows - off;
            // largest variant <= remaining, else the smallest that fits
            let v = self
                .variants
                .iter()
                .rev()
                .find(|v| v.batch <= remaining)
                .or_else(|| self.variants.iter().find(|v| v.batch >= remaining))
                .unwrap_or_else(|| self.variants.last().unwrap());
            let chunk = remaining.min(v.batch);
            // pad to the variant's batch: one contiguous copy out of the
            // row-major matrix, then zero fill
            let mut flat = vec![0f32; v.batch * f];
            flat[..chunk * f].copy_from_slice(&batch.data()[off * f..(off + chunk) * f]);
            let x = self
                .client
                .buffer_from_host_buffer(&flat, &[v.batch, f], None)?;
            let mut args: Vec<&xla::PjRtBuffer> = vec![&x];
            args.extend(self.fixed.iter());
            let result = v.exe.execute_b(&args)?[0][0].to_literal_sync()?;
            let tuple = result.to_tuple1()?; // lowered with return_tuple=True
            let vals = tuple.to_vec::<f32>()?;
            out.extend_from_slice(&vals[..chunk]);
            off += chunk;
        }
        Ok(out)
    }
}

#[cfg(feature = "pjrt")]
impl Predictor for PjrtPredictor {
    fn predict_batch(&self, batch: &FeatureMatrix) -> Result<Vec<f32>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        let _guard = self.lock.lock().unwrap();
        let t0 = Instant::now();
        let out = self.run(batch)?;
        self.stats.record(batch.n_rows(), t0.elapsed().as_nanos() as u64);
        Ok(out)
    }

    fn stats(&self) -> &InferenceStats {
        &self.stats
    }

    fn n_features(&self) -> usize {
        self.params.n_features
    }
}

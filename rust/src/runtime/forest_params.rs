//! Flattened random-forest parameters loaded from `artifacts/forest.json`.
//!
//! Layout matches `python/compile/forest.py::flatten`: perfect depth-D
//! binary trees with level-order internal arrays and a dense leaf array.
//! Thresholds are already standardised (the HLO graph z-scores features
//! before traversal), with `1e30` standing in for +inf padding.

use crate::util::json::Json;
use anyhow::{ensure, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub depth: usize,
    pub n_features: usize,
    /// `[T][2^D - 1]` split feature indices (level order).
    pub feature: Vec<Vec<i32>>,
    /// `[T][2^D - 1]` standardised split thresholds (1e30 = +inf pad).
    pub threshold: Vec<Vec<f32>>,
    /// `[T][2^D]` leaf values in log-latency space.
    pub leaf: Vec<Vec<f32>>,
    /// `[F]` feature standardisation mean.
    pub mean: Vec<f32>,
    /// `[F]` feature standardisation std (clamped away from 0).
    pub std: Vec<f32>,
    /// Held-out relative error recorded at training time (Fig. 15a).
    pub test_error: f64,
    /// Wall-clock training time recorded at training time (Fig. 17a).
    pub fit_seconds: f64,
}

impl ForestParams {
    pub fn load(path: &Path) -> Result<Self> {
        let j = Json::parse_file(path)?;
        let params = Self::from_json(&j)?;
        params.validate()?;
        Ok(params)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mat_f32 = |key: &str| -> Result<Vec<Vec<f32>>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|row| row.f32_vec())
                .collect()
        };
        let mat_i32 = |key: &str| -> Result<Vec<Vec<i32>>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|row| row.i32_vec())
                .collect()
        };
        Ok(Self {
            n_trees: j.get("n_trees")?.as_usize()?,
            depth: j.get("depth")?.as_usize()?,
            n_features: j.get("n_features")?.as_usize()?,
            feature: mat_i32("feature")?,
            threshold: mat_f32("threshold")?,
            leaf: mat_f32("leaf")?,
            mean: j.get("mean")?.f32_vec()?,
            std: j.get("std")?.f32_vec()?,
            test_error: j.opt("test_error").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0),
            fit_seconds: j.opt("fit_seconds").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0),
        })
    }

    pub fn validate(&self) -> Result<()> {
        let n_internal = (1usize << self.depth) - 1;
        let n_leaves = 1usize << self.depth;
        ensure!(self.feature.len() == self.n_trees, "feature rows != n_trees");
        ensure!(self.threshold.len() == self.n_trees, "threshold rows != n_trees");
        ensure!(self.leaf.len() == self.n_trees, "leaf rows != n_trees");
        ensure!(self.mean.len() == self.n_features, "mean len != n_features");
        ensure!(self.std.len() == self.n_features, "std len != n_features");
        for t in 0..self.n_trees {
            ensure!(self.feature[t].len() == n_internal, "tree {t} internal size");
            ensure!(self.threshold[t].len() == n_internal, "tree {t} threshold size");
            ensure!(self.leaf[t].len() == n_leaves, "tree {t} leaf size");
            for &f in &self.feature[t] {
                ensure!(
                    (f as usize) < self.n_features,
                    "tree {t} split feature {f} out of range"
                );
            }
        }
        ensure!(self.std.iter().all(|s| *s > 0.0), "std must be positive");
        Ok(())
    }

    /// Number of internal nodes per tree.
    pub fn n_internal(&self) -> usize {
        (1 << self.depth) - 1
    }

    /// Flat row-major copies for literal/buffer creation.
    pub fn flat_feature(&self) -> Vec<i32> {
        self.feature.iter().flatten().copied().collect()
    }

    pub fn flat_threshold(&self) -> Vec<f32> {
        self.threshold.iter().flatten().copied().collect()
    }

    pub fn flat_leaf(&self) -> Vec<f32> {
        self.leaf.iter().flatten().copied().collect()
    }

    /// Standardise one feature row in place (z-score).
    pub fn standardise(&self, row: &mut [f32]) {
        for (i, v) in row.iter_mut().enumerate() {
            *v = (*v - self.mean[i]) / self.std[i];
        }
    }

    /// A tiny synthetic forest for dependency-free tests: `n_trees` stumps
    /// that split on feature 0 around 0.0 (standardised) and return
    /// log-slowdowns `lo`/`hi` (prediction = solo_latency * exp(leaf)).
    pub fn synthetic_stub(n_features: usize, lo: f32, hi: f32) -> Self {
        let depth = 2;
        let n_internal = 3;
        let _n_leaves = 4;
        let n_trees = 4;
        Self {
            n_trees,
            depth,
            n_features,
            feature: vec![vec![0; n_internal]; n_trees],
            threshold: vec![vec![0.0, 1e30, 1e30]; n_trees],
            leaf: vec![vec![lo, lo, hi, hi]; n_trees],
            mean: vec![0.0; n_features],
            std: vec![1.0; n_features],
            test_error: 0.0,
            fit_seconds: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_stub_validates() {
        ForestParams::synthetic_stub(44, 1.0, 2.0).validate().unwrap();
    }

    #[test]
    fn from_json_roundtrip() {
        let src = r#"{
            "n_trees": 1, "depth": 1, "n_features": 2,
            "feature": [[1]], "threshold": [[0.5]], "leaf": [[1.0, 2.0]],
            "mean": [0.0, 0.0], "std": [1.0, 1.0],
            "test_error": 0.1, "fit_seconds": 3.2
        }"#;
        let p = ForestParams::from_json(&Json::parse(src).unwrap()).unwrap();
        p.validate().unwrap();
        assert_eq!(p.feature[0], vec![1]);
        assert_eq!(p.leaf[0], vec![1.0, 2.0]);
        assert_eq!(p.test_error, 0.1);
    }

    #[test]
    fn validate_rejects_bad_feature_index() {
        let mut p = ForestParams::synthetic_stub(4, 0.0, 1.0);
        p.feature[0][0] = 99;
        assert!(p.validate().is_err());
    }
}

//! Cluster state: nodes, instances and resource accounting.
//!
//! This is the substrate under both the scheduler (which reads node mixes
//! to compute capacities) and the simulator (which drives instance
//! lifecycles).  Instances move through:
//!
//! ```text
//!  Starting ──(init done)──> Saturated <──(release / logical cold start)──> Cached
//!      ▲                          │                                            │
//!      └────── real cold start ───┴──────────── eviction ◄────────────────────┘
//! ```
//!
//! "Saturated" means the router counts the instance as serving load (the
//! paper's saturated instances); "Cached" instances are routed around but
//! kept warm (dual-staged scaling, §5).

use crate::catalog::{Catalog, FunctionId};
use crate::interference::NodeMix;
use std::collections::HashMap;

/// Node identifier (dense index into [`Cluster::nodes`]).
pub type NodeId = usize;

/// Instance identifier, unique across the cluster lifetime.
pub type InstanceId = u64;

/// Lifecycle state of one instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Cold start in progress (scheduling + init); not yet serving.
    Starting,
    /// Serving requests; counted at full interference pressure.
    Saturated,
    /// Routed around but warm (dual-staged scaling stage 1).
    Cached,
}

/// One function instance placed on a node.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: InstanceId,
    pub function: FunctionId,
    pub node: NodeId,
    pub state: InstanceState,
    /// Virtual time (ms) the instance was created.
    pub created_ms: f64,
    /// Virtual time (ms) of the last state change (keep-alive bookkeeping).
    pub state_since_ms: f64,
}

/// Per-node instance sets and request-based resource accounting.
#[derive(Debug, Clone, Default)]
pub struct Node {
    pub instances: Vec<InstanceId>,
    /// Sum of configured requests of *all* instances (K8s-style view).
    pub requested_milli_cpu: u64,
    pub requested_mem_mb: u64,
}

/// The whole cluster: nodes + instance table.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    instances: HashMap<InstanceId, Instance>,
    next_instance: InstanceId,
    /// Cached per-node (function → (sat, cached)) counts, kept incrementally.
    mixes: Vec<HashMap<FunctionId, (u32, u32)>>,
    /// Cluster-wide instance counts per function (any state).
    global_counts: HashMap<FunctionId, u32>,
    /// Cluster-wide Starting counts per function, kept on state
    /// transitions — the autoscaler's per-eval lookup is O(1) instead of
    /// an O(nodes × instances) scan.
    starting: HashMap<FunctionId, u32>,
    /// Cluster-wide Cached instance ids per function in release order
    /// (the logical-cold-start conversion order), same motivation.
    cached: HashMap<FunctionId, Vec<InstanceId>>,
}

impl Cluster {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            nodes: vec![Node::default(); n_nodes],
            instances: HashMap::new(),
            next_instance: 0,
            mixes: vec![HashMap::new(); n_nodes],
            global_counts: HashMap::new(),
            starting: HashMap::new(),
            cached: HashMap::new(),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Grow the cluster (the paper requests new servers when no node fits).
    pub fn add_node(&mut self) -> NodeId {
        self.nodes.push(Node::default());
        self.mixes.push(HashMap::new());
        self.nodes.len() - 1
    }

    pub fn instance(&self, id: InstanceId) -> Option<&Instance> {
        self.instances.get(&id)
    }

    pub fn instances_len(&self) -> usize {
        self.instances.len()
    }

    /// All instances on `node` (unordered).
    pub fn node_instances(&self, node: NodeId) -> impl Iterator<Item = &Instance> + '_ {
        self.nodes[node].instances.iter().filter_map(move |id| self.instances.get(id))
    }

    /// Place a new instance (initially [`InstanceState::Starting`], which
    /// counts as saturated pressure conservatively once it flips; Starting
    /// instances are *reserved* in the mix as saturated so concurrent
    /// schedulings see each other).
    pub fn place(
        &mut self,
        cat: &Catalog,
        function: FunctionId,
        node: NodeId,
        now_ms: f64,
    ) -> InstanceId {
        let id = self.next_instance;
        self.next_instance += 1;
        let spec = cat.get(function);
        let inst = Instance {
            id,
            function,
            node,
            state: InstanceState::Starting,
            created_ms: now_ms,
            state_since_ms: now_ms,
        };
        self.nodes[node].instances.push(id);
        self.nodes[node].requested_milli_cpu += spec.milli_cpu;
        self.nodes[node].requested_mem_mb += spec.mem_mb;
        let e = self.mixes[node].entry(function).or_insert((0, 0));
        e.0 += 1; // Starting reserved as saturated
        *self.global_counts.entry(function).or_insert(0) += 1;
        *self.starting.entry(function).or_insert(0) += 1;
        self.instances.insert(id, inst);
        id
    }

    /// Cluster-wide count of `f` instances still cold-starting — O(1).
    pub fn starting_count(&self, f: FunctionId) -> u32 {
        self.starting.get(&f).copied().unwrap_or(0)
    }

    /// Cluster-wide Cached instances of `f` in release order — O(1)
    /// lookup (the slice the dual-staged reversal converts from).
    pub fn cached_of(&self, f: FunctionId) -> &[InstanceId] {
        self.cached.get(&f).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Whether any instance (any state, any node) of `f` exists.
    pub fn deployed_anywhere(&self, f: FunctionId) -> bool {
        self.global_counts.get(&f).copied().unwrap_or(0) > 0
    }

    /// Cluster-wide instance count of `f` (any state).
    pub fn global_count(&self, f: FunctionId) -> u32 {
        self.global_counts.get(&f).copied().unwrap_or(0)
    }

    /// Flip a Starting instance to Saturated (init finished).
    pub fn mark_ready(&mut self, id: InstanceId, now_ms: f64) {
        if let Some(inst) = self.instances.get_mut(&id) {
            debug_assert_eq!(inst.state, InstanceState::Starting);
            inst.state = InstanceState::Saturated;
            inst.state_since_ms = now_ms;
            let function = inst.function;
            self.dec_starting(function);
        }
    }

    /// Dual-staged scaling stage 1: Saturated → Cached ("release").
    pub fn release(&mut self, id: InstanceId, now_ms: f64) {
        let inst = self.instances.get_mut(&id).expect("release: unknown instance");
        assert_eq!(inst.state, InstanceState::Saturated, "release requires Saturated");
        inst.state = InstanceState::Cached;
        inst.state_since_ms = now_ms;
        let e = self.mixes[inst.node].get_mut(&inst.function).unwrap();
        e.0 -= 1;
        e.1 += 1;
        let function = inst.function;
        self.cached.entry(function).or_default().push(id);
    }

    /// Logical cold start: Cached → Saturated (re-route, <1 ms).
    pub fn reactivate(&mut self, id: InstanceId, now_ms: f64) {
        let inst = self.instances.get_mut(&id).expect("reactivate: unknown instance");
        assert_eq!(inst.state, InstanceState::Cached, "reactivate requires Cached");
        inst.state = InstanceState::Saturated;
        inst.state_since_ms = now_ms;
        let e = self.mixes[inst.node].get_mut(&inst.function).unwrap();
        e.0 += 1;
        e.1 -= 1;
        let function = inst.function;
        self.remove_cached(function, id);
    }

    fn dec_starting(&mut self, function: FunctionId) {
        let s = self.starting.get_mut(&function).expect("starting count underflow");
        *s -= 1;
        if *s == 0 {
            self.starting.remove(&function);
        }
    }

    fn remove_cached(&mut self, function: FunctionId, id: InstanceId) {
        let v = self.cached.get_mut(&function).expect("cached index missing function");
        v.retain(|x| *x != id);
        if v.is_empty() {
            self.cached.remove(&function);
        }
    }

    /// Remove an instance entirely (real eviction or failed start).
    pub fn evict(&mut self, cat: &Catalog, id: InstanceId) -> Option<Instance> {
        let inst = self.instances.remove(&id)?;
        let node = &mut self.nodes[inst.node];
        node.instances.retain(|x| *x != id);
        let spec = cat.get(inst.function);
        node.requested_milli_cpu -= spec.milli_cpu;
        node.requested_mem_mb -= spec.mem_mb;
        let e = self.mixes[inst.node].get_mut(&inst.function).unwrap();
        match inst.state {
            InstanceState::Cached => e.1 -= 1,
            _ => e.0 -= 1,
        }
        if *e == (0, 0) {
            self.mixes[inst.node].remove(&inst.function);
        }
        let g = self.global_counts.get_mut(&inst.function).unwrap();
        *g -= 1;
        if *g == 0 {
            self.global_counts.remove(&inst.function);
        }
        match inst.state {
            InstanceState::Starting => self.dec_starting(inst.function),
            InstanceState::Cached => self.remove_cached(inst.function, id),
            InstanceState::Saturated => {}
        }
        Some(inst)
    }

    /// Move a cached instance to another node (on-demand migration).  The
    /// migrated replica starts Cached on the target.
    pub fn migrate_cached(
        &mut self,
        cat: &Catalog,
        id: InstanceId,
        target: NodeId,
        now_ms: f64,
    ) {
        let inst = self.instances.get_mut(&id).expect("migrate: unknown instance");
        assert_eq!(inst.state, InstanceState::Cached);
        let src = inst.node;
        let function = inst.function;
        let spec = cat.get(function);
        // remove from source
        self.nodes[src].instances.retain(|x| *x != id);
        self.nodes[src].requested_milli_cpu -= spec.milli_cpu;
        self.nodes[src].requested_mem_mb -= spec.mem_mb;
        let e = self.mixes[src].get_mut(&function).unwrap();
        e.1 -= 1;
        if *e == (0, 0) {
            self.mixes[src].remove(&function);
        }
        // add to target
        let inst = self.instances.get_mut(&id).unwrap();
        inst.node = target;
        inst.state_since_ms = now_ms;
        self.nodes[target].instances.push(id);
        self.nodes[target].requested_milli_cpu += spec.milli_cpu;
        self.nodes[target].requested_mem_mb += spec.mem_mb;
        let e = self.mixes[target].entry(function).or_insert((0, 0));
        e.1 += 1;
    }

    /// The interference mix of a node: (function, saturated+starting,
    /// cached) triples.  Starting instances count as saturated — the
    /// scheduler must reserve their pressure before they serve.
    pub fn mix(&self, node: NodeId) -> NodeMix {
        let mut entries: Vec<(FunctionId, u32, u32)> = self.mixes[node]
            .iter()
            .map(|(f, (s, c))| (*f, *s, *c))
            .collect();
        entries.sort_unstable_by_key(|(f, _, _)| *f);
        NodeMix::new(entries)
    }

    /// (saturated+starting, cached) counts of `function` on `node`.
    pub fn counts(&self, node: NodeId, function: FunctionId) -> (u32, u32) {
        self.mixes[node].get(&function).copied().unwrap_or((0, 0))
    }

    /// Instances of `function` on `node` in a given state.
    pub fn find_instances(
        &self,
        node: NodeId,
        function: FunctionId,
        state: InstanceState,
    ) -> Vec<InstanceId> {
        self.node_instances(node)
            .filter(|i| i.function == function && i.state == state)
            .map(|i| i.id)
            .collect()
    }

    /// Whether a node has zero instances (candidate for scale-in).
    pub fn node_empty(&self, node: NodeId) -> bool {
        self.nodes[node].instances.is_empty()
    }

    /// Debug invariant check: mixes and the per-function state index
    /// match the instance table (tests).
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        for (n, _) in self.nodes.iter().enumerate() {
            let mut counted: HashMap<FunctionId, (u32, u32)> = HashMap::new();
            for inst in self.node_instances(n) {
                let e = counted.entry(inst.function).or_insert((0, 0));
                match inst.state {
                    InstanceState::Cached => e.1 += 1,
                    _ => e.0 += 1,
                }
            }
            anyhow::ensure!(
                counted == self.mixes[n],
                "node {n}: mix cache {:?} != actual {:?}",
                self.mixes[n],
                counted
            );
        }
        let mut starting: HashMap<FunctionId, u32> = HashMap::new();
        let mut cached: HashMap<FunctionId, Vec<InstanceId>> = HashMap::new();
        for inst in self.instances.values() {
            match inst.state {
                InstanceState::Starting => *starting.entry(inst.function).or_insert(0) += 1,
                InstanceState::Cached => cached.entry(inst.function).or_default().push(inst.id),
                InstanceState::Saturated => {}
            }
        }
        anyhow::ensure!(
            starting == self.starting,
            "starting index {:?} != actual {:?}",
            self.starting,
            starting
        );
        anyhow::ensure!(
            cached.len() == self.cached.len(),
            "cached index keys {:?} != actual {:?}",
            self.cached.keys(),
            cached.keys()
        );
        for (f, ids) in &cached {
            // membership + uniqueness; the *release order* of the index
            // cannot be reconstructed from the instance table (migration
            // bumps state_since_ms without reordering), so order is
            // pinned by the state_index_tracks_transitions unit test
            let mut expect = ids.clone();
            expect.sort_unstable();
            let mut got = self.cached.get(f).cloned().unwrap_or_default();
            got.sort_unstable();
            got.dedup();
            anyhow::ensure!(
                expect == got,
                "cached index for fn {f}: {got:?} != actual {expect:?}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn place_ready_release_reactivate_evict_roundtrip() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let id = cl.place(&cat, 0, 0, 0.0);
        assert_eq!(cl.counts(0, 0), (1, 0));
        cl.mark_ready(id, 1.0);
        cl.release(id, 2.0);
        assert_eq!(cl.counts(0, 0), (0, 1));
        cl.reactivate(id, 3.0);
        assert_eq!(cl.counts(0, 0), (1, 0));
        cl.evict(&cat, id);
        assert_eq!(cl.counts(0, 0), (0, 0));
        assert!(cl.node_empty(0));
        cl.check_invariants().unwrap();
    }

    #[test]
    fn state_index_tracks_transitions() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let a = cl.place(&cat, 0, 0, 0.0);
        let b = cl.place(&cat, 0, 1, 0.0);
        assert_eq!(cl.starting_count(0), 2);
        assert!(cl.cached_of(0).is_empty());
        cl.mark_ready(a, 1.0);
        assert_eq!(cl.starting_count(0), 1);
        cl.release(a, 2.0);
        assert_eq!(cl.cached_of(0), &[a]);
        cl.mark_ready(b, 2.0);
        cl.release(b, 3.0);
        assert_eq!(cl.cached_of(0), &[a, b], "release order preserved");
        cl.migrate_cached(&cat, a, 1, 4.0);
        assert_eq!(cl.cached_of(0), &[a, b], "migration keeps membership");
        // release a third, then remove the *middle* entry: the survivors
        // must keep release order (a swap-remove would yield [d, b])
        let d = cl.place(&cat, 0, 0, 5.0);
        cl.mark_ready(d, 5.0);
        cl.release(d, 6.0);
        assert_eq!(cl.cached_of(0), &[a, b, d]);
        cl.reactivate(b, 7.0);
        assert_eq!(cl.cached_of(0), &[a, d], "removal preserves release order");
        cl.reactivate(a, 8.0);
        assert_eq!(cl.cached_of(0), &[d]);
        cl.evict(&cat, d); // evict a Cached instance
        assert!(cl.cached_of(0).is_empty());
        cl.check_invariants().unwrap();
        let c = cl.place(&cat, 1, 0, 6.0);
        cl.evict(&cat, c); // evict a Starting instance
        assert_eq!(cl.starting_count(1), 0);
        cl.check_invariants().unwrap();
    }

    #[test]
    fn requested_resources_tracked() {
        let cat = test_catalog();
        let mut cl = Cluster::new(1);
        let a = cl.place(&cat, 0, 0, 0.0);
        let _b = cl.place(&cat, 1, 0, 0.0);
        assert_eq!(cl.nodes[0].requested_milli_cpu, 8000);
        cl.evict(&cat, a);
        assert_eq!(cl.nodes[0].requested_milli_cpu, 4000);
    }

    #[test]
    fn migrate_cached_moves_pressure() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let id = cl.place(&cat, 2, 0, 0.0);
        cl.mark_ready(id, 0.0);
        cl.release(id, 1.0);
        cl.migrate_cached(&cat, id, 1, 2.0);
        assert_eq!(cl.counts(0, 2), (0, 0));
        assert_eq!(cl.counts(1, 2), (0, 1));
        assert_eq!(cl.instance(id).unwrap().node, 1);
        cl.check_invariants().unwrap();
    }

    #[test]
    fn mix_sorted_and_complete() {
        let cat = test_catalog();
        let mut cl = Cluster::new(1);
        for f in [2usize, 0, 1] {
            for _ in 0..2 {
                let id = cl.place(&cat, f, 0, 0.0);
                cl.mark_ready(id, 0.0);
            }
        }
        let mix = cl.mix(0);
        assert_eq!(mix.entries, vec![(0, 2, 0), (1, 2, 0), (2, 2, 0)]);
    }
}

//! Cluster state: nodes, instances and resource accounting.
//!
//! This is the substrate under both the scheduler (which reads node mixes
//! to compute capacities) and the simulator (which drives instance
//! lifecycles).  Instances move through:
//!
//! ```text
//!  Starting ──(init done)──> Saturated <──(release / logical cold start)──> Cached
//!      ▲                          │                                            │
//!      └────── real cold start ───┴──────────── eviction ◄────────────────────┘
//! ```
//!
//! "Saturated" means the router counts the instance as serving load (the
//! paper's saturated instances); "Cached" instances are routed around but
//! kept warm (dual-staged scaling, §5).
//!
//! ## Struct-of-arrays layout
//!
//! The instance table is stored as parallel columns indexed by
//! [`InstanceId`] (ids are dense, monotone and never reused), not as a
//! map of [`Instance`] rows: autoscaler sweeps and the per-request hot
//! path read one column (state, node) per instance instead of chasing
//! hash buckets, and [`Cluster::mix`] copies an already-sorted sparse
//! per-node count vector instead of sorting a `HashMap` on every call.
//! Slots of evicted instances stay allocated (a bounded cost of the
//! id-indexed layout); [`Cluster::instance`] assembles a row **by value**
//! for callers that want the whole record.

use crate::catalog::{Catalog, FunctionId};
use crate::interference::NodeMix;
use std::collections::HashMap;

/// Node identifier (dense index into [`Cluster::nodes`]).
pub type NodeId = usize;

/// Instance identifier, unique across the cluster lifetime.
pub type InstanceId = u64;

/// Lifecycle state of one instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Cold start in progress (scheduling + init); not yet serving.
    Starting,
    /// Serving requests; counted at full interference pressure.
    Saturated,
    /// Routed around but warm (dual-staged scaling stage 1).
    Cached,
}

/// One function instance placed on a node — the by-value row view over
/// the cluster's column store.
#[derive(Debug, Clone, Copy)]
pub struct Instance {
    pub id: InstanceId,
    pub function: FunctionId,
    pub node: NodeId,
    pub state: InstanceState,
    /// Virtual time (ms) the instance was created.
    pub created_ms: f64,
    /// Virtual time (ms) of the last state change (keep-alive bookkeeping).
    pub state_since_ms: f64,
}

/// Per-node instance sets and request-based resource accounting.
#[derive(Debug, Clone, Default)]
pub struct Node {
    pub instances: Vec<InstanceId>,
    /// Sum of configured requests of *all* instances (K8s-style view).
    pub requested_milli_cpu: u64,
    pub requested_mem_mb: u64,
}

/// The whole cluster: nodes + the struct-of-arrays instance table.
#[derive(Debug)]
pub struct Cluster {
    pub nodes: Vec<Node>,
    // --- instance table columns, indexed by InstanceId ---
    inst_function: Vec<FunctionId>,
    inst_node: Vec<NodeId>,
    inst_state: Vec<InstanceState>,
    inst_created_ms: Vec<f64>,
    inst_state_since_ms: Vec<f64>,
    /// Whether the slot still holds a live (non-evicted) instance.
    inst_live: Vec<bool>,
    live_instances: usize,
    next_instance: InstanceId,
    /// Per-node (function, (sat+starting, cached)) counts, sparse and
    /// sorted by function id — kept incrementally, so [`Cluster::mix`]
    /// is a copy, never a sort.
    mixes: Vec<Vec<(FunctionId, (u32, u32))>>,
    /// Cluster-wide instance counts per function (any state), indexed by
    /// function id (grown on demand).
    global_counts: Vec<u32>,
    /// Cluster-wide Starting counts per function, kept on state
    /// transitions — the autoscaler's per-eval lookup is O(1) instead of
    /// an O(nodes × instances) scan.
    starting: Vec<u32>,
    /// Cluster-wide Cached instance ids per function in release order
    /// (the logical-cold-start conversion order), same motivation.
    cached: Vec<Vec<InstanceId>>,
    /// Bumped by every mutation that can change a candidate ranking —
    /// i.e. move some node's `counts` sum or `instances_on` total:
    /// `place`, `evict`, `migrate_cached`.  `mark_ready`, `release` and
    /// `reactivate` shuffle an instance between states *within* a node
    /// (the summed counts and totals are unchanged) and `add_node`
    /// appends an empty node (handled by the order cache's
    /// append-on-grow path), so none of them bump.  See
    /// `scheduler::CandidateOrders` for the consumer of this contract.
    order_epoch: u64,
}

impl Cluster {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            nodes: vec![Node::default(); n_nodes],
            inst_function: Vec::new(),
            inst_node: Vec::new(),
            inst_state: Vec::new(),
            inst_created_ms: Vec::new(),
            inst_state_since_ms: Vec::new(),
            inst_live: Vec::new(),
            live_instances: 0,
            next_instance: 0,
            mixes: vec![Vec::new(); n_nodes],
            global_counts: Vec::new(),
            starting: Vec::new(),
            cached: Vec::new(),
            order_epoch: 0,
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The candidate-order change stamp (see the field doc for exactly
    /// which mutations advance it).
    pub fn order_epoch(&self) -> u64 {
        self.order_epoch
    }

    /// Grow the cluster (the paper requests new servers when no node fits).
    pub fn add_node(&mut self) -> NodeId {
        self.nodes.push(Node::default());
        self.mixes.push(Vec::new());
        self.nodes.len() - 1
    }

    /// The full row of a live instance, by value.
    pub fn instance(&self, id: InstanceId) -> Option<Instance> {
        let i = id as usize;
        if i >= self.inst_live.len() || !self.inst_live[i] {
            return None;
        }
        Some(Instance {
            id,
            function: self.inst_function[i],
            node: self.inst_node[i],
            state: self.inst_state[i],
            created_ms: self.inst_created_ms[i],
            state_since_ms: self.inst_state_since_ms[i],
        })
    }

    /// State of a live instance — one column read, no row assembly.
    pub fn state_of(&self, id: InstanceId) -> Option<InstanceState> {
        let i = id as usize;
        (i < self.inst_live.len() && self.inst_live[i]).then(|| self.inst_state[i])
    }

    /// Node of a live instance — one column read.
    pub fn node_of(&self, id: InstanceId) -> Option<NodeId> {
        let i = id as usize;
        (i < self.inst_live.len() && self.inst_live[i]).then(|| self.inst_node[i])
    }

    /// Creation time of a live instance — one column read.
    pub fn created_ms_of(&self, id: InstanceId) -> Option<f64> {
        let i = id as usize;
        (i < self.inst_live.len() && self.inst_live[i]).then(|| self.inst_created_ms[i])
    }

    pub fn instances_len(&self) -> usize {
        self.live_instances
    }

    /// All instances on `node` (unordered), assembled by value.
    pub fn node_instances(&self, node: NodeId) -> impl Iterator<Item = Instance> + '_ {
        self.nodes[node]
            .instances
            .iter()
            .map(move |&id| self.instance(id).expect("node instance list holds live ids"))
    }

    /// The (sat+starting, cached) cell for `function` in a sorted sparse
    /// mix, inserted at its sort position on first touch.
    fn mix_entry(
        mix: &mut Vec<(FunctionId, (u32, u32))>,
        function: FunctionId,
    ) -> &mut (u32, u32) {
        match mix.binary_search_by_key(&function, |(f, _)| *f) {
            Ok(i) => &mut mix[i].1,
            Err(i) => {
                mix.insert(i, (function, (0, 0)));
                &mut mix[i].1
            }
        }
    }

    fn ensure_function(&mut self, function: FunctionId) {
        if self.global_counts.len() <= function {
            self.global_counts.resize(function + 1, 0);
            self.starting.resize(function + 1, 0);
            self.cached.resize_with(function + 1, Vec::new);
        }
    }

    /// Place a new instance (initially [`InstanceState::Starting`], which
    /// counts as saturated pressure conservatively once it flips; Starting
    /// instances are *reserved* in the mix as saturated so concurrent
    /// schedulings see each other).
    pub fn place(
        &mut self,
        cat: &Catalog,
        function: FunctionId,
        node: NodeId,
        now_ms: f64,
    ) -> InstanceId {
        let id = self.next_instance;
        self.next_instance += 1;
        let spec = cat.get(function);
        debug_assert_eq!(self.inst_function.len() as u64, id);
        self.inst_function.push(function);
        self.inst_node.push(node);
        self.inst_state.push(InstanceState::Starting);
        self.inst_created_ms.push(now_ms);
        self.inst_state_since_ms.push(now_ms);
        self.inst_live.push(true);
        self.live_instances += 1;
        self.nodes[node].instances.push(id);
        self.nodes[node].requested_milli_cpu += spec.milli_cpu;
        self.nodes[node].requested_mem_mb += spec.mem_mb;
        Self::mix_entry(&mut self.mixes[node], function).0 += 1; // Starting reserved as saturated
        self.ensure_function(function);
        self.global_counts[function] += 1;
        self.starting[function] += 1;
        self.order_epoch += 1;
        id
    }

    /// Cluster-wide count of `f` instances still cold-starting — O(1).
    pub fn starting_count(&self, f: FunctionId) -> u32 {
        self.starting.get(f).copied().unwrap_or(0)
    }

    /// Cluster-wide Cached instances of `f` in release order — O(1)
    /// lookup (the slice the dual-staged reversal converts from).
    pub fn cached_of(&self, f: FunctionId) -> &[InstanceId] {
        self.cached.get(f).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Whether any instance (any state, any node) of `f` exists.
    pub fn deployed_anywhere(&self, f: FunctionId) -> bool {
        self.global_counts.get(f).copied().unwrap_or(0) > 0
    }

    /// Cluster-wide instance count of `f` (any state).
    pub fn global_count(&self, f: FunctionId) -> u32 {
        self.global_counts.get(f).copied().unwrap_or(0)
    }

    /// Flip a Starting instance to Saturated (init finished).
    pub fn mark_ready(&mut self, id: InstanceId, now_ms: f64) {
        let i = id as usize;
        if i >= self.inst_live.len() || !self.inst_live[i] {
            return;
        }
        debug_assert_eq!(self.inst_state[i], InstanceState::Starting);
        self.inst_state[i] = InstanceState::Saturated;
        self.inst_state_since_ms[i] = now_ms;
        let function = self.inst_function[i];
        self.dec_starting(function);
        // (sat+starting, cached) sums and totals unchanged: no epoch bump
    }

    /// Dual-staged scaling stage 1: Saturated → Cached ("release").
    pub fn release(&mut self, id: InstanceId, now_ms: f64) {
        let i = id as usize;
        assert!(
            i < self.inst_live.len() && self.inst_live[i],
            "release: unknown instance"
        );
        assert_eq!(
            self.inst_state[i],
            InstanceState::Saturated,
            "release requires Saturated"
        );
        self.inst_state[i] = InstanceState::Cached;
        self.inst_state_since_ms[i] = now_ms;
        let (node, function) = (self.inst_node[i], self.inst_function[i]);
        let e = Self::mix_entry(&mut self.mixes[node], function);
        e.0 -= 1;
        e.1 += 1;
        self.cached[function].push(id);
    }

    /// Logical cold start: Cached → Saturated (re-route, <1 ms).
    pub fn reactivate(&mut self, id: InstanceId, now_ms: f64) {
        let i = id as usize;
        assert!(
            i < self.inst_live.len() && self.inst_live[i],
            "reactivate: unknown instance"
        );
        assert_eq!(
            self.inst_state[i],
            InstanceState::Cached,
            "reactivate requires Cached"
        );
        self.inst_state[i] = InstanceState::Saturated;
        self.inst_state_since_ms[i] = now_ms;
        let (node, function) = (self.inst_node[i], self.inst_function[i]);
        let e = Self::mix_entry(&mut self.mixes[node], function);
        e.0 += 1;
        e.1 -= 1;
        self.remove_cached(function, id);
    }

    fn dec_starting(&mut self, function: FunctionId) {
        let s = &mut self.starting[function];
        *s = s.checked_sub(1).expect("starting count underflow");
    }

    fn remove_cached(&mut self, function: FunctionId, id: InstanceId) {
        self.cached[function].retain(|x| *x != id);
    }

    /// Remove an instance entirely (real eviction or failed start).
    pub fn evict(&mut self, cat: &Catalog, id: InstanceId) -> Option<Instance> {
        let inst = self.instance(id)?;
        self.inst_live[id as usize] = false;
        self.live_instances -= 1;
        let node = &mut self.nodes[inst.node];
        node.instances.retain(|x| *x != id);
        let spec = cat.get(inst.function);
        node.requested_milli_cpu -= spec.milli_cpu;
        node.requested_mem_mb -= spec.mem_mb;
        let mix = &mut self.mixes[inst.node];
        let slot = mix
            .binary_search_by_key(&inst.function, |(f, _)| *f)
            .expect("mix missing evicted function");
        let (_, counts) = &mut mix[slot];
        match inst.state {
            InstanceState::Cached => counts.1 -= 1,
            _ => counts.0 -= 1,
        }
        if *counts == (0, 0) {
            mix.remove(slot);
        }
        self.global_counts[inst.function] -= 1;
        match inst.state {
            InstanceState::Starting => self.dec_starting(inst.function),
            InstanceState::Cached => self.remove_cached(inst.function, id),
            InstanceState::Saturated => {}
        }
        self.order_epoch += 1;
        Some(inst)
    }

    /// Move a cached instance to another node (on-demand migration).  The
    /// migrated replica starts Cached on the target.
    pub fn migrate_cached(
        &mut self,
        cat: &Catalog,
        id: InstanceId,
        target: NodeId,
        now_ms: f64,
    ) {
        let i = id as usize;
        assert!(
            i < self.inst_live.len() && self.inst_live[i],
            "migrate: unknown instance"
        );
        assert_eq!(self.inst_state[i], InstanceState::Cached);
        let src = self.inst_node[i];
        let function = self.inst_function[i];
        let spec = cat.get(function);
        // remove from source
        self.nodes[src].instances.retain(|x| *x != id);
        self.nodes[src].requested_milli_cpu -= spec.milli_cpu;
        self.nodes[src].requested_mem_mb -= spec.mem_mb;
        {
            let mix = &mut self.mixes[src];
            let slot = mix
                .binary_search_by_key(&function, |(f, _)| *f)
                .expect("mix missing migrated function");
            let (_, counts) = &mut mix[slot];
            counts.1 -= 1;
            if *counts == (0, 0) {
                mix.remove(slot);
            }
        }
        // add to target
        self.inst_node[i] = target;
        self.inst_state_since_ms[i] = now_ms;
        self.nodes[target].instances.push(id);
        self.nodes[target].requested_milli_cpu += spec.milli_cpu;
        self.nodes[target].requested_mem_mb += spec.mem_mb;
        Self::mix_entry(&mut self.mixes[target], function).1 += 1;
        self.order_epoch += 1; // instance totals moved between two nodes
    }

    /// The interference mix of a node: (function, saturated+starting,
    /// cached) triples, sorted by function id.  Starting instances count
    /// as saturated — the scheduler must reserve their pressure before
    /// they serve.  The sparse counts are maintained sorted, so this is
    /// a straight copy.
    pub fn mix(&self, node: NodeId) -> NodeMix {
        NodeMix::new(
            self.mixes[node]
                .iter()
                .map(|&(f, (s, c))| (f, s, c))
                .collect(),
        )
    }

    /// (saturated+starting, cached) counts of `function` on `node`.
    pub fn counts(&self, node: NodeId, function: FunctionId) -> (u32, u32) {
        match self.mixes[node].binary_search_by_key(&function, |(f, _)| *f) {
            Ok(i) => self.mixes[node][i].1,
            Err(_) => (0, 0),
        }
    }

    /// Instances of `function` on `node` in a given state.
    pub fn find_instances(
        &self,
        node: NodeId,
        function: FunctionId,
        state: InstanceState,
    ) -> Vec<InstanceId> {
        self.nodes[node]
            .instances
            .iter()
            .copied()
            .filter(|&id| {
                let i = id as usize;
                self.inst_function[i] == function && self.inst_state[i] == state
            })
            .collect()
    }

    /// Whether a node has zero instances (candidate for scale-in).
    pub fn node_empty(&self, node: NodeId) -> bool {
        self.nodes[node].instances.is_empty()
    }

    /// Debug invariant check: mixes and the per-function state index
    /// match the instance table (tests).
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        for n in 0..self.nodes.len() {
            let mut counted: HashMap<FunctionId, (u32, u32)> = HashMap::new();
            for inst in self.node_instances(n) {
                let e = counted.entry(inst.function).or_insert((0, 0));
                match inst.state {
                    InstanceState::Cached => e.1 += 1,
                    _ => e.0 += 1,
                }
            }
            let mut expect: Vec<(FunctionId, (u32, u32))> = counted.into_iter().collect();
            expect.sort_unstable_by_key(|(f, _)| *f);
            anyhow::ensure!(
                expect == self.mixes[n],
                "node {n}: mix cache {:?} != actual {:?}",
                self.mixes[n],
                expect
            );
        }
        let mut live = 0usize;
        let mut starting = vec![0u32; self.starting.len()];
        let mut global = vec![0u32; self.global_counts.len()];
        let mut cached: HashMap<FunctionId, Vec<InstanceId>> = HashMap::new();
        for i in 0..self.inst_live.len() {
            if !self.inst_live[i] {
                continue;
            }
            live += 1;
            let f = self.inst_function[i];
            anyhow::ensure!(f < global.len(), "fn {f} beyond the count index");
            global[f] += 1;
            match self.inst_state[i] {
                InstanceState::Starting => starting[f] += 1,
                InstanceState::Cached => cached.entry(f).or_default().push(i as InstanceId),
                InstanceState::Saturated => {}
            }
        }
        anyhow::ensure!(
            live == self.live_instances,
            "live counter {} != actual {live}",
            self.live_instances
        );
        anyhow::ensure!(
            starting == self.starting,
            "starting index {:?} != actual {starting:?}",
            self.starting
        );
        anyhow::ensure!(
            global == self.global_counts,
            "global counts {:?} != actual {global:?}",
            self.global_counts
        );
        for f in 0..self.cached.len() {
            // membership + uniqueness; the *release order* of the index
            // cannot be reconstructed from the instance table (migration
            // bumps state_since_ms without reordering), so order is
            // pinned by the state_index_tracks_transitions unit test
            let mut expect = cached.remove(&f).unwrap_or_default();
            expect.sort_unstable();
            let mut got = self.cached[f].clone();
            got.sort_unstable();
            got.dedup();
            anyhow::ensure!(
                expect == got,
                "cached index for fn {f}: {got:?} != actual {expect:?}"
            );
        }
        anyhow::ensure!(
            cached.is_empty(),
            "cached instances beyond the index: {:?}",
            cached.keys()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn place_ready_release_reactivate_evict_roundtrip() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let id = cl.place(&cat, 0, 0, 0.0);
        assert_eq!(cl.counts(0, 0), (1, 0));
        cl.mark_ready(id, 1.0);
        cl.release(id, 2.0);
        assert_eq!(cl.counts(0, 0), (0, 1));
        cl.reactivate(id, 3.0);
        assert_eq!(cl.counts(0, 0), (1, 0));
        cl.evict(&cat, id);
        assert_eq!(cl.counts(0, 0), (0, 0));
        assert!(cl.node_empty(0));
        cl.check_invariants().unwrap();
    }

    #[test]
    fn state_index_tracks_transitions() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let a = cl.place(&cat, 0, 0, 0.0);
        let b = cl.place(&cat, 0, 1, 0.0);
        assert_eq!(cl.starting_count(0), 2);
        assert!(cl.cached_of(0).is_empty());
        cl.mark_ready(a, 1.0);
        assert_eq!(cl.starting_count(0), 1);
        cl.release(a, 2.0);
        assert_eq!(cl.cached_of(0), &[a]);
        cl.mark_ready(b, 2.0);
        cl.release(b, 3.0);
        assert_eq!(cl.cached_of(0), &[a, b], "release order preserved");
        cl.migrate_cached(&cat, a, 1, 4.0);
        assert_eq!(cl.cached_of(0), &[a, b], "migration keeps membership");
        // release a third, then remove the *middle* entry: the survivors
        // must keep release order (a swap-remove would yield [d, b])
        let d = cl.place(&cat, 0, 0, 5.0);
        cl.mark_ready(d, 5.0);
        cl.release(d, 6.0);
        assert_eq!(cl.cached_of(0), &[a, b, d]);
        cl.reactivate(b, 7.0);
        assert_eq!(cl.cached_of(0), &[a, d], "removal preserves release order");
        cl.reactivate(a, 8.0);
        assert_eq!(cl.cached_of(0), &[d]);
        cl.evict(&cat, d); // evict a Cached instance
        assert!(cl.cached_of(0).is_empty());
        cl.check_invariants().unwrap();
        let c = cl.place(&cat, 1, 0, 6.0);
        cl.evict(&cat, c); // evict a Starting instance
        assert_eq!(cl.starting_count(1), 0);
        cl.check_invariants().unwrap();
    }

    #[test]
    fn requested_resources_tracked() {
        let cat = test_catalog();
        let mut cl = Cluster::new(1);
        let a = cl.place(&cat, 0, 0, 0.0);
        let _b = cl.place(&cat, 1, 0, 0.0);
        assert_eq!(cl.nodes[0].requested_milli_cpu, 8000);
        cl.evict(&cat, a);
        assert_eq!(cl.nodes[0].requested_milli_cpu, 4000);
    }

    #[test]
    fn migrate_cached_moves_pressure() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let id = cl.place(&cat, 2, 0, 0.0);
        cl.mark_ready(id, 0.0);
        cl.release(id, 1.0);
        cl.migrate_cached(&cat, id, 1, 2.0);
        assert_eq!(cl.counts(0, 2), (0, 0));
        assert_eq!(cl.counts(1, 2), (0, 1));
        assert_eq!(cl.instance(id).unwrap().node, 1);
        assert_eq!(cl.node_of(id), Some(1));
        cl.check_invariants().unwrap();
    }

    #[test]
    fn mix_sorted_and_complete() {
        let cat = test_catalog();
        let mut cl = Cluster::new(1);
        for f in [2usize, 0, 1] {
            for _ in 0..2 {
                let id = cl.place(&cat, f, 0, 0.0);
                cl.mark_ready(id, 0.0);
            }
        }
        let mix = cl.mix(0);
        assert_eq!(mix.entries, vec![(0, 2, 0), (1, 2, 0), (2, 2, 0)]);
    }

    /// The order epoch moves exactly with the mutations that can change a
    /// candidate ranking (place/evict/migrate) and stays put for the ones
    /// that provably cannot (ready/release/reactivate/add_node).
    #[test]
    fn order_epoch_tracks_ranking_mutations_only() {
        let cat = test_catalog();
        let mut cl = Cluster::new(2);
        let e0 = cl.order_epoch();
        let id = cl.place(&cat, 0, 0, 0.0);
        assert_ne!(cl.order_epoch(), e0, "place must bump");
        let e1 = cl.order_epoch();
        cl.mark_ready(id, 1.0);
        cl.release(id, 2.0);
        cl.reactivate(id, 3.0);
        cl.add_node();
        assert_eq!(cl.order_epoch(), e1, "in-node state moves must not bump");
        cl.release(id, 4.0);
        cl.migrate_cached(&cat, id, 1, 5.0);
        assert_ne!(cl.order_epoch(), e1, "migration must bump");
        let e2 = cl.order_epoch();
        cl.evict(&cat, id);
        assert_ne!(cl.order_epoch(), e2, "evict must bump");
        cl.check_invariants().unwrap();
    }

    /// Column accessors agree with the assembled row and observe
    /// evictions.
    #[test]
    fn column_accessors_match_row_view() {
        let cat = test_catalog();
        let mut cl = Cluster::new(1);
        let id = cl.place(&cat, 1, 0, 7.5);
        let row = cl.instance(id).unwrap();
        assert_eq!(cl.state_of(id), Some(row.state));
        assert_eq!(cl.node_of(id), Some(row.node));
        assert_eq!(cl.created_ms_of(id), Some(7.5));
        assert_eq!(cl.instances_len(), 1);
        cl.evict(&cat, id);
        assert!(cl.instance(id).is_none());
        assert_eq!(cl.state_of(id), None);
        assert_eq!(cl.node_of(id), None);
        assert_eq!(cl.instances_len(), 0);
    }
}

//! `jiagu-gen-artifacts` — generate every artifact the Rust stack
//! consumes, natively and deterministically (no Python/JAX required).
//!
//! ```text
//! jiagu-gen-artifacts [--out-dir DIR] [--seed 7] [--functions 6]
//!                     [--train-rows 20000] [--test-rows 2000]
//!                     [--trees 64] [--depth 10] [--quick]
//!                     [--no-model-comparison]
//! jiagu-gen-artifacts --trace-out FILE [--trace-invocations N]
//!                     [--trace-seconds S] [--trace-seed N]
//!                     [--trace-format csv|jsonl] [--functions 6] [--seed 7]
//! ```
//!
//! Defaults mirror the Python pipeline's hyperparameters; `--quick`
//! switches to a small budget for dev loops (tests use an even smaller
//! in-process configuration).  The HLO modules for the optional PJRT
//! runtime still come from `make artifacts-jax`.
//!
//! `--trace-out` switches to trace-generation mode: instead of model
//! artifacts it writes a deterministic Azure-style invocation log
//! ([`jiagu::workload::replay::generate_trace_file`]) against the same
//! synthetic catalog (`--functions`/`--seed`) the artifact pipeline
//! builds, so generated traces replay against stock artifacts.

use anyhow::{bail, Context, Result};
use jiagu::artifacts::{generate, make_catalog, GenConfig};
use jiagu::catalog::Catalog;
use jiagu::workload::replay::{generate_trace_file, TraceFormat, TraceGenSpec};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // --quick is a baseline, not a positional override: apply it first so
    // explicit sizing flags win regardless of where they appear.
    let mut cfg = if raw.iter().any(|a| a == "--quick") {
        GenConfig::quick()
    } else {
        GenConfig::default()
    };
    let mut out_dir: Option<std::path::PathBuf> = None;
    let mut trace_out: Option<std::path::PathBuf> = None;
    let mut trace_spec = TraceGenSpec {
        invocations: 100_000,
        duration_s: 600,
        seed: 7,
        format: TraceFormat::Csv,
    };
    let mut args = raw.into_iter();
    while let Some(a) = args.next() {
        let mut value = |name: &str| {
            args.next().with_context(|| format!("{name} expects a value"))
        };
        match a.as_str() {
            "--out-dir" => out_dir = Some(value("--out-dir")?.into()),
            "--trace-out" => trace_out = Some(value("--trace-out")?.into()),
            "--trace-invocations" => {
                trace_spec.invocations =
                    value("--trace-invocations")?.parse().context("--trace-invocations")?
            }
            "--trace-seconds" => {
                trace_spec.duration_s =
                    value("--trace-seconds")?.parse().context("--trace-seconds")?
            }
            "--trace-seed" => {
                trace_spec.seed = value("--trace-seed")?.parse().context("--trace-seed")?
            }
            "--trace-format" => {
                trace_spec.format = TraceFormat::parse(&value("--trace-format")?)?
            }
            "--seed" => cfg.seed = value("--seed")?.parse().context("--seed")?,
            "--functions" => {
                cfg.n_functions = value("--functions")?.parse().context("--functions")?
            }
            "--train-rows" => {
                cfg.train_rows = value("--train-rows")?.parse().context("--train-rows")?
            }
            "--test-rows" => {
                cfg.test_rows = value("--test-rows")?.parse().context("--test-rows")?
            }
            "--trees" => cfg.n_trees = value("--trees")?.parse().context("--trees")?,
            "--depth" => cfg.depth = value("--depth")?.parse().context("--depth")?,
            "--quick" => {} // applied before parsing; see above
            "--no-model-comparison" => cfg.model_comparison = false,
            "--help" | "-h" => {
                println!(
                    "jiagu-gen-artifacts [--out-dir DIR] [--seed N] [--functions N] \
                     [--train-rows N] [--test-rows N] [--trees N] [--depth N] \
                     [--quick] [--no-model-comparison] | --trace-out FILE \
                     [--trace-invocations N] [--trace-seconds N] [--trace-seed N] \
                     [--trace-format csv|jsonl]"
                );
                return Ok(());
            }
            other => bail!("unknown flag {other:?} (see --help)"),
        }
    }
    if let Some(path) = trace_out {
        let cat = Catalog::from_functions(make_catalog(cfg.n_functions, cfg.seed));
        eprintln!(
            "[gen] generating trace {} (~{} invocations, {} s, seed {})",
            path.display(),
            trace_spec.invocations,
            trace_spec.duration_s,
            trace_spec.seed
        );
        let written = generate_trace_file(&path, &cat, &trace_spec)?;
        eprintln!("[gen] done: {written} invocations written");
        return Ok(());
    }
    let out_dir = out_dir.unwrap_or_else(jiagu::artifacts_dir);
    eprintln!(
        "[gen] generating artifacts in {} (seed {}, {} fns, {} train rows, T={} D={})",
        out_dir.display(),
        cfg.seed,
        cfg.n_functions,
        cfg.train_rows,
        cfg.n_trees,
        cfg.depth
    );
    let report = generate(&out_dir, &cfg)?;
    eprintln!(
        "[gen] done: {} functions, {} train rows, forest test error {:.3}, fit {:.1}s",
        report.n_functions, report.train_rows, report.test_error, report.fit_seconds
    );
    Ok(())
}

//! Sharded parallel control planes with a deterministic report merge.
//!
//! One [`ControlPlane`](crate::controlplane::ControlPlane) drains one
//! event queue on one thread — fine for a 24-node testbed, a ceiling for
//! the ROADMAP's production-scale target.  `router_props` established
//! the precondition (two replica control planes make byte-identical
//! decisions from the same event stream); this module builds on it by
//! **partitioning** the workload into independent control-plane cells
//! and running them on parallel threads:
//!
//! * The [`ShardLayout`] divides the catalog's functions (round-robin by
//!   id, so heavy and light functions interleave) and the cluster's
//!   nodes (proportional split) into `cfg.partitions` disjoint cells.
//! * Each cell is a complete, plain control plane: full catalog, its own
//!   node allotment, its own seeded RNG streams, and only its own
//!   functions' [`LoadEvent`](crate::traces::LoadEvent)s/arrivals —
//!   routed to it by [`Workload::restrict`] with relative event order
//!   preserved, so each cell's `(due_ms, seq)` contract is exactly what
//!   a dedicated control plane would see.
//! * [`ShardedControlPlane::run_workload`] executes the cells on
//!   `cfg.shards` worker threads (`std::thread::scope`; cells are
//!   assigned round-robin to workers) and merges the per-cell
//!   [`RunReport`]s **in ascending cell order** via [`RunReport::merge`].
//!
//! ## The determinism contract
//!
//! The merged report is a function of the *partition layout only*.
//! `shards` picks how many threads drain the cells; it never changes
//! which cells exist, what events they see, or the order reports merge
//! in — so `--shards 1`, `--shards 2` and `--shards 4` emit
//! byte-identical reports (the CI determinism matrix pins this), and a
//! crashed-and-retried run reproduces exactly.  Three properties carry
//! the proof obligation:
//!
//! 1. **cell isolation** — cells share no mutable state.  The one shared
//!    object, the predictor, is `&self`-pure; even its inference
//!    *accounting* is returned by value from each sweep
//!    (`capacity::compute_capacity_counted`) rather than read off the
//!    shared atomic counters, which parallel cells bump concurrently;
//! 2. **per-cell determinism** — each cell replays bit-identically for
//!    its seed (the engine's `(due_ms, seq)` contract, PR 3/4);
//! 3. **pinned merge order** — reports fold in cell order 0..P with the
//!    exactly-associative algebra of [`RunReport::merge`].
//!
//! Semantically a partitioned run is a *different* (coarser-grained)
//! system than the single shared cluster: functions in different cells
//! never colocate, so cross-cell interference is zero by construction —
//! the paper's per-region deployment story, where each region's control
//! plane schedules onto its own nodes.  That is why the reference for
//! the byte-identity matrix is the 1-**shard** run of the same
//! partitioned layout, not the unpartitioned control plane (which
//! `partitions = 1` reproduces exactly — pinned by a test below).

use crate::catalog::Catalog;
use crate::config::RunConfig;
use crate::runtime::Predictor;
use crate::sim::{RunReport, Simulation};
use crate::traces::{TraceSet, Workload};
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

/// Multiplier deriving a cell's seed from the run seed (splitmix64's
/// golden-ratio increment): cell 0 keeps the run seed unchanged — which
/// makes the 1-partition layout bit-equal to the unsharded control plane
/// — while every other cell gets a well-separated stream.
const CELL_SEED_MULT: u64 = 0x9e37_79b9_7f4a_7c15;

/// Deterministic seed of one cell; depends only on (run seed, cell).
pub fn cell_seed(seed: u64, cell: usize) -> u64 {
    seed ^ (cell as u64).wrapping_mul(CELL_SEED_MULT)
}

/// Typed rejection of a layout that would hand a cell an empty
/// sub-cluster.  `ShardLayout::new` clamps `partitions` so every cell
/// owns at least one function and one node *when the cluster has any
/// nodes at all* — but `n_nodes == 0` slips through the clamp (the cap
/// is `max(1)`-ed to keep one cell) and would feed `n_nodes = 0` to the
/// cell's `Simulation`, which cannot place anything.  The orchestrators
/// refuse to run such a layout and surface this error; it implements
/// [`std::error::Error`], so it converts into `anyhow::Error` via `?`
/// and stays readable in the chain's root cause.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ZeroNodeCell {
    /// The first cell whose node allotment is zero.
    pub cell: usize,
}

impl std::fmt::Display for ZeroNodeCell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cell {} owns zero nodes: the cluster needs at least one node per cell",
            self.cell
        )
    }
}

impl std::error::Error for ZeroNodeCell {}

/// The deterministic partition layout: which functions and how many
/// nodes each cell owns.  Built from `(n_functions, n_nodes,
/// partitions)` alone — never from the shard/thread count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardLayout {
    partitions: usize,
    n_functions: usize,
    /// Per-cell node allotment (proportional split of `n_nodes`).
    node_share: Vec<usize>,
}

impl ShardLayout {
    /// Build the layout.  `partitions` is clamped into
    /// `1..=min(n_functions, n_nodes)` so every cell owns at least one
    /// function and one node.
    pub fn new(n_functions: usize, n_nodes: usize, partitions: usize) -> Self {
        let cap = n_functions.min(n_nodes);
        let p = partitions.clamp(1, cap.max(1));
        let node_share = (0..p).map(|i| n_nodes / p + usize::from(i < n_nodes % p)).collect();
        Self { partitions: p, n_functions, node_share }
    }

    /// Number of cells (after clamping).
    pub fn partitions(&self) -> usize {
        self.partitions
    }

    /// The cell owning `function` (round-robin by id).
    pub fn cell_of(&self, function: usize) -> usize {
        function % self.partitions
    }

    /// Starting node count of `cell`'s sub-cluster.
    pub fn nodes_of(&self, cell: usize) -> usize {
        self.node_share[cell]
    }

    /// The (global) function ids `cell` owns, ascending.
    pub fn functions_of(&self, cell: usize) -> Vec<usize> {
        (cell..self.n_functions).step_by(self.partitions).collect()
    }

    /// Reject a layout with a zero-node cell (only reachable with
    /// `n_nodes == 0`; the constructor's clamp guarantees every cell at
    /// least one node otherwise).
    pub fn validate(&self) -> Result<(), ZeroNodeCell> {
        match self.node_share.iter().position(|&n| n == 0) {
            Some(cell) => Err(ZeroNodeCell { cell }),
            None => Ok(()),
        }
    }
}

/// The sharded orchestrator: partitions a workload across independent
/// control-plane cells, drains them on parallel threads, and merges the
/// per-cell reports deterministically (see the module docs).
pub struct ShardedControlPlane {
    cat: Catalog,
    cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
    layout: ShardLayout,
}

impl ShardedControlPlane {
    /// Build the orchestrator, rejecting any layout with a zero-node
    /// cell (the [`ZeroNodeCell`] typed error — in practice
    /// `cfg.n_nodes == 0`, which the layout clamp alone does not catch).
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Result<Self> {
        let layout = ShardLayout::new(cat.len(), cfg.n_nodes, cfg.partitions);
        layout.validate()?;
        Ok(Self { cat, cfg, predictor, layout })
    }

    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// The plain-control-plane configuration `cell` runs with: its node
    /// allotment, its derived seed, sharding itself switched off.
    ///
    /// The arrival seed is pinned to the *run-level* value
    /// ([`crate::sim::effective_arrival_seed`]) rather than derived from
    /// the cell seed: per-invocation synthesis is per-function
    /// (`Workload::synthesize_arrivals_counted` seeds an independent RNG
    /// per function id), so with a shared arrival seed every cell thins
    /// exactly the sub-stream of the unsharded arrival stream its
    /// functions own — and per-cell `arrivals_dropped` counters sum to
    /// the unsharded count under any partition layout.
    pub fn cell_config(&self, cell: usize) -> RunConfig {
        let mut cfg = self.cfg.clone();
        cfg.n_nodes = self.layout.nodes_of(cell);
        cfg.seed = cell_seed(self.cfg.seed, cell);
        cfg.arrival_seed = Some(crate::sim::effective_arrival_seed(&self.cfg));
        cfg.shards = 0;
        cfg.partitions = 1;
        cfg
    }

    /// Run a per-second trace (converted to its event-stream form).
    pub fn run(&self, trace: &TraceSet) -> Result<RunReport> {
        self.run_workload(&trace.workload())
    }

    /// Partition `workload` across the layout's cells, drain every cell
    /// (on `cfg.shards.max(1)` threads, capped at the cell count), and
    /// merge the per-cell reports in ascending cell order.
    pub fn run_workload(&self, workload: &Workload) -> Result<RunReport> {
        self.layout.validate()?;
        ensure!(
            workload.n_functions == self.cat.len(),
            "workload spans {} functions, catalog has {}",
            workload.n_functions,
            self.cat.len()
        );
        let p = self.layout.partitions();
        let mut cells = Vec::with_capacity(p);
        for c in 0..p {
            let cell_workload = workload.restrict(|f| self.layout.cell_of(f) == c);
            cells.push((self.cell_config(c), cell_workload));
        }
        let threads = self.cfg.shards.clamp(1, p);

        let mut reports: Vec<Option<RunReport>> = (0..p).map(|_| None).collect();
        if threads == 1 {
            for (c, (cfg, wl)) in cells.iter().enumerate() {
                reports[c] = Some(self.run_cell(c, cfg, wl)?);
            }
        } else {
            // Workers take cells round-robin; each returns (cell, result)
            // pairs that land back into the cell-indexed slot, so thread
            // scheduling can never reorder anything the merge sees.
            std::thread::scope(|scope| -> Result<()> {
                let cells = &cells;
                let mut handles = Vec::with_capacity(threads);
                for w in 0..threads {
                    handles.push(scope.spawn(move || -> Vec<(usize, Result<RunReport>)> {
                        let mut worker = Vec::new();
                        let mut c = w;
                        while c < p {
                            let (cfg, wl) = &cells[c];
                            worker.push((c, self.run_cell(c, cfg, wl)));
                            c += threads;
                        }
                        worker
                    }));
                }
                for handle in handles {
                    let worker = handle.join().map_err(|_| anyhow!("shard worker panicked"))?;
                    for (c, report) in worker {
                        reports[c] = Some(report?);
                    }
                }
                Ok(())
            })?;
        }

        // pinned merge order: ascending cell index
        let mut iter = reports.into_iter().map(|r| r.expect("every cell ran"));
        let mut merged = iter.next().expect("layout has at least one cell");
        for report in iter {
            merged.merge(&report)?;
        }
        Ok(merged)
    }

    /// One cell = one plain simulation over the full catalog with the
    /// cell's sub-workload, node allotment and seed.  The fresh report
    /// claims ownership of the whole catalog; overwrite it with the
    /// cell's actual slice so the merge's disjointness check holds.
    fn run_cell(&self, cell: usize, cfg: &RunConfig, workload: &Workload) -> Result<RunReport> {
        let mut report = Simulation::new(self.cat.clone(), cfg.clone(), self.predictor.clone())
            .run_workload(workload)?;
        report.owned_functions = self.layout.functions_of(cell);
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};
    use crate::traces::PoissonParams;

    fn stub_predictor() -> Arc<dyn Predictor> {
        Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
            crate::model::N_FEATURES,
            0.05,
            0.05,
        )))
    }

    fn base_cfg() -> RunConfig {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 6;
        cfg.duration_s = 8;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.partitions = 2;
        cfg
    }

    fn test_workload(cat: &Catalog) -> Workload {
        Workload::poisson(cat, &PoissonParams { duration_s: 8, ..Default::default() }, 33)
    }

    fn run_with_shards(shards: usize) -> RunReport {
        let cat = test_catalog();
        let mut cfg = base_cfg();
        cfg.shards = shards;
        let wl = test_workload(&cat);
        ShardedControlPlane::new(cat, cfg, stub_predictor()).unwrap().run_workload(&wl).unwrap()
    }

    #[test]
    fn layout_partitions_functions_and_nodes_exactly() {
        let l = ShardLayout::new(5, 7, 3);
        assert_eq!(l.partitions(), 3);
        assert_eq!(l.functions_of(0), vec![0, 3]);
        assert_eq!(l.functions_of(1), vec![1, 4]);
        assert_eq!(l.functions_of(2), vec![2]);
        // 7 nodes over 3 cells: 3 + 2 + 2
        assert_eq!((0..3).map(|c| l.nodes_of(c)).collect::<Vec<_>>(), vec![3, 2, 2]);
        // every function owned by exactly its cell
        for f in 0..5 {
            assert!(l.functions_of(l.cell_of(f)).contains(&f));
        }
        // clamping: never more cells than functions or nodes, never zero
        assert_eq!(ShardLayout::new(2, 64, 8).partitions(), 2);
        assert_eq!(ShardLayout::new(64, 3, 8).partitions(), 3);
        assert_eq!(ShardLayout::new(4, 4, 0).partitions(), 1);
    }

    #[test]
    fn cell_seeds_derive_deterministically_and_cell0_keeps_run_seed() {
        assert_eq!(cell_seed(42, 0), 42);
        assert_ne!(cell_seed(42, 1), 42);
        assert_ne!(cell_seed(42, 1), cell_seed(42, 2));
        assert_eq!(cell_seed(42, 3), cell_seed(42, 3));
    }

    /// The tentpole invariant: the merged report is a function of the
    /// partition layout only — every worker-thread count produces the
    /// same bytes (asserted through the full `PartialEq` surface,
    /// histogram and raw sample vectors included).
    #[test]
    fn shard_count_never_changes_the_merged_report() {
        let reference = run_with_shards(1);
        assert!(reference.requests_served > 0, "scenario must route traffic");
        assert!(reference.instances_started > 0);
        for shards in [2, 3, 4] {
            let parallel = run_with_shards(shards);
            assert_eq!(
                reference,
                parallel,
                "{shards} worker threads must merge to the 1-thread bytes"
            );
        }
    }

    /// A 1-partition layout is the unsharded control plane, exactly:
    /// cell 0 keeps the run seed, owns every node and every event, and a
    /// single-report merge path is the identity.
    #[test]
    fn single_partition_layout_equals_plain_simulation() {
        let cat = test_catalog();
        let mut cfg = base_cfg();
        cfg.partitions = 1;
        cfg.shards = 1;
        let wl = test_workload(&cat);
        let sharded = ShardedControlPlane::new(cat.clone(), cfg.clone(), stub_predictor())
            .unwrap()
            .run_workload(&wl)
            .unwrap();
        cfg.shards = 0;
        let plain = Simulation::new(cat, cfg, stub_predictor()).run_workload(&wl).unwrap();
        assert_eq!(sharded, plain);
    }

    /// Cells never colocate foreign functions: each cell's per-function
    /// request counts live entirely inside its owned id set.
    #[test]
    fn cells_only_serve_their_own_functions() {
        let cat = test_catalog();
        let cfg = base_cfg();
        let wl = test_workload(&cat);
        let cp = ShardedControlPlane::new(cat, cfg, stub_predictor()).unwrap();
        let layout = cp.layout().clone();
        for cell in 0..layout.partitions() {
            let cell_wl = wl.restrict(|f| layout.cell_of(f) == cell);
            let report = cp.run_cell(cell, &cp.cell_config(cell), &cell_wl).unwrap();
            assert_eq!(report.owned_functions, layout.functions_of(cell));
            for (f, count) in report.request_counts.iter().enumerate() {
                if layout.cell_of(f) != cell {
                    assert_eq!(*count, 0, "cell {cell} served foreign function {f}");
                }
            }
        }
    }

    #[test]
    fn mismatched_workload_is_rejected() {
        let cat = test_catalog();
        let cp = ShardedControlPlane::new(cat, base_cfg(), stub_predictor()).unwrap();
        let wl = Workload {
            name: "wrong-arity".into(),
            n_functions: 1,
            events: Vec::new(),
            duration_ms: 1000.0,
        };
        assert!(cp.run_workload(&wl).is_err());
    }

    /// Regression: `ShardLayout::new(_, 0, _)` emits a zero-node cell
    /// (`node_share = [0]`) that the clamp does not catch; the
    /// orchestrator must refuse to build on it with the typed
    /// [`ZeroNodeCell`] error rather than hand `Simulation` an empty
    /// cluster.  Fails on the pre-fix code, where `new` was infallible.
    #[test]
    fn zero_node_cluster_is_rejected_with_typed_error() {
        let layout = ShardLayout::new(4, 0, 2);
        assert_eq!(layout.validate(), Err(ZeroNodeCell { cell: 0 }));
        assert!(ShardLayout::new(4, 3, 2).validate().is_ok());

        let mut cfg = base_cfg();
        cfg.n_nodes = 0;
        let err = ShardedControlPlane::new(test_catalog(), cfg, stub_predictor())
            .map(|_| ())
            .unwrap_err();
        assert_eq!(err.root_cause(), ZeroNodeCell { cell: 0 }.to_string());
    }
}

//! The steppable control-plane engine: cluster + router + scheduler +
//! autoscaler + deferred-work queue behind one `step` call.
//!
//! [`ControlPlane::step`] drives one tick of virtual time:
//!
//! 1. **deferred-work drain** — asynchronous capacity refreshes whose
//!    virtual completion time has arrived land in the scheduler's tables
//!    ([`Scheduler::complete_deferred`]); anything submitted later this
//!    tick stays invisible, so fast-path decisions genuinely race the
//!    update exactly as §4.3 describes,
//! 2. **cold-start completion** — due instances flip Starting → Saturated
//!    and join the routing set,
//! 3. **autoscaler + commit** — dual-staged scaling plans scale-ups
//!    through [`Scheduler::schedule`] and commits the
//!    [`Plan`](crate::scheduler::Plan)s; the refreshes the scheduler
//!    submits are queued here with a due time of `now + measured async
//!    nanos` in *virtual* time,
//! 4. **QoS measurement** — per (node, function) window latencies from
//!    the ground-truth interference model (plus noise), and on monitor
//!    ticks the §6 accuracy verdicts reach the scheduler as
//!    [`SchedulerFeedback`].
//!
//! Each step emits a [`TickEvents`] record; `sim::Simulation::run` is a
//! thin fold of those records into a report, and step-driven callers
//! (examples, what-if tools) can feed back into the next tick's loads —
//! something a closed run loop cannot express.
//!
//! **Determinism**: the virtual completion delay of deferred work is the
//! *measured* wall-clock cost, clamped to [`MAX_ASYNC_COMPLETION_MS`]
//! (just under the simulator's 1 s tick).  Under whole-second ticks every
//! refresh therefore lands exactly one tick after submission no matter
//! how the wall clock jitters, which keeps replays bit-identical;
//! finer-grained step drivers observe the real latency.

use crate::autoscaler::Autoscaler;
use crate::catalog::Catalog;
use crate::cluster::{Cluster, InstanceId};
use crate::config::{RunConfig, SchedulerKind};
use crate::interference;
use crate::model::AccuracyMonitor;
use crate::router::Router;
use crate::runtime::Predictor;
use crate::scheduler::{
    CommittedPlan, DeferredUpdate, GsightScheduler, JiaguScheduler, KubernetesScheduler,
    OwlScheduler, Scheduler, SchedulerFeedback,
};
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Upper bound on the virtual completion delay of one asynchronous
/// refresh (ms).  Real refreshes cost well under a tick; the clamp only
/// stops a pathological wall-clock stall from pushing a completion across
/// extra tick boundaries and breaking seeded-replay determinism.
pub const MAX_ASYNC_COMPLETION_MS: f64 = 999.0;

/// §6 online accuracy monitoring cadence (ticks between comparisons).
const MONITOR_EVERY: usize = 30;

/// One QoS measurement window: `requests` of `function` observed at
/// `measured_ms` (the consumer judges them against the QoS bound).
#[derive(Debug, Clone, Copy)]
pub struct QosWindow {
    pub function: usize,
    pub requests: f64,
    pub measured_ms: f64,
}

/// Everything one control-plane tick did, for the caller to fold into
/// reports (or react to before the next step).
#[derive(Debug, Default)]
pub struct TickEvents {
    pub now_ms: f64,
    /// Instances whose cold start completed this tick.
    pub cold_starts_completed: u32,
    /// Scheduling plans committed this tick.
    pub scheduled: Vec<CommittedPlan>,
    pub logical_cold_starts: u32,
    pub real_after_release: u32,
    pub migrations: u32,
    pub released: u32,
    pub evicted: u32,
    pub evicted_direct: u32,
    /// Asynchronous refreshes submitted / landed this tick.
    pub deferred_submitted: u32,
    pub deferred_completed: u32,
    /// Off-critical-path cost of the refreshes submitted this tick.
    pub async_nanos: u64,
    pub async_inferences: u64,
    /// QoS measurement windows of this tick.
    pub qos: Vec<QosWindow>,
    /// Deployed instances (any state) at tick end.
    pub instances: usize,
    /// Nodes hosting at least one instance at tick end.
    pub active_nodes: usize,
    /// Cluster size at tick end.
    pub n_nodes: usize,
}

/// Build the scheduler a run configuration asks for.
pub fn make_scheduler(cfg: &RunConfig, predictor: &Arc<dyn Predictor>) -> Box<dyn Scheduler> {
    match cfg.scheduler {
        SchedulerKind::Jiagu => Box::new(JiaguScheduler::new(
            predictor.clone(),
            cfg.capacity.clone(),
            cfg.n_nodes,
        )),
        SchedulerKind::Kubernetes => Box::new(KubernetesScheduler::new()),
        SchedulerKind::Gsight => Box::new(GsightScheduler::new(predictor.clone())),
        SchedulerKind::Owl => Box::new(OwlScheduler::new(cfg.seed ^ 0x071)),
    }
}

/// The reusable engine: owns all control-plane state and advances it one
/// `step` at a time.
pub struct ControlPlane {
    cat: Catalog,
    cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
    cluster: Cluster,
    router: Router,
    sched: Box<dyn Scheduler>,
    autoscaler: Autoscaler,
    monitor: AccuracyMonitor,
    rng: Rng,
    /// (ready_ms, instance) cold starts in flight.
    pending: Vec<(f64, InstanceId)>,
    /// (due_ms, update) asynchronous refreshes in flight, submission
    /// order.
    deferred: Vec<(f64, DeferredUpdate)>,
    init_ms: f64,
    ticks: usize,
}

impl ControlPlane {
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Self {
        let sched = make_scheduler(&cfg, &predictor);
        let n_functions = cat.len();
        let init_ms = cfg.init_model.latency_ms();
        Self {
            cluster: Cluster::new(cfg.n_nodes),
            router: Router::new(),
            autoscaler: Autoscaler::new(cfg.autoscaler.clone(), n_functions),
            monitor: AccuracyMonitor::new(n_functions),
            rng: Rng::seed_from(cfg.seed),
            pending: Vec::new(),
            deferred: Vec::new(),
            init_ms,
            ticks: 0,
            sched,
            predictor,
            cat,
            cfg,
        }
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn scheduler(&self) -> &dyn Scheduler {
        self.sched.as_ref()
    }

    pub fn scheduler_name(&self) -> &'static str {
        self.sched.name()
    }

    pub fn monitor(&self) -> &AccuracyMonitor {
        &self.monitor
    }

    /// Asynchronous refreshes submitted but not yet landed.
    pub fn deferred_in_flight(&self) -> usize {
        self.deferred.len()
    }

    /// Cold starts still in flight.
    pub fn cold_starts_in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Land every deferred refresh due by `now_ms`, in submission order.
    fn drain_deferred(&mut self, now_ms: f64) -> u32 {
        let mut completed = 0u32;
        let (due, rest): (Vec<_>, Vec<_>) = std::mem::take(&mut self.deferred)
            .into_iter()
            .partition(|(due_ms, _)| *due_ms <= now_ms);
        self.deferred = rest;
        for (_, update) in due {
            self.sched.complete_deferred(update);
            completed += 1;
        }
        completed
    }

    /// Advance one tick of virtual time under the offered `loads` (RPS
    /// per function).  `now_ms` must be monotonically non-decreasing
    /// across calls.
    pub fn step(&mut self, now_ms: f64, loads: &[f64]) -> Result<TickEvents> {
        let mut ev = TickEvents { now_ms, ..Default::default() };

        // 1. asynchronous refreshes whose virtual completion time arrived
        ev.deferred_completed = self.drain_deferred(now_ms);

        // 2. complete due cold starts
        let mut pending = std::mem::take(&mut self.pending);
        pending.retain(|(ready_ms, id)| {
            if *ready_ms <= now_ms {
                if let Some(inst) = self.cluster.instance(*id) {
                    let f = inst.function;
                    self.cluster.mark_ready(*id, now_ms);
                    self.router.add(f, *id);
                    ev.cold_starts_completed += 1;
                }
                false
            } else {
                true
            }
        });
        self.pending = pending;

        // 3. autoscaler tick: plans are committed, refreshes submitted
        let outcome = self.autoscaler.tick(
            &self.cat,
            &mut self.cluster,
            &mut self.router,
            self.sched.as_mut(),
            loads,
            now_ms,
        )?;
        ev.logical_cold_starts = outcome.logical_cold_starts;
        ev.real_after_release = outcome.real_after_release;
        ev.migrations = outcome.migrations;
        ev.released = outcome.released;
        ev.evicted = outcome.evicted;
        ev.evicted_direct = outcome.evicted_direct;
        for committed in &outcome.scheduled {
            let ready_ms =
                now_ms + committed.plan.decision_nanos as f64 / 1e6 + self.init_ms;
            for p in &committed.placements {
                self.pending.push((ready_ms, p.instance));
            }
        }
        ev.scheduled = outcome.scheduled;
        for update in outcome.deferred {
            ev.deferred_submitted += 1;
            ev.async_nanos += update.nanos;
            ev.async_inferences += update.inferences;
            let delay_ms =
                (update.nanos.max(1) as f64 / 1e6).min(MAX_ASYNC_COMPLETION_MS);
            // a pending refresh for the same node is superseded (versions
            // are monotone per node): it would be discarded on landing
            // anyway, so drop it at submission — its cost is already
            // accounted above, and at most one update per node stays
            // queued
            self.deferred.retain(|(_, u)| u.node != update.node);
            self.deferred.push((now_ms + delay_ms, update));
        }

        // 4. QoS measurement per (node, function) window; on monitor
        // ticks, feed §6 accuracy verdicts back to the scheduler
        let monitor_tick = self.ticks % MONITOR_EVERY == MONITOR_EVERY - 1;
        for node in 0..self.cluster.n_nodes() {
            let mix = self.cluster.mix(node);
            if mix.is_empty() {
                continue;
            }
            for (f, sat, _) in &mix.entries {
                if *sat == 0 {
                    continue;
                }
                let truth = interference::ground_truth_latency(&self.cat, &mix, *f);
                let measured =
                    truth * (1.0 + self.rng.normal_ms(0.0, self.cfg.measurement_noise));
                // requests this window ≈ serving share of the live load
                let serving_total = self.router.serving_count(*f).max(1) as f64;
                let requests = loads[*f] * (*sat as f64 / serving_total).min(1.0);
                if requests > 0.0 {
                    ev.qos.push(QosWindow { function: *f, requests, measured_ms: measured });
                }
                if monitor_tick {
                    let row = crate::model::feature_row(&self.cat, &mix, *f);
                    if let Ok(pred) = self.predictor.predict(std::slice::from_ref(&row)) {
                        self.monitor.record(*f, pred[0] as f64, measured);
                    }
                }
            }
        }
        if monitor_tick {
            for f in 0..self.cat.len() {
                self.sched.apply_feedback(SchedulerFeedback::Unpredictability {
                    function: f,
                    isolated: self.monitor.is_unpredictable(f),
                });
            }
        }

        // 5. tick-end bookkeeping
        ev.instances = self.cluster.instances_len();
        ev.active_nodes = (0..self.cluster.n_nodes())
            .filter(|n| !self.cluster.node_empty(*n))
            .count();
        ev.n_nodes = self.cluster.n_nodes();
        self.ticks += 1;
        Ok(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};

    fn plane() -> ControlPlane {
        let cat = test_catalog();
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 4;
        let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
            ForestParams::synthetic_stub(crate::model::N_FEATURES, 0.05, 0.05),
        ));
        ControlPlane::new(cat, cfg, predictor)
    }

    #[test]
    fn step_commits_plans_and_defers_refreshes_one_tick() {
        let cat = test_catalog();
        let mut loads = vec![0.0; cat.len()];
        loads[0] = 5.0 * cat.get(0).saturated_rps;
        let mut cp = plane();
        let ev = cp.step(0.0, &loads).unwrap();
        assert!(!ev.scheduled.is_empty(), "scale-up from zero must schedule");
        assert!(ev.deferred_submitted > 0, "placements submit refreshes");
        assert_eq!(ev.deferred_completed, 0, "nothing lands within its tick");
        assert_eq!(cp.deferred_in_flight() as u32, ev.deferred_submitted);
        let ev2 = cp.step(1000.0, &loads).unwrap();
        assert_eq!(ev2.deferred_completed, ev.deferred_submitted, "lands next tick");
        assert!(ev2.cold_starts_completed > 0, "instances become ready");
    }

    #[test]
    fn idle_steps_do_nothing() {
        let mut cp = plane();
        let loads = vec![0.0; test_catalog().len()];
        let ev = cp.step(0.0, &loads).unwrap();
        assert!(ev.scheduled.is_empty());
        assert_eq!(ev.instances, 0);
        assert_eq!(cp.cold_starts_in_flight(), 0);
    }
}

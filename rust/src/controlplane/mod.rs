//! The event-driven control-plane core: cluster + router + scheduler +
//! autoscaler behind one deterministic [`Timeline`] queue.
//!
//! The old engine quantized everything to 1 s ticks: cold starts
//! completed at the next tick boundary, asynchronous refreshes were
//! clamped under one tick, and sub-second load could not be expressed.
//! [`ControlPlane`] is now a handler over typed [`Event`]s popped in
//! `(due_ms, seq)` order (see [`crate::engine`] for the determinism
//! contract):
//!
//! * [`Event::LoadChange`] — a [`crate::traces::Workload`] step lands:
//!   one function's offered RPS changes at millisecond resolution,
//! * [`Event::RequestArrival`] — one request is routed
//!   ([`Router::route`]): it is admitted by an idle serving instance,
//!   joins a busy instance's FIFO queue, or parks on the function's
//!   cold-wait queue when nothing serves it yet; per-request latency
//!   (cold-start wait + queueing + dispatch overhead + the interference
//!   model's latency under the instance's *current* node mix) is
//!   attributed at admission,
//! * [`Event::RequestComplete`] — the request admitted on an instance
//!   releases its service slot — one saturated-rate interval stretched
//!   by the interference slowdown, so per-instance throughput matches
//!   what the capacity model provisions — and the head of its FIFO
//!   queue is admitted at this exact instant,
//! * [`Event::ColdStartComplete`] — an instance flips Starting →
//!   Saturated and joins the routing set at *exactly* its
//!   `sched_cost + init_ms` due time (mid-tick, not rounded up); any
//!   cold-waiting requests of the function are drained onto the routing
//!   set at the same instant,
//! * [`Event::DeferredUpdateDue`] — a §4.3 capacity refresh lands in the
//!   scheduler's tables ([`Scheduler::complete_deferred`]); until then
//!   every fast-path decision genuinely reads the stale table,
//! * [`Event::AutoscalerEval`] — dual-staged scaling plans + commits
//!   through the [`Plan`](crate::scheduler::Plan) API, every
//!   `eval_interval_ms` of virtual time,
//! * [`Event::MonitorTick`] — per-(node, function) QoS windows each
//!   second; every 30th tick the §6 accuracy verdicts reach the
//!   scheduler as [`SchedulerFeedback`].
//!
//! **Why the wall-clock clamp is gone.**  The old loop landed deferred
//! refreshes at `now + measured nanos`, clamped to just under one tick
//! (`MAX_ASYNC_COMPLETION_MS`) so wall-clock jitter could not move a
//! completion across tick boundaries between replays.  Due times now
//! come from the *modelled* [`CostModel`](crate::config::CostModel) —
//! `refresh_base + inferences × per-inference nanos` for refreshes,
//! `decision_base + critical_inferences × per-inference nanos` for
//! decisions — which depends only on deterministic inference counts.
//! Replays are bit-identical without any quantization, and refreshes
//! land at their real sub-millisecond delays instead of a whole tick
//! later.  Measured wall-clock nanos remain on
//! [`DeferredUpdate`]/`Plan` for observability; they never steer
//! virtual time.
//!
//! Drains cost `O(log n)` per event on the reference binary heap and
//! `O(1)` amortised on the timing wheel (`cfg.queue` selects the
//! [`Timeline`] implementation) — the per-tick `Vec::retain` and
//! partition scans of the old loop are gone either way.
//!
//! [`ControlPlane::run_until`] drains the queue to a horizon and returns
//! the accumulated [`EngineEvents`]; `sim::Simulation` folds that into a
//! `RunReport`.  [`ControlPlane::step`] keeps the closed-loop driver
//! API: set the offered loads directly, then drain inclusively up to
//! `now_ms`.
//!
//! One control plane is one thread; the [`shard`] module scales past
//! that by partitioning functions and nodes into independent cells, each
//! a plain `ControlPlane` over its own event sub-stream, drained on
//! parallel threads and merged into one report — byte-identical for any
//! thread count.

pub mod region;
pub mod shard;

use crate::autoscaler::Autoscaler;
use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, InstanceId, InstanceState, NodeId};
use crate::config::{RunConfig, SchedulerKind};
use crate::engine::{AnyTimeline, Event, Timeline};
use crate::interference;
use crate::model::AccuracyMonitor;
use crate::router::{RouteOutcome, Router};
use crate::runtime::Predictor;
use crate::scheduler::{
    CommittedPlan, DeferredUpdate, GsightScheduler, JiaguScheduler, KubernetesScheduler,
    OwlScheduler, Scheduler, SchedulerFeedback,
};
use crate::traces::{Arrival, Workload};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// QoS measurement / utilisation sampling cadence (virtual ms).
pub const MONITOR_INTERVAL_MS: f64 = 1000.0;

/// §6 online accuracy monitoring cadence (monitor ticks between
/// prediction-vs-measurement comparisons).
const MONITOR_EVERY: usize = 30;

/// One QoS measurement window: `requests` of `function` observed at
/// `measured_ms` (the consumer judges them against the QoS bound).
#[derive(Debug, Clone, Copy)]
pub struct QosWindow {
    pub function: usize,
    pub requests: f64,
    pub measured_ms: f64,
}

/// One utilisation sample taken at a monitor tick (density accounting).
#[derive(Debug, Clone, Copy)]
pub struct UtilizationSample {
    pub at_ms: f64,
    /// Deployed instances (any state).
    pub instances: usize,
    /// Nodes hosting at least one instance.
    pub active_nodes: usize,
    /// Cluster size.
    pub n_nodes: usize,
    /// Requests in flight cluster-wide (per-request model; 0 otherwise).
    pub in_flight: u32,
}

/// One routed request's QoS attribution: total latency = cold-start wait
/// + queueing delay + service time, recorded at service start (service
/// time is deterministic once started, so this equals completion-time
/// attribution).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub function: FunctionId,
    pub latency_ms: f64,
}

/// Everything a drain of the event queue did, for the caller to fold
/// into reports (or react to before the next drain).
#[derive(Debug, Default)]
pub struct EngineEvents {
    /// Horizon the drain ran to.
    pub now_ms: f64,
    /// Events popped and handled.
    pub events_processed: u64,
    /// Instances whose cold start completed.
    pub cold_starts_completed: u32,
    /// Request→ready latency (virtual ms) of each completed cold start,
    /// attributed at event resolution: modelled scheduling cost + init.
    pub cold_start_latency_ms: Vec<f64>,
    /// Scheduling plans committed.
    pub scheduled: Vec<CommittedPlan>,
    pub logical_cold_starts: u32,
    pub real_after_release: u32,
    pub migrations: u32,
    pub released: u32,
    pub evicted: u32,
    pub evicted_direct: u32,
    /// Asynchronous refreshes submitted / landed.
    pub deferred_submitted: u32,
    pub deferred_completed: u32,
    /// Modelled off-critical-path cost of the refreshes submitted
    /// (deterministic; see [`crate::config::CostModel`]).
    pub async_nanos: u64,
    pub async_inferences: u64,
    /// Capacity sweeps (critical-path and refresh alike) answered from the
    /// scheduler's mix-signature memo / run because it missed.
    pub memo_hits: u64,
    pub memo_misses: u64,
    /// QoS measurement windows.
    pub qos: Vec<QosWindow>,
    /// Utilisation samples, one per monitor tick in the drain.
    pub samples: Vec<UtilizationSample>,
    /// Per-request latency attributions in this drain (service starts).
    pub requests: Vec<RequestRecord>,
    /// Arrivals whose *first* dispatch found no serving instance and
    /// parked on a cold-wait queue (their latency is attributed once
    /// drained; re-parks after orphan re-dispatch don't re-count).
    pub cold_waits: u64,
    /// Router gauge at drain end: highest per-node in-flight count ever.
    pub peak_node_in_flight: u32,
    /// Router gauge at drain end: requests in flight cluster-wide.
    pub in_flight: u32,
    /// Router gauge at drain end: requests still parked on cold-wait
    /// queues (stranded if the load never returns — see
    /// `RunReport::stranded_requests`).
    pub waiting: u64,
    /// Router gauge at drain end: requests dispatched into instance FIFO
    /// queues but not yet admitted into service.
    pub queued: u64,
    /// Deployed instances (any state) at drain end.
    pub instances: usize,
    /// Nodes hosting at least one instance at drain end.
    pub active_nodes: usize,
    /// Cluster size at drain end.
    pub n_nodes: usize,
    /// Fresh arrivals whose first dispatch could not start service
    /// (parked cold-waiting or queued behind a busy instance), recorded
    /// only under [`RunConfig::collect_overflow`].  The federation layer
    /// reads these as spill candidates for overflow routing; a plain run
    /// never populates the vector, so the hot path stays allocation-free.
    pub overflow_candidates: Vec<Arrival>,
}

/// Build the scheduler a run configuration asks for.
pub fn make_scheduler(cfg: &RunConfig, predictor: &Arc<dyn Predictor>) -> Box<dyn Scheduler> {
    match cfg.scheduler {
        SchedulerKind::Jiagu => Box::new(JiaguScheduler::new(
            predictor.clone(),
            cfg.capacity.clone(),
            cfg.n_nodes,
        )),
        SchedulerKind::Kubernetes => Box::new(KubernetesScheduler::new()),
        SchedulerKind::Gsight => Box::new(GsightScheduler::new(predictor.clone())),
        SchedulerKind::Owl => Box::new(OwlScheduler::new(cfg.seed ^ 0x071)),
    }
}

/// The reusable engine: owns all control-plane state and advances it by
/// draining the deterministic event queue.
pub struct ControlPlane {
    cat: Catalog,
    cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
    cluster: Cluster,
    router: Router,
    sched: Box<dyn Scheduler>,
    autoscaler: Autoscaler,
    monitor: AccuracyMonitor,
    rng: Rng,
    /// The event timeline — heap or wheel per `cfg.queue`; both produce
    /// the same pop stream bit for bit (see [`crate::engine::Timeline`]).
    queue: AnyTimeline,
    /// Latest submitted refresh per node; an older in-flight refresh for
    /// the same node is superseded by overwriting it here (its queued
    /// event then pops as a no-op — versions are monotone per node).
    in_flight: HashMap<NodeId, DeferredUpdate>,
    /// Current offered RPS per function (driven by LoadChange events or
    /// set directly by [`ControlPlane::step`]).
    loads: Vec<f64>,
    now_ms: f64,
    pending_cold_starts: usize,
    monitor_ticks: usize,
    seeded: bool,
    init_ms: f64,
    /// Sanitised copy of `cfg.eval_interval_ms` (finite, >= 1 ms): a
    /// zero/negative interval would re-queue the eval at a due time
    /// never past the drain limit (infinite loop), and NaN would order
    /// after every finite due (autoscaler silently never runs).
    eval_interval_ms: f64,
}

impl ControlPlane {
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Self {
        let sched = make_scheduler(&cfg, &predictor);
        let n_functions = cat.len();
        let init_ms = cfg.init_model.latency_ms();
        let eval_interval_ms = if cfg.eval_interval_ms.is_finite() {
            cfg.eval_interval_ms.max(1.0)
        } else {
            1000.0
        };
        // catalogs reaching the engine passed `Catalog::load` validation
        // (positive finite solo latencies), so policy construction can
        // only fail on a hand-built degenerate catalog — a programming
        // error here, a typed error at the policy layer (see
        // `policy::InvalidDurationEstimate` and its regression test)
        let dispatch = crate::policy::make_dispatch_policy(cfg.dispatch_policy, &cat)
            .expect("dispatch policy rejected the catalog");
        let scaling = crate::policy::make_scaling_policy(cfg.scaling_policy);
        Self {
            cluster: Cluster::new(cfg.n_nodes),
            // the pick stream must differ from every other seeded stream
            // yet derive from the run seed (replica determinism)
            router: Router::with_policy(cfg.seed ^ 0x7e57_0a11, dispatch),
            autoscaler: Autoscaler::with_policy(cfg.autoscaler.clone(), n_functions, scaling),
            monitor: AccuracyMonitor::new(n_functions),
            rng: Rng::seed_from(cfg.seed),
            queue: AnyTimeline::new(cfg.queue),
            in_flight: HashMap::new(),
            loads: vec![0.0; n_functions],
            now_ms: 0.0,
            pending_cold_starts: 0,
            monitor_ticks: 0,
            seeded: false,
            init_ms,
            eval_interval_ms,
            sched,
            predictor,
            cat,
            cfg,
        }
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn scheduler(&self) -> &dyn Scheduler {
        self.sched.as_ref()
    }

    pub fn scheduler_name(&self) -> &'static str {
        self.sched.name()
    }

    pub fn monitor(&self) -> &AccuracyMonitor {
        &self.monitor
    }

    /// Current virtual time (end of the last drain).
    pub fn now_ms(&self) -> f64 {
        self.now_ms
    }

    /// Current offered load per function.
    pub fn loads(&self) -> &[f64] {
        &self.loads
    }

    /// Events currently queued.
    pub fn queued_events(&self) -> usize {
        self.queue.len()
    }

    /// Asynchronous refreshes submitted but not yet landed.
    pub fn deferred_in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Cold starts still in flight.
    pub fn cold_starts_in_flight(&self) -> usize {
        self.pending_cold_starts
    }

    /// Queue a workload's load steps as [`Event::LoadChange`]s.  Call
    /// before the first drain; events sort by `(due_ms, push order)`, so
    /// a load step at time `t` is visible to the autoscaler evaluation
    /// at the same `t`.
    pub fn inject_workload(&mut self, workload: &Workload) {
        let batch: Vec<(f64, Event)> = workload
            .events
            .iter()
            // a non-finite due time would wedge the queue (a negative
            // NaN sorts before every finite due yet never satisfies
            // `due < limit`), so drop malformed events at the door
            .filter(|e| e.function < self.loads.len() && e.at_ms.is_finite())
            .map(|e| (e.at_ms, Event::LoadChange { function: e.function, rps: e.rps }))
            .collect();
        self.queue.extend(batch);
    }

    /// Queue synthesized per-invocation arrivals as
    /// [`Event::RequestArrival`]s.  Call before the first drain, after
    /// [`ControlPlane::inject_workload`]: a load step and an arrival at
    /// the same instant then dispatch in injection order, which the
    /// queue's sequence numbers keep deterministic.
    pub fn inject_arrivals(&mut self, arrivals: &[Arrival]) {
        let batch: Vec<(f64, Event)> = arrivals
            .iter()
            // same door policy as inject_workload: malformed events would
            // wedge or skew the queue, so drop them here
            .filter(|a| a.function < self.loads.len() && a.at_ms.is_finite())
            .map(|a| (a.at_ms, Event::RequestArrival { function: a.function }))
            .collect();
        self.queue.extend(batch);
    }

    /// Seed the self-rescheduling periodic events on first drain (after
    /// any workload injection, so same-instant load steps sort first).
    fn seed(&mut self) {
        if !self.seeded {
            self.seeded = true;
            self.queue.push(self.now_ms, Event::AutoscalerEval);
            self.queue.push(self.now_ms, Event::MonitorTick);
        }
    }

    /// Closed-loop driver API: set the offered loads directly, then
    /// drain every event due **up to and including** `now_ms`.
    /// `now_ms` must be monotonically non-decreasing across calls.
    pub fn step(&mut self, now_ms: f64, loads: &[f64]) -> Result<EngineEvents> {
        debug_assert_eq!(
            loads.len(),
            self.loads.len(),
            "step expects one load per catalog function"
        );
        let n = self.loads.len().min(loads.len());
        self.loads[..n].copy_from_slice(&loads[..n]);
        self.drain(now_ms, true)
    }

    /// Drain every event due **strictly before** `until_ms` — the
    /// half-open window `[now, until)` a simulation horizon covers.
    pub fn run_until(&mut self, until_ms: f64) -> Result<EngineEvents> {
        self.drain(until_ms, false)
    }

    fn drain(&mut self, limit_ms: f64, inclusive: bool) -> Result<EngineEvents> {
        self.seed();
        let mut ev = EngineEvents { now_ms: limit_ms, ..Default::default() };
        while let Some(s) = self.queue.pop_due(limit_ms, inclusive) {
            ev.events_processed += 1;
            self.now_ms = self.now_ms.max(s.due_ms);
            self.dispatch(s.due_ms, s.event, &mut ev)?;
        }
        self.now_ms = self.now_ms.max(limit_ms);
        ev.instances = self.cluster.instances_len();
        ev.active_nodes =
            (0..self.cluster.n_nodes()).filter(|n| !self.cluster.node_empty(*n)).count();
        ev.n_nodes = self.cluster.n_nodes();
        ev.peak_node_in_flight = self.router.peak_node_in_flight();
        ev.in_flight = self.router.total_in_flight();
        ev.waiting = self.router.total_waiting();
        ev.queued = self.router.total_queued();
        Ok(ev)
    }

    /// Handle one event at its exact due time.
    fn dispatch(&mut self, due_ms: f64, event: Event, ev: &mut EngineEvents) -> Result<()> {
        match event {
            Event::LoadChange { function, rps } => {
                if function < self.loads.len() {
                    self.loads[function] = rps;
                }
            }
            Event::RequestArrival { function } => {
                self.route_request(function, due_ms, due_ms, true, ev);
            }
            Event::RequestComplete { instance } => {
                if let Some(next) = self.router.complete(instance) {
                    // the queue head enters service at this exact instant
                    self.begin_service(
                        next.function,
                        instance,
                        next.node,
                        next.arrival_ms,
                        due_ms,
                        ev,
                    );
                }
            }
            Event::ColdStartComplete { instance } => {
                self.pending_cold_starts = self.pending_cold_starts.saturating_sub(1);
                if let Some(inst) = self.cluster.instance(instance) {
                    if inst.state == InstanceState::Starting {
                        let f = inst.function;
                        let node = inst.node;
                        let created = inst.created_ms;
                        self.cluster.mark_ready(instance, due_ms);
                        self.router.add(f, instance, node);
                        ev.cold_starts_completed += 1;
                        ev.cold_start_latency_ms.push(due_ms - created);
                        self.drain_cold_waiters(f, due_ms, ev);
                    }
                }
            }
            Event::DeferredUpdateDue { node, version } => {
                // only the node's latest submitted refresh is live; a
                // superseded event pops as a no-op
                if self.in_flight.get(&node).map(|u| u.version) == Some(version) {
                    let update = self.in_flight.remove(&node).expect("checked above");
                    // locality dispatch reads the refreshed tables: the
                    // node's summed capacity lands as a hint at the same
                    // deterministic virtual time the scheduler sees it
                    let capacity: f64 =
                        update.entries.values().map(|e| f64::from(e.capacity)).sum();
                    self.router.capacity_hint(node, capacity);
                    self.sched.complete_deferred(update);
                    ev.deferred_completed += 1;
                }
            }
            Event::AutoscalerEval => self.autoscaler_eval(due_ms, ev)?,
            Event::MonitorTick => self.monitor_tick(due_ms, ev)?,
        }
        Ok(())
    }

    /// Route one request of `f` that arrived at `arrival_ms` (≤ `now_ms`
    /// for re-dispatched cold-waiters/orphans): admit, queue, or park on
    /// the cold-wait queue.  `fresh` marks a first dispatch — only those
    /// count toward `cold_waits`, so a request re-parked after an orphan
    /// re-dispatch is never double-counted.
    fn route_request(
        &mut self,
        f: FunctionId,
        arrival_ms: f64,
        now_ms: f64,
        fresh: bool,
        ev: &mut EngineEvents,
    ) {
        if f >= self.loads.len() {
            return;
        }
        match self.router.route(f, arrival_ms) {
            RouteOutcome::Started { instance, node } => {
                self.begin_service(f, instance, node, arrival_ms, now_ms, ev);
            }
            RouteOutcome::Queued { .. } => {
                // attributed at admission
                if fresh && self.cfg.collect_overflow {
                    ev.overflow_candidates.push(Arrival { at_ms: arrival_ms, function: f });
                }
            }
            RouteOutcome::ColdWait => {
                if fresh {
                    ev.cold_waits += 1;
                    if self.cfg.collect_overflow {
                        ev.overflow_candidates.push(Arrival { at_ms: arrival_ms, function: f });
                    }
                }
            }
        }
    }

    /// Admit one request into service and attribute its latency.
    ///
    /// The instance is a *pipelined* server: it admits one request per
    /// saturated-rate interval (`1000 / saturated_rps` ms — the
    /// throughput the capacity model provisions against), stretched by
    /// the interference slowdown of the node's *current* mix, plus the
    /// [`CostModel`](crate::config::CostModel) dispatch overhead.  The
    /// attributed latency is the request's *response time*: wait so far
    /// (cold-start wait + queueing) + dispatch overhead + the
    /// interference model's latency.  Attribution happens at admission —
    /// both terms are deterministic from this instant, so this equals
    /// completion-time attribution.
    fn begin_service(
        &mut self,
        f: FunctionId,
        instance: InstanceId,
        node: NodeId,
        arrival_ms: f64,
        now_ms: f64,
        ev: &mut EngineEvents,
    ) {
        let spec = self.cat.get(f);
        let overhead_ms = self.cfg.cost.request_overhead_ms();
        let truth_ms =
            interference::ground_truth_latency(&self.cat, &self.cluster.mix(node), f);
        let latency_ms = (now_ms - arrival_ms).max(0.0) + overhead_ms + truth_ms;
        ev.requests.push(RequestRecord { function: f, latency_ms });
        // slowdown > 1 under colocation pressure: the instance admits
        // slower exactly when its requests run slower
        let slowdown = truth_ms / spec.solo_latency_ms;
        let occupancy_ms = overhead_ms + 1000.0 / spec.saturated_rps * slowdown;
        self.queue.push(now_ms + occupancy_ms, Event::RequestComplete { instance });
    }

    /// Re-dispatch every cold-waiting request of `f` the moment an
    /// instance (re-)joins the routing set; their cold-start wait lands
    /// in the attributed latency.
    fn drain_cold_waiters(&mut self, f: FunctionId, now_ms: f64, ev: &mut EngineEvents) {
        while let Some(arrival_ms) = self.router.pop_waiting(f) {
            self.route_request(f, arrival_ms, now_ms, false, ev);
        }
    }

    /// Dual-staged scaling evaluation: plans are committed, cold starts
    /// scheduled at their modelled `sched_cost + init` due time, and the
    /// scheduler's refreshes queued at their modelled completion delay.
    fn autoscaler_eval(&mut self, now_ms: f64, ev: &mut EngineEvents) -> Result<()> {
        let outcome = self.autoscaler.tick(
            &self.cat,
            &mut self.cluster,
            &mut self.router,
            self.sched.as_mut(),
            &self.loads,
            now_ms,
        )?;
        ev.logical_cold_starts += outcome.logical_cold_starts;
        ev.real_after_release += outcome.real_after_release;
        ev.migrations += outcome.migrations;
        ev.released += outcome.released;
        ev.evicted += outcome.evicted;
        ev.evicted_direct += outcome.evicted_direct;
        for committed in &outcome.scheduled {
            let ready_ms = now_ms
                + self.cfg.cost.decision_ms(committed.plan.critical_inferences)
                + self.init_ms;
            for p in &committed.placements {
                self.queue.push(ready_ms, Event::ColdStartComplete { instance: p.instance });
                self.pending_cold_starts += 1;
            }
        }
        ev.scheduled.extend(outcome.scheduled);
        for update in outcome.deferred {
            ev.deferred_submitted += 1;
            ev.async_inferences += update.inferences;
            ev.memo_hits += update.memo_hits;
            ev.memo_misses += update.memo_misses;
            let cost_ns = self.cfg.cost.refresh_ns(update.inferences);
            ev.async_nanos += cost_ns;
            self.queue.push(
                now_ms + cost_ns as f64 / 1e6,
                Event::DeferredUpdateDue { node: update.node, version: update.version },
            );
            // overwriting supersedes any refresh still in flight for the
            // node: versions are monotone, the old one would be dropped
            // on landing anyway, and its cost is already accounted
            self.in_flight.insert(update.node, update);
        }
        // per-request model: re-dispatch requests orphaned by this eval's
        // releases/evictions (cold-wait if nothing serves them any more),
        // then drain cold-waiters of functions that regained capacity via
        // logical cold starts (real cold starts drain on completion)
        for (f, arrival_ms) in outcome.orphaned {
            self.route_request(f, arrival_ms, now_ms, false, ev);
        }
        for f in 0..self.loads.len() {
            if self.router.serving_count(f) > 0 && self.router.waiting_count(f) > 0 {
                self.drain_cold_waiters(f, now_ms, ev);
            }
        }
        self.queue.push(now_ms + self.eval_interval_ms, Event::AutoscalerEval);
        Ok(())
    }

    /// QoS measurement per (node, function) window; every
    /// [`MONITOR_EVERY`]-th tick, feed §6 accuracy verdicts back to the
    /// scheduler.  Also takes the utilisation sample density folds over.
    fn monitor_tick(&mut self, now_ms: f64, ev: &mut EngineEvents) -> Result<()> {
        let accuracy_tick = self.monitor_ticks % MONITOR_EVERY == MONITOR_EVERY - 1;
        self.monitor_ticks += 1;
        // single-row batch reused across every accuracy probe in the tick
        let mut probe = crate::model::FeatureMatrix::with_capacity(crate::model::N_FEATURES, 1);
        for node in 0..self.cluster.n_nodes() {
            let mix = self.cluster.mix(node);
            if mix.is_empty() {
                continue;
            }
            for (f, sat, _) in &mix.entries {
                if *sat == 0 {
                    continue;
                }
                let truth = interference::ground_truth_latency(&self.cat, &mix, *f);
                let measured =
                    truth * (1.0 + self.rng.normal_ms(0.0, self.cfg.measurement_noise));
                // requests this window ≈ serving share of the live load
                let serving_total = self.router.serving_count(*f).max(1) as f64;
                let requests = self.loads[*f] * (*sat as f64 / serving_total).min(1.0);
                if requests > 0.0 {
                    ev.qos.push(QosWindow { function: *f, requests, measured_ms: measured });
                    // feed the scaling policy the same verdict the report
                    // builder applies downstream; consumes no RNG
                    let violated = measured > self.cat.get(*f).qos_latency_ms;
                    self.autoscaler.observe_qos(*f, violated, now_ms);
                }
                if accuracy_tick {
                    probe.clear();
                    crate::model::FeatureBuilder::new(&self.cat, &mix)
                        .row_into_matrix(*f, &mut probe);
                    if let Ok(pred) = self.predictor.predict_batch(&probe) {
                        self.monitor.record(*f, pred[0] as f64, measured);
                    }
                }
            }
        }
        if accuracy_tick {
            for f in 0..self.cat.len() {
                self.sched.apply_feedback(SchedulerFeedback::Unpredictability {
                    function: f,
                    isolated: self.monitor.is_unpredictable(f),
                });
            }
        }
        ev.samples.push(UtilizationSample {
            at_ms: now_ms,
            instances: self.cluster.instances_len(),
            active_nodes: (0..self.cluster.n_nodes())
                .filter(|n| !self.cluster.node_empty(*n))
                .count(),
            n_nodes: self.cluster.n_nodes(),
            in_flight: self.router.total_in_flight(),
        });
        self.queue.push(now_ms + MONITOR_INTERVAL_MS, Event::MonitorTick);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};

    fn plane() -> ControlPlane {
        let cat = test_catalog();
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 4;
        let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
            ForestParams::synthetic_stub(crate::model::N_FEATURES, 0.05, 0.05),
        ));
        ControlPlane::new(cat, cfg, predictor)
    }

    fn hot_loads(cp: &ControlPlane) -> Vec<f64> {
        let mut loads = vec![0.0; cp.cat.len()];
        loads[0] = 5.0 * cp.cat.get(0).saturated_rps;
        loads
    }

    #[test]
    fn step_commits_plans_and_defers_refreshes() {
        let mut cp = plane();
        let loads = hot_loads(&cp);
        let ev = cp.step(0.0, &loads).unwrap();
        assert!(!ev.scheduled.is_empty(), "scale-up from zero must schedule");
        assert!(ev.deferred_submitted > 0, "placements submit refreshes");
        assert_eq!(ev.deferred_completed, 0, "refreshes take modelled time to land");
        assert_eq!(cp.deferred_in_flight() as u32, ev.deferred_submitted);
        let ev2 = cp.step(1000.0, &loads).unwrap();
        assert_eq!(ev2.deferred_completed, ev.deferred_submitted, "landed by next second");
        assert!(ev2.cold_starts_completed > 0, "instances become ready");
    }

    /// The acceptance test for the event core: a cold start scheduled
    /// mid-tick completes at exactly `sched_cost + init_ms` — under the
    /// old whole-tick quantization it completed only at the next 1 s
    /// boundary, so this test fails there.
    #[test]
    fn cold_start_completes_at_exact_subtick_due_time() {
        let mut cp = plane();
        let loads = hot_loads(&cp);
        let ev = cp.step(0.0, &loads).unwrap();
        assert_eq!(ev.scheduled.len(), 1);
        let started = ev.scheduled[0].placements.len() as u32;
        assert!(started > 0);
        let due_ms = cp.cfg.cost.decision_ms(ev.scheduled[0].plan.critical_inferences)
            + cp.cfg.init_model.latency_ms();
        assert!(due_ms < 1000.0, "due mid-tick, not at a boundary: {due_ms}");
        assert_eq!(cp.cold_starts_in_flight(), started as usize);

        // one microsecond early: nothing has completed yet
        let before = cp.step(due_ms - 1e-3, &loads).unwrap();
        assert_eq!(before.cold_starts_completed, 0, "not due yet at {:.4}", due_ms - 1e-3);

        // at the exact due instant: every placement completes, with its
        // latency attributed at event resolution
        let at = cp.step(due_ms, &loads).unwrap();
        assert_eq!(at.cold_starts_completed, started);
        assert_eq!(cp.cold_starts_in_flight(), 0);
        for l in &at.cold_start_latency_ms {
            assert!((l - due_ms).abs() < 1e-9, "latency {l} != due {due_ms}");
        }
    }

    #[test]
    fn deferred_refresh_lands_at_modelled_submillisecond_delay() {
        let mut cp = plane();
        let loads = hot_loads(&cp);
        let ev = cp.step(0.0, &loads).unwrap();
        assert!(ev.deferred_submitted > 0);
        // the modelled delay is sub-millisecond for any realistic
        // inference count — far below the old one-tick clamp
        let max_delay_ms = cp
            .in_flight
            .values()
            .map(|u| cp.cfg.cost.refresh_ms(u.inferences))
            .fold(0.0, f64::max);
        assert!(max_delay_ms < 1000.0);
        let ev2 = cp.step(max_delay_ms, &loads).unwrap();
        assert_eq!(ev2.deferred_completed, ev.deferred_submitted, "lands mid-tick");
        assert_eq!(cp.deferred_in_flight(), 0);
    }

    #[test]
    fn idle_steps_do_nothing() {
        let mut cp = plane();
        let loads = vec![0.0; test_catalog().len()];
        let ev = cp.step(0.0, &loads).unwrap();
        assert!(ev.scheduled.is_empty());
        assert_eq!(ev.instances, 0);
        assert_eq!(cp.cold_starts_in_flight(), 0);
    }

    #[test]
    fn run_until_drives_injected_subsecond_workload() {
        use crate::traces::{LoadEvent, Workload};
        let mut cp = plane();
        let sat = cp.cat.get(0).saturated_rps;
        // a burst that starts and ends inside one old tick
        let wl = Workload {
            name: "micro-burst".into(),
            n_functions: cp.cat.len(),
            events: vec![
                LoadEvent { at_ms: 0.0, function: 0, rps: 2.0 * sat },
                LoadEvent { at_ms: 1200.0, function: 0, rps: 9.0 * sat },
                LoadEvent { at_ms: 1650.0, function: 0, rps: 2.0 * sat },
            ],
            duration_ms: 4000.0,
        };
        cp.inject_workload(&wl);
        let ev = cp.run_until(4000.0).unwrap();
        assert!(ev.events_processed > 0);
        assert!(!ev.scheduled.is_empty());
        assert_eq!(ev.samples.len(), 4, "one utilisation sample per second");
        // the burst lived only between evaluations (1200–1650 ms): the
        // 1 s-cadence autoscaler saw 2x concurrency at every eval
        assert!((cp.loads()[0] - 2.0 * sat).abs() < 1e-12);
        assert!(ev.instances > 0);
    }

    #[test]
    fn degenerate_eval_interval_is_sanitised_not_hung() {
        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
            let cat = test_catalog();
            let mut cfg = RunConfig::jiagu_45();
            cfg.n_nodes = 2;
            cfg.eval_interval_ms = bad;
            let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
                ForestParams::synthetic_stub(crate::model::N_FEATURES, 0.05, 0.05),
            ));
            let mut cp = ControlPlane::new(cat, cfg, predictor);
            let loads = vec![0.0; cp.cat.len()];
            // must terminate (0/-5 clamp to 1 ms; NaN/inf fall back to 1 s)
            let ev = cp.step(10.0, &loads).unwrap();
            assert!(ev.events_processed >= 2, "eval + monitor must still fire");
        }
    }

    #[test]
    fn per_request_routing_attributes_cold_wait_queueing_and_service() {
        use crate::traces::{LoadEvent, Workload};
        let mut cp = plane();
        let sat = cp.cat.get(0).saturated_rps;
        let wl = Workload {
            name: "request-burst".into(),
            n_functions: cp.cat.len(),
            events: vec![LoadEvent { at_ms: 0.0, function: 0, rps: 3.0 * sat }],
            duration_ms: 5000.0,
        };
        cp.inject_workload(&wl);
        let mut arrivals = wl.synthesize_arrivals(17);
        assert!(!arrivals.is_empty());
        // one guaranteed pre-cold-start arrival: nothing can serve before
        // the first cold start completes at sched_cost + init_ms (≥8.4 ms)
        arrivals.insert(0, crate::traces::Arrival { at_ms: 1.0, function: 0 });
        cp.inject_arrivals(&arrivals);
        let ev = cp.run_until(5000.0).unwrap();
        // before the first cold start completes nothing serves fn 0, so
        // early arrivals must park on the cold-wait queue ...
        assert!(ev.cold_waits > 0, "pre-cold-start arrivals must wait");
        // ... and be drained once instances join the routing set: every
        // attributed latency covers wait + service, bounded below by the
        // modelled per-request cost
        assert!(!ev.requests.is_empty());
        assert!(ev.requests.len() <= arrivals.len());
        let overhead = cp.cfg.cost.request_overhead_ms();
        for r in &ev.requests {
            assert_eq!(r.function, 0);
            assert!(r.latency_ms > overhead, "latency {} must include service", r.latency_ms);
            assert!(r.latency_ms.is_finite());
        }
        assert_eq!(cp.router().waiting_count(0), 0, "cold-waiters drained");
        assert!(ev.peak_node_in_flight > 0);
        // request conservation: every injected arrival is either
        // attributed (admitted) or still waiting/queued at the horizon
        assert_eq!(
            ev.requests.len() as u64 + ev.waiting + ev.queued,
            arrivals.len() as u64,
            "no request may vanish from the accounting"
        );
        cp.router().check_consistent(cp.cluster()).unwrap();
    }

    #[test]
    fn request_replicas_stay_in_lockstep() {
        use crate::traces::{PoissonParams, Workload};
        let run = || {
            let mut cp = plane();
            let params = PoissonParams { duration_s: 6, ..Default::default() };
            let wl = Workload::poisson(&cp.cat, &params, 23);
            cp.inject_workload(&wl);
            cp.inject_arrivals(&wl.synthesize_arrivals(23));
            cp.run_until(6000.0).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.requests, b.requests, "routing decisions must replay bit-identically");
        assert_eq!(a.cold_waits, b.cold_waits);
        assert_eq!(a.peak_node_in_flight, b.peak_node_in_flight);
        assert_eq!(a.in_flight, b.in_flight);
    }

    #[test]
    fn eval_cadence_follows_config_interval() {
        let cat = test_catalog();
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 2;
        cfg.eval_interval_ms = 250.0;
        let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(
            ForestParams::synthetic_stub(crate::model::N_FEATURES, 0.05, 0.05),
        ));
        let mut cp = ControlPlane::new(cat, cfg, predictor);
        let loads = vec![0.0; cp.cat.len()];
        let ev = cp.step(999.0, &loads).unwrap();
        // evals at 0, 250, 500, 750 + monitor tick at 0 = 5 events
        assert_eq!(ev.events_processed, 5);
    }
}

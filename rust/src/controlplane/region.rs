//! Multi-region federation: heterogeneous cells, deterministic failure
//! injection with crash-replay recovery, and cross-region overflow
//! routing.
//!
//! The paper deploys one control plane per region and scales out by
//! adding regions; [`crate::controlplane::shard`] already models the
//! *partitioning* half of that story (disjoint cells, layout-only
//! determinism, exactly-associative report merge).  This module promotes
//! cells to **regions**:
//!
//! * a [`RegionSpec`] per cell with a *heterogeneous* node count (the
//!   shard layout's proportional split becomes an explicit per-region
//!   allotment), functions assigned round-robin by global id and routed
//!   with [`Workload::restrict`] — the same global-id contract the shard
//!   layer pinned, so per-function report tables scatter-add exactly;
//! * a static inter-region [`LatencyMatrix`]: a request spilled from its
//!   home region and served elsewhere pays the matrix's inter-region
//!   milliseconds on top of its in-cluster response time;
//! * a seeded [`FailurePlan`] that kills a region at a chosen virtual
//!   time and recovers it by **replay-from-seed** (below);
//! * **overflow routing**: a saturated region's cold-queued arrivals are
//!   re-targeted to its latency-nearest region in a deterministic
//!   two-phase schedule (below).
//!
//! ## The crash-replay determinism contract
//!
//! A region is a deterministic state machine over its seeded event
//! stream: its state at any virtual time `t` is a pure function of
//! `(catalog, region config, sub-workload, cell_seed)` — nothing else
//! (the shard layer's cell-isolation proof carries over unchanged).
//! When the [`FailurePlan`] crashes a region at `t_c`, recovery is
//! **replay from seed**: the region's timeline is re-executed from
//! virtual time 0 with the same `cell_seed(run_seed, region)`, reaches
//! `t_c` in exactly the state the crashed instance held (byte-for-byte —
//! there is no other state to restore), and *resumes* past the crash
//! horizon to the end of the run.  Consequently:
//!
//! > a region crashed at any `t_c` and replayed from its seed produces a
//! > report **byte-equal** to the uncrashed run of the same sub-stream,
//! > and the merged federation report is byte-equal to the crash-free
//! > federation — which is exactly what the CI determinism matrix pins
//! > (`--regions 2 --fail 1@5000` vs `--regions 2`).
//!
//! The work lost to the crash is *accounted*, not lost silently: the
//! doomed pre-crash execution is drained up to `t_c`, its processed
//! events counted into [`FederationStats::lost_events`] (and the replay
//! re-executes exactly that many to catch up —
//! [`FederationStats::replayed_events`]), then discarded.  The stats
//! ride next to the report, never inside it, so failure injection can
//! never perturb the report bytes.
//!
//! ## Two-phase overflow routing
//!
//! Cross-region spill must not break layout-only determinism, so it is
//! scheduled in two deterministic phases rather than reactively:
//!
//! 1. **Phase 1** runs every region on its own arrivals with
//!    [`RunConfig::collect_overflow`] set, recording each fresh arrival
//!    whose first dispatch could not start service (parked cold-waiting
//!    or queued behind a busy instance) as a spill *candidate*.  A
//!    region is **saturated** when demand is still stranded at its
//!    horizon (`stranded_requests > 0`).
//! 2. Every saturated region re-targets its candidates to its
//!    latency-nearest region ([`LatencyMatrix::nearest`]).  **Phase 2**
//!    re-runs only the affected regions: homes without their spilled
//!    arrivals, targets with the spilled arrivals added — plus derived
//!    load steps binned from the spill (the target's autoscaler must see
//!    the foreign demand) — and the matrix latency added to every
//!    foreign request's response time.  Spill is one hop: phase 2 never
//!    collects candidates, so overflow cannot cascade.
//!
//! Both phases are pure functions of the layout and the run seed;
//! `cfg.shards` only picks how many threads drain phase 1, so the
//! federation inherits the shard layer's byte-identity across `--shards
//! 1/2/4` and both queue backends.

use crate::catalog::Catalog;
use crate::config::RunConfig;
use crate::controlplane::shard::{cell_seed, ZeroNodeCell};
use crate::controlplane::ControlPlane;
use crate::runtime::Predictor;
use crate::sim::{effective_arrival_seed, ReportBuilder, RunReport};
use crate::traces::{Arrival, LoadEvent, Workload};
use crate::util::rng::Rng;
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Fold granularity (virtual ms) of the per-region drains — the same
/// value [`crate::sim::Simulation`] folds with, so a 1-region federation
/// absorbs chunks exactly like the plain driver.
const FOLD_CHUNK_MS: f64 = 60_000.0;

/// Bin width (virtual ms) of the load signal derived from spilled
/// arrivals for an overflow target's autoscaler.
const OVERFLOW_BIN_MS: f64 = 100.0;

/// One region of the federation: a named cell with an explicit
/// (heterogeneous) node allotment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegionSpec {
    pub name: String,
    pub n_nodes: usize,
}

/// Static inter-region latency matrix (virtual ms), row-major:
/// `ms(from, to)` is the extra response time a request of `from`'s
/// functions pays when served in region `to`.  The diagonal is zero.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyMatrix {
    n: usize,
    ms: Vec<f64>,
}

impl LatencyMatrix {
    /// Uniform matrix: `ms` between every distinct pair, zero diagonal.
    pub fn uniform(n: usize, ms: f64) -> Result<Self> {
        ensure!(n > 0, "latency matrix needs at least one region");
        ensure!(ms.is_finite() && ms >= 0.0, "inter-region latency must be finite and >= 0");
        let cells = (0..n * n)
            .map(|i| if i / n == i % n { 0.0 } else { ms })
            .collect();
        Ok(Self { n, ms: cells })
    }

    /// Number of regions the matrix spans.
    pub fn regions(&self) -> usize {
        self.n
    }

    /// Inter-region latency `from → to` (zero on the diagonal).
    pub fn ms(&self, from: usize, to: usize) -> f64 {
        self.ms[from * self.n + to]
    }

    /// The latency-nearest *other* region of `from` (ties break toward
    /// the lower index, keeping overflow targeting deterministic);
    /// `None` for a 1-region federation.
    pub fn nearest(&self, from: usize) -> Option<usize> {
        (0..self.n)
            .filter(|&t| t != from)
            .min_by(|&a, &b| self.ms(from, a).total_cmp(&self.ms(from, b)))
    }
}

/// One injected failure: `region` dies at virtual time `at_ms` and is
/// recovered by replay-from-seed (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RegionCrash {
    pub region: usize,
    pub at_ms: f64,
}

/// A validated set of injected failures: at most one crash per region,
/// each at a finite, non-negative virtual time inside the region range.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FailurePlan {
    crashes: Vec<RegionCrash>,
}

impl FailurePlan {
    /// Build from explicit `(region, at_ms)` specs (the `--fail
    /// region@ms` CLI form).
    pub fn from_specs(specs: &[(usize, f64)], n_regions: usize) -> Result<Self> {
        let mut crashes = Vec::with_capacity(specs.len());
        for &(region, at_ms) in specs {
            ensure!(
                region < n_regions,
                "failure spec targets region {region}, but only {n_regions} regions exist"
            );
            ensure!(
                at_ms.is_finite() && at_ms >= 0.0,
                "failure spec for region {region}: crash time must be finite and >= 0"
            );
            ensure!(
                crashes.iter().all(|c: &RegionCrash| c.region != region),
                "region {region} has more than one scheduled crash"
            );
            crashes.push(RegionCrash { region, at_ms });
        }
        Ok(Self { crashes })
    }

    /// Seeded plan: one region picked uniformly, crashed at a uniform
    /// time inside `(0, horizon_ms)` — deterministic per seed, so a
    /// fuzzing harness can scatter crashes without losing replay.
    pub fn seeded(seed: u64, n_regions: usize, horizon_ms: f64) -> Result<Self> {
        ensure!(n_regions > 0, "seeded failure plan needs at least one region");
        ensure!(
            horizon_ms.is_finite() && horizon_ms > 0.0,
            "seeded failure plan needs a positive horizon"
        );
        let mut rng = Rng::seed_from(seed);
        let region = rng.below(n_regions as u64) as usize;
        let at_ms = rng.f64() * horizon_ms;
        Self::from_specs(&[(region, at_ms)], n_regions)
    }

    /// The scheduled crash of `region`, if any.
    pub fn crash_of(&self, region: usize) -> Option<f64> {
        self.crashes.iter().find(|c| c.region == region).map(|c| c.at_ms)
    }

    /// All scheduled crashes.
    pub fn crashes(&self) -> &[RegionCrash] {
        &self.crashes
    }
}

/// The deterministic region layout: functions round-robin by global id
/// (`region_of(f) = f % regions`), nodes per the explicit
/// [`RegionSpec`] allotments.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegionLayout {
    regions: Vec<RegionSpec>,
    n_functions: usize,
}

impl RegionLayout {
    /// Build the layout from explicit per-region node counts; rejects a
    /// zero-node region with the typed
    /// [`ZeroNodeCell`](crate::controlplane::shard::ZeroNodeCell) error.
    pub fn new(n_functions: usize, node_counts: &[usize]) -> Result<Self> {
        ensure!(!node_counts.is_empty(), "a federation needs at least one region");
        if let Some(cell) = node_counts.iter().position(|&n| n == 0) {
            return Err(ZeroNodeCell { cell }.into());
        }
        let regions = node_counts
            .iter()
            .enumerate()
            .map(|(i, &n)| RegionSpec { name: format!("r{i}"), n_nodes: n })
            .collect();
        Ok(Self { regions, n_functions })
    }

    pub fn regions(&self) -> usize {
        self.regions.len()
    }

    pub fn spec(&self, region: usize) -> &RegionSpec {
        &self.regions[region]
    }

    /// The region owning `function` (round-robin by global id).
    pub fn region_of(&self, function: usize) -> usize {
        function % self.regions.len()
    }

    /// Node allotment of `region`.
    pub fn nodes_of(&self, region: usize) -> usize {
        self.regions[region].n_nodes
    }

    /// The (global) function ids `region` owns, ascending.
    pub fn functions_of(&self, region: usize) -> Vec<usize> {
        (region..self.n_functions).step_by(self.regions.len()).collect()
    }

    /// Total nodes across the federation.
    pub fn total_nodes(&self) -> usize {
        self.regions.iter().map(|r| r.n_nodes).sum()
    }
}

/// Proportional split of `n_nodes` over `regions` cells (the `--regions
/// N` CLI form; earlier regions absorb the remainder) — the same split
/// rule [`crate::controlplane::shard::ShardLayout`] uses.
pub fn proportional_split(n_nodes: usize, regions: usize) -> Vec<usize> {
    let p = regions.max(1);
    (0..p).map(|i| n_nodes / p + usize::from(i < n_nodes % p)).collect()
}

/// Side accounting of a federated run: crash/replay and overflow
/// bookkeeping.  Lives **next to** the merged [`RunReport`], never
/// inside it, so failure injection and spill scheduling can never
/// perturb the report bytes the determinism matrix compares.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FederationStats {
    /// Regions in the layout.
    pub regions: usize,
    /// Regions the failure plan actually crashed (crash time inside the
    /// horizon).
    pub crashes: u64,
    /// Events the doomed pre-crash executions had processed (work lost
    /// to the crashes, re-executed by the replays).
    pub lost_events: u64,
    /// Events the recovery replays re-executed to catch back up to the
    /// crash horizons (equals `lost_events` by determinism).
    pub replayed_events: u64,
    /// Regions whose phase-1 run left demand stranded at the horizon.
    pub saturated_regions: u64,
    /// Arrivals re-targeted from a saturated home to its nearest region.
    pub spilled_arrivals: u64,
    /// Regions re-run in phase 2 (spill homes and targets).
    pub regions_rerun: u64,
}

impl std::fmt::Display for FederationStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} regions | crashes {} (lost {} events, replayed {}) | saturated {} | \
             spilled {} arrivals | reran {} regions",
            self.regions,
            self.crashes,
            self.lost_events,
            self.replayed_events,
            self.saturated_regions,
            self.spilled_arrivals,
            self.regions_rerun
        )
    }
}

/// Outcome of one region's run: its report, its spill candidates
/// (phase 1 only) and its crash accounting.
struct RegionRun {
    report: RunReport,
    overflow: Vec<Arrival>,
    lost_events: u64,
}

/// The federated orchestrator: one control-plane cell per region, a
/// failure plan replayed from seed, and two-phase overflow routing (see
/// the module docs for the determinism contracts).
pub struct FederatedControlPlane {
    cat: Catalog,
    cfg: RunConfig,
    predictor: Arc<dyn Predictor>,
    layout: RegionLayout,
    latency: LatencyMatrix,
    failures: FailurePlan,
}

impl FederatedControlPlane {
    /// Build the federation from `cfg.regions` (per-region node counts),
    /// `cfg.region_latency_ms` (uniform matrix) and `cfg.failures`.
    pub fn new(cat: Catalog, cfg: RunConfig, predictor: Arc<dyn Predictor>) -> Result<Self> {
        let layout = RegionLayout::new(cat.len(), &cfg.regions)?;
        let latency = LatencyMatrix::uniform(layout.regions(), cfg.region_latency_ms)?;
        let failures = FailurePlan::from_specs(&cfg.failures, layout.regions())?;
        Ok(Self { cat, cfg, predictor, layout, latency, failures })
    }

    pub fn layout(&self) -> &RegionLayout {
        &self.layout
    }

    pub fn latency(&self) -> &LatencyMatrix {
        &self.latency
    }

    /// The plain-control-plane configuration `region` runs with — the
    /// shard layer's cell config plus the region's explicit node count:
    /// cell seed derived from the run seed, the arrival seed pinned to
    /// the run-level value so every region thins the same underlying
    /// arrival stream, sharding and federation switched off.
    fn region_config(&self, region: usize, collect_overflow: bool) -> RunConfig {
        let mut cfg = self.cfg.clone();
        cfg.n_nodes = self.layout.nodes_of(region);
        cfg.seed = cell_seed(self.cfg.seed, region);
        cfg.arrival_seed = Some(effective_arrival_seed(&self.cfg));
        cfg.shards = 0;
        cfg.partitions = 1;
        cfg.regions = Vec::new();
        cfg.failures = Vec::new();
        cfg.collect_overflow = collect_overflow;
        cfg
    }

    /// Run `workload` across the federation: phase 1 on
    /// `cfg.shards.clamp(1, regions)` threads with crash-replay applied
    /// per the failure plan, then phase-2 overflow re-runs, then the
    /// pinned ascending-region merge.  Returns the merged report and the
    /// side stats (which never influence the report bytes).
    pub fn run_workload(&self, workload: &Workload) -> Result<(RunReport, FederationStats)> {
        ensure!(
            workload.n_functions == self.cat.len(),
            "workload spans {} functions, catalog has {}",
            workload.n_functions,
            self.cat.len()
        );
        let r = self.layout.regions();
        let duration = workload.duration_s().min(self.cfg.duration_s);
        let horizon_ms = duration as f64 * 1000.0;
        let mut stats = FederationStats { regions: r, ..Default::default() };

        // Per-region sub-streams: restricted workload + its synthesized
        // arrivals.  Synthesis is per-function from the pinned run-level
        // arrival seed, so each region draws exactly the sub-stream of
        // the global arrival stream its functions own.
        let mut subs = Vec::with_capacity(r);
        for region in 0..r {
            let wl = workload.restrict(|f| self.layout.region_of(f) == region);
            let (arrivals, dropped) = if self.cfg.requests {
                wl.synthesize_arrivals_counted(effective_arrival_seed(&self.cfg))
            } else {
                (Vec::new(), 0)
            };
            subs.push((self.region_config(region, true), wl, arrivals, dropped));
        }

        // Phase 1: every region on its own arrivals, spill candidates
        // collected, crashes replayed from seed.
        let threads = self.cfg.shards.clamp(1, r);
        let mut phase1: Vec<Option<RegionRun>> = (0..r).map(|_| None).collect();
        if threads == 1 {
            for (region, (cfg, wl, arrivals, dropped)) in subs.iter().enumerate() {
                phase1[region] =
                    Some(self.run_region(region, cfg, wl, arrivals, *dropped, None, horizon_ms)?);
            }
        } else {
            // same worker discipline as the shard layer: cells taken
            // round-robin, results landing in region-indexed slots so
            // thread scheduling can never reorder anything downstream
            std::thread::scope(|scope| -> Result<()> {
                let subs = &subs;
                let mut handles = Vec::with_capacity(threads);
                for w in 0..threads {
                    handles.push(scope.spawn(move || -> Vec<(usize, Result<RegionRun>)> {
                        let mut worker = Vec::new();
                        let mut region = w;
                        while region < r {
                            let (cfg, wl, arrivals, dropped) = &subs[region];
                            worker.push((
                                region,
                                self.run_region(
                                    region, cfg, wl, arrivals, *dropped, None, horizon_ms,
                                ),
                            ));
                            region += threads;
                        }
                        worker
                    }));
                }
                for handle in handles {
                    let worker =
                        handle.join().map_err(|_| anyhow!("region worker panicked"))?;
                    for (region, run) in worker {
                        phase1[region] = Some(run?);
                    }
                }
                Ok(())
            })?;
        }
        let mut phase1: Vec<RegionRun> =
            phase1.into_iter().map(|p| p.expect("every region ran")).collect();
        for run in &phase1 {
            stats.lost_events += run.lost_events;
        }
        stats.replayed_events = stats.lost_events;
        stats.crashes = self
            .failures
            .crashes()
            .iter()
            .filter(|c| c.at_ms < horizon_ms)
            .count() as u64;

        // Overflow schedule: each saturated region re-targets its
        // candidates to its latency-nearest region.
        let mut spills: Vec<Vec<Arrival>> = (0..r).map(|_| Vec::new()).collect(); // by target
        let mut spilled_from: Vec<Vec<Arrival>> = (0..r).map(|_| Vec::new()).collect(); // by home
        for home in 0..r {
            let saturated = phase1[home].report.stranded_requests > 0
                && !phase1[home].overflow.is_empty();
            if !saturated {
                continue;
            }
            let Some(target) = self.latency.nearest(home) else { continue };
            stats.saturated_regions += 1;
            stats.spilled_arrivals += phase1[home].overflow.len() as u64;
            let candidates = std::mem::take(&mut phase1[home].overflow);
            spilled_from[home].extend_from_slice(&candidates);
            spills[target].extend(candidates);
        }

        // Phase 2: re-run spill homes (their arrivals minus the spilled
        // multiset) and targets (arrivals plus the spill, its derived
        // load signal, and the matrix latency on foreign requests).
        let mut merged: Vec<RunReport> = Vec::with_capacity(r);
        for region in 0..r {
            let rerun = !spilled_from[region].is_empty() || !spills[region].is_empty();
            if !rerun {
                merged.push(phase1[region].report.clone());
                continue;
            }
            stats.regions_rerun += 1;
            let (_, wl, arrivals, dropped) = &subs[region];
            let mut arrivals = remove_multiset(arrivals, &spilled_from[region]);
            let mut wl = wl.clone();
            let mut extra = None;
            if !spills[region].is_empty() {
                arrivals.extend_from_slice(&spills[region]);
                arrivals.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
                wl.events.extend(derive_load_events(&spills[region], horizon_ms));
                wl.events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
                let mut per_function = vec![0.0; self.cat.len()];
                for a in &spills[region] {
                    per_function[a.function] =
                        self.latency.ms(self.layout.region_of(a.function), region);
                }
                extra = Some(per_function);
            }
            let cfg = self.region_config(region, false);
            let run = self.run_region(
                region,
                &cfg,
                &wl,
                &arrivals,
                *dropped,
                extra.as_deref(),
                horizon_ms,
            )?;
            merged.push(run.report);
        }

        // pinned merge order: ascending region index
        let mut iter = merged.into_iter();
        let mut report = iter.next().expect("layout has at least one region");
        for other in iter {
            report.merge(&other)?;
        }
        Ok((report, stats))
    }

    /// One region's run: crash-replay per the failure plan, then the
    /// full deterministic drain.  The doomed pre-crash execution is
    /// drained to the crash horizon, its processed events counted, and
    /// discarded; the recovery replay *is* the fresh full run — the
    /// module-level byte-equality contract.
    #[allow(clippy::too_many_arguments)]
    fn run_region(
        &self,
        region: usize,
        cfg: &RunConfig,
        workload: &Workload,
        arrivals: &[Arrival],
        dropped: u64,
        extra_latency_ms: Option<&[f64]>,
        horizon_ms: f64,
    ) -> Result<RegionRun> {
        let mut lost_events = 0u64;
        if let Some(crash_ms) = self.failures.crash_of(region) {
            if crash_ms < horizon_ms {
                let mut doomed = self.fresh_plane(cfg, workload, arrivals);
                let mut until = 0.0f64;
                while until < crash_ms {
                    until = (until + FOLD_CHUNK_MS).min(crash_ms);
                    lost_events += doomed.run_until(until)?.events_processed;
                }
                // the crashed instance and everything it computed are
                // gone; recovery replays the region from its seed below
            }
        }

        let mut cp = self.fresh_plane(cfg, workload, arrivals);
        let mut builder = ReportBuilder::new(&self.cat, cfg);
        builder.add_arrivals_dropped(dropped);
        let mut overflow = Vec::new();
        let mut until = 0.0f64;
        while until < horizon_ms {
            until = (until + FOLD_CHUNK_MS).min(horizon_ms);
            let mut ev = cp.run_until(until)?;
            if let Some(extra) = extra_latency_ms {
                for rec in &mut ev.requests {
                    let add = extra[rec.function];
                    if add > 0.0 {
                        rec.latency_ms += add;
                    }
                }
            }
            builder.absorb(&ev);
            overflow.append(&mut ev.overflow_candidates);
        }
        let isolated = cp.monitor().unpredictable();
        let duration = (horizon_ms / 1000.0).ceil() as usize;
        let mut report =
            builder.finish(cp.scheduler_name(), &workload.name, duration, isolated);
        report.owned_functions = self.layout.functions_of(region);
        Ok(RegionRun { report, overflow, lost_events })
    }

    /// A fresh, injected control plane for one region run (both the
    /// doomed pre-crash execution and the recovery replay build their
    /// plane here, from the same inputs — which is the whole point).
    fn fresh_plane(
        &self,
        cfg: &RunConfig,
        workload: &Workload,
        arrivals: &[Arrival],
    ) -> ControlPlane {
        let mut cp = ControlPlane::new(self.cat.clone(), cfg.clone(), self.predictor.clone());
        cp.inject_workload(workload);
        if cfg.requests {
            cp.inject_arrivals(arrivals);
        }
        cp
    }
}

/// Remove the `spilled` multiset from `arrivals` (keyed by exact
/// `(at_ms bits, function)` — candidates are copies of injected
/// arrivals, so the match is exact), preserving order.
fn remove_multiset(arrivals: &[Arrival], spilled: &[Arrival]) -> Vec<Arrival> {
    if spilled.is_empty() {
        return arrivals.to_vec();
    }
    let mut counts: HashMap<(u64, usize), usize> = HashMap::new();
    for s in spilled {
        *counts.entry((s.at_ms.to_bits(), s.function)).or_insert(0) += 1;
    }
    let mut kept = Vec::with_capacity(arrivals.len().saturating_sub(spilled.len()));
    for a in arrivals {
        match counts.get_mut(&(a.at_ms.to_bits(), a.function)) {
            Some(c) if *c > 0 => *c -= 1,
            _ => kept.push(*a),
        }
    }
    kept
}

/// Derive a piecewise-constant load signal from spilled arrivals (one
/// [`LoadEvent`] per [`OVERFLOW_BIN_MS`] bin where the binned rate
/// changes), so an overflow target's autoscaler sees the foreign demand
/// it is about to serve.  Functions emit in ascending id order and bins
/// in time order — fully deterministic.
fn derive_load_events(spilled: &[Arrival], horizon_ms: f64) -> Vec<LoadEvent> {
    let mut functions: Vec<usize> = spilled.iter().map(|a| a.function).collect();
    functions.sort_unstable();
    functions.dedup();
    let n_bins = (horizon_ms / OVERFLOW_BIN_MS).ceil() as usize;
    let mut events = Vec::new();
    for f in functions {
        let mut bins = vec![0u32; n_bins.max(1)];
        for a in spilled.iter().filter(|a| a.function == f) {
            let b = ((a.at_ms / OVERFLOW_BIN_MS) as usize).min(bins.len() - 1);
            bins[b] += 1;
        }
        let mut prev = f64::NAN; // always emit the first level
        for (b, count) in bins.iter().enumerate() {
            let rps = *count as f64 * (1000.0 / OVERFLOW_BIN_MS);
            if prev.to_bits() != rps.to_bits() {
                events.push(LoadEvent { at_ms: b as f64 * OVERFLOW_BIN_MS, function: f, rps });
                prev = rps;
            }
        }
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};
    use crate::traces::PoissonParams;

    fn stub_predictor() -> Arc<dyn Predictor> {
        Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
            crate::model::N_FEATURES,
            0.05,
            0.05,
        )))
    }

    fn base_cfg() -> RunConfig {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 6;
        cfg.duration_s = 8;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.regions = vec![3, 3];
        cfg
    }

    fn test_workload(cat: &Catalog) -> Workload {
        Workload::poisson(cat, &PoissonParams { duration_s: 8, ..Default::default() }, 33)
    }

    fn run(cfg: RunConfig) -> (RunReport, FederationStats) {
        let cat = test_catalog();
        let wl = test_workload(&cat);
        FederatedControlPlane::new(cat, cfg, stub_predictor())
            .unwrap()
            .run_workload(&wl)
            .unwrap()
    }

    #[test]
    fn latency_matrix_nearest_breaks_ties_toward_lower_index() {
        let m = LatencyMatrix::uniform(3, 25.0).unwrap();
        assert_eq!(m.ms(0, 0), 0.0);
        assert_eq!(m.ms(0, 2), 25.0);
        assert_eq!(m.nearest(0), Some(1));
        assert_eq!(m.nearest(1), Some(0));
        assert_eq!(m.nearest(2), Some(0));
        assert_eq!(LatencyMatrix::uniform(1, 25.0).unwrap().nearest(0), None);
        assert!(LatencyMatrix::uniform(2, f64::NAN).is_err());
    }

    #[test]
    fn failure_plan_validates_specs() {
        assert!(FailurePlan::from_specs(&[(0, 5000.0)], 2).is_ok());
        assert!(FailurePlan::from_specs(&[(2, 5000.0)], 2).is_err());
        assert!(FailurePlan::from_specs(&[(0, -1.0)], 2).is_err());
        assert!(FailurePlan::from_specs(&[(0, f64::NAN)], 2).is_err());
        assert!(FailurePlan::from_specs(&[(0, 1.0), (0, 2.0)], 2).is_err());
        let seeded = FailurePlan::seeded(7, 3, 8000.0).unwrap();
        assert_eq!(seeded.crashes().len(), 1);
        assert_eq!(seeded, FailurePlan::seeded(7, 3, 8000.0).unwrap());
    }

    #[test]
    fn region_layout_rejects_zero_node_regions() {
        assert!(RegionLayout::new(6, &[3, 0]).is_err());
        assert!(RegionLayout::new(6, &[]).is_err());
        let l = RegionLayout::new(6, &[4, 2]).unwrap();
        assert_eq!(l.regions(), 2);
        assert_eq!(l.functions_of(0), vec![0, 2, 4]);
        assert_eq!(l.functions_of(1), vec![1, 3, 5]);
        assert_eq!(l.total_nodes(), 6);
        assert_eq!(proportional_split(7, 3), vec![3, 2, 2]);
    }

    /// The tentpole contract: a region crashed at mid-horizon and
    /// replayed from its seed merges to the uncrashed run's exact bytes
    /// (full `PartialEq` surface, histogram and sample vectors
    /// included), and the side stats record the recovery.
    #[test]
    fn crash_replay_recovers_byte_identical_reports() {
        let (clean, clean_stats) = run(base_cfg());
        assert!(clean.requests_served > 0, "scenario must route traffic");
        assert_eq!(clean_stats.crashes, 0);

        let mut cfg = base_cfg();
        cfg.failures = vec![(1, 4000.0)];
        let (crashed, stats) = run(cfg);
        assert_eq!(clean, crashed, "crash-replay must reproduce the uncrashed bytes");
        assert_eq!(stats.crashes, 1);
        assert!(stats.lost_events > 0, "the doomed run must have done work to lose");
        assert_eq!(stats.replayed_events, stats.lost_events);
    }

    /// `shards` is a pure thread knob for federations too.
    #[test]
    fn shard_count_never_changes_the_federated_report() {
        let mut cfg = base_cfg();
        cfg.failures = vec![(0, 3000.0)];
        cfg.shards = 1;
        let (reference, _) = run(cfg.clone());
        for shards in [2, 4] {
            cfg.shards = shards;
            let (parallel, _) = run(cfg.clone());
            assert_eq!(reference, parallel, "{shards} threads must reproduce 1-thread bytes");
        }
    }

    /// Region reports own disjoint function slices and the merge counts
    /// cells, so the federated report carries the layout's shape.
    #[test]
    fn merged_report_carries_layout_ownership() {
        let (report, _) = run(base_cfg());
        assert_eq!(report.cells, 2);
        assert_eq!(report.owned_functions, (0..test_catalog().len()).collect::<Vec<_>>());
    }

    /// A starved federation (one node per region, heavy load) saturates,
    /// spills to the latency-nearest region, and stays deterministic:
    /// two identical runs agree byte-for-byte, stats included.
    #[test]
    fn overflow_routing_is_deterministic() {
        let cat = test_catalog();
        let mut cfg = base_cfg();
        cfg.regions = vec![1, 1];
        cfg.n_nodes = 2;
        let wl = Workload::poisson(
            &cat,
            &PoissonParams { duration_s: 8, mean_concurrency: 24.0, ..Default::default() },
            33,
        );
        let fed = FederatedControlPlane::new(cat.clone(), cfg.clone(), stub_predictor()).unwrap();
        let (a, sa) = fed.run_workload(&wl).unwrap();
        let fed2 = FederatedControlPlane::new(cat, cfg, stub_predictor()).unwrap();
        let (b, sb) = fed2.run_workload(&wl).unwrap();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
        if sa.saturated_regions > 0 {
            assert!(sa.spilled_arrivals > 0);
            assert!(sa.regions_rerun > 0);
        }
    }

    #[test]
    fn remove_multiset_is_exact_and_order_preserving() {
        let a = |t: f64, f: usize| Arrival { at_ms: t, function: f };
        let arrivals = vec![a(1.0, 0), a(2.0, 1), a(2.0, 1), a(3.0, 0)];
        let kept = remove_multiset(&arrivals, &[a(2.0, 1)]);
        assert_eq!(kept, vec![a(1.0, 0), a(2.0, 1), a(3.0, 0)]);
        assert_eq!(remove_multiset(&arrivals, &[]), arrivals);
        assert_eq!(remove_multiset(&arrivals, &arrivals), Vec::new());
    }

    #[test]
    fn derived_load_events_bin_the_spill() {
        let a = |t: f64, f: usize| Arrival { at_ms: t, function: f };
        let ev = derive_load_events(&[a(50.0, 2), a(60.0, 2), a(250.0, 2)], 1000.0);
        // bin 0 holds two arrivals (20 rps), bin 1 none, bin 2 one
        assert_eq!(ev[0], LoadEvent { at_ms: 0.0, function: 2, rps: 20.0 });
        assert_eq!(ev[1], LoadEvent { at_ms: 100.0, function: 2, rps: 0.0 });
        assert_eq!(ev[2], LoadEvent { at_ms: 200.0, function: 2, rps: 10.0 });
        assert_eq!(ev[3], LoadEvent { at_ms: 300.0, function: 2, rps: 0.0 });
        assert_eq!(ev.len(), 4);
    }
}

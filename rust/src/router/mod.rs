//! Request router: per-request dispatch and queueing across a function's
//! instances.
//!
//! The router load-balances over **saturated** instances only; **cached**
//! instances (dual-staged scaling) are excluded from the routing set the
//! same way the paper's K8s-Service label trick removes them.  A "logical
//! cold start" is just re-adding a cached instance to the routing set —
//! the <1 ms operation the autoscaler prefers over a real cold start.
//!
//! ## The per-request model
//!
//! Routing is event-driven, one request at a time:
//!
//! * [`Router::pick`] chooses a serving instance through a pluggable
//!   [`DispatchPolicy`] (see [`crate::policy`]; the default weights by
//!   `1 / (1 + in_flight)` — lightly loaded instances draw more
//!   traffic, the saturated ones draw less) from the router's **own
//!   seeded RNG**, so the pick stream is a pure function of the seed
//!   and the dispatch order (bit-identical across replays; it never
//!   touches the control plane's noise RNG).  The result is a typed
//!   [`Dispatch`]: an idle instance ([`Dispatch::Routed`]), a busy one
//!   ([`Dispatch::Saturated`]) or no serving instance at all
//!   ([`Dispatch::ColdQueued`], which consumes no randomness).
//! * Each instance **admits one request at a time** through a FIFO
//!   queue: [`Router::route`] either occupies the free slot (idle
//!   instance) or appends the arrival to the instance's queue;
//!   [`Router::complete`] pops the next queued request into the slot.
//!   The control plane decides how long a slot stays occupied (one
//!   saturated-rate interval stretched by interference — the pipelined
//!   server model that matches the capacity planner's throughput).
//! * A request that finds **no serving instance anywhere** parks on the
//!   function's *cold-wait* queue ([`RouteOutcome::ColdWait`]); the
//!   control plane drains it ([`Router::pop_waiting`]) the moment an
//!   instance joins the routing set, so cold-start wait shows up in that
//!   request's latency instead of being dropped.
//! * [`Router::remove`] (release/eviction) hands the victim's queued
//!   arrivals back to the caller for re-dispatch — the in-service request
//!   finishes where it started, but queued work never strands on an
//!   instance that stopped serving.
//!
//! ## Struct-of-arrays layout
//!
//! Per-instance queueing state lives in parallel columns indexed by
//! [`InstanceId`] (cluster ids are dense and never reused), and the
//! per-function serving/cold-wait tables and per-node gauges are vectors
//! indexed by their dense ids.  The pick loop — the per-request hot path
//! measured by `benches/router_hotpath.rs` — reads one `u32` per serving
//! instance from a contiguous column instead of chasing hash buckets.
//! A slot whose `live` flag is down is semantically absent (the old
//! map-removal); slots stay allocated, a bounded cost of the id-indexed
//! layout.
//!
//! Per-node in-flight gauges (and their peak) come along for free and
//! feed the `RunReport`'s tail-latency accounting.  Determinism contract:
//! the router holds no wall-clock state and draws randomness only from
//! its seeded RNG, one draw per successful pick.

use crate::catalog::FunctionId;
use crate::cluster::{Cluster, InstanceId, InstanceState, NodeId};
use crate::policy::{CandidateView, DispatchPolicy, WeightedPolicy};
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// Where [`Router::route`] sent a request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouteOutcome {
    /// The instance was idle: service starts at the dispatch instant.
    Started { instance: InstanceId, node: NodeId },
    /// The instance was busy: the request joined its FIFO queue.
    Queued { instance: InstanceId, node: NodeId },
    /// No serving instance exists anywhere: parked on the function's
    /// cold-wait queue until one joins the routing set.
    ColdWait,
}

/// What [`Router::pick`] decided for one request — the typed dispatch
/// verdict, before any queueing state is touched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// Picked an instance with a free service slot (`in_flight == 0`):
    /// the request would enter service immediately.
    Routed(InstanceId),
    /// Picked a busy instance: the request would join its FIFO queue
    /// behind the in-service one.
    Saturated(InstanceId),
    /// No serving instance exists for the function; no RNG draw was
    /// consumed and the request belongs on the cold-wait queue.
    ColdQueued,
}

impl Dispatch {
    /// The picked instance, if any.
    pub fn instance(self) -> Option<InstanceId> {
        match self {
            Dispatch::Routed(id) | Dispatch::Saturated(id) => Some(id),
            Dispatch::ColdQueued => None,
        }
    }
}

/// The next request entering service after a [`Router::complete`]: the
/// head of the instance's FIFO queue, with the arrival time the caller
/// needs for queueing-delay attribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NextService {
    pub function: FunctionId,
    pub node: NodeId,
    pub arrival_ms: f64,
}

/// Routing table: function → serving (saturated) instances, plus the
/// per-instance queueing state of the per-request model, stored as
/// parallel columns indexed by instance id (see the module docs).
#[derive(Debug)]
pub struct Router {
    /// Serving (saturated) instances per function, indexed by function id.
    serving: Vec<Vec<InstanceId>>,
    /// Count of re-route operations (logical cold starts, releases).
    pub reroutes: u64,
    /// Seeded pick RNG — the router's only randomness source.
    rng: Rng,
    // --- per-instance queueing state, columns indexed by InstanceId ---
    load_function: Vec<FunctionId>,
    load_node: Vec<NodeId>,
    /// Requests dispatched here and not yet completed (1 in service +
    /// queue length while busy; 0 when idle).
    load_in_flight: Vec<u32>,
    /// Arrival times of requests waiting behind the in-service one.
    load_queue: Vec<VecDeque<f64>>,
    /// Slot validity: down = the router no longer tracks this instance
    /// (created on [`Router::add`], kept up after [`Router::remove`]
    /// only while an in-service request drains).
    load_live: Vec<bool>,
    /// Requests per node currently dispatched (in service + queued),
    /// indexed by node id.
    node_in_flight: Vec<u32>,
    peak_node_in_flight: u32,
    /// Cold-wait queues: arrival times of requests that found no serving
    /// instance, indexed by function id.
    waiting: Vec<VecDeque<f64>>,
    /// Pluggable pick strategy (see [`crate::policy`]); the default
    /// [`WeightedPolicy`] reproduces the original weighted draw
    /// byte-identically.
    policy: Box<dyn DispatchPolicy>,
    /// Gauge under-decrements repaired by saturating at zero instead of
    /// wrapping (see [`Router::gauge_skew_repairs`]).  Any nonzero value
    /// is a routing-accounting bug upstream — an unchecked wrap here used
    /// to corrupt every later [`Router::pick`] weight in release builds.
    gauge_skew_repairs: u64,
}

impl Default for Router {
    fn default() -> Self {
        Self::with_seed(0)
    }
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// A router whose pick stream derives from `seed`, using the default
    /// [`WeightedPolicy`] (the original weighted draw).
    pub fn with_seed(seed: u64) -> Self {
        Self::with_policy(seed, Box::new(WeightedPolicy::new()))
    }

    /// A router whose pick stream derives from `seed` through `policy`.
    pub fn with_policy(seed: u64, policy: Box<dyn DispatchPolicy>) -> Self {
        Self {
            serving: Vec::new(),
            reroutes: 0,
            rng: Rng::seed_from(seed),
            load_function: Vec::new(),
            load_node: Vec::new(),
            load_in_flight: Vec::new(),
            load_queue: Vec::new(),
            load_live: Vec::new(),
            node_in_flight: Vec::new(),
            peak_node_in_flight: 0,
            waiting: Vec::new(),
            policy,
            gauge_skew_repairs: 0,
        }
    }

    /// Name of the active dispatch policy.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Forward a capacity-table hint for `node` (the sum of the node's
    /// per-function capacities from a just-landed deferred update) to
    /// the dispatch policy.  Most policies ignore it; see
    /// [`DispatchPolicy::on_capacity_hint`].
    pub fn capacity_hint(&mut self, node: NodeId, capacity: f64) {
        self.policy.on_capacity_hint(node, capacity);
    }

    fn ensure_function(&mut self, f: FunctionId) {
        if self.serving.len() <= f {
            self.serving.resize_with(f + 1, Vec::new);
            self.waiting.resize_with(f + 1, VecDeque::new);
        }
    }

    fn ensure_instance(&mut self, id: InstanceId) {
        let i = id as usize;
        if self.load_live.len() <= i {
            self.load_function.resize(i + 1, 0);
            self.load_node.resize(i + 1, 0);
            self.load_in_flight.resize(i + 1, 0);
            self.load_queue.resize_with(i + 1, VecDeque::new);
            self.load_live.resize(i + 1, false);
        }
    }

    fn tracked(&self, id: InstanceId) -> bool {
        let i = id as usize;
        i < self.load_live.len() && self.load_live[i]
    }

    /// Instances currently receiving traffic for `f`.
    pub fn serving(&self, f: FunctionId) -> &[InstanceId] {
        self.serving.get(f).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn serving_count(&self, f: FunctionId) -> usize {
        self.serving(f).len()
    }

    /// Add a newly started (or logically cold-started) instance on
    /// `node` to the routing set.
    pub fn add(&mut self, f: FunctionId, id: InstanceId, node: NodeId) {
        self.ensure_function(f);
        self.ensure_instance(id);
        let v = &mut self.serving[f];
        debug_assert!(!v.contains(&id));
        v.push(id);
        self.reroutes += 1;
        let i = id as usize;
        if !self.load_live[i] {
            self.load_live[i] = true;
            self.load_function[i] = f;
            self.load_node[i] = node;
            self.load_in_flight[i] = 0;
            self.load_queue[i].clear();
            return;
        }
        // a re-added instance may still be draining its previous
        // in-service request; keep that state, re-pin identity, and —
        // when a cached instance migrated before rejoining — carry the
        // residual gauge to the new node so per-node counts stay coherent
        let old_node = self.load_node[i];
        let carry = if old_node != node { self.load_in_flight[i] } else { 0 };
        self.load_function[i] = f;
        self.load_node[i] = node;
        if carry > 0 {
            self.dec_node(old_node, carry);
            self.inc_node_by(node, carry);
        }
    }

    /// Remove an instance from the routing set (release or eviction) and
    /// return the arrival times of its **queued** (not yet in service)
    /// requests, which the caller must re-dispatch.  The in-service
    /// request, if any, finishes where it started.  A no-op (empty vec)
    /// when the instance was not serving.
    pub fn remove(&mut self, f: FunctionId, id: InstanceId) -> Vec<f64> {
        let Some(v) = self.serving.get_mut(f) else { return Vec::new() };
        let before = v.len();
        v.retain(|x| *x != id);
        if v.len() == before {
            return Vec::new();
        }
        self.reroutes += 1;
        if !self.tracked(id) {
            return Vec::new();
        }
        let i = id as usize;
        let orphaned: Vec<f64> = self.load_queue[i].drain(..).collect();
        // checked, not unchecked `-=`: a skewed gauge would wrap in
        // release builds and poison every later pick weight
        Self::checked_gauge_sub(
            &mut self.load_in_flight[i],
            orphaned.len() as u32,
            &mut self.gauge_skew_repairs,
        );
        let node = self.load_node[i];
        if self.load_in_flight[i] == 0 {
            self.load_live[i] = false;
        }
        if !orphaned.is_empty() {
            self.dec_node(node, orphaned.len() as u32);
        }
        orphaned
    }

    /// Pick a serving instance of `f` through the dispatch policy (the
    /// default weights by instantaneous in-flight load,
    /// `weight ∝ 1 / (1 + in_flight)`), drawing only from the seeded
    /// pick RNG.  The verdict is typed: [`Dispatch::Routed`] for an idle
    /// pick, [`Dispatch::Saturated`] for a busy one and
    /// [`Dispatch::ColdQueued`] when nothing serves `f` — in which case
    /// **no policy runs and the RNG is not consumed**, so replica
    /// routers fed the same dispatch sequence stay in lockstep
    /// whichever policy they carry.
    pub fn pick(&mut self, f: FunctionId) -> Dispatch {
        let Some(serving) = self.serving.get(f).filter(|v| !v.is_empty()) else {
            return Dispatch::ColdQueued;
        };
        // this is the per-request hot path (benches/router_hotpath.rs):
        // the view hands the policy the SoA load columns by reference
        let view = CandidateView {
            function: f,
            serving: serving.as_slice(),
            in_flight: &self.load_in_flight,
            node_of: &self.load_node,
            node_in_flight: &self.node_in_flight,
        };
        let picked = self.policy.pick(&view, &mut self.rng);
        if self.load_in_flight.get(picked as usize).copied().unwrap_or(0) == 0 {
            Dispatch::Routed(picked)
        } else {
            Dispatch::Saturated(picked)
        }
    }

    /// Route one request for `f` arriving at `arrival_ms` (virtual time).
    pub fn route(&mut self, f: FunctionId, arrival_ms: f64) -> RouteOutcome {
        match self.pick(f) {
            Dispatch::ColdQueued => {
                self.ensure_function(f);
                self.waiting[f].push_back(arrival_ms);
                RouteOutcome::ColdWait
            }
            Dispatch::Routed(instance) => {
                let i = instance as usize;
                debug_assert_eq!(self.load_in_flight[i], 0);
                self.load_in_flight[i] = 1;
                let node = self.load_node[i];
                self.inc_node(node);
                RouteOutcome::Started { instance, node }
            }
            Dispatch::Saturated(instance) => {
                let i = instance as usize;
                self.load_in_flight[i] += 1;
                self.load_queue[i].push_back(arrival_ms);
                let node = self.load_node[i];
                self.inc_node(node);
                RouteOutcome::Queued { instance, node }
            }
        }
    }

    /// A service completes on `instance`.  Returns the next queued
    /// request now entering service, if any.  Gracefully ignores
    /// completions for instances the router no longer tracks.
    pub fn complete(&mut self, instance: InstanceId) -> Option<NextService> {
        let i = instance as usize;
        if !self.tracked(instance) || self.load_in_flight[i] == 0 {
            return None;
        }
        self.load_in_flight[i] -= 1;
        let function = self.load_function[i];
        let node = self.load_node[i];
        let next = self.load_queue[i].pop_front();
        let drained = self.load_in_flight[i] == 0;
        self.dec_node(node, 1);
        if let Some(arrival_ms) = next {
            return Some(NextService { function, node, arrival_ms });
        }
        if drained && !self.serving(function).contains(&instance) {
            // drained after leaving the routing set: drop the state
            self.load_live[i] = false;
        }
        None
    }

    /// Pop the oldest cold-waiting request of `f` (for re-dispatch once
    /// an instance serves again).
    pub fn pop_waiting(&mut self, f: FunctionId) -> Option<f64> {
        self.waiting.get_mut(f)?.pop_front()
    }

    /// Requests parked on `f`'s cold-wait queue.
    pub fn waiting_count(&self, f: FunctionId) -> usize {
        self.waiting.get(f).map(|q| q.len()).unwrap_or(0)
    }

    /// Requests parked on any function's cold-wait queue.
    pub fn total_waiting(&self) -> u64 {
        self.waiting.iter().map(|q| q.len() as u64).sum()
    }

    /// Requests sitting in instance FIFO queues (dispatched but not yet
    /// admitted into service).
    pub fn total_queued(&self) -> u64 {
        self.load_queue
            .iter()
            .zip(&self.load_live)
            .filter(|(_, live)| **live)
            .map(|(q, _)| q.len() as u64)
            .sum()
    }

    /// Requests dispatched to `instance` and not yet completed.
    pub fn in_flight_of(&self, instance: InstanceId) -> u32 {
        if self.tracked(instance) {
            self.load_in_flight[instance as usize]
        } else {
            0
        }
    }

    /// Requests currently dispatched to `node` (in service + queued).
    pub fn node_in_flight(&self, node: NodeId) -> u32 {
        self.node_in_flight.get(node).copied().unwrap_or(0)
    }

    /// Highest per-node in-flight count ever observed.
    pub fn peak_node_in_flight(&self) -> u32 {
        self.peak_node_in_flight
    }

    /// Requests currently dispatched cluster-wide.
    pub fn total_in_flight(&self) -> u32 {
        self.node_in_flight.iter().sum()
    }

    fn inc_node(&mut self, node: NodeId) {
        self.inc_node_by(node, 1);
    }

    fn inc_node_by(&mut self, node: NodeId, by: u32) {
        if self.node_in_flight.len() <= node {
            self.node_in_flight.resize(node + 1, 0);
        }
        let c = &mut self.node_in_flight[node];
        *c += by;
        self.peak_node_in_flight = self.peak_node_in_flight.max(*c);
    }

    fn dec_node(&mut self, node: NodeId, by: u32) {
        if let Some(c) = self.node_in_flight.get_mut(node) {
            Self::checked_gauge_sub(c, by, &mut self.gauge_skew_repairs);
        }
    }

    /// Subtract `by` from an in-flight gauge, loudly: an under-decrement
    /// trips the debug assertion (outside the crate's own unit tests,
    /// which inject skew on purpose to exercise this path) and is then
    /// repaired by saturating at zero and counted, so release builds keep
    /// coherent pick weights instead of a wrapped ~4-billion gauge.
    fn checked_gauge_sub(count: &mut u32, by: u32, repairs: &mut u64) {
        match count.checked_sub(by) {
            Some(v) => *count = v,
            None => {
                debug_assert!(
                    cfg!(test),
                    "in-flight gauge {count} under-decremented by {by}"
                );
                *count = 0;
                *repairs += 1;
            }
        }
    }

    /// Gauge under-decrements repaired since construction.  Zero in any
    /// healthy run — `rust/tests/router_props.rs` pins that across
    /// adversarial add/route/remove/complete storms.
    pub fn gauge_skew_repairs(&self) -> u64 {
        self.gauge_skew_repairs
    }

    /// Per-instance RPS under equal load balancing of `total_rps` (the
    /// aggregate window model).  Returns 0.0 — never NaN/inf — when the
    /// serving set is empty (all instances drained mid-window) or the
    /// offered load itself is not finite.
    pub fn per_instance_rps(&self, f: FunctionId, total_rps: f64) -> f64 {
        let n = self.serving_count(f);
        if n == 0 || !total_rps.is_finite() {
            0.0
        } else {
            total_rps / n as f64
        }
    }

    /// Consistency check against cluster state: the routing set must be
    /// exactly saturated instances of each function, and the queueing
    /// state must be internally coherent (per-node gauges equal the sum
    /// of per-instance in-flight; a busy instance's in-flight exceeds
    /// its queue by exactly one).
    pub fn check_consistent(&self, cluster: &Cluster) -> anyhow::Result<()> {
        use anyhow::ensure;
        for (f, serving) in self.serving.iter().enumerate() {
            for id in serving {
                let inst = cluster
                    .instance(*id)
                    .ok_or_else(|| anyhow::anyhow!("routing to evicted instance {id}"))?;
                ensure!(
                    inst.state == InstanceState::Saturated,
                    "instance {id} routed but {:?}",
                    inst.state
                );
                ensure!(inst.function == f, "instance {id} routed to wrong function");
                ensure!(
                    self.tracked(*id),
                    "serving instance {id} has no load state"
                );
                ensure!(
                    self.load_node[*id as usize] == inst.node,
                    "instance {id} load state on wrong node"
                );
            }
        }
        let mut per_node: Vec<u32> = vec![0; self.node_in_flight.len()];
        for i in 0..self.load_live.len() {
            if !self.load_live[i] {
                continue;
            }
            let (in_flight, queued) = (self.load_in_flight[i], self.load_queue[i].len());
            ensure!(
                in_flight as usize >= queued,
                "instance {i}: queue {queued} longer than in-flight {in_flight}"
            );
            ensure!(
                in_flight as usize - queued <= 1,
                "instance {i}: more than one request in service"
            );
            if in_flight > 0 {
                let node = self.load_node[i];
                if per_node.len() <= node {
                    per_node.resize(node + 1, 0);
                }
                per_node[node] += in_flight;
            }
        }
        for n in 0..per_node.len().max(self.node_in_flight.len()) {
            let gauge = self.node_in_flight.get(n).copied().unwrap_or(0);
            let actual = per_node.get(n).copied().unwrap_or(0);
            ensure!(
                gauge == actual,
                "node {n} in-flight gauge {gauge} != per-instance sum {actual}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn picked(d: Dispatch) -> InstanceId {
        d.instance().expect("expected a successful pick")
    }

    #[test]
    fn add_remove_balance() {
        let mut r = Router::new();
        r.add(0, 1, 0);
        r.add(0, 2, 1);
        assert_eq!(r.serving_count(0), 2);
        assert_eq!(r.per_instance_rps(0, 100.0), 50.0);
        assert!(r.remove(0, 1).is_empty());
        assert!(r.remove(0, 1).is_empty(), "double remove is a no-op");
        assert_eq!(r.per_instance_rps(0, 100.0), 100.0);
        assert_eq!(r.per_instance_rps(1, 100.0), 0.0);
    }

    #[test]
    fn reroute_counting() {
        let mut r = Router::new();
        r.add(0, 1, 0);
        r.remove(0, 1);
        assert_eq!(r.reroutes, 2);
    }

    #[test]
    fn per_instance_rps_never_nan_or_inf() {
        let mut r = Router::new();
        // empty serving set: 0.0, not NaN
        assert_eq!(r.per_instance_rps(0, 120.0), 0.0);
        // drained mid-window: instances existed, then all left
        r.add(0, 1, 0);
        r.add(0, 2, 1);
        r.remove(0, 1);
        r.remove(0, 2);
        assert_eq!(r.per_instance_rps(0, 120.0), 0.0);
        // non-finite offered load degrades to 0.0 as well
        r.add(0, 3, 0);
        assert_eq!(r.per_instance_rps(0, f64::NAN), 0.0);
        assert_eq!(r.per_instance_rps(0, f64::INFINITY), 0.0);
        assert!(r.per_instance_rps(0, 120.0).is_finite());
    }

    #[test]
    fn route_queues_fifo_per_instance() {
        let mut r = Router::with_seed(1);
        r.add(0, 7, 3);
        // idle → service starts; busy → FIFO queue on the same instance
        assert_eq!(r.route(0, 10.0), RouteOutcome::Started { instance: 7, node: 3 });
        assert_eq!(r.route(0, 11.0), RouteOutcome::Queued { instance: 7, node: 3 });
        assert_eq!(r.route(0, 12.0), RouteOutcome::Queued { instance: 7, node: 3 });
        assert_eq!(r.in_flight_of(7), 3);
        assert_eq!(r.node_in_flight(3), 3);
        assert_eq!(r.peak_node_in_flight(), 3);
        // completions pop the queue in arrival order
        let n1 = r.complete(7).unwrap();
        assert_eq!(n1.arrival_ms, 11.0);
        let n2 = r.complete(7).unwrap();
        assert_eq!(n2.arrival_ms, 12.0);
        assert!(r.complete(7).is_none());
        assert_eq!(r.node_in_flight(3), 0);
        // over-completion never underflows the gauges
        assert!(r.complete(7).is_none());
        assert_eq!(r.total_in_flight(), 0);
    }

    #[test]
    fn cold_wait_parks_and_pops_in_order() {
        let mut r = Router::new();
        assert_eq!(r.route(2, 5.0), RouteOutcome::ColdWait);
        assert_eq!(r.route(2, 6.0), RouteOutcome::ColdWait);
        assert_eq!(r.waiting_count(2), 2);
        assert_eq!(r.pop_waiting(2), Some(5.0));
        assert_eq!(r.pop_waiting(2), Some(6.0));
        assert_eq!(r.pop_waiting(2), None);
        assert_eq!(r.waiting_count(2), 0);
    }

    #[test]
    fn remove_orphans_queued_requests_but_not_the_in_service_one() {
        let mut r = Router::with_seed(4);
        r.add(0, 1, 0);
        r.route(0, 1.0); // in service
        r.route(0, 2.0); // queued
        r.route(0, 3.0); // queued
        let orphaned = r.remove(0, 1);
        assert_eq!(orphaned, vec![2.0, 3.0], "queued arrivals handed back in order");
        assert_eq!(r.in_flight_of(1), 1, "in-service request keeps draining");
        assert_eq!(r.node_in_flight(0), 1);
        assert!(r.complete(1).is_none(), "no queue left to pop");
        assert_eq!(r.in_flight_of(1), 0, "state dropped after the drain");
        assert_eq!(r.total_in_flight(), 0);
    }

    /// Regression: `remove` used an unchecked `-=` on the per-instance
    /// gauge, so an injected skew (queue longer than the gauge) wrapped
    /// to ~4 billion in release and panicked in debug — this test fails
    /// on the pre-fix code.  Post-fix the subtraction saturates at zero
    /// and the repair is counted, for both the per-instance gauge and
    /// its `dec_node` mirror.
    #[test]
    fn skewed_gauges_saturate_and_count_instead_of_wrapping() {
        let mut r = Router::with_seed(8);
        r.add(0, 1, 0);
        r.route(0, 1.0); // in service
        r.route(0, 2.0); // queued
        assert_eq!(r.gauge_skew_repairs(), 0);
        // inject skew: the FIFO queue is now longer than both gauges
        r.load_in_flight[1] = 0;
        r.node_in_flight[0] = 0;
        let orphaned = r.remove(0, 1);
        assert_eq!(orphaned, vec![2.0], "queued arrival still handed back");
        assert_eq!(r.in_flight_of(1), 0, "gauge saturated at zero, not wrapped");
        assert_eq!(r.node_in_flight(0), 0, "node gauge saturated too");
        assert_eq!(r.gauge_skew_repairs(), 2, "both repairs counted");
        assert_eq!(r.total_in_flight(), 0, "pick weights stay coherent");
    }

    #[test]
    fn pick_types_idle_vs_busy_vs_cold() {
        let mut r = Router::with_seed(2);
        assert_eq!(r.pick(0), Dispatch::ColdQueued);
        r.add(0, 5, 0);
        assert_eq!(r.pick(0), Dispatch::Routed(5), "idle slot is a Routed verdict");
        r.route(0, 1.0); // occupies the slot
        assert_eq!(r.pick(0), Dispatch::Saturated(5), "busy slot is a Saturated verdict");
        r.complete(5);
        assert_eq!(r.pick(0), Dispatch::Routed(5));
        assert_eq!(Dispatch::ColdQueued.instance(), None);
    }

    #[test]
    fn pick_prefers_lightly_loaded_instances() {
        let mut r = Router::with_seed(9);
        r.add(0, 1, 0);
        r.add(0, 2, 1);
        // saturate instance 1 with queued work
        r.load_in_flight[1] += 20;
        let mut hits = [0u32; 2];
        for _ in 0..400 {
            match picked(r.pick(0)) {
                1 => hits[0] += 1,
                2 => hits[1] += 1,
                other => panic!("picked unknown instance {other}"),
            }
        }
        assert!(
            hits[1] > hits[0] * 5,
            "idle instance must dominate: {hits:?} (weights 1/21 vs 1)"
        );
    }

    #[test]
    fn pluggable_policy_routes_and_receives_capacity_hints() {
        use crate::policy::{make_dispatch_policy, DispatchPolicyKind};
        let cat = crate::catalog::tests::test_catalog();
        let policy = make_dispatch_policy(DispatchPolicyKind::Locality, &cat).unwrap();
        let mut r = Router::with_policy(3, policy);
        assert_eq!(r.policy_name(), "locality");
        assert_eq!(Router::with_seed(0).policy_name(), "weighted", "default unchanged");
        r.add(0, 0, 0);
        r.add(0, 1, 1);
        r.capacity_hint(1, 50.0);
        let mut hits = [0u32; 2];
        for _ in 0..300 {
            hits[picked(r.pick(0)) as usize] += 1;
        }
        assert!(
            hits[1] > hits[0] * 5,
            "capacity-hinted node must draw most traffic: {hits:?}"
        );
    }

    #[test]
    fn pick_is_deterministic_per_seed_and_skips_rng_when_empty() {
        let seq = |seed: u64, warmups: usize| -> Vec<InstanceId> {
            let mut r = Router::with_seed(seed);
            // pick on an empty set must not consume the RNG
            for _ in 0..warmups {
                assert_eq!(r.pick(0), Dispatch::ColdQueued);
            }
            r.add(0, 1, 0);
            r.add(0, 2, 0);
            r.add(0, 3, 1);
            (0..64).map(|_| picked(r.pick(0))).collect()
        };
        assert_eq!(seq(5, 0), seq(5, 7), "empty picks must not advance the stream");
        assert_ne!(seq(5, 0), seq(6, 0), "seed must move the pick stream");
    }
}

//! Request router: dispatches load across a function's instances.
//!
//! The router load-balances over **saturated** instances only; **cached**
//! instances (dual-staged scaling) are excluded from the routing set the
//! same way the paper's K8s-Service label trick removes them.  A "logical
//! cold start" is just re-adding a cached instance to the routing set —
//! the <1 ms operation the autoscaler prefers over a real cold start.

use crate::catalog::FunctionId;
use crate::cluster::{Cluster, InstanceId, InstanceState};
use std::collections::HashMap;

/// Routing table: function → serving (saturated) instances.
#[derive(Debug, Default)]
pub struct Router {
    serving: HashMap<FunctionId, Vec<InstanceId>>,
    /// Count of re-route operations (logical cold starts, releases).
    pub reroutes: u64,
}

impl Router {
    pub fn new() -> Self {
        Self::default()
    }

    /// Instances currently receiving traffic for `f`.
    pub fn serving(&self, f: FunctionId) -> &[InstanceId] {
        self.serving.get(&f).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn serving_count(&self, f: FunctionId) -> usize {
        self.serving(f).len()
    }

    /// Add a newly started (or logically cold-started) instance.
    pub fn add(&mut self, f: FunctionId, id: InstanceId) {
        let v = self.serving.entry(f).or_default();
        debug_assert!(!v.contains(&id));
        v.push(id);
        self.reroutes += 1;
    }

    /// Remove an instance from the routing set (release or eviction).
    /// Returns whether it was serving.
    pub fn remove(&mut self, f: FunctionId, id: InstanceId) -> bool {
        if let Some(v) = self.serving.get_mut(&f) {
            let before = v.len();
            v.retain(|x| *x != id);
            if v.len() != before {
                self.reroutes += 1;
                return true;
            }
        }
        false
    }

    /// Per-instance RPS under equal load balancing of `total_rps`.
    pub fn per_instance_rps(&self, f: FunctionId, total_rps: f64) -> f64 {
        let n = self.serving_count(f);
        if n == 0 {
            0.0
        } else {
            total_rps / n as f64
        }
    }

    /// Consistency check against cluster state: the routing set must be
    /// exactly the saturated instances of each function.
    pub fn check_consistent(&self, cluster: &Cluster) -> anyhow::Result<()> {
        use anyhow::ensure;
        for (f, serving) in &self.serving {
            for id in serving {
                let inst = cluster
                    .instance(*id)
                    .ok_or_else(|| anyhow::anyhow!("routing to evicted instance {id}"))?;
                ensure!(
                    inst.state == InstanceState::Saturated,
                    "instance {id} routed but {:?}",
                    inst.state
                );
                ensure!(inst.function == *f, "instance {id} routed to wrong function");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_remove_balance() {
        let mut r = Router::new();
        r.add(0, 1);
        r.add(0, 2);
        assert_eq!(r.serving_count(0), 2);
        assert_eq!(r.per_instance_rps(0, 100.0), 50.0);
        assert!(r.remove(0, 1));
        assert!(!r.remove(0, 1), "double remove is a no-op");
        assert_eq!(r.per_instance_rps(0, 100.0), 100.0);
        assert_eq!(r.per_instance_rps(1, 100.0), 0.0);
    }

    #[test]
    fn reroute_counting() {
        let mut r = Router::new();
        r.add(0, 1);
        r.remove(0, 1);
        assert_eq!(r.reroutes, 2);
    }
}

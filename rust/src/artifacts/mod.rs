//! Native artifact generation — the zero-dependency replacement for the
//! Python `make artifacts` pipeline.
//!
//! The Python/JAX toolchain (`python/compile/aot.py`) remains the path
//! that lowers the predictor to HLO for the PJRT runtime, but nothing in
//! the default build can assume it exists: the offline image has no JAX
//! and CI machines have no Python deps.  This module regenerates every
//! artifact the Rust side actually consumes —
//!
//! * `functions.json`            synthetic catalog + hidden ground truth
//! * `interference_check.json`   golden vectors for the golden tests
//! * `forest.json`               trained + flattened random forest
//! * `predict_check.json`        feature rows → expected predictions
//! * `meta.json`                 shared contract (dims, layouts, batches)
//! * `latency_golden.json`       per-request p50/p95/p99 + histogram of a
//!   fixed 100 ms-bin Poisson scenario run end-to-end through the
//!   event-driven per-request router (golden-tested byte-identical)
//! * `model_comparison.json`     the natively computable Fig. 15/16/17a rows
//!
//! — in pure Rust, deterministic for a given [`GenConfig`] (all sampling
//! goes through [`crate::util::rng::Rng`]; no wall-clock values are
//! written to the deterministic files, so equal seeds produce
//! byte-identical JSON).  The generation logic mirrors
//! `python/compile/datagen.py`; numeric streams differ from numpy's, so
//! natively generated artifacts are self-consistent rather than
//! bit-identical to the Python ones.

pub mod trainer;

use crate::catalog::{Catalog, FunctionSpec};
use crate::config::RunConfig;
use crate::interference::{self, NodeMix, PROFILE_METRICS, RESOURCES};
use crate::model::{feature_row, N_FEATURES};
use crate::runtime::{ForestParams, NativeForest, NativeForestPredictor, Predictor};
use crate::sim::Simulation;
use crate::traces::{PoissonParams, Workload};
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Node/instance sizing shared with `python/compile/datagen.py`.
pub const NODE_MILLI_CPU: u64 = 48_000;
pub const NODE_MEM_MB: u64 = 128 * 1024;
pub const INSTANCE_MILLI_CPU: u64 = 4_000;
pub const INSTANCE_MEM_MB: u64 = 10 * 1024;
pub const QOS_FACTOR: f64 = 1.2;

/// Global sensitivity scale (datagen.SENS_SCALE).
const SENS_SCALE: f64 = 0.35;
const N_PROFILE: usize = PROFILE_METRICS.len();

/// Compiled batch-size variants advertised in `meta.json` (consumed by
/// the PJRT runtime when the HLO artifacts exist).
const BATCH_VARIANTS: [usize; 7] = [1, 8, 16, 32, 64, 128, 256];

/// All knobs of one generation run.  [`GenConfig::default`] mirrors the
/// Python pipeline's hyperparameters; [`GenConfig::quick`] is a small
/// configuration for tests and fast dev loops.
#[derive(Debug, Clone)]
pub struct GenConfig {
    /// Base seed; the catalog, train, test and golden streams derive from
    /// it with fixed offsets.
    pub seed: u64,
    pub n_functions: usize,
    pub train_rows: usize,
    pub test_rows: usize,
    /// Multiplicative label noise σ (tail-latency measurement jitter).
    pub noise_sigma: f64,
    pub n_trees: usize,
    pub depth: usize,
    pub min_samples_leaf: usize,
    pub feature_frac: f64,
    pub bootstrap_frac: f64,
    pub n_bins: usize,
    pub golden_cases: usize,
    /// Also write `model_comparison.json` (split-half + per-function
    /// errors; carries real fit wall-clock, so it is the one
    /// non-deterministic output).
    pub model_comparison: bool,
}

impl Default for GenConfig {
    fn default() -> Self {
        Self {
            seed: 7,
            n_functions: 6,
            train_rows: 20_000,
            test_rows: 2_000,
            noise_sigma: 0.05,
            n_trees: 64,
            depth: 10,
            min_samples_leaf: 2,
            feature_frac: 0.7,
            bootstrap_frac: 0.8,
            n_bins: 128,
            golden_cases: 64,
            model_comparison: true,
        }
    }
}

impl GenConfig {
    /// Small budget for tests and fast iteration (seconds even in debug).
    pub fn quick() -> Self {
        Self {
            train_rows: 3_000,
            test_rows: 400,
            n_trees: 16,
            depth: 8,
            golden_cases: 48,
            ..Self::default()
        }
    }
}

/// Summary of one generation run (for logging and tests).
#[derive(Debug, Clone)]
pub struct GenReport {
    pub n_functions: usize,
    pub train_rows: usize,
    /// Held-out mean relative error of the trained forest.
    pub test_error: f64,
    /// Wall-clock spent in `fit` (only recorded in model_comparison.json).
    pub fit_seconds: f64,
}

/// Generate every native artifact into `out_dir`.
pub fn generate(out_dir: &Path, cfg: &GenConfig) -> Result<GenReport> {
    ensure!(cfg.n_functions > 0, "catalog cannot be empty");
    ensure!(cfg.depth >= 1 && cfg.depth <= 16, "depth out of range");
    ensure!(cfg.n_bins >= 2, "need at least 2 histogram bins");
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;

    // -- catalog + golden vectors ----------------------------------------
    let specs = make_catalog(cfg.n_functions, cfg.seed);
    let cat = Catalog::from_functions(specs);
    cat.validate()?;
    write_json(&out_dir.join("functions.json"), &catalog_to_json(&cat))?;
    let golden = golden_vectors(&cat, cfg.golden_cases, cfg.seed.wrapping_add(92));
    write_json(&out_dir.join("interference_check.json"), &golden)?;

    // -- datasets ---------------------------------------------------------
    let train = sample_dataset(&cat, cfg.train_rows, cfg.seed.wrapping_add(4), cfg.noise_sigma);
    let test = sample_dataset(&cat, cfg.test_rows, cfg.seed.wrapping_add(6), cfg.noise_sigma);

    // -- forest: target is the log-slowdown (latency / solo) --------------
    let targets: Vec<f64> = train
        .y
        .iter()
        .zip(&train.x)
        .map(|(y, row)| y.ln() - (row[0] as f64).ln())
        .collect();
    let t0 = std::time::Instant::now();
    let params = trainer::train_forest(&train.x, &targets, cfg)?;
    let fit_seconds = t0.elapsed().as_secs_f64();
    let forest = NativeForest::new(params.clone());

    let pred: Vec<f32> = forest.predict(&test.x);
    let test_error = relative_error(&pred, &test.y);
    ensure!(
        test_error.is_finite() && test_error < 0.6,
        "trained forest failed the sanity bar: test error {test_error:.3}"
    );
    write_json(&out_dir.join("forest.json"), &forest_to_json(&params, test_error))?;

    // -- predict_check golden vectors -------------------------------------
    let check_n = test.x.len().min(64);
    let check_rows = &test.x[..check_n];
    let expected = forest.predict(check_rows);
    let check = obj(vec![
        ("x", f32_mat_json(check_rows)),
        ("expected_ms", arr(expected.iter().map(|v| num(*v as f64)))),
    ]);
    write_json(&out_dir.join("predict_check.json"), &check)?;

    // -- per-request latency golden ---------------------------------------
    // Reload the forest through the same loader the tests use so the
    // golden run sees exactly the artifact bytes (f32 round-trips are
    // lossless, but reloading removes even that assumption).
    let reloaded = ForestParams::load(&out_dir.join("forest.json"))?;
    let golden_latency = latency_golden(&cat, reloaded)?;
    write_json(&out_dir.join("latency_golden.json"), &golden_latency)?;

    // -- meta --------------------------------------------------------------
    let meta = obj(vec![
        ("n_features", num(N_FEATURES as f64)),
        ("n_profile_metrics", num(N_PROFILE as f64)),
        ("profile_metrics", arr(PROFILE_METRICS.iter().map(|m| s(m)))),
        ("n_trees", num(cfg.n_trees as f64)),
        ("depth", num(cfg.depth as f64)),
        ("batch_variants", arr(BATCH_VARIANTS.iter().map(|b| num(*b as f64)))),
        (
            "feature_layout",
            arr([
                "solo_latency_ms",
                "target_profile[13]",
                "target_sat",
                "target_cached",
                "agg_sat_profile[13]",
                "agg_cached_profile[13]",
                "total_sat",
                "total_cached",
            ]
            .iter()
            .map(|v| s(v))),
        ),
        ("target", s("p90_latency_ms")),
        ("train_rows", num(cfg.train_rows as f64)),
        ("label_noise_sigma", num(cfg.noise_sigma)),
        ("generator", s("native")),
        ("seed", num(cfg.seed as f64)),
    ]);
    write_json(&out_dir.join("meta.json"), &meta)?;

    // -- natively computable model-comparison rows ------------------------
    if cfg.model_comparison {
        let mc = model_comparison(&pred, &test, test_error, fit_seconds);
        write_json(&out_dir.join("model_comparison.json"), &mc)?;
    }

    Ok(GenReport {
        n_functions: cfg.n_functions,
        train_rows: train.x.len(),
        test_error,
        fit_seconds,
    })
}

/// The fixed scenario behind `latency_golden.json`: a 100 ms-bin Poisson
/// workload routed per-request through the event core.  Kept `pub` so
/// `rust/tests/golden.rs` replays the *identical* configuration and can
/// assert byte-identical histogram JSON against the checked-in artifact.
pub fn latency_golden_scenario(cat: &Catalog) -> (RunConfig, Workload) {
    let mut cfg = RunConfig::jiagu_45();
    cfg.n_nodes = 6;
    cfg.duration_s = 10;
    cfg.seed = 4242;
    cfg.requests = true;
    cfg.eval_interval_ms = 250.0;
    let params = PoissonParams { duration_s: 10, bin_ms: 100.0, mean_concurrency: 2.0 };
    let workload = Workload::poisson(cat, &params, 4242);
    (cfg, workload)
}

/// Run the [`latency_golden_scenario`] end-to-end over `forest` and
/// serialise the per-request golden vectors (percentiles, per-function
/// QoS violations, the full fixed-bin histogram).  Deterministic: equal
/// catalog + forest bytes give equal JSON bytes.
pub fn latency_golden(cat: &Catalog, forest: ForestParams) -> Result<Json> {
    let predictor: Arc<dyn Predictor> = Arc::new(NativeForestPredictor::new(forest));
    let (cfg, workload) = latency_golden_scenario(cat);
    let report = Simulation::new(cat.clone(), cfg, predictor).run_workload(&workload)?;
    ensure!(report.requests_served > 0, "latency golden scenario routed no requests");
    Ok(obj(vec![
        ("scenario", s("poisson-100ms-per-request")),
        ("requests", num(report.requests_served as f64)),
        ("cold_waits", num(report.cold_wait_requests as f64)),
        ("stranded", num(report.stranded_requests as f64)),
        ("peak_node_in_flight", num(report.peak_node_in_flight as f64)),
        ("p50_ms", num(report.request_p50_ms)),
        ("p95_ms", num(report.request_p95_ms)),
        ("p99_ms", num(report.request_p99_ms)),
        (
            "requests_per_function",
            arr(report.request_counts.iter().map(|v| num(*v as f64))),
        ),
        (
            "qos_violations",
            arr(report.request_qos_violations.iter().map(|v| num(*v as f64))),
        ),
        ("histogram", report.latency_hist.to_json()),
    ]))
}

/// Paper's error metric: mean |P̂ − P| / P.
pub fn relative_error(pred: &[f32], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let total: f64 = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| ((*p as f64) - t).abs() / t)
        .sum();
    total / truth.len() as f64
}

// ---------------------------------------------------------------------------
// Catalog synthesis (datagen.make_catalog mirror).
// ---------------------------------------------------------------------------

/// The six named archetypes (ServerlessBench/FunctionBench stand-ins).
/// Columns = RESOURCES: cpu, membw, llc, l1, tlb, branch.
#[rustfmt::skip]
const ARCHETYPES: [(&str, [f64; 6], [f64; 6], f64); 6] = [
    ("rnn",        [2.8, 0.9, 1.2, 0.8, 0.6, 2.6], [0.9, 0.3, 0.5, 0.3, 0.2, 1.0], 118.0),
    ("img_resize", [1.6, 3.2, 2.6, 0.9, 0.7, 0.5], [0.5, 1.1, 0.9, 0.3, 0.2, 0.2],  62.0),
    ("linpack",    [3.4, 1.4, 0.8, 2.4, 0.5, 0.4], [1.2, 0.5, 0.3, 0.8, 0.2, 0.2],  41.0),
    ("log_proc",   [1.2, 1.1, 1.0, 1.3, 2.8, 1.2], [0.4, 0.4, 0.4, 0.5, 1.0, 0.4],  23.0),
    ("chameleon",  [2.0, 1.8, 2.9, 1.1, 1.0, 1.1], [0.7, 0.6, 1.0, 0.4, 0.4, 0.4],  84.0),
    ("gzip",       [2.6, 2.7, 1.4, 0.9, 0.8, 0.7], [0.9, 0.9, 0.5, 0.3, 0.3, 0.3],  35.0),
];

/// Derive the observable Table-3 profile as noisy correlates of the
/// hidden pressure vector (datagen._profile_from_pressure mirror).
fn profile_from_pressure(pressure: &[f64], rng: &mut Rng) -> Vec<f64> {
    let (cpu, membw, llc, l1, tlb, branch) = (
        pressure[0], pressure[1], pressure[2], pressure[3], pressure[4], pressure[5],
    );
    let mut n = |sigma: f64| rng.normal_ms(1.0, sigma);
    vec![
        1000.0 * (0.4 + 0.75 * cpu) * n(0.05),
        1e9 * (0.2 + 0.5 * cpu + 0.2 * l1) * n(0.05),
        (2.6 - 0.25 * membw - 0.2 * llc) * n(0.04),
        900.0 * (0.3 + 0.5 * tlb) * n(0.08),
        (1.0 + 1.3 * membw * 0.4) * n(0.05),
        (2.0 + 9.0 * l1 * 0.4) * n(0.06),
        (1.0 + 5.0 * l1 * 0.3 + 2.0 * branch * 0.2) * n(0.06),
        (1.0 + 6.0 * llc * 0.35) * n(0.06),
        (0.3 + 2.5 * llc * 0.4 + 1.0 * membw * 0.2) * n(0.06),
        (0.2 + 1.8 * tlb * 0.4) * n(0.07),
        (0.1 + 0.9 * tlb * 0.3) * n(0.07),
        (0.5 + 4.0 * branch * 0.4) * n(0.06),
        1000.0 * (0.3 + 2.2 * membw) * n(0.05),
    ]
}

/// Generate a catalog: the six named archetypes first, then functions
/// sampled around the archetype cloud so larger catalogs stay in
/// distribution yet are all distinct.
pub fn make_catalog(n_functions: usize, seed: u64) -> Vec<FunctionSpec> {
    let mut rng = Rng::seed_from(seed);
    let mut specs = Vec::with_capacity(n_functions);
    for i in 0..n_functions {
        let (name, pressure, sensitivity, base) = if i < ARCHETYPES.len() {
            let (name, p, sv, base) = &ARCHETYPES[i];
            let sens: Vec<f64> = sv.iter().map(|v| v * SENS_SCALE).collect();
            (name.to_string(), p.to_vec(), sens, *base)
        } else {
            let (_, p, sv, base) = &ARCHETYPES[rng.below(ARCHETYPES.len() as u64) as usize];
            let pressure: Vec<f64> =
                p.iter().map(|v| (v * rng.range_f64(0.6, 1.5)).max(0.2)).collect();
            let sens: Vec<f64> = sv
                .iter()
                .map(|v| (v * SENS_SCALE * rng.range_f64(0.6, 1.5)).max(0.02))
                .collect();
            let base = base * rng.range_f64(0.5, 1.8);
            (format!("fn_{i:03}"), pressure, sens, base)
        };
        let profile = profile_from_pressure(&pressure, &mut rng);
        let solo = interference::slowdown(
            &interference::utilisation_single(&pressure),
            &sensitivity,
        ) * base;
        specs.push(FunctionSpec {
            name,
            profile,
            solo_latency_ms: solo,
            saturated_rps: (2500.0 / base * 100.0).round() / 100.0,
            qos_latency_ms: QOS_FACTOR * solo,
            milli_cpu: INSTANCE_MILLI_CPU,
            mem_mb: INSTANCE_MEM_MB,
            pressure,
            sensitivity,
            base_latency_ms: base,
        });
    }
    specs
}

// ---------------------------------------------------------------------------
// Training-set sampling (datagen.sample_dataset mirror).
// ---------------------------------------------------------------------------

/// One labelled dataset: feature rows, noisy latency labels (ms), and the
/// target function's name per row.
pub struct Dataset {
    pub x: Vec<Vec<f32>>,
    pub y: Vec<f64>,
    pub names: Vec<String>,
}

/// Sample random node mixes and label every present function.  Coverage
/// bounds must exceed every reachable QoS-capacity (see the note in
/// datagen.py), otherwise the capacity sweep extrapolates past the trees'
/// training range.
pub fn sample_dataset(cat: &Catalog, n_samples: usize, seed: u64, noise_sigma: f64) -> Dataset {
    const MAX_COLOCATED: usize = 6;
    const MAX_SAT: u64 = 24;
    const MAX_CACHED: u64 = 5;
    const MAX_TOTAL_SAT: u32 = 44;
    let mut rng = Rng::seed_from(seed);
    let mut out = Dataset { x: Vec::new(), y: Vec::new(), names: Vec::new() };
    while out.x.len() < n_samples {
        let kmax = MAX_COLOCATED.min(cat.len()) as u64;
        let k = rng.range_u64(1, kmax) as usize;
        let chosen = rng.choose_k(cat.len(), k);
        let sat: Vec<u32> = (0..k).map(|_| rng.range_u64(0, MAX_SAT) as u32).collect();
        let cached: Vec<u32> = (0..k).map(|_| rng.range_u64(0, MAX_CACHED) as u32).collect();
        let tot_sat: u32 = sat.iter().sum();
        let tot_cached: u32 = cached.iter().sum();
        if tot_sat + tot_cached == 0 || tot_sat > MAX_TOTAL_SAT {
            continue;
        }
        let mix = NodeMix::new(
            chosen.iter().enumerate().map(|(i, f)| (*f, sat[i], cached[i])).collect(),
        );
        for t in 0..k {
            if sat[t] == 0 {
                continue;
            }
            let fid = chosen[t];
            let truth = interference::ground_truth_latency(cat, &mix, fid);
            let noisy = (truth * (1.0 + rng.normal_ms(0.0, noise_sigma))).max(truth * 1e-3);
            out.x.push(feature_row(cat, &mix, fid));
            out.y.push(noisy);
            out.names.push(cat.get(fid).name.clone());
            if out.x.len() == n_samples {
                break;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Golden vectors (datagen.golden_vectors mirror).
// ---------------------------------------------------------------------------

/// Random node mixes with exact ground-truth latencies + feature rows,
/// serialised in the layout `rust/tests/golden.rs` consumes.
pub fn golden_vectors(cat: &Catalog, n_cases: usize, seed: u64) -> Json {
    let mut rng = Rng::seed_from(seed);
    let mut cases = Vec::with_capacity(n_cases);
    for _ in 0..n_cases {
        let kmax = 6.min(cat.len()) as u64;
        let k = rng.range_u64(1, kmax) as usize;
        let mut chosen = rng.choose_k(cat.len(), k);
        chosen.sort_unstable();
        let mut sat: Vec<u32> = (0..k).map(|_| rng.range_u64(0, 12) as u32).collect();
        let cached: Vec<u32> = (0..k).map(|_| rng.range_u64(0, 4) as u32).collect();
        if sat.iter().sum::<u32>() == 0 {
            sat[0] = 1;
        }
        let target_pos = rng.below(k as u64) as usize;
        let mix = NodeMix::new(
            chosen.iter().enumerate().map(|(i, f)| (*f, sat[i], cached[i])).collect(),
        );
        let target_fid = chosen[target_pos];
        cases.push(obj(vec![
            ("functions", arr(chosen.iter().map(|f| s(&cat.get(*f).name)))),
            ("sat", arr(sat.iter().map(|v| num(*v as f64)))),
            ("cached", arr(cached.iter().map(|v| num(*v as f64)))),
            ("target", num(target_pos as f64)),
            (
                "utilisation",
                arr(interference::node_utilisation(cat, &mix).into_iter().map(num)),
            ),
            ("latency_ms", num(interference::ground_truth_latency(cat, &mix, target_fid))),
            ("features", arr(feature_row(cat, &mix, target_fid).iter().map(|v| num(*v as f64)))),
        ]));
    }
    Json::Arr(cases)
}

// ---------------------------------------------------------------------------
// JSON serialisation.
// ---------------------------------------------------------------------------

fn f32_mat_json(rows: &[Vec<f32>]) -> Json {
    arr(rows.iter().map(|r| arr(r.iter().map(|v| num(*v as f64)))))
}

fn catalog_to_json(cat: &Catalog) -> Json {
    obj(vec![
        ("profile_metrics", arr(PROFILE_METRICS.iter().map(|m| s(m)))),
        ("resources", arr(RESOURCES.iter().map(|r| s(r)))),
        (
            "resource_capacity",
            arr(interference::RESOURCE_CAPACITY.iter().map(|c| num(*c))),
        ),
        ("cached_pressure_factor", num(interference::CACHED_PRESSURE_FACTOR)),
        ("node_milli_cpu", num(NODE_MILLI_CPU as f64)),
        ("node_mem_mb", num(NODE_MEM_MB as f64)),
        ("qos_factor", num(QOS_FACTOR)),
        (
            "functions",
            arr(cat.functions.iter().map(|f| {
                obj(vec![
                    ("name", s(&f.name)),
                    ("profile", arr(f.profile.iter().map(|v| num(*v)))),
                    ("solo_latency_ms", num(f.solo_latency_ms)),
                    ("saturated_rps", num(f.saturated_rps)),
                    ("qos_latency_ms", num(f.qos_latency_ms)),
                    ("milli_cpu", num(f.milli_cpu as f64)),
                    ("mem_mb", num(f.mem_mb as f64)),
                    ("pressure", arr(f.pressure.iter().map(|v| num(*v)))),
                    ("sensitivity", arr(f.sensitivity.iter().map(|v| num(*v)))),
                    ("base_latency_ms", num(f.base_latency_ms)),
                ])
            })),
        ),
    ])
}

fn forest_to_json(params: &crate::runtime::ForestParams, test_error: f64) -> Json {
    // +inf padding is serialised as 1e30 (the Python contract); fit
    // wall-clock is deliberately NOT written so equal seeds give
    // byte-identical files (it lives in model_comparison.json instead).
    obj(vec![
        ("n_trees", num(params.n_trees as f64)),
        ("depth", num(params.depth as f64)),
        ("n_features", num(params.n_features as f64)),
        (
            "feature",
            arr(params.feature.iter().map(|row| arr(row.iter().map(|v| num(*v as f64))))),
        ),
        (
            "threshold",
            arr(params
                .threshold
                .iter()
                .map(|row| arr(row.iter().map(|v| num(*v as f64))))),
        ),
        (
            "leaf",
            arr(params.leaf.iter().map(|row| arr(row.iter().map(|v| num(*v as f64))))),
        ),
        ("mean", arr(params.mean.iter().map(|v| num(*v as f64)))),
        ("std", arr(params.std.iter().map(|v| num(*v as f64)))),
        ("test_error", num(test_error)),
    ])
}

fn model_comparison(pred: &[f32], test: &Dataset, test_error: f64, fit_seconds: f64) -> Json {
    let half = pred.len() / 2;
    let err_1 = relative_error(&pred[..half], &test.y[..half]);
    let err_2 = relative_error(&pred[half..], &test.y[half..]);
    let mut names: Vec<&String> = test.names.iter().collect();
    names.sort_unstable();
    names.dedup();
    let per_function = obj(names
        .iter()
        .map(|name| {
            let (mut total, mut count) = (0.0, 0usize);
            for i in 0..pred.len() {
                if &test.names[i] == *name {
                    total += ((pred[i] as f64) - test.y[i]).abs() / test.y[i];
                    count += 1;
                }
            }
            (name.as_str(), num(if count == 0 { 0.0 } else { total / count as f64 }))
        })
        .collect());
    obj(vec![
        ("generator", s("native")),
        (
            "fig15a",
            obj(vec![
                ("jiagu", num(test_error)),
                ("jiagu_split1", num(err_1)),
                ("jiagu_split2", num(err_2)),
                ("per_function", per_function),
            ]),
        ),
        (
            "fig16",
            obj(vec![(
                "jiagu_rfr",
                obj(vec![
                    ("error", num(test_error)),
                    ("fit_seconds", num(fit_seconds)),
                    ("dims", num(N_FEATURES as f64)),
                ]),
            )]),
        ),
        (
            "fig17a",
            obj(vec![(
                "jiagu",
                obj(vec![
                    ("dims", num(N_FEATURES as f64)),
                    ("fit_seconds", num(fit_seconds)),
                ]),
            )]),
        ),
    ])
}

fn write_json(path: &Path, j: &Json) -> Result<()> {
    let mut text = j.to_string();
    text.push('\n');
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_archetype_contract() {
        let specs = make_catalog(8, 7);
        assert_eq!(specs.len(), 8);
        assert_eq!(specs[0].name, "rnn");
        assert_eq!(specs[5].name, "gzip");
        assert_eq!(specs[6].name, "fn_006");
        let cat = Catalog::from_functions(specs);
        cat.validate().unwrap();
        for f in 0..cat.len() {
            // same request sizing for every function (paper §7.1)
            assert_eq!(cat.request_packing_limit(f), 12);
        }
    }

    #[test]
    fn make_catalog_is_deterministic() {
        let a = make_catalog(10, 42);
        let b = make_catalog(10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.profile, y.profile);
            assert_eq!(x.solo_latency_ms, y.solo_latency_ms);
        }
        let c = make_catalog(10, 43);
        assert_ne!(a[6].base_latency_ms, c[6].base_latency_ms);
    }

    #[test]
    fn dataset_respects_bounds_and_layout() {
        let cat = Catalog::from_functions(make_catalog(6, 7));
        let d = sample_dataset(&cat, 200, 11, 0.05);
        assert_eq!(d.x.len(), 200);
        assert_eq!(d.y.len(), 200);
        assert_eq!(d.names.len(), 200);
        for (row, y) in d.x.iter().zip(&d.y) {
            assert_eq!(row.len(), N_FEATURES);
            assert!(*y > 0.0);
            // total saturated instances within the documented range
            let tot_sat = row[N_FEATURES - 2];
            assert!(tot_sat >= 1.0 && tot_sat <= 44.0, "total sat {tot_sat}");
        }
    }

    #[test]
    fn golden_vectors_roundtrip_through_json() {
        let cat = Catalog::from_functions(make_catalog(6, 7));
        let golden = golden_vectors(&cat, 16, 99);
        let parsed = Json::parse(&golden.to_string()).unwrap();
        let cases = parsed.as_arr().unwrap();
        assert_eq!(cases.len(), 16);
        for case in cases {
            let want = case.get("latency_ms").unwrap().as_f64().unwrap();
            assert!(want > 0.0 && want.is_finite());
            let feats = case.get("features").unwrap().f32_vec().unwrap();
            assert_eq!(feats.len(), N_FEATURES);
        }
    }
}

//! Histogram-based random-forest training in pure Rust — the native port
//! of `python/compile/forest.py` (bagged CART, quantile-binned splits,
//! perfect-tree flattening).
//!
//! Semantics mirror the Python trainer:
//!
//! * per-feature bin edges at training-set quantiles (deduplicated);
//! * splits maximise `sum_L²/n_L + sum_R²/n_R` (variance reduction with
//!   the constant term dropped), rejecting zero-gain splits;
//! * trees grow to a fixed max depth and are flattened into perfect
//!   binary trees: early leaves pad their subtree with
//!   `(feature=0, threshold=+inf)` internal nodes (comparisons always go
//!   left) and replicate the leaf value across the covered slots;
//! * split thresholds are found in raw feature space and standardised at
//!   the end (`thr' = (thr − mean[f]) / std[f]`), because the runtime
//!   z-scores features before traversal.
//!
//! Determinism: all sampling goes through [`crate::util::rng::Rng`]
//! seeded from the generation config; no wall-clock enters the output.

use super::GenConfig;
use crate::runtime::ForestParams;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};

/// Train a forest on raw feature rows `x` (one row per sample) against
/// log-slowdown targets `y`, returning flattened, standardised
/// [`ForestParams`] ready for [`crate::runtime::NativeForest`].
pub fn train_forest(x: &[Vec<f32>], y: &[f64], cfg: &GenConfig) -> Result<ForestParams> {
    let n = x.len();
    ensure!(n >= 16, "need at least 16 training rows, got {n}");
    ensure!(y.len() == n, "targets/rows length mismatch");
    let n_features = x[0].len();
    ensure!(x.iter().all(|r| r.len() == n_features), "ragged feature rows");
    ensure!(y.iter().all(|v| v.is_finite()), "non-finite training target");

    // -- per-feature stats, bin edges and binned matrix -------------------
    let mut mean = vec![0.0f64; n_features];
    let mut std = vec![0.0f64; n_features];
    let mut edges: Vec<Vec<f64>> = Vec::with_capacity(n_features);
    let mut binned = vec![0u16; n * n_features];
    let mut col = vec![0.0f64; n];
    for f in 0..n_features {
        for (i, row) in x.iter().enumerate() {
            col[i] = row[f] as f64;
        }
        let m = col.iter().sum::<f64>() / n as f64;
        let var = col.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / n as f64;
        mean[f] = m;
        std[f] = var.sqrt().max(1e-6);
        let e = quantile_edges(&col, cfg.n_bins);
        for (i, row) in x.iter().enumerate() {
            let v = row[f] as f64;
            binned[i * n_features + f] = e.partition_point(|edge| *edge <= v) as u16;
        }
        edges.push(e);
    }

    // -- grow the bagged ensemble -----------------------------------------
    let n_internal = (1usize << cfg.depth) - 1;
    let n_leaves = 1usize << cfg.depth;
    let grower = Grower {
        binned: &binned,
        edges: &edges,
        y,
        n_features,
        max_depth: cfg.depth,
        min_leaf: cfg.min_samples_leaf.max(1),
        n_feat_sub: ((cfg.feature_frac * n_features as f64) as usize).max(1),
        n_bins: cfg.n_bins,
        n_internal,
    };
    let mut rng = Rng::seed_from(cfg.seed.wrapping_add(3));
    let n_boot = ((cfg.bootstrap_frac * n as f64) as usize).max(8);
    let mut feature = Vec::with_capacity(cfg.n_trees);
    let mut threshold_raw = Vec::with_capacity(cfg.n_trees);
    let mut leaf = Vec::with_capacity(cfg.n_trees);
    for _ in 0..cfg.n_trees {
        let idx: Vec<u32> = (0..n_boot).map(|_| rng.below(n as u64) as u32).collect();
        let mut feat_t = vec![0i32; n_internal];
        let mut thr_t = vec![f64::INFINITY; n_internal];
        let mut leaf_t = vec![0f32; n_leaves];
        grower.grow(idx, 0, 0, &mut rng, &mut feat_t, &mut thr_t, &mut leaf_t);
        feature.push(feat_t);
        threshold_raw.push(thr_t);
        leaf.push(leaf_t);
    }

    // -- standardise thresholds into the runtime's z-scored space ---------
    let threshold: Vec<Vec<f32>> = threshold_raw
        .iter()
        .zip(&feature)
        .map(|(thr_t, feat_t)| {
            thr_t
                .iter()
                .zip(feat_t)
                .map(|(t, f)| {
                    if t.is_finite() {
                        ((t - mean[*f as usize]) / std[*f as usize]) as f32
                    } else {
                        1e30f32
                    }
                })
                .collect()
        })
        .collect();

    let params = ForestParams {
        n_trees: cfg.n_trees,
        depth: cfg.depth,
        n_features,
        feature,
        threshold,
        leaf,
        mean: mean.iter().map(|v| *v as f32).collect(),
        std: std.iter().map(|v| *v as f32).collect(),
        test_error: 0.0,
        fit_seconds: 0.0,
    };
    params.validate()?;
    Ok(params)
}

/// Per-feature bin edges at training-set quantiles (linear interpolation,
/// exact duplicates removed) — `forest._quantile_bins` mirror.
fn quantile_edges(col: &[f64], n_bins: usize) -> Vec<f64> {
    let mut sorted = col.to_vec();
    sorted.sort_by(f64::total_cmp);
    let n = sorted.len();
    let mut edges: Vec<f64> = Vec::with_capacity(n_bins.saturating_sub(1));
    for j in 1..n_bins {
        let q = j as f64 / n_bins as f64;
        let pos = q * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let frac = pos - lo as f64;
        let v = if lo + 1 < n {
            sorted[lo] + (sorted[lo + 1] - sorted[lo]) * frac
        } else {
            sorted[lo]
        };
        if v > edges.last().copied().unwrap_or(f64::NEG_INFINITY) {
            edges.push(v);
        }
    }
    edges
}

/// Recursive CART grower writing directly into one tree's perfect-shape
/// arrays (`feat`/`thr` level-ordered internal nodes, `leaf` dense).
struct Grower<'a> {
    /// `n × F` row-major quantile-bin indices.
    binned: &'a [u16],
    edges: &'a [Vec<f64>],
    y: &'a [f64],
    n_features: usize,
    max_depth: usize,
    min_leaf: usize,
    n_feat_sub: usize,
    n_bins: usize,
    n_internal: usize,
}

impl Grower<'_> {
    fn grow(
        &self,
        idx: Vec<u32>,
        pos: usize,
        depth: usize,
        rng: &mut Rng,
        feat: &mut [i32],
        thr: &mut [f64],
        leaf: &mut [f32],
    ) {
        let n = idx.len();
        let mean = idx.iter().map(|i| self.y[*i as usize]).sum::<f64>() / n as f64;
        let spread = {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for i in &idx {
                let v = self.y[*i as usize];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            hi - lo
        };
        if depth >= self.max_depth || n < 2 * self.min_leaf || spread == 0.0 {
            self.pad(pos, depth, mean as f32, feat, thr, leaf);
            return;
        }
        let feats = rng.choose_k(self.n_features, self.n_feat_sub);
        let Some((best_f, best_b)) = self.best_split(&idx, &feats) else {
            self.pad(pos, depth, mean as f32, feat, thr, leaf);
            return;
        };
        let (left, right): (Vec<u32>, Vec<u32>) = idx
            .iter()
            .copied()
            .partition(|i| self.binned[*i as usize * self.n_features + best_f] as usize <= best_b);
        if left.len() < self.min_leaf || right.len() < self.min_leaf {
            self.pad(pos, depth, mean as f32, feat, thr, leaf);
            return;
        }
        feat[pos] = best_f as i32;
        thr[pos] = if best_b < self.edges[best_f].len() {
            self.edges[best_f][best_b]
        } else {
            f64::INFINITY
        };
        self.grow(left, 2 * pos + 1, depth + 1, rng, feat, thr, leaf);
        self.grow(right, 2 * pos + 2, depth + 1, rng, feat, thr, leaf);
    }

    /// Variance-reduction split search over the chosen features: one
    /// histogram pass per feature, then a prefix scan over bins.  Returns
    /// `(feature, bin)` of the best valid split, or `None` when no split
    /// beats the parent (`gain ≤ (Σy)²/n + 1e-12`, the zero-gain guard).
    fn best_split(&self, idx: &[u32], feats: &[usize]) -> Option<(usize, usize)> {
        let n = idx.len() as f64;
        let total: f64 = idx.iter().map(|i| self.y[*i as usize]).sum();
        let nb = self.n_bins + 1;
        let mut best = None;
        let mut best_gain = total * total / n + 1e-12;
        let mut counts = vec![0u32; nb];
        let mut sums = vec![0f64; nb];
        for &f in feats {
            counts.fill(0);
            sums.fill(0.0);
            for i in idx {
                let b = self.binned[*i as usize * self.n_features + f] as usize;
                counts[b] += 1;
                sums[b] += self.y[*i as usize];
            }
            let mut count_left = 0usize;
            let mut sum_left = 0f64;
            for b in 0..nb - 1 {
                count_left += counts[b] as usize;
                sum_left += sums[b];
                let count_right = idx.len() - count_left;
                if count_left < self.min_leaf || count_right < self.min_leaf {
                    continue;
                }
                let sum_right = total - sum_left;
                let gain = sum_left * sum_left / count_left as f64
                    + sum_right * sum_right / count_right as f64;
                if gain > best_gain {
                    best_gain = gain;
                    best = Some((f, b));
                }
            }
        }
        best
    }

    /// Fill the perfect-tree subtree under `pos` for an early leaf:
    /// always-left internal padding plus the replicated leaf value.
    fn pad(
        &self,
        pos: usize,
        depth: usize,
        value: f32,
        feat: &mut [i32],
        thr: &mut [f64],
        leaf: &mut [f32],
    ) {
        if depth == self.max_depth {
            leaf[pos - self.n_internal] = value;
            return;
        }
        feat[pos] = 0;
        thr[pos] = f64::INFINITY;
        self.pad(2 * pos + 1, depth + 1, value, feat, thr, leaf);
        self.pad(2 * pos + 2, depth + 1, value, feat, thr, leaf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeForest;

    /// y = step on feature 1 plus a linear term on feature 0 — an easy
    /// target a depth-limited forest must fit well.
    fn toy_dataset(n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<f64>) {
        let mut rng = Rng::seed_from(seed);
        let mut x = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let a = rng.range_f64(0.1, 4.0);
            let b = rng.range_f64(-1.0, 1.0);
            let c = rng.range_f64(0.0, 1.0); // noise-free distractor
            x.push(vec![a as f32, b as f32, c as f32]);
            y.push(0.25 * a + if b > 0.2 { 1.0 } else { 0.0 });
        }
        (x, y)
    }

    fn toy_config() -> GenConfig {
        GenConfig {
            n_trees: 12,
            depth: 6,
            min_samples_leaf: 2,
            feature_frac: 1.0,
            n_bins: 32,
            ..GenConfig::default()
        }
    }

    #[test]
    fn learns_a_simple_function() {
        let (x, y) = toy_dataset(2_000, 9);
        let params = train_forest(&x, &y, &toy_config()).unwrap();
        assert_eq!(params.n_features, 3);
        let forest = NativeForest::new(params);
        // NativeForest semantics: latency = row[0] * exp(leaf mean), so
        // compare in the model's own output space against the same
        // transform of the true target.
        let (xt, yt) = toy_dataset(256, 10);
        let mut err = 0.0;
        for (row, target) in xt.iter().zip(&yt) {
            let want = row[0] as f64 * target.exp();
            let got = forest.predict_one(row) as f64;
            err += (got - want).abs() / want;
        }
        err /= yt.len() as f64;
        assert!(err < 0.08, "toy-function fit error too high: {err:.4}");
    }

    #[test]
    fn training_is_deterministic() {
        let (x, y) = toy_dataset(1_000, 21);
        let a = train_forest(&x, &y, &toy_config()).unwrap();
        let b = train_forest(&x, &y, &toy_config()).unwrap();
        assert_eq!(a.feature, b.feature);
        assert_eq!(a.threshold, b.threshold);
        assert_eq!(a.leaf, b.leaf);
    }

    #[test]
    fn quantile_edges_are_sorted_and_unique() {
        let col: Vec<f64> = (0..500).map(|i| (i % 50) as f64).collect();
        let e = quantile_edges(&col, 64);
        assert!(!e.is_empty());
        for w in e.windows(2) {
            assert!(w[0] < w[1], "edges must be strictly increasing");
        }
        // constant column → no usable edges
        assert!(quantile_edges(&vec![3.0; 100], 64).is_empty());
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let (x, y) = toy_dataset(8, 1);
        assert!(train_forest(&x, &y, &toy_config()).is_err());
        let (x, mut y) = toy_dataset(100, 1);
        y[3] = f64::NAN;
        assert!(train_forest(&x, &y, &toy_config()).is_err());
    }
}

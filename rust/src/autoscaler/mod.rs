//! Autoscaler with **dual-staged scaling** (§5).
//!
//! Stage 1 ("release", sensitivity = `release_duration`): when the
//! expected instance count stays below the serving count for the release
//! duration, surplus instances are *released* — re-routed around and
//! marked [`InstanceState::Cached`] — freeing ~90% of their interference
//! pressure without an eviction.
//!
//! Stage 2 ("real eviction", sensitivity = `keepalive_duration`): cached
//! instances that stay idle long enough are actually evicted.
//!
//! A load rise in between triggers a **logical cold start**: a cached
//! instance is re-added to the routing set (<1 ms) instead of booting a
//! new instance.  **On-demand migration** pre-moves cached instances away
//! from nodes whose capacity shrank so a later conversion never needs a
//! real cold start (Fig. 14b).
//!
//! Scale-ups go through the plan/commit scheduler API: the autoscaler
//! asks the scheduler for a [`Plan`] against the read-only cluster,
//! commits it, and records the scheduler's asynchronous refreshes as
//! [`DeferredUpdate`]s in its [`TickOutcome`] — the control-plane engine
//! decides *when* that deferred work lands in virtual time.
//!
//! With `dual_staged = false` the release stage is disabled and the
//! autoscaler degenerates to the traditional keep-alive design (the
//! Jiagu-NoDS / baseline configuration).

use crate::catalog::{Catalog, FunctionId};
use crate::cluster::{Cluster, InstanceId, InstanceState};
use crate::policy::{BaselineScaling, ScalingPolicy};
use crate::router::Router;
use crate::scheduler::{CommittedPlan, DeferredUpdate, Plan, Scheduler};
use anyhow::Result;

/// Autoscaler tunables (defaults follow the paper: 45 s release, 60 s
/// keep-alive, dual-staged + migration on).
#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Stage-1 sensitivity (seconds of sustained lower load before
    /// releasing instances).  30/45 in the paper's Jiagu-30/Jiagu-45.
    pub release_duration_s: f64,
    /// Stage-2 / traditional keep-alive duration (seconds from load drop
    /// to eviction).  OpenFaaS default: 60.
    pub keepalive_duration_s: f64,
    /// Enable stage 1 (false = Jiagu-NoDS / traditional autoscaling).
    pub dual_staged: bool,
    /// Enable on-demand migration of stranded cached instances.
    pub migration: bool,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        Self {
            release_duration_s: 45.0,
            keepalive_duration_s: 60.0,
            dual_staged: true,
            migration: true,
        }
    }
}

/// What a tick did (the engine turns these into events/metrics).
#[derive(Debug, Default)]
pub struct TickOutcome {
    /// Cached instances converted back to saturated (<1 ms re-route).
    pub logical_cold_starts: u32,
    /// Newly placed instances (Starting); the caller schedules their
    /// readiness after scheduling cost + init latency.
    pub cold_started: Vec<InstanceId>,
    /// Committed scheduling plans for cost accounting.
    pub scheduled: Vec<CommittedPlan>,
    /// Asynchronous refreshes the scheduler submitted this tick; the
    /// engine completes them at their virtual-time due point.
    pub deferred: Vec<DeferredUpdate>,
    /// Saturated → Cached transitions this tick.
    pub released: u32,
    /// Cached instances evicted this tick.
    pub evicted: u32,
    /// Saturated instances evicted directly (NoDS path).
    pub evicted_direct: u32,
    /// Cached instances migrated off full nodes.
    pub migrations: u32,
    /// Scale-ups that required a *real* cold start while cached instances
    /// of the function existed but could not be converted (the cost
    /// migration avoids; only occurs with `migration = false`).
    pub real_after_release: u32,
    /// Arrival times of requests that were queued on instances this tick
    /// released/evicted — the engine re-dispatches them (per-request
    /// model; see [`crate::router::Router::remove`]).
    pub orphaned: Vec<(FunctionId, f64)>,
}

impl TickOutcome {
    fn merge(&mut self, other: TickOutcome) {
        self.logical_cold_starts += other.logical_cold_starts;
        self.cold_started.extend(other.cold_started);
        self.scheduled.extend(other.scheduled);
        self.deferred.extend(other.deferred);
        self.released += other.released;
        self.evicted += other.evicted;
        self.evicted_direct += other.evicted_direct;
        self.migrations += other.migrations;
        self.real_after_release += other.real_after_release;
        self.orphaned.extend(other.orphaned);
    }

    /// Record a committed node change: ask the scheduler for its refresh
    /// and keep it as deferred work.
    fn notify(
        &mut self,
        sched: &mut dyn Scheduler,
        cat: &Catalog,
        cluster: &Cluster,
        node: usize,
        now_ms: f64,
    ) -> Result<()> {
        if let Some(update) = sched.on_node_changed(cat, cluster, node, now_ms)? {
            self.deferred.push(update);
        }
        Ok(())
    }
}

/// Per-function scaling state.
#[derive(Debug, Clone, Copy, Default)]
struct FnState {
    /// Virtual time (ms) the serving surplus was first observed.
    surplus_since_ms: Option<f64>,
}

pub struct Autoscaler {
    pub cfg: AutoscalerConfig,
    state: Vec<FnState>,
    /// Pluggable scaling strategy (see [`crate::policy`]); the default
    /// [`BaselineScaling`] reproduces the original release/keep-alive
    /// behaviour exactly.
    policy: Box<dyn ScalingPolicy>,
}

impl Autoscaler {
    /// An autoscaler with the default [`BaselineScaling`] policy.
    pub fn new(cfg: AutoscalerConfig, n_functions: usize) -> Self {
        Self::with_policy(cfg, n_functions, Box::new(BaselineScaling))
    }

    /// An autoscaler driven by `policy`.
    pub fn with_policy(
        cfg: AutoscalerConfig,
        n_functions: usize,
        policy: Box<dyn ScalingPolicy>,
    ) -> Self {
        Self { cfg, state: vec![FnState::default(); n_functions], policy }
    }

    /// Name of the active scaling policy.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Forward one QoS-monitor observation to the scaling policy (the
    /// harvesting policy reclaims lent capacity on recent violations;
    /// the baseline ignores it).  Consumes no randomness.
    pub fn observe_qos(&mut self, f: FunctionId, violated: bool, now_ms: f64) {
        self.policy.observe_qos(f, violated, now_ms);
    }

    /// Expected saturated-instance count for a load level — the
    /// baseline target formula (kept as the policy-independent
    /// reference; [`BaselineScaling`] computes exactly this).
    pub fn expected_instances(cat: &Catalog, f: FunctionId, rps: f64) -> u32 {
        if rps <= 0.0 {
            0
        } else {
            (rps / cat.get(f).saturated_rps).ceil() as u32
        }
    }

    /// One autoscaler evaluation over all functions.
    ///
    /// `loads[f]` is the live RPS of function `f`; `now_ms` is virtual
    /// time.  Mutates cluster/router; scheduling is planned by `sched`
    /// and committed here.
    pub fn tick(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        router: &mut Router,
        sched: &mut dyn Scheduler,
        loads: &[f64],
        now_ms: f64,
    ) -> Result<TickOutcome> {
        let mut out = TickOutcome::default();
        for f in 0..loads.len() {
            let o = self.tick_function(cat, cluster, router, sched, f, loads[f], now_ms)?;
            out.merge(o);
        }
        self.evict_expired(cat, cluster, sched, now_ms, &mut out)?;
        if self.cfg.dual_staged && self.cfg.migration {
            self.migrate_stranded(cat, cluster, sched, now_ms, &mut out)?;
        }
        Ok(out)
    }

    /// Plan + commit a scale-up of `need` instances, collecting the
    /// per-touched-node asynchronous refreshes as deferred work.
    fn scale_up(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        sched: &mut dyn Scheduler,
        f: FunctionId,
        need: u32,
        now_ms: f64,
        out: &mut TickOutcome,
    ) -> Result<()> {
        let plan: Plan = sched.schedule(cat, cluster, f, need, now_ms)?;
        let committed = plan.commit(cat, cluster, now_ms);
        out.cold_started
            .extend(committed.placements.iter().map(|p| p.instance));
        for node in committed.touched_nodes() {
            out.notify(sched, cat, cluster, node, now_ms)?;
        }
        out.scheduled.push(committed);
        Ok(())
    }

    fn tick_function(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        router: &mut Router,
        sched: &mut dyn Scheduler,
        f: FunctionId,
        rps: f64,
        now_ms: f64,
    ) -> Result<TickOutcome> {
        let mut out = TickOutcome::default();
        let expected = self.policy.target_instances(cat, f, rps);
        // serving = saturated in router + instances still starting (they
        // will serve once ready; double-starting would overshoot)
        let serving = router.serving_count(f) as u32;
        let starting = cluster.starting_count(f);
        let current = serving + starting;

        if expected > current {
            self.state[f].surplus_since_ms = None;
            let mut need = expected - current;
            // stage-1 reversal: logical cold starts from cached instances
            if self.cfg.dual_staged {
                let cached = cluster.cached_of(f).to_vec();
                let had_cached = !cached.is_empty();
                for id in cached {
                    if need == 0 {
                        break;
                    }
                    let node = cluster.instance(id).unwrap().node;
                    if sched.find_feasible_conversion(cat, cluster, node, f)? {
                        cluster.reactivate(id, now_ms);
                        router.add(f, id, node);
                        out.logical_cold_starts += 1;
                        need -= 1;
                        out.notify(sched, cat, cluster, node, now_ms)?;
                    }
                }
                if need > 0 && had_cached {
                    // cached existed but (some) couldn't convert: these
                    // scale-ups fall through to real cold starts
                    out.real_after_release += need;
                }
            }
            if need > 0 {
                self.scale_up(cat, cluster, sched, f, need, now_ms, &mut out)?;
            }
        } else if expected < serving {
            // sustained surplus → stage 1 release (or direct eviction
            // when dual-staged scaling is disabled); the policy decides
            // how long the surplus must sustain (the harvesting policy
            // stretches it to lend idle capacity, reclaiming when the
            // function or a node neighbour shows recent QoS pressure)
            let since = self.state[f].surplus_since_ms.get_or_insert(now_ms);
            let sustained_s = (now_ms - *since) / 1000.0;
            let neighbours = Self::colocated(cluster, router, f);
            let trigger_s =
                self.policy.release_trigger_s(&self.cfg, f, &neighbours, now_ms);
            if sustained_s >= trigger_s {
                let surplus = serving - expected;
                let victims = self.newest_serving(cluster, router, f, surplus);
                for id in victims {
                    let node = cluster.instance(id).unwrap().node;
                    let drained = router.remove(f, id);
                    out.orphaned.extend(drained.into_iter().map(|a| (f, a)));
                    if self.cfg.dual_staged {
                        cluster.release(id, now_ms);
                        out.released += 1;
                    } else {
                        cluster.evict(cat, id);
                        out.evicted_direct += 1;
                    }
                    out.notify(sched, cat, cluster, node, now_ms)?;
                }
                self.state[f].surplus_since_ms = Some(now_ms); // re-arm
            }
        } else {
            self.state[f].surplus_since_ms = None;
        }
        Ok(out)
    }

    /// Stage 2: evict cached instances older than (keep-alive − release).
    fn evict_expired(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        sched: &mut dyn Scheduler,
        now_ms: f64,
        out: &mut TickOutcome,
    ) -> Result<()> {
        if !self.cfg.dual_staged {
            return Ok(());
        }
        let ttl_ms =
            (self.cfg.keepalive_duration_s - self.cfg.release_duration_s).max(0.0) * 1000.0;
        let mut victims = Vec::new();
        for node in 0..cluster.n_nodes() {
            for inst in cluster.node_instances(node) {
                if inst.state == InstanceState::Cached
                    && now_ms - inst.state_since_ms >= ttl_ms
                {
                    victims.push((inst.id, node));
                }
            }
        }
        for (id, node) in victims {
            cluster.evict(cat, id);
            out.evicted += 1;
            out.notify(sched, cat, cluster, node, now_ms)?;
        }
        Ok(())
    }

    /// On-demand migration: a node is "full" for a function when
    /// converting its cached instances back to saturated would exceed the
    /// node's capacity; move the stranded ones elsewhere ahead of time.
    fn migrate_stranded(
        &mut self,
        cat: &Catalog,
        cluster: &mut Cluster,
        sched: &mut dyn Scheduler,
        now_ms: f64,
        out: &mut TickOutcome,
    ) -> Result<()> {
        for node in 0..cluster.n_nodes() {
            let mix = cluster.mix(node);
            for (f, sat, cached) in mix.entries {
                if cached == 0 {
                    continue;
                }
                let stranded = sched.stranded_cached(cat, cluster, node, f, sat, cached)?;
                if stranded == 0 {
                    continue;
                }
                let ids = cluster.find_instances(node, f, InstanceState::Cached);
                for id in ids.into_iter().take(stranded as usize) {
                    if let Some(target) = sched.find_feasible_node(cat, cluster, f, node)? {
                        cluster.migrate_cached(cat, id, target, now_ms);
                        out.migrations += 1;
                        out.notify(sched, cat, cluster, node, now_ms)?;
                        out.notify(sched, cat, cluster, target, now_ms)?;
                    }
                }
            }
        }
        Ok(())
    }

    // -- helpers -------------------------------------------------------------

    /// Functions co-located with `f`'s serving instances (saturated or
    /// cached on the same nodes), sorted and deduplicated — the
    /// neighbour set the scaling policy's release trigger may consult.
    /// Only computed on the surplus branch, off the per-request hot
    /// path; deterministic because node mixes are.
    fn colocated(cluster: &Cluster, router: &Router, f: FunctionId) -> Vec<FunctionId> {
        let mut out: Vec<FunctionId> = Vec::new();
        for &id in router.serving(f) {
            let Some(inst) = cluster.instance(id) else { continue };
            for (g, sat, cached) in cluster.mix(inst.node).entries {
                if g != f && (sat > 0 || cached > 0) && !out.contains(&g) {
                    out.push(g);
                }
            }
        }
        out.sort_unstable();
        out
    }

    /// Newest `k` serving instances of `f` (LIFO release policy).  The
    /// sort key is a total order (`f64::total_cmp`), so a NaN-poisoned
    /// `created_ms` can no longer panic the comparator.
    fn newest_serving(
        &self,
        cluster: &Cluster,
        router: &Router,
        f: FunctionId,
        k: u32,
    ) -> Vec<InstanceId> {
        let mut serving: Vec<InstanceId> = router.serving(f).to_vec();
        serving.sort_by(|a, b| {
            let ca = cluster.instance(*a).map(|i| i.created_ms).unwrap_or(0.0);
            let cb = cluster.instance(*b).map(|i| i.created_ms).unwrap_or(0.0);
            cb.total_cmp(&ca)
        });
        serving.truncate(k as usize);
        serving
    }
}

//! `jiagu` — launcher for the reproduced serverless control plane.
//!
//! Subcommands (hand-rolled CLI; no clap offline):
//!
//! ```text
//! jiagu run   [--scheduler jiagu|k8s|gsight|owl] [--trace A|B|C|D|timer|worst|golden]
//!             [--release 45] [--no-ds] [--no-migration] [--duration 1800]
//!             [--init cfork|docker|<ms>] [--native] [--config file.json]
//!             [--requests]            # per-request routing + tail latency
//!             [--shards N]            # sharded control planes on N threads
//!             [--partitions P]        # partition layout (default 4)
//!             [--queue heap|wheel]    # Timeline impl (binary heap | timing wheel)
//!             [--regions N|a,b,c]     # multi-region federation (N proportional
//!                                     # regions, or explicit per-region node counts)
//!             [--region-latency MS]   # uniform inter-region latency matrix
//!             [--fail R@MS,...]       # crash region R at virtual ms MS
//!             [--dispatch-policy P]   # weighted|p2c|locality|sita (policy lab)
//!             [--scaling-policy S]    # baseline|harvesting (policy lab)
//!             [--json]                # emit the RunReport as JSON
//! jiagu compare [--duration 900]      # all schedulers on trace A
//! jiagu replay  --trace FILE          # stream an invocation log (CSV/JSONL)
//!             [--rescale X] [--bin-ms B] [--chunk-ms C] [--duration S]
//!             [--shards N] [--partitions P] [--queue heap|wheel] [--json]
//! jiagu fuzz  [--seeds 7,11,13] [--families correlated-burst,...]
//!             [--duration 8] [--require-divergence] [--json] [--out FILE]
//! jiagu policy-matrix [--duration 6] [--seed 4242] [--json] [--out FILE]
//!                                     # rank every dispatch x scaling combo
//! jiagu info                          # artifacts + model summary
//! ```
//!
//! `--trace golden` replays the fixed-seed latency-golden scenario
//! (`artifacts::latency_golden_scenario`) — the CI determinism matrix
//! runs it at `--shards 1,2,4` and byte-compares the `--json` outputs;
//! only the parallelism knobs apply on top of the pinned scenario.
//!
//! `replay` streams a real-trace invocation log through the control
//! plane in bounded memory (`workload::replay`); same file + options ⇒
//! byte-identical `--json` output at any shard count.  `fuzz` runs the
//! seeded adversarial scenario fuzzer through the differential QoS
//! matrix over all four schedulers (`workload::diff`) and exits
//! non-zero on any invariant violation — or, with
//! `--require-divergence`, when no scenario separates any baseline from
//! jiagu.  `policy-matrix` runs the policy lab (`jiagu::policy`): every
//! dispatch × scaling policy combination across the sweepable autoscaler
//! cadence, ranked on the golden latency histogram (`workload::diff::
//! run_policy_matrix`); exits non-zero on any invariant violation.

use anyhow::{bail, Context, Result};
use jiagu::config::{InitModel, RunConfig, SchedulerKind};
use jiagu::engine::QueueKind;
use jiagu::sim::{load_predictor, Simulation};
use jiagu::traces;
use jiagu::workload::fuzz::{ScenarioFamily, ScenarioFuzzer};
use jiagu::workload::{diff, replay};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
    switches: std::collections::HashSet<String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut switches = std::collections::HashSet::new();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            // value-taking flag if the next token isn't a flag
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                switches.insert(name.to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { positional, flags, switches }
}

fn build_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.flags.get("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(s) = args.flags.get("scheduler") {
        cfg.scheduler = SchedulerKind::parse(s)?;
        if cfg.scheduler != SchedulerKind::Jiagu {
            cfg.autoscaler.dual_staged = false;
            cfg.autoscaler.migration = false;
        }
    }
    if let Some(v) = args.flags.get("release") {
        cfg.autoscaler.release_duration_s = v.parse().context("--release")?;
    }
    if let Some(v) = args.flags.get("duration") {
        cfg.duration_s = v.parse().context("--duration")?;
    }
    if let Some(v) = args.flags.get("init") {
        cfg.init_model = InitModel::parse(v)?;
    }
    if let Some(v) = args.flags.get("nodes") {
        cfg.n_nodes = v.parse().context("--nodes")?;
    }
    if let Some(v) = args.flags.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    if args.switches.contains("no-ds") {
        cfg.autoscaler.dual_staged = false;
        cfg.autoscaler.migration = false;
    }
    if args.switches.contains("no-migration") {
        cfg.autoscaler.migration = false;
    }
    if args.switches.contains("requests") {
        cfg.requests = true;
    }
    if let Some(v) = args.flags.get("shards") {
        cfg.shards = v.parse().context("--shards")?;
    }
    if let Some(v) = args.flags.get("partitions") {
        cfg.partitions = v.parse().context("--partitions")?;
    }
    if let Some(v) = args.flags.get("queue") {
        cfg.queue = QueueKind::parse(v)
            .ok_or_else(|| anyhow::anyhow!("--queue {v:?} (heap|wheel)"))?;
    }
    if let Some(v) = args.flags.get("regions") {
        cfg.regions = parse_regions(v, cfg.n_nodes)?;
    }
    if let Some(v) = args.flags.get("region-latency") {
        cfg.region_latency_ms = v.parse().context("--region-latency")?;
    }
    if let Some(v) = args.flags.get("fail") {
        cfg.failures = v
            .split(',')
            .map(jiagu::config::parse_fail_spec)
            .collect::<Result<_>>()?;
    }
    if let Some(v) = args.flags.get("dispatch-policy") {
        cfg.dispatch_policy = jiagu::policy::DispatchPolicyKind::parse(v)?;
    }
    if let Some(v) = args.flags.get("scaling-policy") {
        cfg.scaling_policy = jiagu::policy::ScalingPolicyKind::parse(v)?;
    }
    Ok(cfg)
}

/// `--regions N` splits the cluster's `n_nodes` proportionally into `N`
/// regions; `--regions a,b,c` gives explicit heterogeneous per-region
/// node counts.
fn parse_regions(v: &str, n_nodes: usize) -> Result<Vec<usize>> {
    let counts: Vec<usize> = v
        .split(',')
        .map(|s| s.trim().parse().context("--regions"))
        .collect::<Result<_>>()?;
    Ok(if counts.len() == 1 {
        jiagu::controlplane::region::proportional_split(n_nodes, counts[0])
    } else {
        counts
    })
}

fn make_trace(
    cat: &jiagu::catalog::Catalog,
    name: &str,
    duration: usize,
) -> Result<traces::TraceSet> {
    Ok(match name {
        "A" | "B" | "C" | "D" => {
            let idx = (name.as_bytes()[0] - b'A') as usize;
            traces::paper_traces(cat, duration).swap_remove(idx)
        }
        "timer" => traces::timer_trace(cat, duration, 60),
        "worst" => traces::worstcase_trace(cat, duration, 90, 20),
        _ => bail!("unknown trace {name:?} (A|B|C|D|timer|worst|golden)"),
    })
}

/// Machine-readable form of a run report (`jiagu run --json`), so bench
/// trajectories can be captured without scraping the human table.
fn report_json(r: &jiagu::sim::RunReport) -> jiagu::util::json::Json {
    use jiagu::util::json::{arr, num, obj, s};
    obj(vec![
        ("scheduler", s(&r.scheduler)),
        ("trace", s(&r.trace)),
        ("duration_s", num(r.duration_s as f64)),
        ("cells", num(r.cells as f64)),
        (
            "owned_functions",
            arr(r.owned_functions.iter().map(|f| num(*f as f64))),
        ),
        ("events_processed", num(r.events_processed as f64)),
        ("density", num(r.density)),
        ("qos_violation_rate", num(r.qos_violation_rate)),
        (
            "per_function_violation",
            arr(r.per_function_violation.iter().map(|v| num(*v))),
        ),
        ("scheduling_ms_mean", num(r.scheduling_ms_mean)),
        ("scheduling_ms_p99", num(r.scheduling_ms_p99)),
        ("cold_start_ms_mean", num(r.cold_start_ms_mean)),
        ("cold_start_ms_p99", num(r.cold_start_ms_p99)),
        ("inferences_per_schedule", num(r.inferences_per_schedule)),
        ("critical_inferences", num(r.critical_inferences as f64)),
        ("async_inferences", num(r.async_inferences as f64)),
        ("memo_hits", num(r.memo_hits as f64)),
        ("memo_misses", num(r.memo_misses as f64)),
        ("schedule_calls", num(r.schedule_calls as f64)),
        ("instances_started", num(r.instances_started as f64)),
        ("fast_decisions", num(r.fast_decisions as f64)),
        ("slow_decisions", num(r.slow_decisions as f64)),
        ("logical_cold_starts", num(r.logical_cold_starts as f64)),
        ("real_after_release", num(r.real_after_release as f64)),
        ("logical_fraction", num(r.logical_fraction())),
        ("migrations", num(r.migrations as f64)),
        ("released", num(r.released as f64)),
        ("evicted", num(r.evicted as f64)),
        ("peak_nodes", num(r.peak_nodes as f64)),
        ("async_nanos", num(r.async_nanos as f64)),
        (
            "isolated_functions",
            arr(r.isolated_functions.iter().map(|f| num(*f as f64))),
        ),
        ("requests_served", num(r.requests_served as f64)),
        ("request_p50_ms", num(r.request_p50_ms)),
        ("request_p95_ms", num(r.request_p95_ms)),
        ("request_p99_ms", num(r.request_p99_ms)),
        (
            "request_counts",
            arr(r.request_counts.iter().map(|v| num(*v as f64))),
        ),
        (
            "request_qos_violations",
            arr(r.request_qos_violations.iter().map(|v| num(*v as f64))),
        ),
        ("cold_wait_requests", num(r.cold_wait_requests as f64)),
        ("stranded_requests", num(r.stranded_requests as f64)),
        ("arrivals_dropped", num(r.arrivals_dropped as f64)),
        ("peak_node_in_flight", num(r.peak_node_in_flight as f64)),
        ("peak_in_flight", num(r.peak_in_flight as f64)),
        ("latency_histogram", r.latency_hist.to_json()),
    ])
}

fn print_report(r: &jiagu::sim::RunReport) {
    println!("== run report: {} on {} ({}s) ==", r.scheduler, r.trace, r.duration_s);
    println!("  density (inst/node, time-weighted): {:.3}", r.density);
    println!("  QoS violation rate:                 {:.2}%", r.qos_violation_rate * 100.0);
    println!(
        "  scheduling cost: mean {:.3} ms, p99 {:.3} ms over {} calls",
        r.scheduling_ms_mean, r.scheduling_ms_p99, r.schedule_calls
    );
    println!(
        "  cold start:      mean {:.3} ms, p99 {:.3} ms over {} instances",
        r.cold_start_ms_mean, r.cold_start_ms_p99, r.instances_started
    );
    println!(
        "  inferences: {:.2}/schedule critical ({} critical, {} async); sweep memo {} hits / {} misses",
        r.inferences_per_schedule,
        r.critical_inferences,
        r.async_inferences,
        r.memo_hits,
        r.memo_misses
    );
    println!(
        "  paths: {} fast / {} slow; logical cold starts {}, migrations {}",
        r.fast_decisions, r.slow_decisions, r.logical_cold_starts, r.migrations
    );
    println!(
        "  released {} / evicted {}; peak nodes {}; {} events processed",
        r.released, r.evicted, r.peak_nodes, r.events_processed
    );
    if r.requests_served > 0 {
        println!(
            "  per-request: {} served, p50 {:.1} / p95 {:.1} / p99 {:.1} ms, {} cold-waited, peak {} in flight/node",
            r.requests_served,
            r.request_p50_ms,
            r.request_p95_ms,
            r.request_p99_ms,
            r.cold_wait_requests,
            r.peak_node_in_flight
        );
    }
    if r.arrivals_dropped > 0 {
        println!(
            "  WARNING: {} synthesized arrivals dropped by the per-function safety cap",
            r.arrivals_dropped
        );
    }
}

fn run() -> Result<()> {
    let args = parse_args();
    let artifacts = jiagu::artifacts_dir();
    match args.positional.first().map(|s| s.as_str()) {
        Some("run") | None => {
            let cfg = build_config(&args)?;
            let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
            let trace_name = args.flags.get("trace").map(|s| s.as_str()).unwrap_or("A");
            let native = args.switches.contains("native");
            let predictor = load_predictor(&artifacts, native)?;
            let (cfg, workload) = if trace_name == "golden" {
                // the fixed-seed latency-golden scenario: everything is
                // pinned except the parallelism knobs, so shard counts
                // are byte-comparable against each other
                let (mut golden_cfg, wl) = jiagu::artifacts::latency_golden_scenario(&cat);
                golden_cfg.shards = cfg.shards;
                golden_cfg.partitions = cfg.partitions;
                golden_cfg.queue = cfg.queue;
                // federation knobs ride on top of the pinned scenario;
                // `--regions N` re-splits the golden cluster size
                if let Some(v) = args.flags.get("regions") {
                    golden_cfg.regions = parse_regions(v, golden_cfg.n_nodes)?;
                }
                golden_cfg.region_latency_ms = cfg.region_latency_ms;
                golden_cfg.failures = cfg.failures.clone();
                golden_cfg.dispatch_policy = cfg.dispatch_policy;
                golden_cfg.scaling_policy = cfg.scaling_policy;
                (golden_cfg, wl)
            } else {
                let trace = make_trace(&cat, trace_name, cfg.duration_s)?;
                (cfg, trace.workload())
            };
            let mut federation_stats = None;
            let report = if !cfg.regions.is_empty() {
                let fed = jiagu::controlplane::region::FederatedControlPlane::new(
                    cat, cfg, predictor,
                )?;
                let (report, stats) = fed.run_workload(&workload)?;
                federation_stats = Some(stats);
                report
            } else if cfg.shards > 0 {
                jiagu::controlplane::shard::ShardedControlPlane::new(cat, cfg, predictor)?
                    .run_workload(&workload)?
            } else {
                Simulation::new(cat, cfg, predictor).run_workload(&workload)?
            };
            if args.switches.contains("json") {
                // federation stats stay out of the JSON deliberately:
                // the determinism matrix byte-compares this output, and
                // crash-replay accounting must never perturb it
                println!("{}", report_json(&report).to_string());
            } else {
                print_report(&report);
                if let Some(stats) = federation_stats {
                    println!("  federation: {stats}");
                }
            }
        }
        Some("compare") => {
            let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
            let duration: usize = args
                .flags
                .get("duration")
                .map(|v| v.parse())
                .transpose()?
                .unwrap_or(900);
            let trace = make_trace(&cat, "A", duration)?;
            let native = args.switches.contains("native");
            let predictor = load_predictor(&artifacts, native)?;
            for kind in [
                SchedulerKind::Kubernetes,
                SchedulerKind::Owl,
                SchedulerKind::Gsight,
                SchedulerKind::Jiagu,
            ] {
                let mut cfg = RunConfig::with_scheduler(kind);
                cfg.duration_s = duration;
                let sim = Simulation::new(cat.clone(), cfg, predictor.clone());
                let report = sim.run(&trace)?;
                print_report(&report);
            }
        }
        Some("replay") => {
            let mut cfg = build_config(&args)?;
            cfg.requests = true; // replay is per-invocation by construction
            let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
            let native = args.switches.contains("native");
            let predictor = load_predictor(&artifacts, native)?;
            let trace = args
                .flags
                .get("trace")
                .context("replay needs --trace <invocation log>")?;
            let mut opts = replay::ReplayOptions { seed: cfg.seed, ..Default::default() };
            if let Some(v) = args.flags.get("rescale") {
                opts.rescale = v.parse().context("--rescale")?;
            }
            if let Some(v) = args.flags.get("bin-ms") {
                opts.bin_ms = v.parse().context("--bin-ms")?;
            }
            if let Some(v) = args.flags.get("chunk-ms") {
                opts.chunk_ms = v.parse().context("--chunk-ms")?;
            }
            let (report, stats) = replay::replay_path(
                &cat,
                &cfg,
                predictor,
                std::path::Path::new(trace),
                &opts,
            )?;
            if args.switches.contains("json") {
                println!("{}", report_json(&report).to_string());
            } else {
                print_report(&report);
                println!(
                    "  replay: {} records read, {} arrivals emitted, {} clipped at the horizon",
                    stats.invocations, stats.emitted, stats.clipped
                );
            }
        }
        Some("fuzz") => {
            let mut cfg = build_config(&args)?;
            cfg.requests = true;
            if !args.flags.contains_key("duration") {
                cfg.duration_s = 8; // short adversarial horizons by default
            }
            let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
            let native = args.switches.contains("native");
            let predictor = load_predictor(&artifacts, native)?;
            let seeds: Vec<u64> = match args.flags.get("seeds") {
                Some(v) => v
                    .split(',')
                    .map(|s| s.trim().parse().context("--seeds"))
                    .collect::<Result<_>>()?,
                None => vec![7, 11, 13],
            };
            let families: Vec<ScenarioFamily> = match args.flags.get("families") {
                Some(v) => v
                    .split(',')
                    .map(|s| ScenarioFamily::parse(s.trim()))
                    .collect::<Result<_>>()?,
                None => ScenarioFamily::ALL.to_vec(),
            };
            let mut matrices = Vec::new();
            for &seed in &seeds {
                let fuzzer = ScenarioFuzzer::new(seed, cfg.duration_s);
                for &family in &families {
                    let wl = fuzzer.workload(&cat, family);
                    matrices.push(diff::run_matrix(&cat, &cfg, &predictor, &wl, true)?);
                }
            }
            let divergences: usize = matrices.iter().map(|m| m.divergences.len()).sum();
            let violations: usize = matrices.iter().map(|m| m.violations.len()).sum();
            let json = jiagu::util::json::obj(vec![
                (
                    "matrices",
                    jiagu::util::json::arr(matrices.iter().map(diff::matrix_json)),
                ),
                ("total_divergences", jiagu::util::json::num(divergences as f64)),
                (
                    "total_invariant_violations",
                    jiagu::util::json::num(violations as f64),
                ),
            ]);
            if let Some(path) = args.flags.get("out") {
                std::fs::write(path, json.to_string())
                    .with_context(|| format!("writing divergence report {path}"))?;
            }
            if args.switches.contains("json") {
                println!("{}", json.to_string());
            } else {
                for m in &matrices {
                    println!(
                        "== {}: {} divergences, {} invariant violations ==",
                        m.scenario,
                        m.divergences.len(),
                        m.violations.len()
                    );
                    for d in &m.divergences {
                        println!(
                            "  {:<12} {:<18} jiagu {:>10.3}  baseline {:>10.3}",
                            d.scheduler, d.metric, d.jiagu, d.baseline
                        );
                    }
                    for v in &m.violations {
                        println!("  VIOLATION {} [{}]: {}", v.scheduler, v.invariant, v.detail);
                    }
                }
                println!(
                    "fuzz matrix: {} scenarios, {divergences} divergences, {violations} invariant violations",
                    matrices.len()
                );
            }
            if violations > 0 {
                bail!("{violations} invariant violation(s) across the fuzz matrix");
            }
            if args.switches.contains("require-divergence") && divergences == 0 {
                bail!(
                    "no scenario separated any baseline from jiagu \
                     (--require-divergence)"
                );
            }
        }
        Some("policy-matrix") => {
            let mut cfg = build_config(&args)?;
            cfg.requests = true; // the rankings live on the latency histogram
            if !args.flags.contains_key("duration") {
                cfg.duration_s = 6; // short smoke horizon by default
            }
            if !args.flags.contains_key("seed") {
                cfg.seed = 4242; // the golden scenario's seed
            }
            // shorten both release triggers so scaling policies can differ
            // observably inside the smoke horizon (the defaults, 45/60 s,
            // never fire before a sub-minute run ends)
            cfg.autoscaler.release_duration_s = 3.0;
            cfg.autoscaler.keepalive_duration_s = 6.0;
            let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
            let native = args.switches.contains("native");
            let predictor = load_predictor(&artifacts, native)?;
            let wl = jiagu::traces::Workload::poisson(
                &cat,
                &jiagu::traces::PoissonParams {
                    duration_s: cfg.duration_s,
                    ..Default::default()
                },
                cfg.seed,
            );
            let matrix = diff::run_policy_matrix(&cat, &cfg, &predictor, &wl, true)?;
            let json = diff::matrix_json(&matrix);
            if let Some(path) = args.flags.get("out") {
                std::fs::write(path, json.to_string())
                    .with_context(|| format!("writing policy matrix {path}"))?;
            }
            if args.switches.contains("json") {
                println!("{}", json.to_string());
            } else {
                println!(
                    "== policy matrix: {} combos, {} invariant violations ==",
                    matrix.outcomes.len(),
                    matrix.violations.len()
                );
                for (metric, order) in &matrix.rankings {
                    println!("  ranking by {metric} (best first):");
                    for (i, combo) in order.iter().enumerate() {
                        println!("    {:>2}. {combo}", i + 1);
                    }
                }
                for v in &matrix.violations {
                    println!("  VIOLATION {} [{}]: {}", v.scheduler, v.invariant, v.detail);
                }
            }
            if !matrix.violations.is_empty() {
                bail!(
                    "{} invariant violation(s) across the policy matrix",
                    matrix.violations.len()
                );
            }
        }
        Some("info") => {
            let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
            println!("artifacts: {}", artifacts.display());
            println!("catalog: {} functions", cat.len());
            for f in &cat.functions {
                println!(
                    "  {:<12} solo {:7.1} ms  qos {:7.1} ms  sat {:6.1} rps",
                    f.name, f.solo_latency_ms, f.qos_latency_ms, f.saturated_rps
                );
            }
            let predictor = load_predictor(&artifacts, false)?;
            let backend = if cfg!(feature = "pjrt") { "pjrt" } else { "native" };
            println!("predictor: {backend}, {} features", predictor.n_features());
        }
        Some(other) => bail!(
            "unknown subcommand {other:?} (run|compare|replay|fuzz|policy-matrix|info)"
        ),
    }
    Ok(())
}

//! The discrete-event core: a deterministic, millisecond-resolution
//! [`Timeline`] abstraction that replaced the 1 s tick loop.
//!
//! ## Event taxonomy
//!
//! | [`Event`] | Emitted by | Effect when due |
//! |---|---|---|
//! | [`Event::LoadChange`] | [`crate::traces::Workload`] generators | update one function's offered RPS |
//! | [`Event::RequestArrival`] | [`crate::traces::Workload::synthesize_arrivals`] | route one request ([`crate::router::Router::pick`]) |
//! | [`Event::RequestComplete`] | service start (routing / queue pop) | finish service, start the next queued request |
//! | [`Event::ColdStartComplete`] | plan commit (autoscaler eval) | Starting → Saturated, join routing set, drain cold-waiters |
//! | [`Event::DeferredUpdateDue`] | §4.3 asynchronous refresh submission | land the capacity-table refresh |
//! | [`Event::AutoscalerEval`] | self-rescheduling, every eval interval | dual-staged scaling + plan/commit |
//! | [`Event::MonitorTick`] | self-rescheduling, every second | QoS windows, density sample, §6 feedback |
//!
//! ## Determinism contract
//!
//! Events pop in ascending `(due_ms, seq)` order where `seq` is a
//! monotone sequence number assigned at push.  `due_ms` is compared with
//! [`f64::total_cmp`], and the `seq` tie-break makes the order a *total*
//! order over any event multiset — two replays that push the same events
//! in the same order pop them in the same order, bit for bit.  Nothing in
//! the queue reads the wall clock: due times come from virtual time plus
//! the modelled costs in [`crate::config::CostModel`], so the popped
//! stream (and everything folded from it) replays identically for a given
//! seed.  This is what lets the engine drop the old tick loop's
//! wall-clock completion clamp (`MAX_ASYNC_COMPLETION_MS`): deferred
//! work no longer needs quantization to stay replayable.
//!
//! ## Two interchangeable implementations
//!
//! The contract above is an *API*, not a data structure: the sealed
//! [`Timeline`] trait captures it (`push`, `extend` batch admission,
//! `pop`, `peek_due`, `pop_due`), and two implementations satisfy it:
//!
//! * [`EventQueue`] — the reference `BinaryHeap` implementation,
//!   `O(log n)` per operation;
//! * [`TimingWheel`] — a hierarchical timing wheel (4 levels × 64 slots
//!   of 1 ms / 64 ms / 4.096 s / 262 s, bitmap-indexed, with an overflow
//!   list beyond ~4.66 h), `O(1)` amortised per operation at steady
//!   state, which is what keeps a million-event queue off the
//!   `O(log 10^6)` pointer-chasing path.
//!
//! [`AnyTimeline`] dispatches between them at runtime; the control plane
//! selects the implementation from [`crate::config::RunConfig`]
//! (`jiagu run --queue {heap,wheel}`).  Because both implement the same
//! total order, swapping the implementation never changes a single
//! popped bit — the CI determinism matrix byte-compares golden
//! `RunReport`s across `--queue heap` and `--queue wheel` at every shard
//! count, and `rust/tests/timeline_props.rs` pins pop-order equivalence
//! on randomized streams.
//!
//! The contract is also what makes control planes **composable**: a
//! partitioned sub-stream of a workload (see
//! [`crate::traces::Workload::restrict`]) pushed into a fresh queue
//! preserves the original relative order, so each shard cell of
//! [`crate::controlplane::shard`] replays exactly as a dedicated control
//! plane fed that sub-stream would — per-cell determinism is what the
//! parallel drain and the pinned-order report merge build on.

use crate::catalog::FunctionId;
use crate::cluster::{InstanceId, NodeId};
use std::collections::BinaryHeap;

/// One typed control-plane event (see the module table).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// The offered load of `function` becomes `rps` from this instant on.
    LoadChange { function: FunctionId, rps: f64 },
    /// One request for `function` arrives and must be routed now: onto an
    /// idle serving instance (service starts), a busy one (FIFO queue),
    /// or — with no serving instance anywhere — the function's cold-wait
    /// queue, drained when an instance next joins the routing set.
    RequestArrival { function: FunctionId },
    /// The request admitted on `instance` releases its service slot (one
    /// saturated-rate interval, stretched by the interference slowdown);
    /// the head of the instance's FIFO queue (if any) is admitted at
    /// this instant.
    RequestComplete { instance: InstanceId },
    /// A cold start finishes: the instance flips Starting → Saturated and
    /// joins the routing set at exactly its `sched_cost + init_ms` due
    /// time — mid-tick, not at the next tick boundary.
    ColdStartComplete { instance: InstanceId },
    /// An asynchronous capacity refresh for `node` lands.  The payload
    /// stays with the control plane (keyed by node); `version` guards
    /// against superseded refreshes — only the event matching the node's
    /// latest submitted version completes.
    DeferredUpdateDue { node: NodeId, version: u64 },
    /// Dual-staged autoscaler evaluation (plan + commit scale decisions).
    AutoscalerEval,
    /// QoS measurement window + utilisation sample; every
    /// `MONITOR_EVERY`-th tick also runs the §6 accuracy comparison.
    MonitorTick,
}

/// An event with its due time and push-order sequence number.
#[derive(Debug, Clone)]
pub struct Scheduled {
    pub due_ms: f64,
    /// Monotone per-queue push counter — the deterministic tie-break.
    pub seq: u64,
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.due_ms.total_cmp(&other.due_ms).is_eq()
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reversed comparison so [`BinaryHeap`] (a max-heap) pops the
    /// earliest `(due_ms, seq)` first — and a plain ascending sort of a
    /// `Vec<Scheduled>` puts the earliest event *last* (cheap `Vec::pop`
    /// drains in due order; the [`TimingWheel`] ready-run relies on it).
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .due_ms
            .total_cmp(&self.due_ms)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

mod sealed {
    /// [`super::Timeline`] is sealed: the determinism matrix can only
    /// vouch for implementations this module knows about.
    pub trait Sealed {}
    impl Sealed for super::EventQueue {}
    impl Sealed for super::TimingWheel {}
    impl Sealed for super::AnyTimeline {}
}

/// The engine's time-ordering API — a deterministic priority queue of
/// [`Scheduled`] events.
///
/// # The `(due_ms, seq)` determinism contract
///
/// Implementations MUST pop events in ascending `(due_ms, seq)` order,
/// where `due_ms` is compared with [`f64::total_cmp`] at full `f64`
/// resolution (an event due at `8.4320` ms pops before one due at
/// `8.4321` ms) and `seq` is the monotone counter assigned by `push` —
/// so equal due times resolve by push order and the pop order is a
/// *total* order over any event multiset.  `pop_due(limit, inclusive)`
/// honours a strict (`<`) or inclusive (`<=`) due-time limit.  Two
/// implementations fed the same push sequence must therefore produce
/// bit-identical pop streams; that equivalence is what lets
/// [`crate::config::RunConfig`] select the implementation without
/// perturbing a single byte of any `RunReport`.
///
/// The trait is sealed: [`EventQueue`] (reference `BinaryHeap`),
/// [`TimingWheel`] (hierarchical timing wheel) and the dispatching
/// [`AnyTimeline`] are the only implementations, because each one is
/// pinned against the others by `rust/tests/timeline_props.rs` and the
/// CI determinism matrix.
pub trait Timeline: sealed::Sealed + Send {
    /// Schedule `event` at `due_ms`; returns its sequence number.
    fn push(&mut self, due_ms: f64, event: Event) -> u64;

    /// Batch admission: push every `(due_ms, event)` pair in order.
    ///
    /// Equivalent to a `push` loop (sequence numbers are assigned in
    /// iteration order); implementations may pre-size internal storage.
    fn extend(&mut self, batch: Vec<(f64, Event)>) {
        for (due_ms, event) in batch {
            self.push(due_ms, event);
        }
    }

    /// Pop the earliest event unconditionally.
    fn pop(&mut self) -> Option<Scheduled>;

    /// Due time of the earliest queued event.
    ///
    /// Takes `&mut self`: a wheel implementation may advance its cursor
    /// to locate the minimum, which never changes the observable pop
    /// order.
    fn peek_due(&mut self) -> Option<f64>;

    /// Pop the earliest event if it is due by `limit_ms`.  With
    /// `inclusive = false` only events strictly before the limit pop —
    /// the half-open window `Simulation` drains per horizon.
    fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        let due = self.peek_due()?;
        let ready = if inclusive { due <= limit_ms } else { due < limit_ms };
        if ready {
            self.pop()
        } else {
            None
        }
    }

    /// Number of queued events.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Deterministic min-heap of [`Scheduled`] events — the reference
/// [`Timeline`] implementation (`O(log n)` per operation).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at `due_ms`; returns its sequence number.
    pub fn push(&mut self, due_ms: f64, event: Event) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { due_ms, seq, event });
        seq
    }

    /// Due time of the earliest queued event.
    pub fn peek_due(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.due_ms)
    }

    /// Pop the earliest event unconditionally.
    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    /// Pop the earliest event if it is due by `limit_ms`.  With
    /// `inclusive = false` only events strictly before the limit pop —
    /// the half-open window `Simulation` drains per horizon.
    pub fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        let due = self.peek_due()?;
        let ready = if inclusive { due <= limit_ms } else { due < limit_ms };
        if ready {
            self.heap.pop()
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl Timeline for EventQueue {
    fn push(&mut self, due_ms: f64, event: Event) -> u64 {
        EventQueue::push(self, due_ms, event)
    }

    fn pop(&mut self) -> Option<Scheduled> {
        EventQueue::pop(self)
    }

    fn peek_due(&mut self) -> Option<f64> {
        EventQueue::peek_due(self)
    }

    fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        EventQueue::pop_due(self, limit_ms, inclusive)
    }

    fn len(&self) -> usize {
        EventQueue::len(self)
    }
}

const SLOT_BITS: u32 = 6;
const SLOTS: usize = 1 << SLOT_BITS; // 64 slots per level
const SLOT_MASK: u64 = SLOTS as u64 - 1;
const LEVELS: usize = 4;
/// Whole-millisecond ticks one wheel rotation covers before events fall
/// into the overflow list: 64^4 ms ≈ 4.66 h of virtual time.
const TOP_SHIFT: u32 = SLOT_BITS * LEVELS as u32;

/// Hierarchical timing wheel over the same `(due_ms, seq)` contract as
/// [`EventQueue`] — `O(1)` amortised push/pop at steady state.
///
/// Four levels of 64 slots each cover 1 ms / 64 ms / 4.096 s / ~262 s
/// per slot; a `u64` occupancy bitmap per level turns "find the next
/// non-empty slot" into a `trailing_zeros`, so advancing over sparse
/// regions costs `O(levels)`, not `O(gap)`.  Events beyond the top
/// level's window from the cursor wait in an overflow list and are
/// re-admitted when the cursor reaches their rotation.
///
/// Determinism: slots bucket events by *whole* milliseconds only; a slot
/// is drained into a run sorted by `(f64::total_cmp(due_ms), seq)`, so
/// sub-millisecond resolution and push-order tie-breaks are preserved
/// exactly — the pop stream is bit-identical to [`EventQueue`]'s
/// (pinned by `rust/tests/timeline_props.rs`).  Late pushes whose due
/// time is already behind the cursor splice into the sorted run at the
/// position the heap would have given them.
#[derive(Debug)]
pub struct TimingWheel {
    seq: u64,
    len: usize,
    /// Absolute tick (whole ms): every event at a tick `< cursor` is in
    /// `ready` by the time `refill` returns (the level-0 drain can carry
    /// the cursor into a not-yet-cascaded higher-level slot; the next
    /// `refill` re-admits it before any event is observable).
    cursor: u64,
    /// Drained events awaiting pop, sorted ascending by the reversed
    /// [`Scheduled`] `Ord` — i.e. the earliest `(due_ms, seq)` is
    /// *last*, so `Vec::pop` drains in due order.
    ready: Vec<Scheduled>,
    /// `LEVELS × SLOTS` buckets, flattened level-major.
    slots: Vec<Vec<Scheduled>>,
    /// Per-level slot-occupancy bitmaps.
    occupied: [u64; LEVELS],
    /// Events more than one top-level rotation ahead of the cursor.
    overflow: Vec<Scheduled>,
}

impl Default for TimingWheel {
    fn default() -> Self {
        Self::new()
    }
}

impl TimingWheel {
    pub fn new() -> Self {
        Self {
            seq: 0,
            len: 0,
            cursor: 0,
            ready: Vec::new(),
            slots: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; LEVELS],
            overflow: Vec::new(),
        }
    }

    /// Whole-millisecond tick of a due time.  Non-finite dues saturate
    /// to the last tick; `total_cmp` ordering inside that bucket then
    /// reproduces the heap's `inf < NaN` tail order.
    fn tick(due_ms: f64) -> u64 {
        if due_ms <= 0.0 {
            0
        } else if due_ms.is_finite() {
            due_ms as u64
        } else {
            u64::MAX
        }
    }

    /// Schedule `event` at `due_ms`; returns its sequence number.
    pub fn push(&mut self, due_ms: f64, event: Event) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        self.insert(Scheduled { due_ms, seq, event });
        self.len += 1;
        seq
    }

    fn insert(&mut self, ev: Scheduled) {
        let t = Self::tick(ev.due_ms);
        if t < self.cursor {
            // Already behind the cursor: splice into the sorted ready
            // run at the exact `(due_ms, seq)` position.
            let pos = self.ready.partition_point(|e| e < &ev);
            self.ready.insert(pos, ev);
            return;
        }
        // Lowest level whose window (one slot of the level above)
        // contains both `t` and the cursor; slot width at level k is
        // 64^k ticks.
        for k in 0..LEVELS {
            let window_shift = SLOT_BITS * (k as u32 + 1);
            if t >> window_shift == self.cursor >> window_shift {
                let slot = ((t >> (SLOT_BITS * k as u32)) & SLOT_MASK) as usize;
                self.slots[k * SLOTS + slot].push(ev);
                self.occupied[k] |= 1u64 << slot;
                return;
            }
        }
        self.overflow.push(ev);
    }

    /// Move the next non-empty bucket's events into `ready` (sorted).
    /// Requires `ready` empty; a no-op only when the wheel holds nothing.
    fn refill(&mut self) {
        debug_assert!(self.ready.is_empty());
        loop {
            // The level-0 drain below can carry the cursor across a
            // higher-level slot boundary (slot 63 + 1) without cascading
            // the slot it lands in.  Re-admit the slot *containing* the
            // cursor at every level, top-down, before trusting the
            // level-0 window — otherwise fresh level-0 inserts for the
            // same window would drain ahead of (or instead of) the
            // still-racked contents above them.
            for k in (1..LEVELS).rev() {
                let shift = SLOT_BITS * k as u32;
                let idx = ((self.cursor >> shift) & SLOT_MASK) as usize;
                if self.occupied[k] & (1u64 << idx) != 0 {
                    self.occupied[k] &= !(1u64 << idx);
                    let batch = std::mem::take(&mut self.slots[k * SLOTS + idx]);
                    for ev in batch {
                        self.insert(ev); // lands below level k, or splices
                    }
                }
            }
            if !self.ready.is_empty() {
                // Ticks already behind the cursor were spliced straight
                // into `ready` by the re-admission; their whole-ms ticks
                // strictly precede everything still racked in the wheel.
                return;
            }
            // Level 0: the next occupied 1 ms slot in the current window.
            let idx0 = (self.cursor & SLOT_MASK) as usize;
            let pending0 = self.occupied[0] & (!0u64 << idx0);
            if pending0 != 0 {
                let slot = pending0.trailing_zeros() as usize;
                self.occupied[0] &= !(1u64 << slot);
                let mut run = std::mem::take(&mut self.slots[slot]);
                // one slot = one whole-ms tick; its events differ only in
                // fractional due and seq — sort restores the total order
                // (reversed Ord: earliest last, popped first)
                run.sort_unstable();
                self.cursor = (self.cursor & !SLOT_MASK) + slot as u64 + 1;
                if !run.is_empty() {
                    self.ready = run;
                    return;
                }
                continue;
            }
            // Level-0 window exhausted: jump to the next occupied slot of
            // the lowest non-empty level and cascade it down.
            let mut cascaded = false;
            for k in 1..LEVELS {
                let shift = SLOT_BITS * k as u32;
                let idx = ((self.cursor >> shift) & SLOT_MASK) as usize;
                // the re-admission pass above cleared the slot containing
                // the cursor, so its bit is clear — `>= idx` cannot
                // revisit the past
                let pending = self.occupied[k] & (!0u64 << idx);
                if pending != 0 {
                    let slot = pending.trailing_zeros() as usize;
                    self.occupied[k] &= !(1u64 << slot);
                    let window_base =
                        (self.cursor >> (shift + SLOT_BITS)) << (shift + SLOT_BITS);
                    self.cursor = window_base | ((slot as u64) << shift);
                    let batch = std::mem::take(&mut self.slots[k * SLOTS + slot]);
                    for ev in batch {
                        self.insert(ev); // lands at a level below k
                    }
                    cascaded = true;
                    break;
                }
            }
            if cascaded {
                continue;
            }
            // Every level is empty: whatever remains sits one or more
            // top-level rotations ahead — jump there and re-admit.
            if self.overflow.is_empty() {
                return;
            }
            let min_tick = self
                .overflow
                .iter()
                .map(|e| Self::tick(e.due_ms))
                .min()
                .expect("non-empty overflow");
            self.cursor = (min_tick >> TOP_SHIFT) << TOP_SHIFT;
            let batch = std::mem::take(&mut self.overflow);
            for ev in batch {
                self.insert(ev); // still-far events return to overflow
            }
        }
    }

    /// Due time of the earliest queued event.
    pub fn peek_due(&mut self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        if self.ready.is_empty() {
            self.refill();
        }
        self.ready.last().map(|s| s.due_ms)
    }

    /// Pop the earliest event unconditionally.
    pub fn pop(&mut self) -> Option<Scheduled> {
        if self.len == 0 {
            return None;
        }
        if self.ready.is_empty() {
            self.refill();
        }
        let ev = self.ready.pop();
        debug_assert!(ev.is_some(), "len says non-empty but refill found nothing");
        self.len -= ev.is_some() as usize;
        ev
    }

    /// Pop the earliest event if it is due by `limit_ms` (strict or
    /// inclusive — same semantics as [`EventQueue::pop_due`]).
    pub fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        let due = self.peek_due()?;
        let ready = if inclusive { due <= limit_ms } else { due < limit_ms };
        if ready {
            self.pop()
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Timeline for TimingWheel {
    fn push(&mut self, due_ms: f64, event: Event) -> u64 {
        TimingWheel::push(self, due_ms, event)
    }

    fn pop(&mut self) -> Option<Scheduled> {
        TimingWheel::pop(self)
    }

    fn peek_due(&mut self) -> Option<f64> {
        TimingWheel::peek_due(self)
    }

    fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        TimingWheel::pop_due(self, limit_ms, inclusive)
    }

    fn len(&self) -> usize {
        TimingWheel::len(self)
    }
}

/// Which [`Timeline`] implementation a run uses (JSON key `queue`,
/// CLI `jiagu run --queue {heap,wheel}`).  Both produce byte-identical
/// `RunReport`s; `wheel` is the million-event hot-path choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueKind {
    /// [`EventQueue`]: the reference binary heap.
    Heap,
    /// [`TimingWheel`]: the hierarchical timing wheel.
    Wheel,
}

impl Default for QueueKind {
    fn default() -> Self {
        QueueKind::Heap
    }
}

impl QueueKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "heap" => Some(QueueKind::Heap),
            "wheel" => Some(QueueKind::Wheel),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QueueKind::Heap => "heap",
            QueueKind::Wheel => "wheel",
        }
    }
}

/// Runtime-selected [`Timeline`]: enum dispatch between the two sealed
/// implementations (no virtual calls on the hot path).
#[derive(Debug)]
pub enum AnyTimeline {
    Heap(EventQueue),
    Wheel(TimingWheel),
}

impl AnyTimeline {
    pub fn new(kind: QueueKind) -> Self {
        match kind {
            QueueKind::Heap => AnyTimeline::Heap(EventQueue::new()),
            QueueKind::Wheel => AnyTimeline::Wheel(TimingWheel::new()),
        }
    }
}

impl Timeline for AnyTimeline {
    fn push(&mut self, due_ms: f64, event: Event) -> u64 {
        match self {
            AnyTimeline::Heap(q) => q.push(due_ms, event),
            AnyTimeline::Wheel(w) => w.push(due_ms, event),
        }
    }

    fn extend(&mut self, batch: Vec<(f64, Event)>) {
        match self {
            AnyTimeline::Heap(q) => Timeline::extend(q, batch),
            AnyTimeline::Wheel(w) => Timeline::extend(w, batch),
        }
    }

    fn pop(&mut self) -> Option<Scheduled> {
        match self {
            AnyTimeline::Heap(q) => q.pop(),
            AnyTimeline::Wheel(w) => w.pop(),
        }
    }

    fn peek_due(&mut self) -> Option<f64> {
        match self {
            AnyTimeline::Heap(q) => EventQueue::peek_due(q),
            AnyTimeline::Wheel(w) => w.peek_due(),
        }
    }

    fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        match self {
            AnyTimeline::Heap(q) => q.pop_due(limit_ms, inclusive),
            AnyTimeline::Wheel(w) => w.pop_due(limit_ms, inclusive),
        }
    }

    fn len(&self) -> usize {
        match self {
            AnyTimeline::Heap(q) => q.len(),
            AnyTimeline::Wheel(w) => w.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_due_order() {
        let mut q = EventQueue::new();
        q.push(300.0, Event::AutoscalerEval);
        q.push(8.4, Event::ColdStartComplete { instance: 1 });
        q.push(150.25, Event::MonitorTick);
        let dues: Vec<f64> = std::iter::from_fn(|| q.pop().map(|s| s.due_ms)).collect();
        assert_eq!(dues, vec![8.4, 150.25, 300.0]);
    }

    #[test]
    fn equal_due_ties_break_by_push_order() {
        let mut q = EventQueue::new();
        for f in 0..10usize {
            q.push(1000.0, Event::LoadChange { function: f, rps: f as f64 });
        }
        q.push(1000.0, Event::AutoscalerEval);
        let order: Vec<Event> = std::iter::from_fn(|| q.pop().map(|s| s.event)).collect();
        for (f, e) in order.iter().take(10).enumerate() {
            assert_eq!(*e, Event::LoadChange { function: f, rps: f as f64 });
        }
        assert_eq!(order[10], Event::AutoscalerEval);
    }

    #[test]
    fn pop_due_honours_half_open_and_inclusive_limits() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::MonitorTick);
        q.push(10.0, Event::AutoscalerEval);
        assert!(q.pop_due(5.0, false).is_none(), "strict: 5.0 not < 5.0");
        assert!(q.pop_due(5.0, true).is_some(), "inclusive: 5.0 <= 5.0");
        assert!(q.pop_due(10.0, false).is_none());
        assert_eq!(q.pop_due(10.0, true).unwrap().due_ms, 10.0);
        assert!(q.is_empty());
    }

    #[test]
    fn sub_millisecond_resolution_is_preserved() {
        let mut q = EventQueue::new();
        q.push(8.4321, Event::ColdStartComplete { instance: 0 });
        q.push(8.4320, Event::ColdStartComplete { instance: 1 });
        assert_eq!(
            q.pop().unwrap().event,
            Event::ColdStartComplete { instance: 1 },
            "0.0001 ms earlier must pop first"
        );
    }

    // -- TimingWheel: the same contract, plus wheel-specific edges ----------

    #[test]
    fn wheel_pops_in_due_order() {
        let mut w = TimingWheel::new();
        w.push(300.0, Event::AutoscalerEval);
        w.push(8.4, Event::ColdStartComplete { instance: 1 });
        w.push(150.25, Event::MonitorTick);
        let dues: Vec<f64> = std::iter::from_fn(|| w.pop().map(|s| s.due_ms)).collect();
        assert_eq!(dues, vec![8.4, 150.25, 300.0]);
    }

    #[test]
    fn wheel_equal_due_ties_break_by_push_order() {
        let mut w = TimingWheel::new();
        for f in 0..10usize {
            w.push(1000.0, Event::LoadChange { function: f, rps: f as f64 });
        }
        w.push(1000.0, Event::AutoscalerEval);
        let order: Vec<Event> = std::iter::from_fn(|| w.pop().map(|s| s.event)).collect();
        for (f, e) in order.iter().take(10).enumerate() {
            assert_eq!(*e, Event::LoadChange { function: f, rps: f as f64 });
        }
        assert_eq!(order[10], Event::AutoscalerEval);
    }

    #[test]
    fn wheel_preserves_sub_millisecond_resolution_within_one_slot() {
        let mut w = TimingWheel::new();
        w.push(8.4321, Event::ColdStartComplete { instance: 0 });
        w.push(8.4320, Event::ColdStartComplete { instance: 1 });
        assert_eq!(
            w.pop().unwrap().event,
            Event::ColdStartComplete { instance: 1 },
            "0.0001 ms earlier must pop first"
        );
        assert_eq!(w.pop().unwrap().event, Event::ColdStartComplete { instance: 0 });
        assert!(w.is_empty());
    }

    #[test]
    fn wheel_pop_due_honours_half_open_and_inclusive_limits() {
        let mut w = TimingWheel::new();
        w.push(5.0, Event::MonitorTick);
        w.push(10.0, Event::AutoscalerEval);
        assert!(w.pop_due(5.0, false).is_none(), "strict: 5.0 not < 5.0");
        assert!(w.pop_due(5.0, true).is_some(), "inclusive: 5.0 <= 5.0");
        assert!(w.pop_due(10.0, false).is_none());
        assert_eq!(w.pop_due(10.0, true).unwrap().due_ms, 10.0);
        assert!(w.is_empty());
    }

    #[test]
    fn wheel_crosses_level_boundaries_and_far_future_dues() {
        let mut w = TimingWheel::new();
        // one event per level span plus one beyond the whole rotation
        let dues = [3.0, 100.0, 5_000.0, 300_000.0, 20_000_000.0];
        for (i, due) in dues.iter().enumerate() {
            w.push(*due, Event::ColdStartComplete { instance: i as u64 });
        }
        assert_eq!(w.len(), dues.len());
        for (i, due) in dues.iter().enumerate() {
            let popped = w.pop().expect("event per due");
            assert_eq!(popped.due_ms, *due);
            assert_eq!(popped.event, Event::ColdStartComplete { instance: i as u64 });
        }
        assert!(w.pop().is_none());
    }

    #[test]
    fn wheel_accepts_pushes_behind_the_cursor() {
        let mut w = TimingWheel::new();
        w.push(10.9, Event::MonitorTick);
        w.push(10.2, Event::AutoscalerEval);
        assert_eq!(w.pop().unwrap().due_ms, 10.2); // cursor is now at tick 11
        w.push(10.5, Event::ColdStartComplete { instance: 7 }); // behind the cursor
        w.push(3.0, Event::ColdStartComplete { instance: 8 }); // far behind
        assert_eq!(w.pop().unwrap().due_ms, 3.0);
        assert_eq!(w.pop().unwrap().due_ms, 10.5);
        assert_eq!(w.pop().unwrap().due_ms, 10.9);
        assert!(w.is_empty());
    }

    #[test]
    fn wheel_steady_churn_matches_heap() {
        // the engine's periodic-event pattern: every pop pushes a
        // successor a fixed interval later — the wheel and the heap must
        // emit identical (due, seq) streams throughout
        let mut heap = EventQueue::new();
        let mut wheel = TimingWheel::new();
        for i in 0..64u64 {
            let due = (i as f64) * 37.5;
            heap.push(due, Event::MonitorTick);
            wheel.push(due, Event::MonitorTick);
        }
        for _ in 0..4_096 {
            let a = heap.pop().expect("heap never drains");
            let b = wheel.pop().expect("wheel never drains");
            assert_eq!(a.due_ms.to_bits(), b.due_ms.to_bits());
            assert_eq!(a.seq, b.seq);
            heap.push(a.due_ms + 1000.0, Event::MonitorTick);
            wheel.push(b.due_ms + 1000.0, Event::MonitorTick);
        }
        assert_eq!(heap.len(), wheel.len());
    }

    #[test]
    fn any_timeline_dispatches_both_kinds() {
        for kind in [QueueKind::Heap, QueueKind::Wheel] {
            let mut q = AnyTimeline::new(kind);
            Timeline::extend(
                &mut q,
                vec![(20.0, Event::AutoscalerEval), (10.0, Event::MonitorTick)],
            );
            assert_eq!(Timeline::len(&q), 2);
            assert_eq!(Timeline::peek_due(&mut q), Some(10.0));
            assert_eq!(Timeline::pop(&mut q).unwrap().due_ms, 10.0);
            assert_eq!(Timeline::pop(&mut q).unwrap().due_ms, 20.0);
            assert!(Timeline::is_empty(&q));
        }
    }

    #[test]
    fn queue_kind_parses_and_names() {
        assert_eq!(QueueKind::parse("heap"), Some(QueueKind::Heap));
        assert_eq!(QueueKind::parse("wheel"), Some(QueueKind::Wheel));
        assert_eq!(QueueKind::parse("ring"), None);
        assert_eq!(QueueKind::default().name(), "heap");
        assert_eq!(QueueKind::Wheel.name(), "wheel");
    }
}

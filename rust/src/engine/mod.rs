//! The discrete-event core: a deterministic, millisecond-resolution
//! [`EventQueue`] that replaced the 1 s tick loop.
//!
//! ## Event taxonomy
//!
//! | [`Event`] | Emitted by | Effect when due |
//! |---|---|---|
//! | [`Event::LoadChange`] | [`crate::traces::Workload`] generators | update one function's offered RPS |
//! | [`Event::RequestArrival`] | [`crate::traces::Workload::synthesize_arrivals`] | route one request ([`crate::router::Router::pick`]) |
//! | [`Event::RequestComplete`] | service start (routing / queue pop) | finish service, start the next queued request |
//! | [`Event::ColdStartComplete`] | plan commit (autoscaler eval) | Starting → Saturated, join routing set, drain cold-waiters |
//! | [`Event::DeferredUpdateDue`] | §4.3 asynchronous refresh submission | land the capacity-table refresh |
//! | [`Event::AutoscalerEval`] | self-rescheduling, every eval interval | dual-staged scaling + plan/commit |
//! | [`Event::MonitorTick`] | self-rescheduling, every second | QoS windows, density sample, §6 feedback |
//!
//! ## Determinism contract
//!
//! Events pop in ascending `(due_ms, seq)` order where `seq` is a
//! monotone sequence number assigned at push.  `due_ms` is compared with
//! [`f64::total_cmp`], and the `seq` tie-break makes the order a *total*
//! order over any event multiset — two replays that push the same events
//! in the same order pop them in the same order, bit for bit.  Nothing in
//! the queue reads the wall clock: due times come from virtual time plus
//! the modelled costs in [`crate::config::CostModel`], so the popped
//! stream (and everything folded from it) replays identically for a given
//! seed.  This is what lets the engine drop the old tick loop's
//! wall-clock completion clamp (`MAX_ASYNC_COMPLETION_MS`): deferred
//! work no longer needs quantization to stay replayable.
//!
//! Pop-until-due is `O(log n)` per event against the old loop's
//! `O(n)`-per-tick `Vec::retain`/partition scans, and due times are
//! honoured at full `f64` millisecond resolution instead of being rounded
//! up to the next 1 s tick boundary.
//!
//! The contract is also what makes control planes **composable**: a
//! partitioned sub-stream of a workload (see
//! [`crate::traces::Workload::restrict`]) pushed into a fresh queue
//! preserves the original relative order, so each shard cell of
//! [`crate::controlplane::shard`] replays exactly as a dedicated control
//! plane fed that sub-stream would — per-cell determinism is what the
//! parallel drain and the pinned-order report merge build on.

use crate::catalog::FunctionId;
use crate::cluster::{InstanceId, NodeId};
use std::collections::BinaryHeap;

/// One typed control-plane event (see the module table).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// The offered load of `function` becomes `rps` from this instant on.
    LoadChange { function: FunctionId, rps: f64 },
    /// One request for `function` arrives and must be routed now: onto an
    /// idle serving instance (service starts), a busy one (FIFO queue),
    /// or — with no serving instance anywhere — the function's cold-wait
    /// queue, drained when an instance next joins the routing set.
    RequestArrival { function: FunctionId },
    /// The request admitted on `instance` releases its service slot (one
    /// saturated-rate interval, stretched by the interference slowdown);
    /// the head of the instance's FIFO queue (if any) is admitted at
    /// this instant.
    RequestComplete { instance: InstanceId },
    /// A cold start finishes: the instance flips Starting → Saturated and
    /// joins the routing set at exactly its `sched_cost + init_ms` due
    /// time — mid-tick, not at the next tick boundary.
    ColdStartComplete { instance: InstanceId },
    /// An asynchronous capacity refresh for `node` lands.  The payload
    /// stays with the control plane (keyed by node); `version` guards
    /// against superseded refreshes — only the event matching the node's
    /// latest submitted version completes.
    DeferredUpdateDue { node: NodeId, version: u64 },
    /// Dual-staged autoscaler evaluation (plan + commit scale decisions).
    AutoscalerEval,
    /// QoS measurement window + utilisation sample; every
    /// `MONITOR_EVERY`-th tick also runs the §6 accuracy comparison.
    MonitorTick,
}

/// An event with its due time and push-order sequence number.
#[derive(Debug, Clone)]
pub struct Scheduled {
    pub due_ms: f64,
    /// Monotone per-queue push counter — the deterministic tie-break.
    pub seq: u64,
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.due_ms.total_cmp(&other.due_ms).is_eq()
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reversed comparison so [`BinaryHeap`] (a max-heap) pops the
    /// earliest `(due_ms, seq)` first.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .due_ms
            .total_cmp(&self.due_ms)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-heap of [`Scheduled`] events.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at `due_ms`; returns its sequence number.
    pub fn push(&mut self, due_ms: f64, event: Event) -> u64 {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { due_ms, seq, event });
        seq
    }

    /// Due time of the earliest queued event.
    pub fn peek_due(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.due_ms)
    }

    /// Pop the earliest event unconditionally.
    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    /// Pop the earliest event if it is due by `limit_ms`.  With
    /// `inclusive = false` only events strictly before the limit pop —
    /// the half-open window `Simulation` drains per horizon.
    pub fn pop_due(&mut self, limit_ms: f64, inclusive: bool) -> Option<Scheduled> {
        let due = self.peek_due()?;
        let ready = if inclusive { due <= limit_ms } else { due < limit_ms };
        if ready {
            self.heap.pop()
        } else {
            None
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_due_order() {
        let mut q = EventQueue::new();
        q.push(300.0, Event::AutoscalerEval);
        q.push(8.4, Event::ColdStartComplete { instance: 1 });
        q.push(150.25, Event::MonitorTick);
        let dues: Vec<f64> = std::iter::from_fn(|| q.pop().map(|s| s.due_ms)).collect();
        assert_eq!(dues, vec![8.4, 150.25, 300.0]);
    }

    #[test]
    fn equal_due_ties_break_by_push_order() {
        let mut q = EventQueue::new();
        for f in 0..10usize {
            q.push(1000.0, Event::LoadChange { function: f, rps: f as f64 });
        }
        q.push(1000.0, Event::AutoscalerEval);
        let order: Vec<Event> = std::iter::from_fn(|| q.pop().map(|s| s.event)).collect();
        for (f, e) in order.iter().take(10).enumerate() {
            assert_eq!(*e, Event::LoadChange { function: f, rps: f as f64 });
        }
        assert_eq!(order[10], Event::AutoscalerEval);
    }

    #[test]
    fn pop_due_honours_half_open_and_inclusive_limits() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::MonitorTick);
        q.push(10.0, Event::AutoscalerEval);
        assert!(q.pop_due(5.0, false).is_none(), "strict: 5.0 not < 5.0");
        assert!(q.pop_due(5.0, true).is_some(), "inclusive: 5.0 <= 5.0");
        assert!(q.pop_due(10.0, false).is_none());
        assert_eq!(q.pop_due(10.0, true).unwrap().due_ms, 10.0);
        assert!(q.is_empty());
    }

    #[test]
    fn sub_millisecond_resolution_is_preserved() {
        let mut q = EventQueue::new();
        q.push(8.4321, Event::ColdStartComplete { instance: 0 });
        q.push(8.4320, Event::ColdStartComplete { instance: 1 });
        assert_eq!(
            q.pop().unwrap().event,
            Event::ColdStartComplete { instance: 1 },
            "0.0001 ms earlier must pop first"
        );
    }
}

//! Seeded adversarial scenario fuzzer: workloads the stock generators
//! cannot express, each an ordinary [`Workload`] so every existing
//! determinism / shard / queue contract applies unchanged.
//!
//! From a single seed the [`ScenarioFuzzer`] derives one independent
//! RNG stream per [`ScenarioFamily`] (seed XOR family salt), so the
//! families are mutually independent but individually reproducible:
//! same `(seed, duration, catalog, family)` ⇒ byte-identical event
//! stream — the `workload_props` suite pins exactly that, through to
//! byte-identical `RunReport`s at shards 1/2/4 × queue heap/wheel.
//!
//! Substitution note: a [`Workload`] carries *offered load* (RPS
//! levels), not per-request service times, so the paper's heavy-tailed
//! service-time adversary enters through Pareto-distributed load levels
//! and holding times ([`ScenarioFamily::HeavyTail`]) — the scheduler
//! faces the same tail-driven capacity churn either way.

use crate::catalog::Catalog;
use crate::traces::{LoadEvent, Workload};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Golden-ratio mixer separating per-family RNG streams.
const FAMILY_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

/// The adversarial scenario families the fuzzer can generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioFamily {
    /// Cross-function correlated bursts: a majority subset of functions
    /// spikes *simultaneously* for 300–1500 ms — the anti-case for
    /// per-function capacity tables, since colocated interference jumps
    /// everywhere at once.
    CorrelatedBurst,
    /// Heavy-tailed (Pareto) load process: levels and holding times both
    /// Pareto-distributed, so rare enormous levels dominate the mass.
    HeavyTail,
    /// Flash crowd: near-idle baseline, then one function ramps to
    /// 20–40× its saturation within a few hundred ms and holds.
    FlashCrowd,
    /// Cold-start stampede: every function idles long enough to be
    /// released, then all jump to load at the same instant, repeatedly.
    ColdStampede,
    /// On/off square waves at 100–500 ms periods — faster than the 1 s
    /// autoscaler cadence, the *Tiny Autoscalers* trap.
    SquareWave,
}

impl ScenarioFamily {
    pub const ALL: [ScenarioFamily; 5] = [
        ScenarioFamily::CorrelatedBurst,
        ScenarioFamily::HeavyTail,
        ScenarioFamily::FlashCrowd,
        ScenarioFamily::ColdStampede,
        ScenarioFamily::SquareWave,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Self::CorrelatedBurst => "correlated-burst",
            Self::HeavyTail => "heavy-tail",
            Self::FlashCrowd => "flash-crowd",
            Self::ColdStampede => "cold-stampede",
            Self::SquareWave => "square-wave",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        for family in Self::ALL {
            if s.eq_ignore_ascii_case(family.name()) {
                return Ok(family);
            }
        }
        bail!(
            "unknown scenario family {s:?} (correlated-burst|heavy-tail|flash-crowd|\
             cold-stampede|square-wave)"
        )
    }

    fn index(&self) -> u64 {
        Self::ALL.iter().position(|f| f == self).unwrap() as u64
    }
}

/// The seeded fuzzer: one seed, one horizon, five families.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioFuzzer {
    pub seed: u64,
    pub duration_s: usize,
}

impl ScenarioFuzzer {
    pub fn new(seed: u64, duration_s: usize) -> Self {
        Self { seed, duration_s }
    }

    fn family_rng(&self, family: ScenarioFamily) -> Rng {
        Rng::seed_from(self.seed ^ (family.index() + 1).wrapping_mul(FAMILY_SALT))
    }

    /// Generate one family's workload.  Deterministic: same
    /// `(seed, duration, catalog, family)` ⇒ identical event stream.
    pub fn workload(&self, cat: &Catalog, family: ScenarioFamily) -> Workload {
        let mut rng = self.family_rng(family);
        let duration_ms = self.duration_s as f64 * 1000.0;
        let events = match family {
            ScenarioFamily::CorrelatedBurst => correlated_burst(cat, &mut rng, duration_ms),
            ScenarioFamily::HeavyTail => heavy_tail(cat, &mut rng, duration_ms),
            ScenarioFamily::FlashCrowd => flash_crowd(cat, &mut rng, duration_ms),
            ScenarioFamily::ColdStampede => cold_stampede(cat, &mut rng, duration_ms),
            ScenarioFamily::SquareWave => square_wave(cat, &mut rng, duration_ms),
        };
        Workload::finish(
            format!("fuzz-{}-{}", family.name(), self.seed),
            cat.len(),
            events,
            duration_ms,
        )
    }

    /// All five families' workloads, in [`ScenarioFamily::ALL`] order.
    pub fn all(&self, cat: &Catalog) -> Vec<Workload> {
        ScenarioFamily::ALL.iter().map(|f| self.workload(cat, *f)).collect()
    }
}

fn correlated_burst(cat: &Catalog, rng: &mut Rng, duration_ms: f64) -> Vec<LoadEvent> {
    let n = cat.len();
    let mut events = Vec::new();
    // steady per-function baselines
    let base: Vec<f64> = (0..n)
        .map(|f| rng.range_f64(1.0, 2.0) * cat.get(f).saturated_rps)
        .collect();
    for (f, b) in base.iter().enumerate() {
        events.push(LoadEvent { at_ms: 0.0, function: f, rps: *b });
    }
    // bursts hit a majority subset of functions at the same instant
    let mut t_ms = rng.exp(0.4) * 1000.0;
    while t_ms < duration_ms {
        let gain = rng.range_f64(3.0, 8.0);
        let len_ms = rng.range_f64(300.0, 1500.0);
        let k = n / 2 + 1 + rng.below((n - n / 2) as u64) as usize;
        let victims = rng.choose_k(n, k.min(n));
        let end = (t_ms + len_ms).min(duration_ms);
        for f in victims {
            events.push(LoadEvent { at_ms: t_ms, function: f, rps: base[f] * gain });
            events.push(LoadEvent { at_ms: end, function: f, rps: base[f] });
        }
        t_ms = end + rng.exp(0.4) * 1000.0;
    }
    events
}

fn heavy_tail(cat: &Catalog, rng: &mut Rng, duration_ms: f64) -> Vec<LoadEvent> {
    let mut events = Vec::new();
    for f in 0..cat.len() {
        let sat = cat.get(f).saturated_rps;
        let mut t_ms = 0.0;
        while t_ms < duration_ms {
            // Pareto level (α = 1.2: infinite variance) over a Pareto
            // holding time (α = 1.5), both capped to keep runs bounded
            let level = rng.pareto(0.4, 1.2).min(40.0) * sat;
            let hold_ms = rng.pareto(120.0, 1.5).min(15_000.0);
            events.push(LoadEvent { at_ms: t_ms, function: f, rps: level });
            t_ms += hold_ms;
        }
    }
    events
}

fn flash_crowd(cat: &Catalog, rng: &mut Rng, duration_ms: f64) -> Vec<LoadEvent> {
    let n = cat.len();
    let mut events = Vec::new();
    for f in 0..n {
        events.push(LoadEvent {
            at_ms: 0.0,
            function: f,
            rps: 0.05 * cat.get(f).saturated_rps,
        });
    }
    let crowds = 1 + rng.below(3) as usize;
    for _ in 0..crowds {
        let f = rng.below(n as u64) as usize;
        let sat = cat.get(f).saturated_rps;
        let start = rng.range_f64(0.1, 0.7) * duration_ms;
        let peak = rng.range_f64(20.0, 40.0) * sat;
        let hold_ms = rng.range_f64(2000.0, 5000.0);
        // ramp up in 3 steps of 100 ms, hold, then decay in 3 steps;
        // steps past the horizon are dropped (the crowd persists to the
        // end — the engine never pops events beyond the horizon anyway)
        for (i, frac) in [0.2, 0.55, 1.0].iter().enumerate() {
            let at_ms = start + i as f64 * 100.0;
            if at_ms < duration_ms {
                events.push(LoadEvent { at_ms, function: f, rps: peak * frac });
            }
        }
        let down = start + 300.0 + hold_ms;
        for (i, frac) in [0.4, 0.1, 0.0].iter().enumerate() {
            let at_ms = down + i as f64 * 200.0;
            if at_ms < duration_ms {
                events.push(LoadEvent {
                    at_ms,
                    function: f,
                    rps: (peak * frac).max(0.05 * sat),
                });
            }
        }
    }
    events
}

fn cold_stampede(cat: &Catalog, rng: &mut Rng, duration_ms: f64) -> Vec<LoadEvent> {
    let n = cat.len();
    let mut events = Vec::new();
    // idle gap long enough for keep-alive release, then everyone at once
    let idle_ms = rng.range_f64(2500.0, 5000.0);
    let on_ms = rng.range_f64(800.0, 1800.0);
    let mut t_ms = 0.0;
    while t_ms < duration_ms {
        let gains: Vec<f64> = (0..n).map(|_| rng.range_f64(2.0, 4.0)).collect();
        for (f, gain) in gains.iter().enumerate() {
            events.push(LoadEvent {
                at_ms: t_ms,
                function: f,
                rps: gain * cat.get(f).saturated_rps,
            });
        }
        let off = (t_ms + on_ms).min(duration_ms);
        for f in 0..n {
            events.push(LoadEvent { at_ms: off, function: f, rps: 0.0 });
        }
        t_ms = off + idle_ms;
    }
    events
}

fn square_wave(cat: &Catalog, rng: &mut Rng, duration_ms: f64) -> Vec<LoadEvent> {
    let mut events = Vec::new();
    for f in 0..cat.len() {
        let sat = cat.get(f).saturated_rps;
        let period_ms = rng.range_f64(100.0, 500.0);
        let amplitude = rng.range_f64(1.0, 4.0) * sat;
        let phase_ms = rng.f64() * period_ms;
        let mut t_ms = phase_ms - period_ms; // first toggle inside [0, period)
        let mut on = false;
        events.push(LoadEvent { at_ms: 0.0, function: f, rps: 0.0 });
        while t_ms < duration_ms {
            if t_ms >= 0.0 {
                events.push(LoadEvent {
                    at_ms: t_ms,
                    function: f,
                    rps: if on { amplitude } else { 0.0 },
                });
            }
            on = !on;
            t_ms += period_ms / 2.0;
        }
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;

    #[test]
    fn families_parse_roundtrip() {
        for family in ScenarioFamily::ALL {
            assert_eq!(ScenarioFamily::parse(family.name()).unwrap(), family);
        }
        assert!(ScenarioFamily::parse("poisson").is_err());
    }

    #[test]
    fn every_family_emits_a_wellformed_deterministic_workload() {
        let cat = test_catalog();
        let fuzzer = ScenarioFuzzer::new(123, 10);
        for family in ScenarioFamily::ALL {
            let a = fuzzer.workload(&cat, family);
            let b = fuzzer.workload(&cat, family);
            assert_eq!(a.events, b.events, "{}: same seed, same stream", family.name());
            assert_eq!(a.name, format!("fuzz-{}-123", family.name()));
            assert_eq!(a.n_functions, cat.len());
            assert!(!a.events.is_empty(), "{}: must emit load", family.name());
            for w in a.events.windows(2) {
                assert!(w[0].at_ms <= w[1].at_ms, "{}: sorted", family.name());
            }
            for e in &a.events {
                assert!(e.rps.is_finite() && e.rps >= 0.0, "{}: finite levels", family.name());
                assert!(e.at_ms >= 0.0 && e.at_ms <= a.duration_ms);
                assert!(e.function < cat.len());
            }
            let c = ScenarioFuzzer::new(124, 10).workload(&cat, family);
            assert_ne!(a.events, c.events, "{}: seed must move the stream", family.name());
        }
    }

    #[test]
    fn families_are_mutually_independent_streams() {
        let cat = test_catalog();
        let fuzzer = ScenarioFuzzer::new(9, 8);
        let all = fuzzer.all(&cat);
        assert_eq!(all.len(), ScenarioFamily::ALL.len());
        for pair in all.windows(2) {
            assert_ne!(pair[0].events, pair[1].events);
        }
    }

    #[test]
    fn square_wave_periods_stay_subsecond() {
        let cat = test_catalog();
        let wl = ScenarioFuzzer::new(5, 6).workload(&cat, ScenarioFamily::SquareWave);
        // per function, consecutive toggles are half a period apart:
        // 50–250 ms, always under the 1 s autoscaler cadence
        for f in 0..cat.len() {
            let times: Vec<f64> = wl
                .events
                .iter()
                .filter(|e| e.function == f && e.at_ms > 0.0)
                .map(|e| e.at_ms)
                .collect();
            assert!(times.len() > 20, "fn {f}: dense toggling expected");
            for w in times.windows(2) {
                let gap = w[1] - w[0];
                assert!(gap <= 250.0 + 1e-9, "fn {f}: toggle gap {gap} ms");
            }
        }
    }

    #[test]
    fn cold_stampede_synchronises_functions() {
        let cat = test_catalog();
        let wl = ScenarioFuzzer::new(31, 12).workload(&cat, ScenarioFamily::ColdStampede);
        // at every stampede instant, all functions step together
        let mut onsets: Vec<f64> = wl
            .events
            .iter()
            .filter(|e| e.rps > 0.0)
            .map(|e| e.at_ms)
            .collect();
        onsets.sort_by(f64::total_cmp);
        onsets.dedup();
        for t in onsets {
            let count = wl
                .events
                .iter()
                .filter(|e| e.at_ms == t && e.rps > 0.0)
                .count();
            assert_eq!(count, cat.len(), "stampede at {t} ms must hit every function");
        }
    }
}

//! Workload lab: the scenario-diversity surface the evaluation runs on.
//!
//! Jiagu's headline numbers come from replaying real production traces;
//! the generators in [`crate::traces`] only synthesize Poisson, spike
//! and diurnal shapes.  This subsystem closes that gap with three
//! layers, all built on the deterministic event core:
//!
//! * [`replay`] — **streaming real-trace replay**: a bounded-memory
//!   reader for Azure-Functions-style per-invocation logs
//!   (newline-delimited JSON or CSV: `function_id, arrival_ms,
//!   duration_ms`) that drives a control plane chunk by chunk via
//!   `Timeline::extend`, never materializing the full trace, with
//!   function-id interning against the catalog, horizon clipping and an
//!   RPS-rescaling knob so one trace file exercises many densities.
//! * [`fuzz`] — **seeded scenario fuzzer**: a [`fuzz::ScenarioFuzzer`]
//!   that, from a single seed, produces adversarial workloads the stock
//!   generators cannot express — correlated cross-function bursts,
//!   heavy-tailed (Pareto) load processes, flash crowds, cold-start
//!   stampedes, 100–500 ms on/off square waves — each an ordinary
//!   [`crate::traces::Workload`], so every existing determinism /
//!   shard / queue contract applies unchanged.
//! * [`diff`] — **differential QoS harness**: [`diff::run_matrix`] runs
//!   one workload across all four schedulers, compares the
//!   [`crate::sim::RunReport`]s (p99, per-function violations, density,
//!   cold-start latency, dropped arrivals) and emits a machine-readable
//!   divergence report with per-scheduler rankings and invariant
//!   checks.  `make fuzz-smoke` pins the harness in CI.
//!
//! Every layer inherits the engine's replay guarantee: same inputs and
//! seed ⇒ byte-identical reports, at any shard count and for either
//! timeline implementation.

pub mod diff;
pub mod fuzz;
pub mod replay;

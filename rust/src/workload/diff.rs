//! Differential QoS harness: one workload, all four schedulers, one
//! machine-readable divergence report.
//!
//! [`run_matrix`] runs a workload under jiagu, gsight, owl and
//! kubernetes (× the configured shard/queue setup), compares every
//! baseline's [`RunReport`] against jiagu's, and emits:
//!
//! * **divergences** — metrics where a baseline measurably departs from
//!   jiagu (p99 latency, QoS-violation counts, density, cold-start p99,
//!   dropped arrivals), with lenient thresholds so the report flags
//!   scheduler *behaviour*, not simulation noise;
//! * **invariant violations** — properties no scheduler may break on
//!   any workload: request accounting must balance, percentiles must be
//!   monotone, latency samples must all be valid, and a workload whose
//!   peak modeled demand fits comfortably inside modeled capacity must
//!   not be majority-QoS-violated;
//! * **rankings** — per-metric best-first scheduler orderings.
//!
//! `make fuzz-smoke` runs this matrix over the scenario fuzzer's
//! families and fails CI on any invariant violation — and, with
//! `--require-divergence`, when no adversarial scenario separates the
//! baselines from jiagu at all (the regression expectation: the
//! workload lab must keep producing scenarios that discriminate).
//!
//! [`run_policy_matrix`] reuses the same invariants, divergence
//! thresholds and rankings to judge the policy lab ([`crate::policy`]):
//! every dispatch × scaling combination across the sweepable autoscaler
//! cadence, ranked on the latency histogram (`make policy-smoke`).

use crate::catalog::Catalog;
use crate::config::{RunConfig, SchedulerKind};
use crate::controlplane::shard::ShardedControlPlane;
use crate::policy::{DispatchPolicyKind, ScalingPolicyKind};
use crate::runtime::Predictor;
use crate::sim::{RunReport, Simulation};
use crate::traces::Workload;
use crate::util::json::{arr, num, obj, s, Json};
use anyhow::Result;
use std::sync::Arc;

/// Matrix order: jiagu first (the comparison baseline), then the three
/// paper baselines.
pub const MATRIX_SCHEDULERS: [SchedulerKind; 4] = [
    SchedulerKind::Jiagu,
    SchedulerKind::Gsight,
    SchedulerKind::Owl,
    SchedulerKind::Kubernetes,
];

/// Absolute / relative thresholds for latency-metric divergence: small
/// enough to catch real behaviour gaps, large enough to ignore one-bin
/// histogram quantisation.
const DIVERGE_ABS_MS: f64 = 4.0;
const DIVERGE_REL: f64 = 0.05;

/// One scheduler's full outcome.
#[derive(Debug, Clone)]
pub struct SchedulerOutcome {
    pub scheduler: String,
    pub report: RunReport,
}

/// A metric where a baseline measurably departs from jiagu.
#[derive(Debug, Clone)]
pub struct Divergence {
    pub scheduler: String,
    pub metric: &'static str,
    pub jiagu: f64,
    pub baseline: f64,
}

/// A property no scheduler may break, broken.
#[derive(Debug, Clone)]
pub struct InvariantViolation {
    pub scheduler: String,
    pub invariant: &'static str,
    pub detail: String,
}

/// The differential matrix over one workload.
#[derive(Debug, Clone)]
pub struct MatrixReport {
    pub scenario: String,
    /// In [`MATRIX_SCHEDULERS`] order; `outcomes[0]` is jiagu.
    pub outcomes: Vec<SchedulerOutcome>,
    pub divergences: Vec<Divergence>,
    pub violations: Vec<InvariantViolation>,
    /// Per metric: schedulers best-first (ties keep matrix order).
    pub rankings: Vec<(&'static str, Vec<String>)>,
}

fn scheduler_cfg(base: &RunConfig, kind: SchedulerKind) -> RunConfig {
    let mut cfg = base.clone();
    cfg.scheduler = kind;
    if kind != SchedulerKind::Jiagu {
        // dual-staged scaling and migration are Jiagu's mechanisms
        cfg.autoscaler.dual_staged = false;
        cfg.autoscaler.migration = false;
    }
    cfg
}

fn run_one(
    cat: &Catalog,
    cfg: &RunConfig,
    predictor: &Arc<dyn Predictor>,
    workload: &Workload,
) -> Result<RunReport> {
    if cfg.shards > 0 {
        ShardedControlPlane::new(cat.clone(), cfg.clone(), predictor.clone())?
            .run_workload(workload)
    } else {
        Simulation::new(cat.clone(), cfg.clone(), predictor.clone()).run_workload(workload)
    }
}

/// Peak instantaneous modeled demand of the workload, in expected
/// instances (`ceil(rps / saturated_rps)` summed over functions).
fn peak_expected_instances(cat: &Catalog, wl: &Workload) -> f64 {
    let mut inst = vec![0.0f64; wl.n_functions];
    let mut total = 0.0f64;
    let mut peak = 0.0f64;
    for e in &wl.events {
        if e.function >= wl.n_functions || !e.rps.is_finite() {
            continue;
        }
        let ni = (e.rps / cat.get(e.function).saturated_rps).ceil().max(0.0);
        total += ni - inst[e.function];
        inst[e.function] = ni;
        peak = peak.max(total);
    }
    peak
}

fn total_qos_violations(report: &RunReport) -> u64 {
    report.request_qos_violations.iter().sum()
}

fn check_invariants(
    cat: &Catalog,
    cfg: &RunConfig,
    workload: &Workload,
    outcome: &SchedulerOutcome,
    out: &mut Vec<InvariantViolation>,
) {
    let r = &outcome.report;
    let mut push = |invariant: &'static str, detail: String| {
        out.push(InvariantViolation {
            scheduler: outcome.scheduler.clone(),
            invariant,
            detail,
        });
    };
    let counted: u64 = r.request_counts.iter().sum();
    if counted != r.requests_served {
        push(
            "request-accounting",
            format!("served {} != per-function sum {counted}", r.requests_served),
        );
    }
    for (f, (v, c)) in r.request_qos_violations.iter().zip(&r.request_counts).enumerate() {
        if v > c {
            push("violations-bounded", format!("fn {f}: {v} violations > {c} requests"));
        }
    }
    if !(r.request_p50_ms <= r.request_p95_ms && r.request_p95_ms <= r.request_p99_ms) {
        push(
            "percentiles-monotone",
            format!("p50 {} p95 {} p99 {}", r.request_p50_ms, r.request_p95_ms, r.request_p99_ms),
        );
    }
    if r.latency_hist.invalid() > 0 {
        push(
            "no-invalid-latency",
            format!("{} degenerate latency samples recorded", r.latency_hist.invalid()),
        );
    }
    // capacity invariant: when peak modeled demand fits inside half the
    // modeled capacity, no scheduler may majority-violate QoS
    let capacity =
        (cfg.n_nodes as f64) * f64::from(cfg.capacity.max_instances_per_node);
    let peak = peak_expected_instances(cat, workload);
    if peak * 2.0 <= capacity && r.qos_violation_rate > 0.5 {
        push(
            "capacity-qos",
            format!(
                "peak demand {peak:.1} instances fits capacity {capacity:.0}, \
                 yet violation rate is {:.3}",
                r.qos_violation_rate
            ),
        );
    }
}

fn latency_diverges(jiagu: f64, baseline: f64) -> bool {
    let d = (baseline - jiagu).abs();
    d >= DIVERGE_ABS_MS || (jiagu > 0.0 && d / jiagu > DIVERGE_REL && d >= 0.5)
}

fn find_divergences(outcomes: &[SchedulerOutcome], out: &mut Vec<Divergence>) {
    let jiagu = &outcomes[0].report;
    for o in &outcomes[1..] {
        let b = &o.report;
        let mut push = |metric: &'static str, j: f64, v: f64| {
            out.push(Divergence {
                scheduler: o.scheduler.clone(),
                metric,
                jiagu: j,
                baseline: v,
            });
        };
        if latency_diverges(jiagu.request_p99_ms, b.request_p99_ms) {
            push("request_p99_ms", jiagu.request_p99_ms, b.request_p99_ms);
        }
        let (jv, bv) = (total_qos_violations(jiagu), total_qos_violations(b));
        if jv != bv {
            push("qos_violations", jv as f64, bv as f64);
        }
        if latency_diverges(jiagu.cold_start_ms_p99, b.cold_start_ms_p99) {
            push("cold_start_ms_p99", jiagu.cold_start_ms_p99, b.cold_start_ms_p99);
        }
        let dd = (b.density - jiagu.density).abs();
        if jiagu.density > 0.0 && dd / jiagu.density > DIVERGE_REL {
            push("density", jiagu.density, b.density);
        }
        if jiagu.arrivals_dropped != b.arrivals_dropped {
            push(
                "arrivals_dropped",
                jiagu.arrivals_dropped as f64,
                b.arrivals_dropped as f64,
            );
        }
    }
}

fn rank(
    outcomes: &[SchedulerOutcome],
    key: impl Fn(&RunReport) -> f64,
    ascending: bool,
) -> Vec<String> {
    let mut order: Vec<&SchedulerOutcome> = outcomes.iter().collect();
    order.sort_by(|a, b| {
        let (ka, kb) = (key(&a.report), key(&b.report));
        if ascending { ka.total_cmp(&kb) } else { kb.total_cmp(&ka) }
    });
    order.into_iter().map(|o| o.scheduler.clone()).collect()
}

/// Run `workload` across all four schedulers under `base_cfg`'s cluster
/// setup (shards/queue included) and build the differential report.
/// With `check_determinism` every scheduler runs twice and a mismatch
/// is an invariant violation — the whole matrix then costs 8 runs.
pub fn run_matrix(
    cat: &Catalog,
    base_cfg: &RunConfig,
    predictor: &Arc<dyn Predictor>,
    workload: &Workload,
    check_determinism: bool,
) -> Result<MatrixReport> {
    let mut outcomes = Vec::with_capacity(MATRIX_SCHEDULERS.len());
    let mut violations = Vec::new();
    for kind in MATRIX_SCHEDULERS {
        let cfg = scheduler_cfg(base_cfg, kind);
        let report = run_one(cat, &cfg, predictor, workload)?;
        if check_determinism {
            let replayed = run_one(cat, &cfg, predictor, workload)?;
            if replayed != report {
                violations.push(InvariantViolation {
                    scheduler: kind.name().to_string(),
                    invariant: "determinism",
                    detail: "second run of the same seed produced different bytes".into(),
                });
            }
        }
        let outcome = SchedulerOutcome { scheduler: kind.name().to_string(), report };
        check_invariants(cat, &cfg, workload, &outcome, &mut violations);
        outcomes.push(outcome);
    }
    let mut divergences = Vec::new();
    find_divergences(&outcomes, &mut divergences);
    let rankings = vec![
        ("request_p99_ms", rank(&outcomes, |r| r.request_p99_ms, true)),
        ("qos_violations", rank(&outcomes, |r| total_qos_violations(r) as f64, true)),
        ("density", rank(&outcomes, |r| r.density, false)),
        ("cold_start_ms_p99", rank(&outcomes, |r| r.cold_start_ms_p99, true)),
    ];
    Ok(MatrixReport {
        scenario: workload.name.clone(),
        outcomes,
        divergences,
        violations,
        rankings,
    })
}

/// The autoscaler cadences the policy matrix sweeps (ISSUE: 100–250 ms).
/// Descending so the first combo — `weighted+baseline@250`, today's
/// defaults at the golden scenario's cadence — is `outcomes[0]`, the
/// baseline every divergence is measured against.
pub const POLICY_EVAL_INTERVALS_MS: [f64; 3] = [250.0, 175.0, 100.0];

/// Label of one policy-lab combination: `dispatch+scaling@cadence_ms`.
pub fn policy_combo_label(
    dispatch: DispatchPolicyKind,
    scaling: ScalingPolicyKind,
    eval_interval_ms: f64,
) -> String {
    format!("{}+{}@{}", dispatch.name(), scaling.name(), eval_interval_ms as u32)
}

/// Policy-lab differential matrix: one workload, every dispatch ×
/// scaling policy combination × every sweepable autoscaler cadence
/// ([`POLICY_EVAL_INTERVALS_MS`]), all under the Jiagu scheduler, judged
/// on the golden latency histogram exactly like [`run_matrix`] judges
/// schedulers.  `outcomes[0]` is `weighted+baseline@250` — the
/// pre-policy-lab defaults — so divergences read as "what this policy
/// combination changes relative to today".  With `check_determinism`
/// every combo runs twice and a byte mismatch is an invariant violation.
pub fn run_policy_matrix(
    cat: &Catalog,
    base_cfg: &RunConfig,
    predictor: &Arc<dyn Predictor>,
    workload: &Workload,
    check_determinism: bool,
) -> Result<MatrixReport> {
    let n_combos = DispatchPolicyKind::ALL.len()
        * ScalingPolicyKind::ALL.len()
        * POLICY_EVAL_INTERVALS_MS.len();
    let mut outcomes = Vec::with_capacity(n_combos);
    let mut violations = Vec::new();
    for dispatch in DispatchPolicyKind::ALL {
        for scaling in ScalingPolicyKind::ALL {
            for eval_interval_ms in POLICY_EVAL_INTERVALS_MS {
                let mut cfg = scheduler_cfg(base_cfg, SchedulerKind::Jiagu);
                cfg.dispatch_policy = dispatch;
                cfg.scaling_policy = scaling;
                cfg.eval_interval_ms = eval_interval_ms;
                let label = policy_combo_label(dispatch, scaling, eval_interval_ms);
                let report = run_one(cat, &cfg, predictor, workload)?;
                if check_determinism {
                    let replayed = run_one(cat, &cfg, predictor, workload)?;
                    if replayed != report {
                        violations.push(InvariantViolation {
                            scheduler: label.clone(),
                            invariant: "determinism",
                            detail: "second run of the same seed produced different bytes"
                                .into(),
                        });
                    }
                }
                let outcome = SchedulerOutcome { scheduler: label, report };
                check_invariants(cat, &cfg, workload, &outcome, &mut violations);
                outcomes.push(outcome);
            }
        }
    }
    let mut divergences = Vec::new();
    find_divergences(&outcomes, &mut divergences);
    let rankings = vec![
        ("request_p99_ms", rank(&outcomes, |r| r.request_p99_ms, true)),
        ("qos_violations", rank(&outcomes, |r| total_qos_violations(r) as f64, true)),
        ("density", rank(&outcomes, |r| r.density, false)),
        ("cold_start_ms_p99", rank(&outcomes, |r| r.cold_start_ms_p99, true)),
    ];
    Ok(MatrixReport {
        scenario: workload.name.clone(),
        outcomes,
        divergences,
        violations,
        rankings,
    })
}

/// Deterministic JSON surface of one matrix (sorted keys; the CLI and
/// `make fuzz-smoke` emit this verbatim).
pub fn matrix_json(m: &MatrixReport) -> Json {
    obj(vec![
        ("scenario", s(&m.scenario)),
        (
            "schedulers",
            arr(m.outcomes.iter().map(|o| {
                obj(vec![
                    ("scheduler", s(&o.scheduler)),
                    ("request_p99_ms", num(o.report.request_p99_ms)),
                    ("qos_violation_rate", num(o.report.qos_violation_rate)),
                    ("qos_violations", num(total_qos_violations(&o.report) as f64)),
                    ("density", num(o.report.density)),
                    ("cold_start_ms_p99", num(o.report.cold_start_ms_p99)),
                    ("requests_served", num(o.report.requests_served as f64)),
                    ("arrivals_dropped", num(o.report.arrivals_dropped as f64)),
                ])
            })),
        ),
        (
            "divergences",
            arr(m.divergences.iter().map(|d| {
                obj(vec![
                    ("scheduler", s(&d.scheduler)),
                    ("metric", s(d.metric)),
                    ("jiagu", num(d.jiagu)),
                    ("baseline", num(d.baseline)),
                ])
            })),
        ),
        (
            "invariant_violations",
            arr(m.violations.iter().map(|v| {
                obj(vec![
                    ("scheduler", s(&v.scheduler)),
                    ("invariant", s(v.invariant)),
                    ("detail", s(&v.detail)),
                ])
            })),
        ),
        (
            "rankings",
            arr(m.rankings.iter().map(|(metric, order)| {
                obj(vec![
                    ("metric", s(metric)),
                    ("best_first", arr(order.iter().map(|n| s(n)))),
                ])
            })),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};
    use crate::workload::fuzz::{ScenarioFamily, ScenarioFuzzer};

    fn stub_predictor() -> Arc<dyn Predictor> {
        Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
            crate::model::N_FEATURES,
            0.05,
            0.05,
        )))
    }

    fn base_cfg() -> RunConfig {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 6;
        cfg.duration_s = 5;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg
    }

    #[test]
    fn matrix_runs_all_four_schedulers_in_pinned_order() {
        let cat = test_catalog();
        let wl =
            ScenarioFuzzer::new(7, 5).workload(&cat, ScenarioFamily::CorrelatedBurst);
        let m =
            run_matrix(&cat, &base_cfg(), &stub_predictor(), &wl, true).unwrap();
        assert_eq!(m.scenario, wl.name);
        let names: Vec<&str> =
            m.outcomes.iter().map(|o| o.scheduler.as_str()).collect();
        assert_eq!(names, vec!["jiagu", "gsight", "owl", "kubernetes"]);
        assert!(
            m.outcomes.iter().all(|o| o.report.requests_served > 0),
            "every scheduler must route traffic"
        );
        assert!(
            m.violations.is_empty(),
            "no invariant may break on a stock scenario: {:?}",
            m.violations
        );
        for (metric, order) in &m.rankings {
            assert_eq!(order.len(), 4, "{metric}: all schedulers ranked");
        }
    }

    #[test]
    fn matrix_json_is_deterministic_and_carries_all_sections() {
        let cat = test_catalog();
        let wl = ScenarioFuzzer::new(13, 5).workload(&cat, ScenarioFamily::SquareWave);
        let cfg = base_cfg();
        let p = stub_predictor();
        let a = matrix_json(&run_matrix(&cat, &cfg, &p, &wl, false).unwrap());
        let b = matrix_json(&run_matrix(&cat, &cfg, &p, &wl, false).unwrap());
        assert_eq!(a.to_string(), b.to_string(), "matrix JSON must be byte-stable");
        for key in ["scenario", "schedulers", "divergences", "invariant_violations", "rankings"]
        {
            assert!(a.opt(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn policy_matrix_covers_every_combo_and_leads_with_the_defaults() {
        let cat = test_catalog();
        let wl = ScenarioFuzzer::new(11, 3).workload(&cat, ScenarioFamily::SquareWave);
        let mut cfg = base_cfg();
        cfg.duration_s = 3;
        let m = run_policy_matrix(&cat, &cfg, &stub_predictor(), &wl, false).unwrap();
        let combos = DispatchPolicyKind::ALL.len()
            * ScalingPolicyKind::ALL.len()
            * POLICY_EVAL_INTERVALS_MS.len();
        assert_eq!(m.outcomes.len(), combos);
        assert_eq!(
            m.outcomes[0].scheduler, "weighted+baseline@250",
            "the divergence baseline must be today's defaults at the golden cadence"
        );
        let mut labels: Vec<&str> =
            m.outcomes.iter().map(|o| o.scheduler.as_str()).collect();
        labels.sort_unstable();
        labels.dedup();
        assert_eq!(labels.len(), combos, "combo labels must be unique");
        assert!(
            m.outcomes.iter().all(|o| o.report.requests_served > 0),
            "every policy combination must route traffic"
        );
        assert!(
            m.violations.is_empty(),
            "no invariant may break on a stock scenario: {:?}",
            m.violations
        );
        for (metric, order) in &m.rankings {
            assert_eq!(order.len(), combos, "{metric}: all combos ranked");
        }
    }

    #[test]
    fn capacity_invariant_flags_violations_inside_capacity() {
        // a report majority-violating QoS on a tiny workload must trip
        // the capacity invariant check
        let cat = test_catalog();
        let wl = Workload {
            name: "tiny".into(),
            n_functions: cat.len(),
            events: vec![crate::traces::LoadEvent {
                at_ms: 0.0,
                function: 0,
                rps: 0.5 * cat.get(0).saturated_rps,
            }],
            duration_ms: 2000.0,
        };
        let cfg = base_cfg();
        let p = stub_predictor();
        let report =
            Simulation::new(cat.clone(), scheduler_cfg(&cfg, SchedulerKind::Jiagu), p)
                .run_workload(&wl)
                .unwrap();
        let mut bad = SchedulerOutcome { scheduler: "jiagu".into(), report };
        bad.report.qos_violation_rate = 0.9; // forge a broken scheduler
        let mut out = Vec::new();
        check_invariants(&cat, &cfg, &wl, &bad, &mut out);
        assert!(
            out.iter().any(|v| v.invariant == "capacity-qos"),
            "forged 90% violation rate inside capacity must be flagged: {out:?}"
        );
    }

    #[test]
    fn latency_divergence_thresholds() {
        assert!(!latency_diverges(100.0, 102.0)); // 2 ms, 2% — noise
        assert!(latency_diverges(100.0, 106.0)); // 6 ms
        assert!(latency_diverges(10.0, 11.0)); // 10% relative
        assert!(!latency_diverges(1.0, 1.2)); // big rel, sub-noise abs
        assert!(latency_diverges(0.0, 4.0)); // absolute floor
    }
}

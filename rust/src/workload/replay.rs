//! Streaming real-trace replay: drive a control plane from an
//! Azure-Functions-style per-invocation log in bounded memory.
//!
//! ## Trace format
//!
//! Newline-delimited records, one invocation per line, auto-detected per
//! line as either CSV or JSON:
//!
//! ```text
//! function_id,arrival_ms,duration_ms
//! rnn,12.500,118.000
//! {"function_id": "gzip", "arrival_ms": 14.25, "duration_ms": 31.0}
//! ```
//!
//! `function_id` interns against the catalog by name, or parses as a
//! numeric catalog index; `arrival_ms` must be finite, non-negative and
//! non-decreasing (production invocation logs are time-sorted);
//! `duration_ms` is parsed and validated for format fidelity — the
//! simulator's interference model supplies service times, so the column
//! does not steer the run.  Blank lines, `#` comments and a CSV header
//! line are skipped.
//!
//! ## Streaming contract
//!
//! [`replay_path`] never materializes the trace: the reader yields one
//! [`Invocation`] at a time and the driver injects work chunk by chunk
//! (each injection is one batched `Timeline::extend`), draining the
//! engine between chunks and folding the emitted events through the
//! same [`ReportBuilder`] the batch simulator uses.  Memory is bounded
//! by one chunk's arrivals, never by trace length.  Arrivals at or past
//! the horizon (`cfg.duration_s`) are clipped and counted into
//! `RunReport::arrivals_dropped` — a clipped replay is never mistaken
//! for a fully-served one.
//!
//! The [`ReplayOptions::rescale`] knob multiplies every function's
//! offered load so one trace file drives many densities: each
//! invocation is emitted `floor(r)` times plus one more with
//! probability `frac(r)`, decided by a per-invocation RNG seeded from
//! `(seed, function, per-function ordinal)` — invariant under chunking
//! and under the sharded path's per-cell re-read, so the replay is
//! byte-identical at any shard count.

use crate::catalog::Catalog;
use crate::config::RunConfig;
use crate::controlplane::shard::ShardedControlPlane;
use crate::controlplane::ControlPlane;
use crate::runtime::Predictor;
use crate::sim::{ReportBuilder, RunReport};
use crate::traces::{Arrival, LoadEvent, Workload};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

/// One trace record: `function` invoked at `at_ms`, observed to run for
/// `duration_ms` (carried for format fidelity; see the module docs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Invocation {
    pub function: usize,
    pub at_ms: f64,
    pub duration_ms: f64,
}

/// Streaming trace reader: yields [`Invocation`]s one line at a time,
/// interning function names against the catalog and validating as it
/// goes.  Errors carry the 1-based line number.
pub struct TraceReader<R> {
    reader: R,
    buf: String,
    line_no: u64,
    by_name: HashMap<String, usize>,
    n_functions: usize,
    last_at_ms: f64,
}

impl TraceReader<BufReader<File>> {
    pub fn from_path(path: &Path, cat: &Catalog) -> Result<Self> {
        let file = File::open(path)
            .with_context(|| format!("opening trace {}", path.display()))?;
        Ok(Self::new(BufReader::new(file), cat))
    }
}

impl<R: BufRead> TraceReader<R> {
    pub fn new(reader: R, cat: &Catalog) -> Self {
        Self {
            reader,
            buf: String::new(),
            line_no: 0,
            by_name: cat
                .functions
                .iter()
                .enumerate()
                .map(|(i, f)| (f.name.clone(), i))
                .collect(),
            n_functions: cat.len(),
            last_at_ms: f64::NEG_INFINITY,
        }
    }
}

fn parse_trace_line(
    by_name: &HashMap<String, usize>,
    n_functions: usize,
    line: &str,
) -> Result<Invocation> {
    let intern = |token: &str| -> Result<usize> {
        if let Some(id) = by_name.get(token) {
            return Ok(*id);
        }
        if let Ok(id) = token.parse::<usize>() {
            ensure!(
                id < n_functions,
                "function index {id} out of range (catalog has {n_functions})"
            );
            return Ok(id);
        }
        bail!("unknown function {token:?} (not a catalog name, not an index)")
    };
    let (function, at_ms, duration_ms) = if line.starts_with('{') {
        let j = Json::parse(line)?;
        let fid = j.get("function_id")?;
        let function = match fid.as_str() {
            Ok(name) => intern(name)?,
            Err(_) => {
                let id = fid.as_usize()?;
                ensure!(
                    id < n_functions,
                    "function index {id} out of range (catalog has {n_functions})"
                );
                id
            }
        };
        (function, j.get("arrival_ms")?.as_f64()?, j.get("duration_ms")?.as_f64()?)
    } else {
        let mut parts = line.split(',').map(str::trim);
        let (Some(f), Some(a), Some(d)) = (parts.next(), parts.next(), parts.next()) else {
            bail!("expected `function_id,arrival_ms,duration_ms`, got {line:?}");
        };
        let at_ms: f64 = a.parse().map_err(|_| anyhow!("bad arrival_ms {a:?}"))?;
        let duration_ms: f64 = d.parse().map_err(|_| anyhow!("bad duration_ms {d:?}"))?;
        (intern(f)?, at_ms, duration_ms)
    };
    ensure!(
        at_ms.is_finite() && at_ms >= 0.0,
        "arrival_ms {at_ms} must be finite and non-negative"
    );
    ensure!(
        duration_ms.is_finite() && duration_ms >= 0.0,
        "duration_ms {duration_ms} must be finite and non-negative"
    );
    Ok(Invocation { function, at_ms, duration_ms })
}

impl<R: BufRead> Iterator for TraceReader<R> {
    type Item = Result<Invocation>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            self.buf.clear();
            match self.reader.read_line(&mut self.buf) {
                Ok(0) => return None,
                Ok(_) => {}
                Err(e) => return Some(Err(anyhow!("trace line {}: {e}", self.line_no + 1))),
            }
            self.line_no += 1;
            {
                let line = self.buf.trim();
                if line.is_empty() || line.starts_with('#') {
                    continue;
                }
                // CSV header (any position, so concatenated traces work)
                if !line.starts_with('{')
                    && line.split(',').next().map(str::trim) == Some("function_id")
                {
                    continue;
                }
            }
            let parsed = parse_trace_line(&self.by_name, self.n_functions, self.buf.trim());
            return Some(match parsed {
                Ok(inv) if inv.at_ms < self.last_at_ms => Err(anyhow!(
                    "trace line {}: arrival_ms {} regresses below {} (trace must be time-sorted)",
                    self.line_no,
                    inv.at_ms,
                    self.last_at_ms
                )),
                Ok(inv) => {
                    self.last_at_ms = inv.at_ms;
                    Ok(inv)
                }
                Err(e) => Err(anyhow!("trace line {}: {e}", self.line_no)),
            });
        }
    }
}

/// Knobs of one replay.  All defaults reproduce the trace as recorded.
#[derive(Debug, Clone)]
pub struct ReplayOptions {
    /// Offered-load multiplier: `floor(r)` copies of every invocation
    /// plus one more with probability `frac(r)` (see the module docs).
    /// `1.0` replays the trace verbatim.
    pub rescale: f64,
    /// Width of the bins the replay derives offered-load levels at (ms):
    /// per bin and function, the arrival count becomes the RPS level the
    /// autoscaler sees.
    pub bin_ms: f64,
    /// Streaming chunk length (virtual ms) — a memory bound, fixed so
    /// the replay contract stays "same options ⇒ byte-identical report".
    pub chunk_ms: f64,
    /// Seed of the per-invocation rescaling decisions.
    pub seed: u64,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        Self { rescale: 1.0, bin_ms: 100.0, chunk_ms: 10_000.0, seed: 1 }
    }
}

/// Side counters of one replay (merged additively across cells).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    /// Trace records read (after cell filtering, before rescaling).
    pub invocations: u64,
    /// Arrivals injected after rescaling, inside the horizon.
    pub emitted: u64,
    /// Rescaled arrivals clipped at the horizon (also surfaced as
    /// `RunReport::arrivals_dropped`).
    pub clipped: u64,
}

impl ReplayStats {
    fn merge(&mut self, other: &ReplayStats) {
        self.invocations += other.invocations;
        self.emitted += other.emitted;
        self.clipped += other.clipped;
    }
}

/// Copies of one invocation under `rescale`, decided by a per-invocation
/// RNG over `(seed, function, per-function ordinal)` — so the decision
/// is invariant under chunk boundaries and per-cell re-reads.
fn rescale_copies(opts: &ReplayOptions, function: usize, ordinal: u64) -> u64 {
    let whole = opts.rescale.max(0.0).floor();
    let frac = opts.rescale.max(0.0) - whole;
    let extra = if frac > 0.0 {
        let mut rng = Rng::seed_from(
            opts.seed
                ^ (function as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ ordinal.wrapping_mul(0xbf58_476d_1ce4_e5b9),
        );
        u64::from(rng.f64() < frac)
    } else {
        0
    };
    whole as u64 + extra
}

/// Draw one invocation's copy count *and* advance its per-function
/// ordinal — the single accessor both the emission path and the
/// horizon-clip paths go through.  Sharing it is what guarantees the
/// fractional-rescale decisions stay aligned between emitted and clipped
/// accounting: if either path read a different ordinal, `emitted +
/// clipped` would drift from the trace's expected copy total.
fn take_copies(opts: &ReplayOptions, ordinals: &mut [u64], function: usize) -> u64 {
    let copies = rescale_copies(opts, function, ordinals[function]);
    ordinals[function] += 1;
    copies
}

/// Stream `invocations` through one plain control plane configured by
/// `cfg`, keeping only functions `keep` accepts (the sharded path's
/// cell filter; pass `|_| true` for the whole trace).
fn replay_stream(
    cat: &Catalog,
    cfg: &RunConfig,
    predictor: Arc<dyn Predictor>,
    mut invocations: impl Iterator<Item = Result<Invocation>>,
    opts: &ReplayOptions,
    keep: impl Fn(usize) -> bool,
    name: &str,
) -> Result<(RunReport, ReplayStats)> {
    let n_functions = cat.len();
    let mut cp = ControlPlane::new(cat.clone(), cfg.clone(), predictor);
    let mut builder = ReportBuilder::new(cat, cfg);
    let horizon_ms = cfg.duration_s as f64 * 1000.0;
    let bin_ms = opts.bin_ms.max(1.0);
    let bin_s = bin_ms / 1000.0;
    // chunk length snapped up to a whole number of bins
    let chunk_ms = (opts.chunk_ms.max(bin_ms) / bin_ms).ceil() * bin_ms;

    let mut stats = ReplayStats::default();
    let mut ordinals = vec![0u64; n_functions];
    let mut last_rate = vec![0.0f64; n_functions];
    let mut pending: Option<Invocation> = None;
    let mut chunk_start = 0.0f64;

    while chunk_start < horizon_ms {
        let chunk_end = (chunk_start + chunk_ms).min(horizon_ms);
        let n_bins = ((chunk_end - chunk_start) / bin_ms).ceil() as usize;
        let mut counts = vec![0u64; n_bins * n_functions];
        let mut arrivals: Vec<Arrival> = Vec::new();
        loop {
            let inv = match pending.take() {
                Some(p) => p,
                None => match invocations.next() {
                    Some(r) => r?,
                    None => break,
                },
            };
            if !keep(inv.function) {
                continue;
            }
            if inv.at_ms >= chunk_end {
                pending = Some(inv);
                break;
            }
            stats.invocations += 1;
            let copies = take_copies(opts, &mut ordinals, inv.function);
            if copies == 0 {
                continue;
            }
            let bin = (((inv.at_ms - chunk_start) / bin_ms) as usize).min(n_bins - 1);
            counts[inv.function * n_bins + bin] += copies;
            for _ in 0..copies {
                arrivals.push(Arrival { at_ms: inv.at_ms, function: inv.function });
            }
            stats.emitted += copies;
        }
        // per-bin arrival counts become the offered-load levels the
        // autoscaler sees; emit a LoadEvent only where a level changes
        let mut events: Vec<LoadEvent> = Vec::new();
        for b in 0..n_bins {
            let at_ms = chunk_start + b as f64 * bin_ms;
            for (f, last) in last_rate.iter_mut().enumerate() {
                let rate = counts[f * n_bins + b] as f64 / bin_s;
                if rate != *last {
                    events.push(LoadEvent { at_ms, function: f, rps: rate });
                    *last = rate;
                }
            }
        }
        if !events.is_empty() {
            cp.inject_workload(&Workload {
                name: name.to_string(),
                n_functions,
                events,
                duration_ms: horizon_ms,
            });
        }
        if !arrivals.is_empty() {
            cp.inject_arrivals(&arrivals);
        }
        builder.absorb(&cp.run_until(chunk_end)?);
        chunk_start = chunk_end;
    }

    // everything at/after the horizon is clipped — counted, not dropped
    // silently (rescaling still advances so the knob stays chunk-stable)
    if let Some(inv) = pending.take() {
        stats.invocations += 1;
        stats.clipped += take_copies(opts, &mut ordinals, inv.function);
    }
    for r in invocations {
        let inv = r?;
        if !keep(inv.function) {
            continue;
        }
        stats.invocations += 1;
        stats.clipped += take_copies(opts, &mut ordinals, inv.function);
    }
    builder.add_arrivals_dropped(stats.clipped);

    let isolated = cp.monitor().unpredictable();
    let report = builder.finish(cp.scheduler_name(), name, cfg.duration_s, isolated);
    Ok((report, stats))
}

/// Replay the trace at `path` under `cfg`: unsharded when
/// `cfg.shards == 0`, otherwise across the partition layout of the
/// sharded control plane — each cell re-reads the file with its own
/// function filter (streaming stays bounded per cell) and the per-cell
/// reports merge in ascending cell order, so the merged report depends
/// on the layout only, never the thread count.
pub fn replay_path(
    cat: &Catalog,
    cfg: &RunConfig,
    predictor: Arc<dyn Predictor>,
    path: &Path,
    opts: &ReplayOptions,
) -> Result<(RunReport, ReplayStats)> {
    let name = format!(
        "replay-{}",
        path.file_name().and_then(|s| s.to_str()).unwrap_or("trace")
    );
    if cfg.shards == 0 {
        let reader = TraceReader::from_path(path, cat)?;
        return replay_stream(cat, cfg, predictor, reader, opts, |_| true, &name);
    }
    let scp = ShardedControlPlane::new(cat.clone(), cfg.clone(), predictor.clone())?;
    let layout = scp.layout().clone();
    let p = layout.partitions();
    let threads = cfg.shards.clamp(1, p);

    let run_cell = |c: usize| -> Result<(RunReport, ReplayStats)> {
        let reader = TraceReader::from_path(path, cat)?;
        let cell_cfg = scp.cell_config(c);
        let (mut report, stats) = replay_stream(
            cat,
            &cell_cfg,
            predictor.clone(),
            reader,
            opts,
            |f| layout.cell_of(f) == c,
            &name,
        )?;
        // the fresh report claims the whole catalog; narrow it to the
        // cell's slice so the merge's disjointness check holds
        report.owned_functions = layout.functions_of(c);
        Ok((report, stats))
    };

    let mut results: Vec<Option<(RunReport, ReplayStats)>> = (0..p).map(|_| None).collect();
    if threads == 1 {
        for (c, slot) in results.iter_mut().enumerate() {
            *slot = Some(run_cell(c)?);
        }
    } else {
        std::thread::scope(|scope| -> Result<()> {
            let run_cell = &run_cell;
            let mut handles = Vec::with_capacity(threads);
            for w in 0..threads {
                handles.push(scope.spawn(
                    move || -> Vec<(usize, Result<(RunReport, ReplayStats)>)> {
                        let mut worker = Vec::new();
                        let mut c = w;
                        while c < p {
                            worker.push((c, run_cell(c)));
                            c += threads;
                        }
                        worker
                    },
                ));
            }
            for handle in handles {
                let worker =
                    handle.join().map_err(|_| anyhow!("replay worker panicked"))?;
                for (c, result) in worker {
                    results[c] = Some(result?);
                }
            }
            Ok(())
        })?;
    }

    // pinned merge order: ascending cell index
    let mut iter = results.into_iter().map(|r| r.expect("every cell ran"));
    let (mut report, mut stats) = iter.next().expect("layout has at least one cell");
    for (r, s) in iter {
        report.merge(&r)?;
        stats.merge(&s);
    }
    Ok((report, stats))
}

// ---------------------------------------------------------------------------
// Deterministic trace-file generation (CI needs no downloads).
// ---------------------------------------------------------------------------

/// On-disk encoding of a generated trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    Csv,
    Jsonl,
}

impl TraceFormat {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "csv" => Self::Csv,
            "jsonl" | "json" => Self::Jsonl,
            _ => bail!("unknown trace format {s:?} (csv|jsonl)"),
        })
    }
}

/// Parameters of [`generate_trace_file`].
#[derive(Debug, Clone)]
pub struct TraceGenSpec {
    /// Approximate total invocation count (Poisson-distributed).
    pub invocations: u64,
    pub duration_s: usize,
    pub seed: u64,
    pub format: TraceFormat,
}

/// Write a deterministic Azure-style invocation log: per-function
/// Poisson arrival processes with heavy-tailed (Pareto) per-function
/// shares — some functions dominate, as in the production traces —
/// merged time-sorted.  Same `(catalog, spec)` ⇒ byte-identical file.
/// Returns the number of invocations written.
pub fn generate_trace_file(path: &Path, cat: &Catalog, spec: &TraceGenSpec) -> Result<u64> {
    ensure!(spec.duration_s > 0, "trace duration must be positive");
    let n = cat.len();
    let mut rng = Rng::seed_from(spec.seed);
    let weights: Vec<f64> = (0..n).map(|_| rng.pareto(1.0, 1.2).min(50.0)).collect();
    let wsum: f64 = weights.iter().sum();
    let horizon_ms = spec.duration_s as f64 * 1000.0;

    let mut all: Vec<(f64, usize, f64)> = Vec::with_capacity(spec.invocations as usize);
    for f in 0..n {
        let target = spec.invocations as f64 * weights[f] / wsum;
        let rate_per_ms = target / horizon_ms;
        if rate_per_ms <= 0.0 {
            continue;
        }
        let mut frng = Rng::seed_from(
            spec.seed.wrapping_add((f as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)),
        );
        let base = cat.get(f).solo_latency_ms;
        let mut t = 0.0f64;
        loop {
            t += frng.exp(rate_per_ms);
            if t >= horizon_ms {
                break;
            }
            let duration = base * (0.35 + frng.exp(1.0)).min(20.0);
            all.push((t, f, duration));
        }
    }
    all.sort_by(|a, b| a.0.total_cmp(&b.0));

    let file = File::create(path)
        .with_context(|| format!("creating trace {}", path.display()))?;
    let mut w = BufWriter::new(file);
    if spec.format == TraceFormat::Csv {
        writeln!(w, "function_id,arrival_ms,duration_ms")?;
    }
    for (t, f, d) in &all {
        let name = &cat.get(*f).name;
        match spec.format {
            TraceFormat::Csv => writeln!(w, "{name},{t:.3},{d:.3}")?,
            TraceFormat::Jsonl => writeln!(
                w,
                "{{\"function_id\": \"{name}\", \"arrival_ms\": {t:.3}, \"duration_ms\": {d:.3}}}"
            )?,
        }
    }
    w.flush()?;
    Ok(all.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::tests::test_catalog;
    use crate::runtime::{ForestParams, NativeForestPredictor};
    use std::io::Cursor;

    fn stub_predictor() -> Arc<dyn Predictor> {
        Arc::new(NativeForestPredictor::new(ForestParams::synthetic_stub(
            crate::model::N_FEATURES,
            0.05,
            0.05,
        )))
    }

    fn read_all(text: &str) -> Result<Vec<Invocation>> {
        let cat = test_catalog();
        TraceReader::new(Cursor::new(text.to_string()), &cat).collect()
    }

    #[test]
    fn reader_parses_csv_and_jsonl_interchangeably() {
        let text = "\
function_id,arrival_ms,duration_ms
# a comment

fn0,10.5,120.0
{\"function_id\": \"fn1\", \"arrival_ms\": 12.25, \"duration_ms\": 80.0}
2,14.0,55.0
{\"function_id\": 3, \"arrival_ms\": 14.0, \"duration_ms\": 9.0}
";
        let got = read_all(text).unwrap();
        assert_eq!(
            got,
            vec![
                Invocation { function: 0, at_ms: 10.5, duration_ms: 120.0 },
                Invocation { function: 1, at_ms: 12.25, duration_ms: 80.0 },
                Invocation { function: 2, at_ms: 14.0, duration_ms: 55.0 },
                Invocation { function: 3, at_ms: 14.0, duration_ms: 9.0 },
            ]
        );
    }

    #[test]
    fn reader_rejects_malformed_records_with_line_numbers() {
        for (text, needle) in [
            ("nosuchfn,1.0,2.0", "unknown function"),
            ("99,1.0,2.0", "out of range"),
            ("fn0,abc,2.0", "bad arrival_ms"),
            ("fn0,1.0", "expected"),
            ("fn0,-1.0,2.0", "non-negative"),
            ("fn0,1.0,nan", "finite"),
            ("fn0,5.0,1.0\nfn0,4.0,1.0", "regresses"),
        ] {
            let err = read_all(text).unwrap_err().to_string();
            assert!(err.contains(needle), "{text:?}: {err}");
            assert!(err.contains("line"), "{text:?}: {err} must carry a line number");
        }
    }

    #[test]
    fn rescale_copies_are_deterministic_and_mean_tracks_knob() {
        let opts = ReplayOptions { rescale: 2.5, ..Default::default() };
        let a: Vec<u64> = (0..2000).map(|i| rescale_copies(&opts, 1, i)).collect();
        let b: Vec<u64> = (0..2000).map(|i| rescale_copies(&opts, 1, i)).collect();
        assert_eq!(a, b);
        assert!(a.iter().all(|k| *k == 2 || *k == 3));
        let mean = a.iter().sum::<u64>() as f64 / a.len() as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean {mean}");
        // integral rescale is exact, zero thins everything
        let one = ReplayOptions::default();
        assert!((0..100).all(|i| rescale_copies(&one, 0, i) == 1));
        let zero = ReplayOptions { rescale: 0.0, ..Default::default() };
        assert!((0..100).all(|i| rescale_copies(&zero, 0, i) == 0));
    }

    #[test]
    fn generated_trace_roundtrips_and_is_deterministic() {
        let cat = test_catalog();
        let dir = std::env::temp_dir();
        for format in [TraceFormat::Csv, TraceFormat::Jsonl] {
            let spec =
                TraceGenSpec { invocations: 500, duration_s: 5, seed: 77, format };
            let p1 = dir.join(format!("jiagu_replay_gen_a_{format:?}.trace"));
            let p2 = dir.join(format!("jiagu_replay_gen_b_{format:?}.trace"));
            let n1 = generate_trace_file(&p1, &cat, &spec).unwrap();
            let n2 = generate_trace_file(&p2, &cat, &spec).unwrap();
            assert_eq!(n1, n2);
            assert!(n1 > 200, "expected a few hundred invocations, got {n1}");
            assert_eq!(
                std::fs::read(&p1).unwrap(),
                std::fs::read(&p2).unwrap(),
                "same spec must write identical bytes"
            );
            let invs: Vec<Invocation> = TraceReader::from_path(&p1, &cat)
                .unwrap()
                .collect::<Result<_>>()
                .unwrap();
            assert_eq!(invs.len() as u64, n1);
            for w in invs.windows(2) {
                assert!(w[0].at_ms <= w[1].at_ms);
            }
            std::fs::remove_file(&p1).ok();
            std::fs::remove_file(&p2).ok();
        }
    }

    fn replay_cfg(shards: usize) -> RunConfig {
        let mut cfg = RunConfig::jiagu_45();
        cfg.n_nodes = 6;
        cfg.duration_s = 4;
        cfg.requests = true;
        cfg.eval_interval_ms = 250.0;
        cfg.shards = shards;
        cfg
    }

    #[test]
    fn replay_is_deterministic_and_clips_the_horizon() {
        let cat = test_catalog();
        let path = std::env::temp_dir().join("jiagu_replay_e2e.csv");
        // 6 s of trace against a 4 s horizon: the tail must be clipped
        let spec = TraceGenSpec {
            invocations: 1200,
            duration_s: 6,
            seed: 5,
            format: TraceFormat::Csv,
        };
        let total = generate_trace_file(&path, &cat, &spec).unwrap();
        let cfg = replay_cfg(0);
        let opts = ReplayOptions::default();
        let (r1, s1) =
            replay_path(&cat, &cfg, stub_predictor(), &path, &opts).unwrap();
        let (r2, s2) =
            replay_path(&cat, &cfg, stub_predictor(), &path, &opts).unwrap();
        assert_eq!(r1, r2, "same options must replay byte-identically");
        assert_eq!(s1, s2);
        assert_eq!(s1.invocations, total);
        assert!(s1.clipped > 0, "the 2 s tail must be clipped");
        assert_eq!(s1.emitted + s1.clipped, total, "verbatim replay: every record accounted");
        assert_eq!(r1.arrivals_dropped, s1.clipped);
        assert!(r1.requests_served > 0, "the replay must route traffic");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sharded_replay_matches_across_shard_counts_and_partitions_stats() {
        let cat = test_catalog();
        let path = std::env::temp_dir().join("jiagu_replay_shard.csv");
        let spec = TraceGenSpec {
            invocations: 900,
            duration_s: 4,
            seed: 11,
            format: TraceFormat::Csv,
        };
        let total = generate_trace_file(&path, &cat, &spec).unwrap();
        let opts = ReplayOptions::default();
        let mut cfg = replay_cfg(1);
        cfg.partitions = 2;
        let (reference, ref_stats) =
            replay_path(&cat, &cfg, stub_predictor(), &path, &opts).unwrap();
        assert_eq!(ref_stats.invocations, total, "cells partition the record stream");
        for shards in [2, 4] {
            let mut cfg = replay_cfg(shards);
            cfg.partitions = 2;
            let (parallel, stats) =
                replay_path(&cat, &cfg, stub_predictor(), &path, &opts).unwrap();
            assert_eq!(reference, parallel, "{shards} shards");
            assert_eq!(ref_stats, stats);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rescale_two_doubles_emitted_arrivals_exactly() {
        let cat = test_catalog();
        let path = std::env::temp_dir().join("jiagu_replay_rescale.csv");
        let spec = TraceGenSpec {
            invocations: 400,
            duration_s: 3,
            seed: 21,
            format: TraceFormat::Csv,
        };
        generate_trace_file(&path, &cat, &spec).unwrap();
        let cfg = replay_cfg(0);
        let one = ReplayOptions::default();
        let two = ReplayOptions { rescale: 2.0, ..Default::default() };
        let (_, s1) = replay_path(&cat, &cfg, stub_predictor(), &path, &one).unwrap();
        let (_, s2) = replay_path(&cat, &cfg, stub_predictor(), &path, &two).unwrap();
        assert_eq!(s2.emitted, 2 * s1.emitted);
        assert_eq!(s1.invocations, s2.invocations);
        std::fs::remove_file(&path).ok();
    }

    /// Pin of the `--rescale` clipped accounting audit: the emission and
    /// horizon-clip paths must consume the *same* per-invocation ordinal
    /// stream (they now share [`take_copies`]), so under fractional
    /// rescale every trace record's copy decision is drawn exactly once
    /// and `emitted + clipped` equals the expectation recomputed
    /// independently of chunking and of the emit/clip split.
    #[test]
    fn fractional_rescale_accounts_every_copy_across_the_clip_horizon() {
        let cat = test_catalog();
        let path = std::env::temp_dir().join("jiagu_replay_rescale_clip.csv");
        // 6 s of trace against a 4 s horizon: a fat clipped tail
        let spec = TraceGenSpec {
            invocations: 900,
            duration_s: 6,
            seed: 31,
            format: TraceFormat::Csv,
        };
        let total = generate_trace_file(&path, &cat, &spec).unwrap();
        let cfg = replay_cfg(0);
        let opts = ReplayOptions { rescale: 1.5, ..Default::default() };

        // independent expectation: one flat walk of the raw trace, one
        // ordinal per record per function
        let mut ordinals = vec![0u64; cat.len()];
        let mut expected = 0u64;
        for inv in TraceReader::from_path(&path, &cat).unwrap() {
            let inv = inv.unwrap();
            expected += rescale_copies(&opts, inv.function, ordinals[inv.function]);
            ordinals[inv.function] += 1;
        }

        let (report, stats) =
            replay_path(&cat, &cfg, stub_predictor(), &path, &opts).unwrap();
        assert_eq!(stats.invocations, total);
        assert!(stats.clipped > 0, "the 2 s tail must be clipped");
        assert_eq!(
            stats.emitted + stats.clipped,
            expected,
            "clip paths must draw the same per-invocation ordinals as emission"
        );
        assert_eq!(report.arrivals_dropped, stats.clipped);
        std::fs::remove_file(&path).ok();
    }
}

//! Dual-staged scaling walkthrough (§5, Fig. 10): a square-wave load
//! drives release → logical cold start → migration → real eviction, and
//! the demo prints the state machine as it happens — driven tick by tick
//! through the steppable `ControlPlane` engine.
//!
//! ```bash
//! make artifacts && cargo run --release --example dual_staged_demo
//! ```

use anyhow::Result;
use jiagu::autoscaler::{Autoscaler, AutoscalerConfig};
use jiagu::catalog::Catalog;
use jiagu::cluster::{Cluster, InstanceState};
use jiagu::config::RunConfig;
use jiagu::controlplane::ControlPlane;
use jiagu::sim::load_predictor;

fn count_state(cluster: &Cluster, f: usize, state: InstanceState) -> usize {
    (0..cluster.n_nodes())
        .map(|n| cluster.find_instances(n, f, state).len())
        .sum()
}

fn main() -> Result<()> {
    let artifacts = jiagu::artifacts_dir();
    let cat = Catalog::load(&artifacts.join("functions.json"))?;
    let predictor = load_predictor(&artifacts, false)?;

    let mut cfg = RunConfig::jiagu_45();
    cfg.n_nodes = 4;
    cfg.autoscaler = AutoscalerConfig {
        release_duration_s: 10.0, // compressed for the demo
        keepalive_duration_s: 30.0,
        dual_staged: true,
        migration: true,
    };
    let mut cp = ControlPlane::new(cat.clone(), cfg, predictor);

    let f = cat.id_of("gzip").unwrap();
    let sat_rps = cat.get(f).saturated_rps;
    println!("function: gzip (saturated at {sat_rps:.1} rps/instance)");
    println!("release after 10 s of lower load; eviction after 30 s\n");
    println!(
        "{:>5} {:>8} {:>9} {:>7} {:>7}  events",
        "t(s)", "rps", "expected", "serving", "cached"
    );

    let mut loads = vec![0.0; cat.len()];
    for t in 0..90usize {
        let now = t as f64 * 1000.0;
        // square wave: 8 instances worth of load, dropping to 3, rising back
        loads[f] = match t {
            0..=24 => 8.0 * sat_rps,
            25..=54 => 3.0 * sat_rps,
            _ => 7.0 * sat_rps,
        } * 0.95;
        let ev = cp.step(now, &loads)?;
        let started: usize = ev.scheduled.iter().map(|c| c.placements.len()).sum();
        let mut events = Vec::new();
        if started > 0 {
            events.push(format!("{started} real cold starts planned+committed"));
        }
        if ev.cold_starts_completed > 0 {
            events.push(format!("{} cold starts completed", ev.cold_starts_completed));
        }
        if ev.logical_cold_starts > 0 {
            events.push(format!(
                "{} LOGICAL cold starts (<1ms re-route)",
                ev.logical_cold_starts
            ));
        }
        if ev.released > 0 {
            events.push(format!("{} released -> cached", ev.released));
        }
        if ev.evicted > 0 {
            events.push(format!("{} cached evicted", ev.evicted));
        }
        if ev.migrations > 0 {
            events.push(format!("{} cached migrated", ev.migrations));
        }
        if ev.deferred_completed > 0 {
            events.push(format!("{} async refreshes landed", ev.deferred_completed));
        }
        if !events.is_empty() || t % 15 == 0 {
            println!(
                "{:>5} {:>8.1} {:>9} {:>7} {:>7}  {}",
                t,
                loads[f],
                Autoscaler::expected_instances(&cat, f, loads[f]),
                cp.router().serving_count(f),
                count_state(cp.cluster(), f, InstanceState::Cached),
                events.join("; ")
            );
        }
    }
    println!("\nrouter re-routes total: {}", cp.router().reroutes);
    Ok(())
}

//! Dual-staged scaling walkthrough (§5, Fig. 10): a square-wave load
//! drives release → logical cold start → migration → real eviction, and
//! the demo prints the state machine as it happens.
//!
//! ```bash
//! make artifacts && cargo run --release --example dual_staged_demo
//! ```

use anyhow::Result;
use jiagu::autoscaler::{Autoscaler, AutoscalerConfig};
use jiagu::capacity::CapacityConfig;
use jiagu::catalog::Catalog;
use jiagu::cluster::{Cluster, InstanceState};
use jiagu::router::Router;
use jiagu::scheduler::JiaguScheduler;
use jiagu::sim::load_predictor;

fn count_state(cluster: &Cluster, f: usize, state: InstanceState) -> usize {
    (0..cluster.n_nodes())
        .map(|n| cluster.find_instances(n, f, state).len())
        .sum()
}

fn main() -> Result<()> {
    let artifacts = jiagu::artifacts_dir();
    let cat = Catalog::load(&artifacts.join("functions.json"))?;
    let predictor = load_predictor(&artifacts, false)?;

    let mut cluster = Cluster::new(4);
    let mut router = Router::new();
    let mut sched = JiaguScheduler::new(predictor, CapacityConfig::default(), 4);
    let mut autoscaler = Autoscaler::new(
        AutoscalerConfig {
            release_duration_s: 10.0, // compressed for the demo
            keepalive_duration_s: 30.0,
            dual_staged: true,
            migration: true,
        },
        cat.len(),
    );

    let f = cat.id_of("gzip").unwrap();
    let sat_rps = cat.get(f).saturated_rps;
    println!("function: gzip (saturated at {sat_rps:.1} rps/instance)");
    println!("release after 10 s of lower load; eviction after 30 s\n");
    println!("{:>5} {:>8} {:>9} {:>7} {:>7}  events", "t(s)", "rps", "expected", "serving", "cached");

    let mut loads = vec![0.0; cat.len()];
    for t in 0..90usize {
        let now = t as f64 * 1000.0;
        // square wave: 8 instances worth of load, dropping to 3, rising back
        loads[f] = match t {
            0..=24 => 8.0 * sat_rps,
            25..=54 => 3.0 * sat_rps,
            _ => 7.0 * sat_rps,
        } * 0.95;
        let out = autoscaler.tick(&cat, &mut cluster, &mut router, &mut sched, &loads, now)?;
        for id in &out.cold_started {
            cluster.mark_ready(*id, now);
            router.add(f, *id);
        }
        let mut events = Vec::new();
        if !out.cold_started.is_empty() {
            events.push(format!("{} real cold starts", out.cold_started.len()));
        }
        if out.logical_cold_starts > 0 {
            events.push(format!("{} LOGICAL cold starts (<1ms re-route)", out.logical_cold_starts));
        }
        if out.released > 0 {
            events.push(format!("{} released -> cached", out.released));
        }
        if out.evicted > 0 {
            events.push(format!("{} cached evicted", out.evicted));
        }
        if out.migrations > 0 {
            events.push(format!("{} cached migrated", out.migrations));
        }
        if !events.is_empty() || t % 15 == 0 {
            println!(
                "{:>5} {:>8.1} {:>9} {:>7} {:>7}  {}",
                t,
                loads[f],
                Autoscaler::expected_instances(&cat, f, loads[f]),
                router.serving_count(f),
                count_state(&cluster, f, InstanceState::Cached),
                events.join("; ")
            );
        }
    }
    println!("\nrouter re-routes total: {}", router.reroutes);
    Ok(())
}

//! End-to-end driver (the required E2E validation): the full stack —
//! trace → router → dual-staged autoscaler → pre-decision scheduler →
//! AOT predictor over PJRT → simulated cluster — on a real-world-like
//! trace, reporting the paper's headline metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_trace -- [--duration 1800] [--trace A]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;
use jiagu::config::{RunConfig, SchedulerKind};
use jiagu::sim::{load_predictor, Simulation};
use jiagu::traces;

fn flag(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == &format!("--{name}"))
        .and_then(|i| args.get(i + 1).cloned())
}

fn main() -> Result<()> {
    let duration: usize = flag("duration").map(|v| v.parse().unwrap()).unwrap_or(1800);
    let trace_name = flag("trace").unwrap_or_else(|| "A".into());
    let artifacts = jiagu::artifacts_dir();
    let cat = jiagu::catalog::Catalog::load(&artifacts.join("functions.json"))?;
    let predictor = load_predictor(&artifacts, false)?;

    let idx = (trace_name.as_bytes()[0].to_ascii_uppercase() - b'A') as usize;
    let trace = traces::paper_traces(&cat, duration).swap_remove(idx.min(3));
    println!(
        "E2E: {} | {} functions | {} s horizon | PJRT predictor",
        trace.name,
        cat.len(),
        duration
    );

    let t0 = std::time::Instant::now();
    let mut cfg = RunConfig::jiagu_45();
    cfg.duration_s = duration;
    cfg.scheduler = SchedulerKind::Jiagu;
    let sim = Simulation::new(cat.clone(), cfg, predictor.clone());
    let r = sim.run(&trace)?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n== headline metrics (Jiagu-45 on {}) ==", trace.name);
    println!("  function density:         {:.3} instances/node (K8s request packing = 12)", r.density);
    println!("  QoS violation rate:       {:.2}% (target < 10%)", r.qos_violation_rate * 100.0);
    println!("  scheduling cost:          mean {:.3} ms / p99 {:.3} ms", r.scheduling_ms_mean, r.scheduling_ms_p99);
    println!("  cold start (cfork):       mean {:.3} ms / p99 {:.3} ms", r.cold_start_ms_mean, r.cold_start_ms_p99);
    println!("  fast path rate:           {:.1}% ({} fast / {} slow)",
        100.0 * r.fast_decisions as f64 / (r.fast_decisions + r.slow_decisions).max(1) as f64,
        r.fast_decisions, r.slow_decisions);
    println!("  inferences per schedule:  {:.3} critical / {:.3} async",
        r.inferences_per_schedule,
        r.async_inferences as f64 / r.schedule_calls.max(1) as f64);
    println!("  dual-staged scaling:      {} released, {} logical cold starts, {} migrations",
        r.released, r.logical_cold_starts, r.migrations);
    println!("  instances started:        {} over {} schedule calls", r.instances_started, r.schedule_calls);
    println!("  cluster:                  {} nodes peak", r.peak_nodes);
    println!("  per-function QoS violation:");
    for (f, v) in r.per_function_violation.iter().enumerate() {
        println!("    {:12} {:.2}%", cat.get(f).name, v * 100.0);
    }
    let (calls, rows, nanos) = predictor.stats().snapshot();
    println!(
        "\npredictor: {} PJRT calls, {} rows, {:.1} ms total ({:.3} ms/call)",
        calls, rows, nanos as f64 / 1e6, nanos as f64 / 1e6 / calls.max(1) as f64
    );
    println!("simulated {duration} s in {wall:.1} s wall-clock");
    Ok(())
}
